//! `safa report` — offline analyzer for SAFA_TRACE v2 JSONL files.
//!
//! Parses a trace produced by `SAFA_TRACE=<path> safa run ...` (one JSON
//! object per line: a `meta` header, per-round `round` records, and
//! sampled per-client `client` lifecycle events) and renders the paper's
//! observability axes (Figs. 9–13): round-duration percentiles, the
//! applied-staleness CDF, an EUR / wasted-work breakdown per protocol,
//! and per-client timelines — as fixed-width tables and as JSON.
//!
//! This module is strictly offline: it never touches the live telemetry
//! statics, so it can analyze traces from other runs (or machines)
//! without interference.

use crate::error::{Result, SafaError};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One parsed `{"type":"round",...}` line.
#[derive(Debug, Clone)]
pub struct RoundLine {
    pub protocol: String,
    pub round: usize,
    pub round_len: f64,
    pub m_sync: f64,
    pub picked: f64,
    pub picked_crashed: f64,
    pub committed: f64,
    pub crashed: f64,
    pub undrafted: f64,
    pub futility_wasted: f64,
    pub futility_total: f64,
    pub staleness: Vec<u32>,
}

/// One parsed `{"type":"client",...}` lifecycle line.
#[derive(Debug, Clone)]
pub struct ClientLine {
    pub round: usize,
    pub client: usize,
    pub event: String,
    /// Simulated time within the round (None when the trace logged null).
    pub t: Option<f64>,
    pub version: Option<usize>,
    pub staleness: Option<u32>,
    pub reason: Option<String>,
    /// Round phase a fault hit (`download` / `train` / `upload`) — only
    /// present on `crashed` / `retry` lines from fault-injection runs.
    pub phase: Option<String>,
}

/// A fully parsed trace file.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// Fleet size from the meta header (EUR's denominator).
    pub m: Option<usize>,
    /// Protocol named in the meta header (rounds may still carry their
    /// own protocol tag — grouping always uses the per-round tag).
    pub protocol: Option<String>,
    pub task: Option<String>,
    pub seed: Option<u64>,
    /// Lifecycle sampling stride the run was recorded with.
    pub sample: Option<u64>,
    pub rounds: Vec<RoundLine>,
    pub clients: Vec<ClientLine>,
    /// Lines that were valid JSON but not a recognized v2 record (e.g.
    /// v1 traces without a `type` key).
    pub skipped: usize,
}

fn num(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

/// Parse a whole trace file's text. Malformed JSON is an error (a
/// truncated trace is worth surfacing loudly); well-formed lines of
/// unknown type are counted in [`Trace::skipped`].
pub fn parse_trace(text: &str) -> Result<Trace> {
    let mut trace = Trace::default();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| {
            SafaError::Data(format!("trace line {}: invalid JSON ({e})", i + 1))
        })?;
        match j.get("type").and_then(Json::as_str) {
            Some("meta") => {
                trace.m = j.get("m").and_then(Json::as_usize);
                trace.protocol = j.get("protocol").and_then(Json::as_str).map(str::to_string);
                trace.task = j.get("task").and_then(Json::as_str).map(str::to_string);
                trace.seed = j.get("seed").and_then(Json::as_f64).map(|s| s as u64);
                trace.sample = j.get("sample").and_then(Json::as_f64).map(|s| s as u64);
            }
            Some("round") => {
                let staleness = j
                    .get("staleness")
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .filter_map(Json::as_f64)
                            .map(|s| s as u32)
                            .collect()
                    })
                    .unwrap_or_default();
                trace.rounds.push(RoundLine {
                    protocol: j
                        .get("protocol")
                        .and_then(Json::as_str)
                        .unwrap_or("?")
                        .to_string(),
                    round: num(&j, "round") as usize,
                    round_len: num(&j, "round_len"),
                    m_sync: num(&j, "m_sync"),
                    picked: num(&j, "picked"),
                    picked_crashed: num(&j, "picked_crashed"),
                    committed: num(&j, "committed"),
                    crashed: num(&j, "crashed"),
                    undrafted: num(&j, "undrafted"),
                    futility_wasted: num(&j, "futility_wasted"),
                    futility_total: num(&j, "futility_total"),
                    staleness,
                });
            }
            Some("client") => {
                trace.clients.push(ClientLine {
                    round: num(&j, "round") as usize,
                    client: num(&j, "client") as usize,
                    event: j
                        .get("event")
                        .and_then(Json::as_str)
                        .unwrap_or("?")
                        .to_string(),
                    t: j.get("t").and_then(Json::as_f64),
                    version: j.get("version").and_then(Json::as_usize),
                    staleness: j.get("staleness").and_then(Json::as_f64).map(|s| s as u32),
                    reason: j.get("reason").and_then(Json::as_str).map(str::to_string),
                    phase: j.get("phase").and_then(Json::as_str).map(str::to_string),
                });
            }
            _ => trace.skipped += 1,
        }
    }
    Ok(trace)
}

/// Nearest-rank percentile of an ascending-sorted slice (exact — unlike
/// the live log2-bucket histograms this analyzer holds every value).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank.min(sorted.len()) - 1]
}

/// Per-protocol aggregates computed from the round lines.
#[derive(Debug, Clone)]
pub struct ProtocolSummary {
    pub protocol: String,
    pub rounds: usize,
    pub round_len_sorted: Vec<f64>,
    pub picked: f64,
    pub picked_crashed: f64,
    pub committed: f64,
    pub crashed: f64,
    pub undrafted: f64,
    pub futility_wasted: f64,
    pub futility_total: f64,
    /// Applied-staleness histogram: index s counts merges s rounds stale.
    pub staleness_hist: Vec<usize>,
}

impl ProtocolSummary {
    /// Mean per-round EUR (Eq. 4) given the fleet size.
    pub fn eur(&self, m: usize) -> f64 {
        if self.rounds == 0 || m == 0 {
            return 0.0;
        }
        (self.picked - self.picked_crashed) / (self.rounds * m) as f64
    }

    /// Wasted / attempted local work over the trace (futility, Eq. 11).
    pub fn futility(&self) -> f64 {
        if self.futility_total > 0.0 {
            self.futility_wasted / self.futility_total
        } else {
            0.0
        }
    }

    pub fn mean_round_len(&self) -> f64 {
        if self.round_len_sorted.is_empty() {
            return 0.0;
        }
        self.round_len_sorted.iter().sum::<f64>() / self.round_len_sorted.len() as f64
    }

    /// Staleness CDF: fraction of merges with staleness <= s, for each
    /// s up to the maximum seen.
    pub fn staleness_cdf(&self) -> Vec<f64> {
        let total: usize = self.staleness_hist.iter().sum();
        if total == 0 {
            return Vec::new();
        }
        let mut acc = 0usize;
        self.staleness_hist
            .iter()
            .map(|&c| {
                acc += c;
                acc as f64 / total as f64
            })
            .collect()
    }
}

/// Group the trace's round lines by protocol (insertion order = first
/// appearance, so single-protocol traces stay single-row).
pub fn summarize(trace: &Trace) -> Vec<ProtocolSummary> {
    let mut order: Vec<String> = Vec::new();
    let mut by_proto: BTreeMap<String, ProtocolSummary> = BTreeMap::new();
    for r in &trace.rounds {
        let s = by_proto.entry(r.protocol.clone()).or_insert_with(|| {
            order.push(r.protocol.clone());
            ProtocolSummary {
                protocol: r.protocol.clone(),
                rounds: 0,
                round_len_sorted: Vec::new(),
                picked: 0.0,
                picked_crashed: 0.0,
                committed: 0.0,
                crashed: 0.0,
                undrafted: 0.0,
                futility_wasted: 0.0,
                futility_total: 0.0,
                staleness_hist: Vec::new(),
            }
        });
        s.rounds += 1;
        s.round_len_sorted.push(r.round_len);
        s.picked += r.picked;
        s.picked_crashed += r.picked_crashed;
        s.committed += r.committed;
        s.crashed += r.crashed;
        s.undrafted += r.undrafted;
        s.futility_wasted += r.futility_wasted;
        s.futility_total += r.futility_total;
        for &st in &r.staleness {
            let st = st as usize;
            if s.staleness_hist.len() <= st {
                s.staleness_hist.resize(st + 1, 0);
            }
            s.staleness_hist[st] += 1;
        }
    }
    let mut out: Vec<ProtocolSummary> = Vec::with_capacity(order.len());
    for name in order {
        let mut s = by_proto.remove(&name).unwrap();
        s.round_len_sorted
            .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        out.push(s);
    }
    out
}

/// The fleet size to use for EUR: the meta header when present, else the
/// largest per-round participant count (committed + crashed) as a lower
/// bound — reported traces always carry meta, this is for hand-built
/// fixtures.
pub fn fleet_size(trace: &Trace) -> usize {
    trace.m.unwrap_or_else(|| {
        trace
            .rounds
            .iter()
            .map(|r| (r.committed + r.crashed) as usize)
            .max()
            .unwrap_or(0)
    })
}

/// Round-duration percentile table (Fig. 9's axis).
pub fn render_durations(summaries: &[ProtocolSummary]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "== round duration (sim-seconds) ==");
    let _ = writeln!(
        out,
        "{:<10} {:>7} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "protocol", "rounds", "mean", "p50", "p90", "p99", "max"
    );
    for s in summaries {
        let v = &s.round_len_sorted;
        let _ = writeln!(
            out,
            "{:<10} {:>7} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>10.2}",
            s.protocol,
            s.rounds,
            s.mean_round_len(),
            percentile(v, 0.50),
            percentile(v, 0.90),
            percentile(v, 0.99),
            v.last().copied().unwrap_or(0.0),
        );
    }
    out
}

/// EUR / wasted-work breakdown per protocol (Figs. 10–13's axes).
pub fn render_effectiveness(summaries: &[ProtocolSummary], m: usize) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "== effectiveness (m = {m}) ==");
    let _ = writeln!(
        out,
        "{:<10} {:>8} {:>10} {:>10} {:>10} {:>12} {:>12} {:>10}",
        "protocol", "eur", "committed", "crashed", "undrafted", "wasted", "attempted", "futility"
    );
    for s in summaries {
        let _ = writeln!(
            out,
            "{:<10} {:>8.3} {:>10} {:>10} {:>10} {:>12.2} {:>12.2} {:>9.1}%",
            s.protocol,
            s.eur(m),
            s.committed as u64,
            s.crashed as u64,
            s.undrafted as u64,
            s.futility_wasted,
            s.futility_total,
            s.futility() * 100.0,
        );
    }
    out
}

/// Staleness CDF table: one row per staleness value, one column per
/// protocol that merged at least one update.
pub fn render_staleness_cdf(summaries: &[ProtocolSummary]) -> String {
    let cdfs: Vec<(&str, Vec<f64>)> = summaries
        .iter()
        .map(|s| (s.protocol.as_str(), s.staleness_cdf()))
        .filter(|(_, c)| !c.is_empty())
        .collect();
    let mut out = String::new();
    let _ = writeln!(out, "== applied-staleness CDF  P(s <= x) ==");
    if cdfs.is_empty() {
        let _ = writeln!(out, "(no merged updates in trace)");
        return out;
    }
    let mut header = format!("{:<10}", "s");
    for (name, _) in &cdfs {
        let _ = write!(header, " {name:>10}");
    }
    let _ = writeln!(out, "{header}");
    let depth = cdfs.iter().map(|(_, c)| c.len()).max().unwrap_or(0);
    for s in 0..depth {
        let mut row = format!("{s:<10}");
        for (_, cdf) in &cdfs {
            // A CDF saturates at 1 past its last bucket.
            let v = cdf.get(s).copied().unwrap_or(1.0);
            let _ = write!(row, " {v:>10.3}");
        }
        let _ = writeln!(out, "{row}");
    }
    out
}

/// Per-client timeline: every lifecycle event for one client, in trace
/// order (which is round order, then within-round emission order).
pub fn render_timeline(trace: &Trace, client: usize) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "== client {client} timeline ==");
    let _ = writeln!(
        out,
        "{:<7} {:<12} {:>10} {:>8} {:>9} {:<10}",
        "round", "event", "t", "version", "stale", "reason"
    );
    let mut n = 0;
    for c in trace.clients.iter().filter(|c| c.client == client) {
        n += 1;
        let t = c
            .t
            .map(|t| format!("{t:.2}"))
            .unwrap_or_else(|| "-".to_string());
        let v = c
            .version
            .map(|v| v.to_string())
            .unwrap_or_else(|| "-".to_string());
        let s = c
            .staleness
            .map(|s| s.to_string())
            .unwrap_or_else(|| "-".to_string());
        let _ = writeln!(
            out,
            "{:<7} {:<12} {:>10} {:>8} {:>9} {:<10}",
            c.round,
            c.event,
            t,
            v,
            s,
            c.reason.as_deref().unwrap_or("-"),
        );
    }
    if n == 0 {
        let _ = writeln!(
            out,
            "(no events for client {client} — check SAFA_TRACE_SAMPLE stride)"
        );
    }
    out
}

/// Per-round fault-injection tallies derived from phased lifecycle
/// lines (a `crashed` or `retry` line carries `phase` only when the
/// fault engine cut or replayed a transfer/train leg).
#[derive(Debug, Clone, Default)]
pub struct FaultSummary {
    /// Mid-download / mid-train / mid-upload crash counts.
    pub crashed_download: usize,
    pub crashed_train: usize,
    pub crashed_upload: usize,
    /// Bounded-retry attempts, total and by leg.
    pub retries: usize,
    pub retries_download: usize,
    pub retries_upload: usize,
    /// Per-round activity: (round, phased crashes, retries) for every
    /// round that saw at least one fault event, in round order — the
    /// outage timeline (a correlated regional outage shows up as a
    /// same-round cluster of phased crashes).
    pub timeline: Vec<(usize, usize, usize)>,
}

impl FaultSummary {
    pub fn total_crashes(&self) -> usize {
        self.crashed_download + self.crashed_train + self.crashed_upload
    }

    pub fn any(&self) -> bool {
        self.total_crashes() > 0 || self.retries > 0
    }
}

/// Tally the trace's fault-injection events.
pub fn summarize_faults(trace: &Trace) -> FaultSummary {
    let mut s = FaultSummary::default();
    let mut per_round: BTreeMap<usize, (usize, usize)> = BTreeMap::new();
    for c in &trace.clients {
        let Some(phase) = c.phase.as_deref() else {
            continue;
        };
        match c.event.as_str() {
            "crashed" => {
                match phase {
                    "download" => s.crashed_download += 1,
                    "upload" => s.crashed_upload += 1,
                    _ => s.crashed_train += 1,
                }
                per_round.entry(c.round).or_insert((0, 0)).0 += 1;
            }
            "retry" => {
                s.retries += 1;
                match phase {
                    "download" => s.retries_download += 1,
                    "upload" => s.retries_upload += 1,
                    _ => {}
                }
                per_round.entry(c.round).or_insert((0, 0)).1 += 1;
            }
            _ => {}
        }
    }
    s.timeline = per_round
        .into_iter()
        .map(|(round, (crashes, retries))| (round, crashes, retries))
        .collect();
    s
}

/// Fault-injection tables: crash-phase breakdown, retry counts and the
/// per-round outage timeline.
pub fn render_faults(trace: &Trace) -> String {
    let s = summarize_faults(trace);
    let mut out = String::new();
    let _ = writeln!(out, "== fault injection ==");
    if !s.any() {
        let _ = writeln!(out, "(no fault-injection events in trace)");
        return out;
    }
    let _ = writeln!(
        out,
        "{:<16} {:>10} {:>10} {:>10} {:>10}",
        "kind", "download", "train", "upload", "total"
    );
    let _ = writeln!(
        out,
        "{:<16} {:>10} {:>10} {:>10} {:>10}",
        "crashed",
        s.crashed_download,
        s.crashed_train,
        s.crashed_upload,
        s.total_crashes(),
    );
    let _ = writeln!(
        out,
        "{:<16} {:>10} {:>10} {:>10} {:>10}",
        "retry", s.retries_download, "-", s.retries_upload, s.retries,
    );
    let _ = writeln!(out);
    let _ = writeln!(out, "-- fault timeline (rounds with activity) --");
    let _ = writeln!(out, "{:<7} {:>9} {:>9}", "round", "crashes", "retries");
    for &(round, crashes, retries) in &s.timeline {
        let _ = writeln!(out, "{round:<7} {crashes:>9} {retries:>9}");
    }
    out
}

/// Fleet-population summary derived from `join` / `leave` lifecycle
/// lines (scenario flash crowds). Counts are over *sampled* clients —
/// with `SAFA_TRACE_SAMPLE=k` above 1 they undercount by roughly k×.
#[derive(Debug, Clone, Default)]
pub struct PopulationSummary {
    pub joins: usize,
    pub leaves: usize,
    /// (round, joins, leaves, population-after) for every round with
    /// churn activity, in round order. The running population starts
    /// from the founding cohort (fleet size minus every latecomer join
    /// seen in the trace) so it ends at the final live population.
    pub timeline: Vec<(usize, usize, usize, i64)>,
}

impl PopulationSummary {
    pub fn any(&self) -> bool {
        self.joins > 0 || self.leaves > 0
    }
}

/// Tally the trace's join/leave events into a population timeline.
pub fn summarize_population(trace: &Trace, m: usize) -> PopulationSummary {
    let mut s = PopulationSummary::default();
    let mut per_round: BTreeMap<usize, (usize, usize)> = BTreeMap::new();
    for c in &trace.clients {
        match c.event.as_str() {
            "join" => {
                s.joins += 1;
                per_round.entry(c.round).or_insert((0, 0)).0 += 1;
            }
            "leave" => {
                s.leaves += 1;
                per_round.entry(c.round).or_insert((0, 0)).1 += 1;
            }
            _ => {}
        }
    }
    let mut pop = m as i64 - s.joins as i64;
    for (round, (joins, leaves)) in per_round {
        pop += joins as i64 - leaves as i64;
        s.timeline.push((round, joins, leaves, pop));
    }
    s
}

/// Population-over-time table: per-round joins/leaves and the running
/// fleet population (scenario flash crowds).
pub fn render_population(trace: &Trace) -> String {
    let m = fleet_size(trace);
    let s = summarize_population(trace, m);
    let mut out = String::new();
    let _ = writeln!(out, "== fleet population ==");
    if !s.any() {
        let _ = writeln!(out, "(no join/leave events in trace)");
        return out;
    }
    let _ = writeln!(
        out,
        "{} join(s), {} leave(s) over the trace (founding population {})",
        s.joins,
        s.leaves,
        m as i64 - s.joins as i64,
    );
    let _ = writeln!(
        out,
        "{:<7} {:>7} {:>7} {:>11}",
        "round", "joins", "leaves", "population"
    );
    for &(round, joins, leaves, pop) in &s.timeline {
        let _ = writeln!(out, "{round:<7} {joins:>7} {leaves:>7} {pop:>11}");
    }
    out
}

/// Lifecycle event counts across all sampled clients.
pub fn render_event_counts(trace: &Trace) -> String {
    let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
    for c in &trace.clients {
        *counts.entry(c.event.as_str()).or_insert(0) += 1;
    }
    let mut out = String::new();
    let _ = writeln!(out, "== lifecycle events ==");
    if counts.is_empty() {
        let _ = writeln!(out, "(no client lines in trace)");
        return out;
    }
    let _ = writeln!(out, "{:<14} {:>10}", "event", "count");
    for (event, count) in counts {
        let _ = writeln!(out, "{event:<14} {count:>10}");
    }
    out
}

/// The whole report as one JSON document (`--json` output).
pub fn report_json(trace: &Trace) -> Json {
    let m = fleet_size(trace);
    let summaries = summarize(trace);
    let mut o = Json::obj();
    let mut meta = Json::obj();
    meta.set("m", Json::Num(m as f64));
    if let Some(p) = &trace.protocol {
        meta.set("protocol", Json::Str(p.clone()));
    }
    if let Some(t) = &trace.task {
        meta.set("task", Json::Str(t.clone()));
    }
    if let Some(s) = trace.seed {
        meta.set("seed", Json::Num(s as f64));
    }
    if let Some(s) = trace.sample {
        meta.set("sample", Json::Num(s as f64));
    }
    meta.set("round_lines", Json::Num(trace.rounds.len() as f64));
    meta.set("client_lines", Json::Num(trace.clients.len() as f64));
    meta.set("skipped_lines", Json::Num(trace.skipped as f64));
    o.set("meta", meta);
    let mut protos = Vec::new();
    for s in &summaries {
        let mut p = Json::obj();
        p.set("protocol", Json::Str(s.protocol.clone()));
        p.set("rounds", Json::Num(s.rounds as f64));
        let v = &s.round_len_sorted;
        let mut dur = Json::obj();
        dur.set("mean", Json::Num(s.mean_round_len()));
        dur.set("p50", Json::Num(percentile(v, 0.50)));
        dur.set("p90", Json::Num(percentile(v, 0.90)));
        dur.set("p99", Json::Num(percentile(v, 0.99)));
        dur.set("max", Json::Num(v.last().copied().unwrap_or(0.0)));
        p.set("round_duration", dur);
        p.set("eur", Json::Num(s.eur(m)));
        p.set("committed", Json::Num(s.committed));
        p.set("crashed", Json::Num(s.crashed));
        p.set("undrafted", Json::Num(s.undrafted));
        p.set("futility_wasted", Json::Num(s.futility_wasted));
        p.set("futility_total", Json::Num(s.futility_total));
        p.set("futility", Json::Num(s.futility()));
        p.set(
            "staleness_cdf",
            Json::Arr(s.staleness_cdf().into_iter().map(Json::Num).collect()),
        );
        protos.push(p);
    }
    o.set("protocols", Json::Arr(protos));
    let mut counts: BTreeMap<String, usize> = BTreeMap::new();
    for c in &trace.clients {
        *counts.entry(c.event.clone()).or_insert(0) += 1;
    }
    let mut ev = Json::obj();
    for (event, count) in counts {
        ev.set(&event, Json::Num(count as f64));
    }
    o.set("events", ev);
    let fs = summarize_faults(trace);
    let mut faults = Json::obj();
    let mut crashed = Json::obj();
    crashed.set("download", Json::Num(fs.crashed_download as f64));
    crashed.set("train", Json::Num(fs.crashed_train as f64));
    crashed.set("upload", Json::Num(fs.crashed_upload as f64));
    faults.set("crashed_by_phase", crashed);
    let mut retries = Json::obj();
    retries.set("download", Json::Num(fs.retries_download as f64));
    retries.set("upload", Json::Num(fs.retries_upload as f64));
    retries.set("total", Json::Num(fs.retries as f64));
    faults.set("retries", retries);
    faults.set(
        "timeline",
        Json::Arr(
            fs.timeline
                .iter()
                .map(|&(round, crashes, retries)| {
                    let mut row = Json::obj();
                    row.set("round", Json::Num(round as f64));
                    row.set("crashes", Json::Num(crashes as f64));
                    row.set("retries", Json::Num(retries as f64));
                    row
                })
                .collect(),
        ),
    );
    o.set("faults", faults);
    let ps = summarize_population(trace, m);
    let mut population = Json::obj();
    population.set("joins", Json::Num(ps.joins as f64));
    population.set("leaves", Json::Num(ps.leaves as f64));
    population.set(
        "timeline",
        Json::Arr(
            ps.timeline
                .iter()
                .map(|&(round, joins, leaves, pop)| {
                    let mut row = Json::obj();
                    row.set("round", Json::Num(round as f64));
                    row.set("joins", Json::Num(joins as f64));
                    row.set("leaves", Json::Num(leaves as f64));
                    row.set("population", Json::Num(pop as f64));
                    row
                })
                .collect(),
        ),
    );
    o.set("population", population);
    o
}

/// The full fixed-width report (everything except per-client timelines,
/// which are opt-in via `--client`).
pub fn render_report(trace: &Trace) -> String {
    let m = fleet_size(trace);
    let summaries = summarize(trace);
    let mut out = String::new();
    if let (Some(p), Some(t)) = (&trace.protocol, &trace.task) {
        let _ = writeln!(
            out,
            "trace: protocol={p} task={t} m={m} rounds={} client_lines={} (sample stride {})",
            trace.rounds.len(),
            trace.clients.len(),
            trace.sample.unwrap_or(1),
        );
    }
    if trace.skipped > 0 {
        let _ = writeln!(out, "note: {} unrecognized line(s) skipped", trace.skipped);
    }
    let _ = writeln!(out);
    out.push_str(&render_durations(&summaries));
    let _ = writeln!(out);
    out.push_str(&render_effectiveness(&summaries, m));
    let _ = writeln!(out);
    out.push_str(&render_staleness_cdf(&summaries));
    let _ = writeln!(out);
    out.push_str(&render_event_counts(trace));
    let faults = summarize_faults(trace);
    if faults.any() {
        let _ = writeln!(out);
        out.push_str(&render_faults(trace));
    }
    if summarize_population(trace, m).any() {
        let _ = writeln!(out);
        out.push_str(&render_population(trace));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const FIXTURE: &str = concat!(
        "{\"type\":\"meta\",\"v\":2,\"schema\":\"safa-trace\",\"protocol\":\"SAFA\",",
        "\"task\":\"regression\",\"m\":4,\"rounds\":2,\"seed\":1,\"sample\":1}\n",
        "{\"type\":\"round\",\"v\":2,\"protocol\":\"SAFA\",\"round\":1,\"round_len\":10.0,",
        "\"m_sync\":4,\"picked\":3,\"picked_crashed\":0,\"committed\":3,\"crashed\":1,",
        "\"undrafted\":0,\"futility_wasted\":0.0,\"futility_total\":4.0,\"staleness\":[0,0,1]}\n",
        "{\"type\":\"round\",\"v\":2,\"protocol\":\"SAFA\",\"round\":2,\"round_len\":30.0,",
        "\"m_sync\":2,\"picked\":2,\"picked_crashed\":0,\"committed\":2,\"crashed\":2,",
        "\"undrafted\":1,\"futility_wasted\":1.0,\"futility_total\":4.0,\"staleness\":[0,2]}\n",
        "{\"type\":\"client\",\"v\":2,\"round\":1,\"client\":0,\"event\":\"picked\",\"t\":4.5}\n",
        "{\"type\":\"client\",\"v\":2,\"round\":1,\"client\":0,\"event\":\"merged\",\"t\":10.0,",
        "\"version\":0,\"staleness\":0}\n",
        "{\"type\":\"client\",\"v\":2,\"round\":2,\"client\":1,\"event\":\"crashed\",\"t\":null,",
        "\"reason\":\"crash\"}\n",
        "{\"type\":\"client\",\"v\":2,\"round\":2,\"client\":2,\"event\":\"crashed\",\"t\":8.0,",
        "\"reason\":\"crash\",\"phase\":\"download\"}\n",
        "{\"type\":\"client\",\"v\":2,\"round\":2,\"client\":3,\"event\":\"retry\",\"t\":12.0,",
        "\"phase\":\"upload\"}\n",
    );

    #[test]
    fn parses_all_line_types() {
        let trace = parse_trace(FIXTURE).unwrap();
        assert_eq!(trace.m, Some(4));
        assert_eq!(trace.protocol.as_deref(), Some("SAFA"));
        assert_eq!(trace.rounds.len(), 2);
        assert_eq!(trace.clients.len(), 5);
        assert_eq!(trace.skipped, 0);
        assert_eq!(trace.clients[2].t, None);
        assert_eq!(trace.clients[2].reason.as_deref(), Some("crash"));
        // Legacy crash lines parse with no phase; fault lines carry one.
        assert_eq!(trace.clients[2].phase, None);
        assert_eq!(trace.clients[3].phase.as_deref(), Some("download"));
        assert_eq!(trace.clients[4].event, "retry");
        assert_eq!(trace.clients[4].phase.as_deref(), Some("upload"));
    }

    #[test]
    fn unknown_lines_are_skipped_not_fatal() {
        let trace = parse_trace("{\"round\":1}\n{\"type\":\"future\"}\n").unwrap();
        assert_eq!(trace.skipped, 2);
        assert!(parse_trace("not json\n").is_err());
    }

    #[test]
    fn summary_matches_hand_computation() {
        let trace = parse_trace(FIXTURE).unwrap();
        let s = summarize(&trace);
        assert_eq!(s.len(), 1);
        let s = &s[0];
        assert_eq!(s.rounds, 2);
        // EUR = (3 + 2) / (2 rounds * m=4) = 0.625.
        assert!((s.eur(4) - 0.625).abs() < 1e-12);
        // Futility = 1.0 wasted / 8.0 attempted.
        assert!((s.futility() - 0.125).abs() < 1e-12);
        assert_eq!(s.staleness_hist, vec![3, 1, 1]);
        let cdf = s.staleness_cdf();
        assert!((cdf[0] - 0.6).abs() < 1e-12);
        assert!((cdf[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_are_nearest_rank() {
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&v, 0.50), 5.0);
        assert_eq!(percentile(&v, 0.90), 9.0);
        assert_eq!(percentile(&v, 0.99), 10.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn tables_render_expected_cells() {
        let trace = parse_trace(FIXTURE).unwrap();
        let s = summarize(&trace);
        let dur = render_durations(&s);
        assert!(dur.contains("SAFA"), "{dur}");
        assert!(dur.contains("20.00"), "mean of 10 and 30:\n{dur}");
        let eff = render_effectiveness(&s, fleet_size(&trace));
        assert!(eff.contains("0.625"), "{eff}");
        assert!(eff.contains("12.5%"), "{eff}");
        let cdf = render_staleness_cdf(&s);
        assert!(cdf.contains("0.600"), "{cdf}");
        let tl = render_timeline(&trace, 0);
        assert!(tl.contains("picked"), "{tl}");
        assert!(tl.contains("merged"), "{tl}");
        let missing = render_timeline(&trace, 3);
        assert!(missing.contains("no events"), "{missing}");
    }

    #[test]
    fn faults_section_counts_phases_and_rounds() {
        let trace = parse_trace(FIXTURE).unwrap();
        let s = summarize_faults(&trace);
        assert_eq!(s.crashed_download, 1);
        assert_eq!(s.crashed_train, 0);
        assert_eq!(s.crashed_upload, 0);
        assert_eq!(s.retries, 1);
        assert_eq!(s.retries_upload, 1);
        // The phase-less legacy crash (client 1) is not a fault event.
        assert_eq!(s.total_crashes(), 1);
        assert_eq!(s.timeline, vec![(2, 1, 1)]);
        let text = render_faults(&trace);
        assert!(text.contains("fault injection"), "{text}");
        assert!(text.contains("crashed"), "{text}");
        assert!(text.contains("retry"), "{text}");
        // A faultless trace renders the placeholder and the full report
        // omits the section entirely.
        let clean = parse_trace(
            "{\"type\":\"client\",\"v\":2,\"round\":1,\"client\":0,\
             \"event\":\"crashed\",\"t\":null,\"reason\":\"crash\"}\n",
        )
        .unwrap();
        assert!(render_faults(&clean).contains("no fault-injection events"));
        assert!(!render_report(&clean).contains("== fault injection =="));
        assert!(render_report(&trace).contains("== fault injection =="));
    }

    #[test]
    fn population_section_tracks_joins_and_leaves() {
        // m=10 with 3 joins seen -> founding population 7; flash crowd
        // at round 3 (+3), flash leave at round 5 (-2).
        let trace = parse_trace(concat!(
            "{\"type\":\"meta\",\"v\":2,\"schema\":\"safa-trace\",\"protocol\":\"SAFA\",",
            "\"task\":\"regression\",\"m\":10,\"rounds\":6,\"seed\":1,\"sample\":1}\n",
            "{\"type\":\"client\",\"v\":2,\"round\":3,\"client\":7,\"event\":\"join\",\"t\":0}\n",
            "{\"type\":\"client\",\"v\":2,\"round\":3,\"client\":8,\"event\":\"join\",\"t\":0}\n",
            "{\"type\":\"client\",\"v\":2,\"round\":3,\"client\":9,\"event\":\"join\",\"t\":0}\n",
            "{\"type\":\"client\",\"v\":2,\"round\":5,\"client\":0,\"event\":\"leave\",\"t\":0}\n",
            "{\"type\":\"client\",\"v\":2,\"round\":5,\"client\":1,\"event\":\"leave\",\"t\":0}\n",
        ))
        .unwrap();
        let s = summarize_population(&trace, fleet_size(&trace));
        assert_eq!(s.joins, 3);
        assert_eq!(s.leaves, 2);
        assert_eq!(s.timeline, vec![(3, 3, 0, 10), (5, 0, 2, 8)]);
        let text = render_population(&trace);
        assert!(text.contains("fleet population"), "{text}");
        assert!(text.contains("founding population 7"), "{text}");
        let report = render_report(&trace);
        assert!(report.contains("== fleet population =="), "{report}");
        // JSON mirror carries the same timeline.
        let j = report_json(&trace);
        let pop = j.get("population").unwrap();
        assert_eq!(pop.get("joins").and_then(Json::as_f64), Some(3.0));
        assert_eq!(
            pop.get("timeline").and_then(Json::as_arr).map(Vec::len),
            Some(2)
        );
        // A churn-free trace omits the section.
        let clean = parse_trace(FIXTURE).unwrap();
        assert!(render_population(&clean).contains("no join/leave events"));
        assert!(!render_report(&clean).contains("== fleet population =="));
    }

    #[test]
    fn json_report_has_all_sections() {
        let trace = parse_trace(FIXTURE).unwrap();
        let j = report_json(&trace);
        assert_eq!(
            j.get("meta").and_then(|m| m.get("m")).and_then(Json::as_f64),
            Some(4.0)
        );
        let protos = j.get("protocols").and_then(Json::as_arr).unwrap();
        assert_eq!(protos.len(), 1);
        assert!(protos[0].get("round_duration").is_some());
        assert!(protos[0].get("staleness_cdf").is_some());
        assert_eq!(
            j.get("events")
                .and_then(|e| e.get("picked"))
                .and_then(Json::as_f64),
            Some(1.0)
        );
        let faults = j.get("faults").unwrap();
        assert_eq!(
            faults
                .get("crashed_by_phase")
                .and_then(|c| c.get("download"))
                .and_then(Json::as_f64),
            Some(1.0)
        );
        assert_eq!(
            faults
                .get("retries")
                .and_then(|r| r.get("total"))
                .and_then(Json::as_f64),
            Some(1.0)
        );
        assert_eq!(
            faults.get("timeline").and_then(Json::as_arr).map(Vec::len),
            Some(1)
        );
        // Round-trips through the serializer.
        assert!(Json::parse(&j.to_string_compact()).is_ok());
    }
}
