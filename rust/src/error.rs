//! Library-wide error type (hand-rolled — no `thiserror` offline).

use std::fmt;

/// Errors surfaced by the SAFA library.
#[derive(Debug)]
pub enum SafaError {
    Config(String),
    Data(String),
    Runtime(String),
    Artifact(String),
    Protocol(String),
    Io(std::io::Error),
    Json(crate::util::json::JsonError),
    Toml(crate::util::toml::TomlError),
    Xla(String),
}

impl fmt::Display for SafaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SafaError::Config(msg) => write!(f, "config error: {msg}"),
            SafaError::Data(msg) => write!(f, "data error: {msg}"),
            SafaError::Runtime(msg) => write!(f, "runtime error: {msg}"),
            SafaError::Artifact(msg) => write!(f, "artifact error: {msg}"),
            SafaError::Protocol(msg) => write!(f, "protocol error: {msg}"),
            SafaError::Io(e) => write!(f, "io error: {e}"),
            SafaError::Json(e) => write!(f, "json error: {e}"),
            SafaError::Toml(e) => write!(f, "toml error: {e}"),
            SafaError::Xla(msg) => write!(f, "xla error: {msg}"),
        }
    }
}

impl std::error::Error for SafaError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SafaError::Io(e) => Some(e),
            SafaError::Json(e) => Some(e),
            SafaError::Toml(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SafaError {
    fn from(e: std::io::Error) -> Self {
        SafaError::Io(e)
    }
}

impl From<crate::util::json::JsonError> for SafaError {
    fn from(e: crate::util::json::JsonError) -> Self {
        SafaError::Json(e)
    }
}

impl From<crate::util::toml::TomlError> for SafaError {
    fn from(e: crate::util::toml::TomlError) -> Self {
        SafaError::Toml(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for SafaError {
    fn from(e: xla::Error) -> Self {
        SafaError::Xla(format!("{e:?}"))
    }
}

pub type Result<T> = std::result::Result<T, SafaError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_match_variant() {
        assert_eq!(
            SafaError::Config("bad".into()).to_string(),
            "config error: bad"
        );
        assert_eq!(
            SafaError::Artifact("missing".into()).to_string(),
            "artifact error: missing"
        );
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let e: SafaError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        use std::error::Error as _;
        assert!(e.source().is_some());
    }
}
