//! Library-wide error type.

use thiserror::Error;

/// Errors surfaced by the SAFA library.
#[derive(Debug, Error)]
pub enum SafaError {
    #[error("config error: {0}")]
    Config(String),

    #[error("data error: {0}")]
    Data(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("protocol error: {0}")]
    Protocol(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(#[from] crate::util::json::JsonError),

    #[error("toml error: {0}")]
    Toml(#[from] crate::util::toml::TomlError),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for SafaError {
    fn from(e: xla::Error) -> Self {
        SafaError::Xla(format!("{e:?}"))
    }
}

pub type Result<T> = std::result::Result<T, SafaError>;
