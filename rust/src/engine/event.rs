//! Typed events and the binary-heap scheduler over one virtual clock.
//!
//! Ordering is fully deterministic: events pop by `(time, class, seq)`
//! where `seq` is the scheduling order. `class` separates ordinary client
//! events (class 0) from the round deadline (class 1), so an upload that
//! lands *exactly* on `T_lim` is still processed before the deadline
//! fires — matching the paper's `finish <= T_lim` commit rule.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What happened at a point in virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A client finished downloading the global model.
    DownloadDone,
    /// A client finished its local training epochs.
    TrainDone,
    /// A client's upload reached the server (a commit, if in time).
    UploadDone,
    /// A client dropped offline mid-round (churn).
    GoOffline,
    /// A previously offline client came back mid-round (churn).
    ComeOnline,
    /// The round deadline `T_lim` fired.
    RoundDeadline,
    /// A fault injector cut a client off mid-round (crash / flap /
    /// regional outage); cancels whatever leg is in flight.
    ClientCrash,
    /// A time-varying link condition window opened for a client
    /// (fault-injected degradation scaling its transfer legs).
    NetworkCondition,
}

/// One scheduled occurrence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Virtual time, seconds from round start.
    pub time: f64,
    /// The client concerned (`None` for fleet-wide events).
    pub client: Option<usize>,
    pub kind: EventKind,
}

#[derive(Debug, Clone, Copy)]
struct Scheduled {
    time: f64,
    /// 0 = ordinary event, 1 = deadline (fires after same-time events).
    class: u8,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Scheduled {}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed on every key: `BinaryHeap` is a max-heap and we want
        // the earliest (time, class, seq) out first.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.class.cmp(&self.class))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic discrete-event queue over one virtual clock.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
    now: f64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Reset for reuse: drop all events and rewind the clock and
    /// sequence counter, keeping the heap's allocation (the engine's
    /// round scratch pools queues across rounds).
    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.now = 0.0;
    }

    /// Pre-reserve heap capacity so steady-state rounds never grow it.
    pub fn reserve(&mut self, additional: usize) {
        self.heap.reserve(additional);
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule an ordinary event. Must not be in the queue's past.
    pub fn schedule(&mut self, event: Event) {
        debug_assert!(
            event.time >= self.now,
            "event at {} scheduled in the past (now {})",
            event.time,
            self.now
        );
        self.push(event, 0);
    }

    /// Schedule a deadline-class event: at equal timestamps it fires
    /// *after* every ordinary event, so `finish == T_lim` still commits.
    pub fn schedule_deadline(&mut self, event: Event) {
        debug_assert!(event.time >= self.now);
        self.push(event, 1);
    }

    fn push(&mut self, event: Event, class: u8) {
        crate::telemetry::count(crate::telemetry::Counter::EventsScheduled, 1);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled {
            time: event.time,
            class,
            seq,
            event,
        });
    }

    /// Pop the earliest event and advance the clock to it.
    pub fn pop(&mut self) -> Option<Event> {
        let s = self.heap.pop()?;
        crate::telemetry::count(crate::telemetry::Counter::EventsPopped, 1);
        self.now = s.time;
        Some(s.event)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(time: f64, kind: EventKind) -> Event {
        Event {
            time,
            client: None,
            kind,
        }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(ev(3.0, EventKind::TrainDone));
        q.schedule(ev(1.0, EventKind::DownloadDone));
        q.schedule(ev(2.0, EventKind::GoOffline));
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop().unwrap().time, 1.0);
        assert_eq!(q.now(), 1.0);
        assert_eq!(q.pop().unwrap().time, 2.0);
        assert_eq!(q.pop().unwrap().time, 3.0);
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn ties_break_by_scheduling_order() {
        let mut q = EventQueue::new();
        q.schedule(Event {
            time: 5.0,
            client: Some(2),
            kind: EventKind::UploadDone,
        });
        q.schedule(Event {
            time: 5.0,
            client: Some(0),
            kind: EventKind::UploadDone,
        });
        assert_eq!(q.pop().unwrap().client, Some(2));
        assert_eq!(q.pop().unwrap().client, Some(0));
    }

    #[test]
    fn deadline_fires_after_same_time_events() {
        let mut q = EventQueue::new();
        // Deadline scheduled FIRST (lower seq) but still loses the tie.
        q.schedule_deadline(ev(10.0, EventKind::RoundDeadline));
        q.schedule(ev(10.0, EventKind::UploadDone));
        assert_eq!(q.pop().unwrap().kind, EventKind::UploadDone);
        assert_eq!(q.pop().unwrap().kind, EventKind::RoundDeadline);
    }

    #[test]
    fn deadline_still_respects_time() {
        let mut q = EventQueue::new();
        q.schedule_deadline(ev(4.0, EventKind::RoundDeadline));
        q.schedule(ev(9.0, EventKind::UploadDone));
        assert_eq!(q.pop().unwrap().kind, EventKind::RoundDeadline);
    }
}
