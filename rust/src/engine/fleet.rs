//! The fleet engine: event-driven execution of one federated round.
//!
//! Every participant's round is a chain of typed events on one virtual
//! clock — `DownloadDone → TrainDone → UploadDone` for fresh jobs
//! ([`FleetEngine::run_round`]) or a single resumed `UploadDone` for
//! SAFA's in-flight jobs ([`FleetEngine::run_continuation`]) — preempted
//! by `GoOffline` / `ComeOnline` churn events and closed by the
//! `RoundDeadline`. Outputs are the same [`RoundSim`] / [`ContinuationSim`]
//! records the protocols already consume.
//!
//! # Execution strategy
//!
//! Under an *event-free* availability model (Bernoulli, trace replay —
//! no mid-round transitions, no cross-round state) every participant's
//! outcome is independent of every other's, so the engine skips the
//! event queue and computes the round as a chunked parallel map over
//! participants (`util::parallel`), followed by a serial consolidation
//! in participant order. Markov churn keeps the full event path (its
//! windows interact through the shared clock), but everything around
//! the queue is fleet-chunked across the pool: the per-client window
//! draws, the per-participant setup precompute ([`RoundSetup`] /
//! [`ContSetup`]: slot geometry, initial event times, whole-round
//! failures), the deadline overtime sweep and the pending-outcome
//! resolution. Only event *scheduling* and the pop loop stay serial, so
//! the queue's pop order remains authoritative — each client owns an
//! independent `round_rng.split(k)` stream and its own state cell, so
//! the parallel passes are invisible to the results.
//!
//! All per-round storage lives in a [`RoundScratch`] pool owned by the
//! engine: steady-state rounds are allocation-free (asserted by
//! `tests/alloc_free.rs` with a counting allocator — including with the
//! persistent worker pool dispatching, whose park/wake broadcast
//! allocates nothing once its workers are spawned).
//!
//! # Equivalence guarantee
//!
//! Under [`AvailabilityModel::BernoulliPerRound`] the engine consumes the
//! per-(round, client) RNG streams in exactly the legacy order (crash
//! draw, then crash-partial draw) and accumulates finish times with the
//! same operation order, so arrivals, times and failure sets are
//! **bit-for-bit identical** to the seed implementation (asserted by the
//! property and preset tests in this module) — and identical at every
//! fork width, because chunking never changes any per-participant
//! computation or the serial consolidation order (`tests/determinism.rs`).
//!
//! # Churn semantics (Markov / trace models)
//!
//! * A client offline at round start that never recovers is a `Crash`
//!   failure with zero partial progress (it never trained).
//! * A mid-round `GoOffline` before the upload lands is a `Crash` with
//!   partial progress equal to the fraction of the job done at the drop.
//!   In continuation mode the paused job conservatively keeps its full
//!   remaining time (progress in a partially-online round is dropped).
//! * A `ComeOnline` recovery lets the client start (or resume) late; jobs
//!   that still fit before `T_lim` commit. A late starter that misses the
//!   deadline is an `Overtime` failure in [`FleetEngine::run_round`]
//!   (fresh jobs are round-scoped), while in
//!   [`FleetEngine::run_continuation`] it counts as crashed-for-the-round
//!   rather than a straggler, because the client was not online for the
//!   round's full span.
//! * Ties between a drop and an upload at the same instant resolve in
//!   favour of the drop (the crash event is scheduled first).

use super::availability::{AvailabilityModel, ClientWindow, ScenarioTimeline};
use super::event::{Event, EventKind, EventQueue};
use crate::scenario::ScenarioProcess;
use crate::client::ClientState;
use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::faults::FaultRuntime;
use crate::net::fabric::FabricRuntime;
use crate::net::NetworkModel;
use crate::sim::{Arrival, ContinuationSim, FailReason, RoundSim};
use crate::telemetry;
use crate::telemetry::hist::{self, HistMetric};
use crate::telemetry::lifecycle::{self, ClientEvent, Event as LcEvent};
use crate::util::parallel;
use crate::util::rng::Pcg64;

/// Minimum per-worker share of the per-client parallel loops (window
/// draws, direct outcomes, setup precompute). A draw is a few RNG ops,
/// so below ~64 of them the dispatch cost dominates and the engine
/// stays serial.
const DRAW_GRAIN: usize = 64;

/// Grain for the trivial branch-and-store sweeps (deadline overtime,
/// pending-outcome resolution): ~2 ns per element, so only very large
/// fleets justify even a pooled wake.
const SWEEP_GRAIN: usize = 4_096;

/// Shared references a [`FleetEngine::run_round`] call needs (bundled to
/// keep the call site readable and the argument list short).
pub struct RoundCtx<'a> {
    pub cfg: &'a ExperimentConfig,
    pub net: &'a NetworkModel,
    pub clients: &'a [ClientState],
    /// Network fabric, when enabled: transfer legs are priced per
    /// (round, client) and synced downloads pick up contention queueing
    /// delays. `None` = the closed-form `net` arithmetic, bit-for-bit.
    pub fabric: Option<&'a FabricRuntime>,
    /// Fault injector, when enabled with at least one live injector:
    /// transfers become cancellable event-queue legs, crash / flap /
    /// outage / degradation injectors fire, and the server's bounded
    /// retry-with-backoff policy applies. `None` (or a neutral plan)
    /// keeps the legacy paths, bit-for-bit.
    pub faults: Option<&'a FaultRuntime>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Offline, waiting for a `ComeOnline` recovery.
    Idle,
    /// Online and working through its event chain.
    Active,
    Done,
    Failed,
}

#[derive(Debug, Clone, Copy)]
struct Slot {
    /// When this participant's job (re)starts (0.0, or the recovery time).
    start: f64,
    /// Full job duration from `start` (download + train + upload).
    duration: f64,
    phase: Phase,
    synced: bool,
}

/// Per-participant precompute for the event path's fresh-job setup:
/// everything the serial scheduling pass needs, derived in a
/// fleet-chunked parallel pass (each entry is a pure function of its
/// own draw + client, so chunking is invisible to the results — the
/// event queue's pop order stays authoritative because scheduling
/// itself remains serial in participant order).
#[derive(Debug, Clone, Copy)]
struct RoundSetup {
    online_secs: f64,
    slot: Slot,
    /// Mid-round drop to schedule (`GoOffline`), before the head event.
    offline_at: Option<f64>,
    /// First work event of the chain (`DownloadDone` / `TrainDone` /
    /// `ComeOnline`).
    head: Option<(f64, EventKind)>,
    failure: Option<(FailReason, f64)>,
}

const EMPTY_ROUND_SETUP: RoundSetup = RoundSetup {
    online_secs: 0.0,
    slot: Slot {
        start: 0.0,
        duration: 0.0,
        phase: Phase::Failed,
        synced: false,
    },
    offline_at: None,
    head: None,
    failure: None,
};

/// Per-participant precompute for the event path's continuation setup
/// (same contract as [`RoundSetup`]).
#[derive(Debug, Clone, Copy)]
struct ContSetup {
    online_secs: f64,
    /// Mid-round drop to schedule, before the upload.
    offline_at: Option<f64>,
    /// Resumed upload landing time, when the job is finite and starts.
    upload_at: Option<f64>,
    late_start: bool,
    /// Offline all round: the job pauses.
    crashed: bool,
}

const EMPTY_CONT_SETUP: ContSetup = ContSetup {
    online_secs: 0.0,
    offline_at: None,
    upload_at: None,
    late_start: false,
    crashed: false,
};

/// Per-participant outcome of a continuation round (event path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ContState {
    Pending,
    Arrived,
    Crashed,
    Straggler,
}

/// Per-participant outcome of an event-free fresh-job round.
#[derive(Debug, Clone, Copy)]
struct DirectSlot {
    online_secs: f64,
    /// Arrival time when committed (unset while failed).
    finish: f64,
    /// Training span endpoints when committed (lifecycle trace only).
    train_start: f64,
    train_end: f64,
    failure: Option<(FailReason, f64)>,
}

const EMPTY_DIRECT: DirectSlot = DirectSlot {
    online_secs: 0.0,
    finish: f64::NAN,
    train_start: f64::NAN,
    train_end: f64::NAN,
    failure: None,
};

/// Stable lifecycle `reason` string for a failure.
fn fail_reason_name(r: FailReason) -> &'static str {
    match r {
        FailReason::Crash => "crash",
        FailReason::Overtime => "overtime",
    }
}

/// Per-participant outcome of an event-free continuation round.
#[derive(Debug, Clone, Copy)]
enum ContOutcome {
    Arrived(f64),
    Crashed,
    Straggler,
}

/// Which leg of a fresh-job chain is in flight (faults path): the leg a
/// mid-round cut cancels, and the lifecycle `phase` tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FaultLeg {
    Download,
    Train,
    Upload,
}

impl FaultLeg {
    fn name(self) -> &'static str {
        match self {
            FaultLeg::Download => "download",
            FaultLeg::Train => "train",
            FaultLeg::Upload => "upload",
        }
    }
}

/// Per-participant precompute for the faults event path: degraded leg
/// times, the churn window, and the injector cut — all pure in
/// `(round, participant)`, so the pass fans out like [`RoundSetup`].
#[derive(Debug, Clone, Copy)]
struct FaultSetup {
    online_secs: f64,
    /// When the client's chain starts (0.0, or its churn recovery).
    start: f64,
    /// Churn drop (always hard); `INFINITY` when none.
    offline_at: f64,
    /// Injector cut at/after `start`; `INFINITY` when none fires.
    fault_at: f64,
    /// Injector recovery time; `NAN` for a hard interruption.
    fault_resume: f64,
    /// Transfer legs with link degradation applied (queueing wait is
    /// added serially by the contention pass).
    td: f64,
    tu: f64,
    t_train: f64,
    /// Link degradation fired this round (`NetworkCondition` marker).
    degraded: bool,
    /// Churn late start: the chain begins at a `ComeOnline` head.
    late: bool,
    /// Offline for the whole round (legacy whole-round failure).
    failure: Option<(FailReason, f64)>,
}

const EMPTY_FAULT_SETUP: FaultSetup = FaultSetup {
    online_secs: 0.0,
    start: 0.0,
    offline_at: f64::INFINITY,
    fault_at: f64::INFINITY,
    fault_resume: f64::NAN,
    td: 0.0,
    tu: 0.0,
    t_train: 0.0,
    degraded: false,
    late: false,
    failure: None,
};

/// Mutable pop-loop state for one faults-path participant.
#[derive(Debug, Clone, Copy)]
struct FaultSlot {
    start: f64,
    /// Full chain duration from `start` (wait + down + train + up).
    duration: f64,
    phase: Phase,
    synced: bool,
    /// Leg currently in flight (what a cut cancels).
    leg: FaultLeg,
    /// Timestamp of this client's one live completion event. A popped
    /// completion with any other timestamp is a cancelled leg's stale
    /// event and is ignored (exact f64 equality: resumed legs are
    /// rescheduled at strictly later times, so stale events never
    /// collide with a live expectation).
    expect: f64,
    /// Remaining train seconds at a mid-train cut (training resumes
    /// where it stopped; transfers restart instead).
    train_left: f64,
    /// The injector cut has fired (a later `ComeOnline` is a fault
    /// recovery, not a churn late start).
    cut_hit: bool,
    /// A fault cut ended this client's round (tags the lifecycle
    /// `crashed` line with the cancelled leg's phase).
    cut_failed: bool,
}

/// Per-participant precompute for the faults continuation path.
#[derive(Debug, Clone, Copy)]
struct ContFaultSetup {
    online_secs: f64,
    /// 0.0, or the churn recovery time (late start).
    start: f64,
    /// When the job's resumed upload lands; `INFINITY` = infinite job.
    upload_at: f64,
    /// Churn drop (pauses the job, hard); `INFINITY` when none.
    offline_at: f64,
    /// Injector cut at/after `start`; `INFINITY` when none fires.
    fault_at: f64,
    /// Injector recovery time; `NAN` for a hard interruption.
    fault_resume: f64,
    /// Upload-leg seconds at the job's end (classifies a cut as
    /// mid-upload vs mid-train and prices an upload retry).
    tail: f64,
    late: bool,
    /// Offline all round: the job pauses (legacy crashed).
    offline_all: bool,
}

const EMPTY_CONT_FAULT_SETUP: ContFaultSetup = ContFaultSetup {
    online_secs: 0.0,
    start: 0.0,
    upload_at: f64::INFINITY,
    offline_at: f64::INFINITY,
    fault_at: f64::INFINITY,
    fault_resume: f64::NAN,
    tail: 0.0,
    late: false,
    offline_all: false,
};

/// Mutable pop-loop state for one faults-path continuation job.
#[derive(Debug, Clone, Copy)]
struct ContFaultSlot {
    /// Live completion timestamp (stale-event guard, see [`FaultSlot`]).
    expect: f64,
    /// When the injector cut the job (`NAN` until it happens).
    cut_at: f64,
    /// Seconds of this round's work completed at the cut (the
    /// partial-progress credit reported via `crash_info`).
    done_at_cut: f64,
    /// The cut cancelled the job's upload leg (vs local training).
    upload_leg: bool,
    /// Cut happened and the client is waiting out the downtime.
    waiting: bool,
    was_cut: bool,
}

const EMPTY_CONT_FAULT_SLOT: ContFaultSlot = ContFaultSlot {
    expect: f64::NAN,
    cut_at: f64::NAN,
    done_at_cut: 0.0,
    upload_leg: false,
    waiting: false,
    was_cut: false,
};

/// Reusable per-round storage: cleared and refilled every round instead
/// of reallocated, so steady-state rounds cost zero heap traffic no
/// matter how large the fleet is.
#[derive(Default)]
struct RoundScratch {
    /// Fleet-indexed windows (Markov whole-fleet draws only).
    windows: Vec<Option<(ClientWindow, Pcg64)>>,
    /// Participant-indexed window draws (stream positioned after the
    /// availability draw, exactly like the legacy simulator).
    draws: Vec<Option<(ClientWindow, Pcg64)>>,
    /// Fleet-indexed participant positions (duplicate detection + event
    /// routing).
    pos_of: Vec<Option<usize>>,
    slots: Vec<Slot>,
    failures: Vec<Option<(FailReason, f64)>>,
    outcome: Vec<ContState>,
    late_start: Vec<bool>,
    /// Parallel per-participant precompute (event paths).
    setup_round: Vec<RoundSetup>,
    setup_cont: Vec<ContSetup>,
    direct_round: Vec<DirectSlot>,
    direct_cont: Vec<(f64, ContOutcome)>,
    /// Faults event path: per-participant precompute and pop-loop state.
    setup_faults: Vec<FaultSetup>,
    fslots: Vec<FaultSlot>,
    setup_cfaults: Vec<ContFaultSetup>,
    cfslots: Vec<ContFaultSlot>,
    /// Per-stream next-free times for the cancellable contention pass.
    stream_free: Vec<f64>,
    /// (participant position, arrival) pairs, sorted before output.
    arrivals: Vec<(usize, Arrival)>,
    /// Participant-indexed contention queueing delays (fabric rounds with
    /// an active contention policy only; zero-filled otherwise unused).
    dist_wait: Vec<f64>,
    queue: EventQueue,
}

/// Fill `dw` with each participant's contention queueing delay (indexed
/// like `synced`; non-synced entries stay 0.0 — they download nothing).
/// Returns false (leaving `dw` untouched) when the fabric is off or the
/// policy is uncontended, so the hot paths skip the lookup entirely.
fn fill_dist_waits(dw: &mut Vec<f64>, fabric: Option<&FabricRuntime>, synced: &[bool]) -> bool {
    let Some(f) = fabric else { return false };
    if !f.has_dist_wait() {
        return false;
    }
    let _span = telemetry::span(telemetry::Phase::TransferWait);
    let m_sync = synced.iter().filter(|&&s| s).count();
    dw.clear();
    dw.resize(synced.len(), 0.0);
    let mut idx = 0;
    for (pos, &s) in synced.iter().enumerate() {
        if s {
            dw[pos] = f.dist_wait(idx, m_sync);
            hist::record_secs_as_ms(HistMetric::TransferWaitMs, dw[pos]);
            idx += 1;
        }
    }
    true
}

/// Discrete-event simulator for a fleet of clients under an availability
/// model. One engine instance should drive all rounds of a run so that
/// Markov churn state persists across rounds; the availability draws use
/// per-(round, client) streams, so patterns are identical across
/// protocols for the same seed regardless of which protocol runs.
pub struct FleetEngine {
    avail: AvailabilityModel,
    /// Fleet size. Windows are drawn for the *whole* fleet every round so
    /// Markov state advances identically no matter which subset a
    /// protocol selects.
    m: usize,
    /// Persisted per-client on/off state (Markov churn).
    churn_state: Vec<Option<bool>>,
    /// Continuous wall-clock scenario timeline; when installed it
    /// supersedes `avail` as the window source (rounds route through
    /// the event paths) and the legacy Bernoulli crash-partial draw is
    /// suppressed.
    scenario: Option<ScenarioTimeline>,
    /// A scenario reduction pinned `avail` at compile time; skip the
    /// legacy late-binding of `crash_prob` from the config.
    scenario_pinned: bool,
    /// Pooled per-round buffers (see [`RoundScratch`]).
    scratch: RoundScratch,
}

impl FleetEngine {
    pub fn new(avail: AvailabilityModel, m: usize) -> FleetEngine {
        FleetEngine {
            avail,
            m,
            churn_state: vec![None; m],
            scenario: None,
            scenario_pinned: false,
            scratch: RoundScratch::default(),
        }
    }

    /// Build from the experiment config (`env.churn` + `env.scenario`);
    /// loads the trace file for trace replay. An enabled scenario
    /// overrides the churn model: the Bernoulli/Markov reductions
    /// compile straight to the legacy availability models (bit-for-bit
    /// identical to configuring `env.churn` / `env.crash_prob`), while
    /// the continuous process installs a [`ScenarioTimeline`].
    pub fn from_config(cfg: &ExperimentConfig) -> Result<FleetEngine> {
        let mut engine =
            FleetEngine::new(AvailabilityModel::from_env(&cfg.env)?, cfg.env.m);
        if cfg.env.scenario.enabled {
            match cfg.env.scenario.process {
                ScenarioProcess::Bernoulli { crash_prob } => {
                    engine.avail = AvailabilityModel::BernoulliPerRound { crash_prob };
                    engine.scenario_pinned = true;
                }
                ScenarioProcess::Markov {
                    mean_uptime_s,
                    mean_downtime_s,
                } => {
                    engine.avail = AvailabilityModel::Markov {
                        mean_uptime_s,
                        mean_downtime_s,
                    };
                    engine.scenario_pinned = true;
                }
                ScenarioProcess::Continuous => {
                    engine.set_scenario(ScenarioTimeline::new(
                        &cfg.env.scenario,
                        cfg.env.m,
                        cfg.train.t_lim,
                        cfg.seed,
                    ));
                }
            }
        }
        Ok(engine)
    }

    pub fn availability(&self) -> &AvailabilityModel {
        &self.avail
    }

    /// Install a continuous scenario timeline (tests construct engines
    /// directly; `from_config` uses this too).
    pub fn set_scenario(&mut self, timeline: ScenarioTimeline) {
        self.scenario = Some(timeline);
    }

    /// The installed scenario timeline, if any (protocols consult it
    /// for dynamic fleet membership).
    pub fn scenario(&self) -> Option<&ScenarioTimeline> {
        self.scenario.as_ref()
    }

    fn ensure_fleet(&mut self, m: usize) {
        if m > self.m {
            self.m = m;
            self.churn_state.resize(m, None);
        }
    }

    /// Draw this round's availability windows into `scratch.draws`,
    /// aligned with `participants`: each entry is the drawn window plus
    /// its RNG stream positioned after the availability draw (the
    /// Bernoulli crash-partial draw continues from there, exactly like
    /// the legacy simulator).
    ///
    /// Markov churn advances the *whole* fleet so the on/off pattern is
    /// identical no matter which subset a protocol selects; the
    /// stateless models (Bernoulli, trace) draw participants only —
    /// per-client streams are independent splits, so skipping
    /// non-participants changes nothing they observe. Either way the
    /// draws fan out across the pool: every client owns its own stream
    /// (and, for Markov, its own state cell), so chunking is invisible
    /// to the results.
    fn begin_round(&mut self, t: usize, horizon: f64, round_rng: &Pcg64, participants: &[usize]) {
        let m = self.m;
        let avail = &self.avail;
        let scratch = &mut self.scratch;
        scratch.draws.clear();
        scratch.draws.resize(participants.len(), None);
        if let Some(tl) = self.scenario.as_mut() {
            // Continuous scenario: windows come off the wall-clock
            // timeline (round t covers absolute [(t-1)·T_lim, t·T_lim]),
            // not a per-round draw. The per-client stream is still
            // provided for layout compatibility, but it is *unadvanced*:
            // the timeline's dwell draws live on the per-(client,
            // transition-index) streams, and the legacy Bernoulli
            // crash-partial draw never fires in scenario rounds.
            tl.prepare_round(t);
            let tl = &*tl;
            parallel::for_each_chunk(&mut scratch.draws, DRAW_GRAIN, |base, chunk| {
                for (i, d) in chunk.iter_mut().enumerate() {
                    let k = participants[base + i];
                    *d = Some((tl.window(k), round_rng.split(k as u64)));
                }
            });
            return;
        }
        if matches!(avail, AvailabilityModel::Markov { .. }) {
            if scratch.windows.len() < m {
                scratch.windows.resize(m, None);
            }
            parallel::for_each_chunk2(
                &mut scratch.windows[..m],
                &mut self.churn_state[..m],
                DRAW_GRAIN,
                |base, ws, states| {
                    for (i, (w, st)) in ws.iter_mut().zip(states.iter_mut()).enumerate() {
                        let k = base + i;
                        let mut crng = round_rng.split(k as u64);
                        *w = Some((avail.window(st, &mut crng, t, k, horizon), crng));
                    }
                },
            );
            for (pos, &k) in participants.iter().enumerate() {
                scratch.draws[pos] = scratch.windows[k].take();
            }
        } else {
            parallel::for_each_chunk(&mut scratch.draws, DRAW_GRAIN, |base, chunk| {
                for (i, d) in chunk.iter_mut().enumerate() {
                    let k = participants[base + i];
                    let mut crng = round_rng.split(k as u64);
                    // Stateless models never read or write churn state.
                    let mut state = None;
                    *d = Some((avail.window(&mut state, &mut crng, t, k, horizon), crng));
                }
            });
        }
    }

    /// The paper's crash probability is late-bound in the legacy
    /// simulator (read from the config at every call); keep that
    /// contract so tests and sweeps may adjust `cfg.env.crash_prob`
    /// between rounds.
    fn refresh_bernoulli(&mut self, cfg: &ExperimentConfig) {
        if self.scenario_pinned {
            // A scenario reduction fixed crash_prob at compile time;
            // `cfg.env.crash_prob` belongs to the superseded churn model.
            return;
        }
        if let AvailabilityModel::BernoulliPerRound { crash_prob } = &mut self.avail {
            *crash_prob = cfg.env.crash_prob;
        }
    }

    /// Simulate the training phase of round `t` where every participant
    /// starts a fresh job (FedAvg / FedCS / fully-local semantics, and
    /// SAFA's forced syncs). Drop-in replacement for the seed's
    /// `simulate_round` loop. Participant ids must be distinct (events
    /// route per client, so a duplicate has no well-defined outcome).
    pub fn run_round(
        &mut self,
        t: usize,
        ctx: RoundCtx<'_>,
        participants: &[usize],
        synced: &[bool],
        round_rng: &Pcg64,
    ) -> RoundSim {
        let mut out = RoundSim::default();
        self.run_round_into(t, ctx, participants, synced, round_rng, &mut out);
        out
    }

    /// [`FleetEngine::run_round`] writing into a caller-owned record
    /// whose buffers are reused across rounds (the allocation-free form
    /// the protocols drive).
    pub fn run_round_into(
        &mut self,
        t: usize,
        ctx: RoundCtx<'_>,
        participants: &[usize],
        synced: &[bool],
        round_rng: &Pcg64,
        out: &mut RoundSim,
    ) {
        assert_eq!(participants.len(), synced.len());
        self.refresh_bernoulli(ctx.cfg);
        self.ensure_fleet(ctx.clients.len());
        let p = participants.len();
        out.arrivals.clear();
        out.arrivals.reserve(p);
        out.failures.clear();
        out.failures.reserve(p);
        out.retx_bytes_down = 0.0;
        out.retx_bytes_up = 0.0;
        // A neutral plan (no injector can fire) keeps the legacy paths:
        // retry/backoff policy knobs only matter once an injector fires,
        // so routing on the injectors alone preserves bit-compatibility.
        let faults = ctx
            .faults
            .filter(|f| f.active() && f.plan().any_injector());
        if let Some(fr) = faults {
            self.run_round_faults(t, &ctx, participants, synced, round_rng, fr, out);
        } else if self.scenario.is_none() && self.avail.is_event_free() {
            self.run_round_direct(t, &ctx, participants, synced, round_rng, out);
        } else {
            self.run_round_event(t, &ctx, participants, synced, round_rng, out);
        }
    }

    /// Event-free fast path: no mid-round transitions can occur, so each
    /// participant's outcome is an independent function of its own RNG
    /// stream — computed as a parallel map, then consolidated serially
    /// in participant order (fixed f64 accumulation order, duplicate
    /// check, output layout — all identical to the event path).
    fn run_round_direct(
        &mut self,
        t: usize,
        ctx: &RoundCtx<'_>,
        participants: &[usize],
        synced: &[bool],
        round_rng: &Pcg64,
        out: &mut RoundSim,
    ) {
        let t_lim = ctx.cfg.train.t_lim;
        let epochs = ctx.cfg.train.epochs;
        let p = participants.len();
        let (t_down, t_up) = (ctx.net.t_down(), ctx.net.t_up());
        let clients = ctx.clients;
        let fabric = ctx.fabric;
        let avail = &self.avail;
        let scratch = &mut self.scratch;
        let contended = fill_dist_waits(&mut scratch.dist_wait, fabric, synced);
        let dw: Option<&[f64]> = if contended {
            Some(&scratch.dist_wait)
        } else {
            None
        };
        scratch.direct_round.clear();
        scratch.direct_round.resize(p, EMPTY_DIRECT);
        parallel::for_each_chunk(&mut scratch.direct_round, DRAW_GRAIN, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                let pos = base + i;
                let k = participants[pos];
                let mut crng = round_rng.split(k as u64);
                let mut state = None; // event-free models carry no churn state
                let w = avail.window(&mut state, &mut crng, t, k, t_lim);
                let online_secs = w.online_seconds(t_lim);
                if w.online_at_start {
                    // Same accumulation order as the event chain:
                    // ((wait + down) + train) + up. Fabric-off keeps the
                    // legacy values bitwise (0.0 + x == x exactly).
                    let (td, tu) = match fabric {
                        Some(f) => (f.t_down(t, k), f.t_up(t, k)),
                        None => (t_down, t_up),
                    };
                    let head = if synced[pos] {
                        dw.map_or(0.0, |d| d[pos]) + td
                    } else {
                        0.0
                    };
                    let train_end = head + clients[k].t_train(epochs);
                    let finish = train_end + tu;
                    *slot = if finish <= t_lim {
                        DirectSlot {
                            online_secs,
                            finish,
                            train_start: head,
                            train_end,
                            failure: None,
                        }
                    } else {
                        DirectSlot {
                            online_secs,
                            failure: Some((
                                FailReason::Overtime,
                                (t_lim / finish).clamp(0.0, 1.0),
                            )),
                            ..EMPTY_DIRECT
                        }
                    };
                } else {
                    // Offline for the whole round. Under Bernoulli this
                    // is the paper's crash: the device trained into the
                    // round and dropped uniformly through its work
                    // (legacy second draw); under trace replay it never
                    // started.
                    let partial = if avail.is_bernoulli() {
                        crng.next_f64()
                    } else {
                        0.0
                    };
                    *slot = DirectSlot {
                        online_secs,
                        failure: Some((FailReason::Crash, partial)),
                        ..EMPTY_DIRECT
                    };
                }
            }
        });

        scratch.pos_of.clear();
        scratch.pos_of.resize(self.m, None);
        scratch.arrivals.clear();
        scratch.arrivals.reserve(p);
        let lc = lifecycle::active();
        let mut online_time = 0.0;
        for (pos, &k) in participants.iter().enumerate() {
            assert!(scratch.pos_of[k].is_none(), "duplicate participant {k}");
            scratch.pos_of[k] = Some(pos);
            let slot = scratch.direct_round[pos];
            online_time += slot.online_secs;
            hist::record_secs_as_ms(HistMetric::ClientDwellMs, slot.online_secs);
            match slot.failure {
                Some((reason, partial)) => {
                    if lc {
                        lifecycle::emit(
                            ClientEvent::new(t, k, LcEvent::Crashed, t_lim)
                                .reason(fail_reason_name(reason)),
                        );
                    }
                    out.failures.push((k, reason, partial))
                }
                None => {
                    if lc {
                        lifecycle::emit(ClientEvent::new(
                            t,
                            k,
                            LcEvent::TrainStart,
                            slot.train_start,
                        ));
                        lifecycle::emit(ClientEvent::new(t, k, LcEvent::TrainEnd, slot.train_end));
                        lifecycle::emit(ClientEvent::new(t, k, LcEvent::Upload, slot.finish));
                    }
                    scratch.arrivals.push((
                        pos,
                        Arrival {
                            client: k,
                            time: slot.finish,
                        },
                    ))
                }
            }
        }
        sort_arrivals_into(&mut scratch.arrivals, &mut out.arrivals);
        out.online_time = online_time;
        out.offline_time = p as f64 * t_lim - online_time;
        out.last_drop = 0.0;
    }

    /// Full event path (Markov churn: windows interact through the
    /// shared clock).
    fn run_round_event(
        &mut self,
        t: usize,
        ctx: &RoundCtx<'_>,
        participants: &[usize],
        synced: &[bool],
        round_rng: &Pcg64,
        out: &mut RoundSim,
    ) {
        let t_lim = ctx.cfg.train.t_lim;
        let epochs = ctx.cfg.train.epochs;
        self.begin_round(t, t_lim, round_rng, participants);
        let p = participants.len();
        let m = self.m;
        let is_bernoulli = self.scenario.is_none() && self.avail.is_bernoulli();
        let fabric = ctx.fabric;
        let scratch = &mut self.scratch;
        let contended = fill_dist_waits(&mut scratch.dist_wait, fabric, synced);

        // Fleet-chunked parallel precompute: each participant's slot,
        // initial events and whole-round failure derive only from its
        // own window draw (plus its RNG stream for the legacy
        // crash-partial draw), so this pass fans out across the pool.
        // Only the *scheduling* below stays serial. (Fabric transfer
        // times are pure in (round, client), so they fan out too.)
        scratch.setup_round.clear();
        scratch.setup_round.resize(p, EMPTY_ROUND_SETUP);
        let dw: Option<&[f64]> = if contended {
            Some(&scratch.dist_wait)
        } else {
            None
        };
        parallel::for_each_chunk2(
            &mut scratch.setup_round,
            &mut scratch.draws,
            DRAW_GRAIN,
            |base, setups, draws| {
                for (i, (su, draw)) in setups.iter_mut().zip(draws.iter_mut()).enumerate() {
                    let pos = base + i;
                    let k = participants[pos];
                    let was_synced = synced[pos];
                    let (w, mut crng) = draw.take().expect("window drawn for participant");
                    let t_train = ctx.clients[k].t_train(epochs);
                    let (td, tu) = match fabric {
                        Some(f) => (f.t_down(t, k), f.t_up(t, k)),
                        None => (ctx.net.t_down(), ctx.net.t_up()),
                    };
                    // Fabric-off keeps legacy values bitwise (0.0 + x
                    // == x exactly).
                    let dl_head = if was_synced {
                        dw.map_or(0.0, |d| d[pos]) + td
                    } else {
                        0.0
                    };
                    let duration = dl_head + t_train + tu;
                    let online_secs = w.online_seconds(t_lim);
                    *su = if w.online_at_start {
                        RoundSetup {
                            online_secs,
                            slot: Slot {
                                start: 0.0,
                                duration,
                                phase: Phase::Active,
                                synced: was_synced,
                            },
                            offline_at: w.goes_offline_at,
                            head: Some(if was_synced {
                                (dl_head, EventKind::DownloadDone)
                            } else {
                                (t_train, EventKind::TrainDone)
                            }),
                            failure: None,
                        }
                    } else if let Some(on) = w.comes_online_at {
                        RoundSetup {
                            online_secs,
                            slot: Slot {
                                start: on,
                                duration,
                                phase: Phase::Idle,
                                synced: was_synced,
                            },
                            // Legacy windows never pair a recovery with a
                            // drop (this stays None, bit-for-bit); the
                            // scenario timeline's recover-then-drop shape
                            // schedules the second transition here.
                            offline_at: w.goes_offline_at,
                            head: Some((on, EventKind::ComeOnline)),
                            failure: None,
                        }
                    } else {
                        // Offline for the whole round. Under Bernoulli
                        // this is the paper's crash: the device trained
                        // into the round and dropped uniformly through
                        // its work (legacy second draw); under churn
                        // models it never started.
                        let partial = if is_bernoulli { crng.next_f64() } else { 0.0 };
                        RoundSetup {
                            online_secs,
                            slot: Slot {
                                start: 0.0,
                                duration,
                                phase: Phase::Failed,
                                synced: was_synced,
                            },
                            offline_at: None,
                            head: None,
                            failure: Some((FailReason::Crash, partial)),
                        }
                    };
                }
            },
        );

        scratch.pos_of.clear();
        scratch.pos_of.resize(m, None);
        scratch.slots.clear();
        scratch.slots.reserve(p);
        scratch.failures.clear();
        scratch.failures.resize(p, None);
        scratch.arrivals.clear();
        scratch.arrivals.reserve(p);
        scratch.queue.clear();
        scratch.queue.reserve(2 * p + 2);
        let q = &mut scratch.queue;
        let mut online_time = 0.0;
        let mut last_drop = 0.0f64;

        // Serial scheduling in participant order: heap sequence numbers
        // (tie-breaks) and the online-time fold stay width-invariant.
        let lc = lifecycle::active();
        for (pos, &k) in participants.iter().enumerate() {
            assert!(scratch.pos_of[k].is_none(), "duplicate participant {k}");
            scratch.pos_of[k] = Some(pos);
            let su = scratch.setup_round[pos];
            online_time += su.online_secs;
            hist::record_secs_as_ms(HistMetric::ClientDwellMs, su.online_secs);
            scratch.slots.push(su.slot);
            scratch.failures[pos] = su.failure;
            // Crash first so an exact drop/upload tie favours the drop.
            if let Some(off) = su.offline_at {
                q.schedule(Event {
                    time: off,
                    client: Some(k),
                    kind: EventKind::GoOffline,
                });
            }
            if let Some((time, kind)) = su.head {
                // A TrainDone head means training began at round start
                // (non-synced client, no download leg).
                if lc && kind == EventKind::TrainDone {
                    lifecycle::emit(ClientEvent::new(t, k, LcEvent::TrainStart, 0.0));
                }
                q.schedule(Event {
                    time,
                    client: Some(k),
                    kind,
                });
            }
        }
        q.schedule_deadline(Event {
            time: t_lim,
            client: None,
            kind: EventKind::RoundDeadline,
        });

        let pop_span = crate::telemetry::span(crate::telemetry::Phase::EventPop);
        while let Some(ev) = q.pop() {
            if ev.kind == EventKind::RoundDeadline {
                break;
            }
            let k = ev.client.expect("client event without a client");
            let pos = scratch.pos_of[k].expect("event for a non-participant");
            let slot = &mut scratch.slots[pos];
            match ev.kind {
                EventKind::ComeOnline => {
                    if slot.phase == Phase::Idle {
                        slot.phase = Phase::Active;
                        let t_train = ctx.clients[k].t_train(epochs);
                        let head = if slot.synced {
                            // Pure in (t, k): recomputing the transfer
                            // time here matches the setup pass exactly.
                            let td = match fabric {
                                Some(f) => f.t_down(t, k),
                                None => ctx.net.t_down(),
                            };
                            Event {
                                time: ev.time + (dw.map_or(0.0, |d| d[pos]) + td),
                                client: Some(k),
                                kind: EventKind::DownloadDone,
                            }
                        } else {
                            // Training begins at the recovery instant.
                            if lc {
                                lifecycle::emit(ClientEvent::new(
                                    t,
                                    k,
                                    LcEvent::TrainStart,
                                    ev.time,
                                ));
                            }
                            Event {
                                time: ev.time + t_train,
                                client: Some(k),
                                kind: EventKind::TrainDone,
                            }
                        };
                        q.schedule(head);
                    }
                }
                EventKind::DownloadDone => {
                    if slot.phase == Phase::Active {
                        if lc {
                            lifecycle::emit(ClientEvent::new(t, k, LcEvent::TrainStart, ev.time));
                        }
                        q.schedule(Event {
                            time: ev.time + ctx.clients[k].t_train(epochs),
                            client: Some(k),
                            kind: EventKind::TrainDone,
                        });
                    }
                }
                EventKind::TrainDone => {
                    if slot.phase == Phase::Active {
                        if lc {
                            lifecycle::emit(ClientEvent::new(t, k, LcEvent::TrainEnd, ev.time));
                        }
                        let tu = match fabric {
                            Some(f) => f.t_up(t, k),
                            None => ctx.net.t_up(),
                        };
                        q.schedule(Event {
                            time: ev.time + tu,
                            client: Some(k),
                            kind: EventKind::UploadDone,
                        });
                    }
                }
                EventKind::UploadDone => {
                    if slot.phase == Phase::Active {
                        slot.phase = Phase::Done;
                        if lc {
                            lifecycle::emit(ClientEvent::new(t, k, LcEvent::Upload, ev.time));
                        }
                        scratch.arrivals.push((
                            pos,
                            Arrival {
                                client: k,
                                time: ev.time,
                            },
                        ));
                    }
                }
                EventKind::GoOffline => {
                    // Only Active slots can drop. Legacy windows carry at
                    // most one transition; a scenario recover-then-drop
                    // window schedules its drop strictly after the
                    // `ComeOnline` that activates the slot, so the guard
                    // holds for both shapes (a slot already Done is
                    // untouched).
                    if slot.phase == Phase::Active {
                        slot.phase = Phase::Failed;
                        let done = ((ev.time - slot.start) / slot.duration).clamp(0.0, 1.0);
                        scratch.failures[pos] = Some((FailReason::Crash, done));
                        last_drop = last_drop.max(ev.time);
                    }
                }
                EventKind::RoundDeadline => unreachable!(),
            }
        }
        drop(pop_span);

        // Deadline: anyone still working goes overtime (the paper counts
        // them as crashed too, §III-B), credited with the fraction of the
        // job done by T_lim — a fleet-chunked pass (each slot's verdict
        // is a pure function of that slot).
        parallel::for_each_chunk2(
            &mut scratch.slots,
            &mut scratch.failures,
            SWEEP_GRAIN,
            |_, slots, failures| {
                for (slot, failure) in slots.iter().zip(failures.iter_mut()) {
                    if matches!(slot.phase, Phase::Active | Phase::Idle) {
                        let partial = ((t_lim - slot.start) / slot.duration).clamp(0.0, 1.0);
                        *failure = Some((FailReason::Overtime, partial));
                    }
                }
            },
        );

        sort_arrivals_into(&mut scratch.arrivals, &mut out.arrivals);
        for (pos, &k) in participants.iter().enumerate() {
            if let Some((reason, partial)) = scratch.failures[pos] {
                if lc {
                    lifecycle::emit(
                        ClientEvent::new(t, k, LcEvent::Crashed, t_lim)
                            .reason(fail_reason_name(reason)),
                    );
                }
                out.failures.push((k, reason, partial));
            }
        }
        out.online_time = online_time;
        out.offline_time = p as f64 * t_lim - online_time;
        out.last_drop = last_drop;
    }

    /// Faults event path for fresh-job rounds: every transfer is a
    /// cancellable event-queue leg, injector cuts (`ClientCrash`)
    /// cancel whatever leg is in flight, and the server's graceful-
    /// degradation policies apply (bounded retry with capped
    /// exponential backoff for transfers, free resume for training).
    ///
    /// * **Contention rescheduling** — under a contended fabric the
    ///   distribution queue is simulated as `S` server streams serving
    ///   one copy in `service` seconds ([`FabricRuntime::contention_slots`],
    ///   which reproduces `dist_wait` when nothing is cancelled). A
    ///   client cut mid-push frees its stream at the cut, so survivors'
    ///   queue waits shrink; one cut before its turn never occupies a
    ///   stream. Retried legs bypass the queue (the server re-sends
    ///   point-to-point after backoff).
    /// * **Retransmit accounting** — on each *completed* transfer leg
    ///   the fabric's priced loss-retransmits are booked as re-sent
    ///   bytes (`RoundSim::retx_bytes_*`), plus one payload per server
    ///   retry copy. Cancelled partial transmissions are not booked.
    /// * **Determinism** — every injector query is pure in `(t, k)`
    ///   and the parallel setup pass never touches shared state, so
    ///   results are bit-identical at any thread width.
    #[allow(clippy::too_many_arguments)]
    fn run_round_faults(
        &mut self,
        t: usize,
        ctx: &RoundCtx<'_>,
        participants: &[usize],
        synced: &[bool],
        round_rng: &Pcg64,
        fr: &FaultRuntime,
        out: &mut RoundSim,
    ) {
        let t_lim = ctx.cfg.train.t_lim;
        let epochs = ctx.cfg.train.epochs;
        self.begin_round(t, t_lim, round_rng, participants);
        let p = participants.len();
        let m = self.m;
        let is_bernoulli = self.scenario.is_none() && self.avail.is_bernoulli();
        let fabric = ctx.fabric;
        let retry_max = fr.plan().retry_max;
        let payload = fabric.map(|f| f.payload_bytes());
        let scratch = &mut self.scratch;

        // Parallel per-participant precompute (see run_round_event):
        // every field is a pure function of the participant's own
        // window draw and the pure injector queries.
        scratch.setup_faults.clear();
        scratch.setup_faults.resize(p, EMPTY_FAULT_SETUP);
        parallel::for_each_chunk2(
            &mut scratch.setup_faults,
            &mut scratch.draws,
            DRAW_GRAIN,
            |base, setups, draws| {
                for (i, (su, draw)) in setups.iter_mut().zip(draws.iter_mut()).enumerate() {
                    let pos = base + i;
                    let k = participants[pos];
                    let (w, mut crng) = draw.take().expect("window drawn for participant");
                    let online_secs = w.online_seconds(t_lim);
                    let t_train = ctx.clients[k].t_train(epochs);
                    let deg = fr.degrade(t, k);
                    let (mut td, mut tu) = match fabric {
                        Some(f) => (f.t_down(t, k), f.t_up(t, k)),
                        None => (ctx.net.t_down(), ctx.net.t_up()),
                    };
                    if deg > 1.0 {
                        td *= deg;
                        tu *= deg;
                    }
                    if !w.online_at_start && w.comes_online_at.is_none() {
                        // Offline for the whole round (legacy failure;
                        // no injector can hit a client that never runs).
                        let partial = if is_bernoulli { crng.next_f64() } else { 0.0 };
                        *su = FaultSetup {
                            online_secs,
                            td,
                            tu,
                            t_train,
                            failure: Some((FailReason::Crash, partial)),
                            ..EMPTY_FAULT_SETUP
                        };
                    } else {
                        let (start, late) = match w.comes_online_at {
                            Some(on) if !w.online_at_start => (on, true),
                            _ => (0.0, false),
                        };
                        let (fault_at, fault_resume) = match fr.interrupt(t, k, t_lim) {
                            // A cut while the client is still offline
                            // is unobservable: only cuts at/after its
                            // start interrupt anything.
                            Some(i) if i.at >= start => {
                                (i.at, i.resume.unwrap_or(f64::NAN))
                            }
                            _ => (f64::INFINITY, f64::NAN),
                        };
                        *su = FaultSetup {
                            online_secs,
                            start,
                            offline_at: w.goes_offline_at.unwrap_or(f64::INFINITY),
                            fault_at,
                            fault_resume,
                            td,
                            tu,
                            t_train,
                            degraded: deg > 1.0,
                            late,
                            failure: None,
                        };
                    }
                }
            },
        );

        // Serial contention pass: synced copies queue on the fabric's
        // server streams in participant order; a copy whose owner is
        // cut mid-push frees its stream early (survivors re-price), a
        // copy cut before its turn is never pushed. Whole-round-offline
        // clients still receive a full push (the server cannot know).
        let (streams, service) = fabric.map_or((0, 0.0), |f| f.contention_slots());
        scratch.dist_wait.clear();
        scratch.dist_wait.resize(p, 0.0);
        if streams > 0 {
            let _span = telemetry::span(telemetry::Phase::TransferWait);
            scratch.stream_free.clear();
            scratch.stream_free.resize(streams, 0.0);
            for pos in 0..p {
                if !synced[pos] {
                    continue;
                }
                let su = &scratch.setup_faults[pos];
                // Earliest-free stream, lowest index on ties.
                let mut j = 0;
                for jj in 1..streams {
                    if scratch.stream_free[jj] < scratch.stream_free[j] {
                        j = jj;
                    }
                }
                let w = scratch.stream_free[j];
                scratch.dist_wait[pos] = w;
                hist::record_secs_as_ms(HistMetric::TransferWaitMs, w);
                let cut = su.offline_at.min(su.fault_at);
                if su.failure.is_some() || cut >= w + service {
                    scratch.stream_free[j] = w + service;
                } else if cut > w {
                    // Aborted mid-push: the stream frees at the cut.
                    scratch.stream_free[j] = cut;
                }
                // cut <= w: the copy is never pushed; stream untouched.
            }
        }

        scratch.pos_of.clear();
        scratch.pos_of.resize(m, None);
        scratch.fslots.clear();
        scratch.fslots.reserve(p);
        scratch.failures.clear();
        scratch.failures.resize(p, None);
        scratch.arrivals.clear();
        scratch.arrivals.reserve(p);
        scratch.queue.clear();
        scratch.queue.reserve(4 * p + 2);
        let q = &mut scratch.queue;
        let mut online_time = 0.0;
        let mut last_drop = 0.0f64;
        let mut retx_down = 0.0f64;
        let mut retx_up = 0.0f64;

        // Serial scheduling in participant order (pop order stays
        // authoritative; see run_round_event).
        let lc = lifecycle::active();
        for (pos, &k) in participants.iter().enumerate() {
            assert!(scratch.pos_of[k].is_none(), "duplicate participant {k}");
            scratch.pos_of[k] = Some(pos);
            let su = scratch.setup_faults[pos];
            online_time += su.online_secs;
            hist::record_secs_as_ms(HistMetric::ClientDwellMs, su.online_secs);
            let dl_head = if synced[pos] {
                scratch.dist_wait[pos] + su.td
            } else {
                0.0
            };
            let mut slot = FaultSlot {
                start: su.start,
                duration: dl_head + su.t_train + su.tu,
                phase: if su.failure.is_some() {
                    Phase::Failed
                } else if su.late {
                    Phase::Idle
                } else {
                    Phase::Active
                },
                synced: synced[pos],
                leg: if synced[pos] {
                    FaultLeg::Download
                } else {
                    FaultLeg::Train
                },
                expect: f64::NAN,
                train_left: su.t_train,
                cut_hit: false,
                cut_failed: false,
            };
            scratch.failures[pos] = su.failure;
            if su.failure.is_none() {
                // Hard churn drop first, then the injector cut, so an
                // exact drop/cut/completion tie resolves hard-first.
                if su.offline_at.is_finite() {
                    q.schedule(Event {
                        time: su.offline_at,
                        client: Some(k),
                        kind: EventKind::GoOffline,
                    });
                }
                if su.fault_at.is_finite() {
                    telemetry::count(telemetry::Counter::FaultsInjected, 1);
                    q.schedule(Event {
                        time: su.fault_at,
                        client: Some(k),
                        kind: EventKind::ClientCrash,
                    });
                }
                if su.degraded {
                    // Visibility marker: the degradation is already
                    // priced into td/tu; the event records the window
                    // opening on the queue's clock.
                    q.schedule(Event {
                        time: su.start,
                        client: Some(k),
                        kind: EventKind::NetworkCondition,
                    });
                }
                if su.late {
                    q.schedule(Event {
                        time: su.start,
                        client: Some(k),
                        kind: EventKind::ComeOnline,
                    });
                } else {
                    let (head, kind) = if slot.synced {
                        (dl_head, EventKind::DownloadDone)
                    } else {
                        if lc {
                            lifecycle::emit(ClientEvent::new(t, k, LcEvent::TrainStart, 0.0));
                        }
                        (su.t_train, EventKind::TrainDone)
                    };
                    slot.expect = head;
                    q.schedule(Event {
                        time: head,
                        client: Some(k),
                        kind,
                    });
                }
            }
            scratch.fslots.push(slot);
        }
        q.schedule_deadline(Event {
            time: t_lim,
            client: None,
            kind: EventKind::RoundDeadline,
        });

        let pop_span = crate::telemetry::span(crate::telemetry::Phase::EventPop);
        while let Some(ev) = q.pop() {
            if ev.kind == EventKind::RoundDeadline {
                break;
            }
            let k = ev.client.expect("client event without a client");
            let pos = scratch.pos_of[k].expect("event for a non-participant");
            let su = scratch.setup_faults[pos];
            let dw_pos = scratch.dist_wait[pos];
            let slot = &mut scratch.fslots[pos];
            match ev.kind {
                EventKind::NetworkCondition => {}
                EventKind::ComeOnline => {
                    if slot.phase == Phase::Idle {
                        slot.phase = Phase::Active;
                        if !slot.cut_hit {
                            // Churn late start: the chain begins now.
                            if slot.synced {
                                slot.leg = FaultLeg::Download;
                                slot.expect = ev.time + (dw_pos + su.td);
                                q.schedule(Event {
                                    time: slot.expect,
                                    client: Some(k),
                                    kind: EventKind::DownloadDone,
                                });
                            } else {
                                if lc {
                                    lifecycle::emit(ClientEvent::new(
                                        t,
                                        k,
                                        LcEvent::TrainStart,
                                        ev.time,
                                    ));
                                }
                                slot.leg = FaultLeg::Train;
                                slot.expect = ev.time + su.t_train;
                                q.schedule(Event {
                                    time: slot.expect,
                                    client: Some(k),
                                    kind: EventKind::TrainDone,
                                });
                            }
                        } else {
                            // Fault recovery: resume training for free,
                            // or retry the cancelled transfer leg after
                            // backoff (retry_max was checked at the cut).
                            match slot.leg {
                                FaultLeg::Train => {
                                    slot.expect = ev.time + slot.train_left;
                                    q.schedule(Event {
                                        time: slot.expect,
                                        client: Some(k),
                                        kind: EventKind::TrainDone,
                                    });
                                }
                                FaultLeg::Download | FaultLeg::Upload => {
                                    telemetry::count(telemetry::Counter::Retries, 1);
                                    if lc {
                                        lifecycle::emit(
                                            ClientEvent::new(t, k, LcEvent::Retry, ev.time)
                                                .phase(slot.leg.name()),
                                        );
                                    }
                                    let (leg_s, kind) = match slot.leg {
                                        FaultLeg::Download => {
                                            (su.td, EventKind::DownloadDone)
                                        }
                                        _ => (su.tu, EventKind::UploadDone),
                                    };
                                    if let Some(b) = payload {
                                        match slot.leg {
                                            FaultLeg::Download => retx_down += b,
                                            _ => retx_up += b,
                                        }
                                    }
                                    slot.expect = ev.time + fr.backoff(1) + leg_s;
                                    q.schedule(Event {
                                        time: slot.expect,
                                        client: Some(k),
                                        kind,
                                    });
                                }
                            }
                        }
                    }
                }
                EventKind::ClientCrash => {
                    if slot.phase == Phase::Active && !slot.cut_hit {
                        slot.cut_hit = true;
                        if slot.leg == FaultLeg::Train {
                            // Training pauses where it stopped.
                            slot.train_left = slot.expect - ev.time;
                        }
                        let resumable = su.fault_resume.is_finite()
                            && (slot.leg == FaultLeg::Train || retry_max >= 1);
                        if resumable {
                            slot.phase = Phase::Idle;
                            q.schedule(Event {
                                time: su.fault_resume,
                                client: Some(k),
                                kind: EventKind::ComeOnline,
                            });
                        } else {
                            slot.phase = Phase::Failed;
                            slot.cut_failed = true;
                            let done =
                                ((ev.time - slot.start) / slot.duration).clamp(0.0, 1.0);
                            scratch.failures[pos] = Some((FailReason::Crash, done));
                            last_drop = last_drop.max(ev.time);
                        }
                    }
                }
                EventKind::DownloadDone => {
                    if slot.phase == Phase::Active && ev.time == slot.expect {
                        if let (Some(b), Some(f)) = (payload, fabric) {
                            retx_down += b * f.extra_down_attempts(t, k) as f64;
                        }
                        if lc {
                            lifecycle::emit(ClientEvent::new(t, k, LcEvent::TrainStart, ev.time));
                        }
                        slot.leg = FaultLeg::Train;
                        slot.train_left = su.t_train;
                        slot.expect = ev.time + su.t_train;
                        q.schedule(Event {
                            time: slot.expect,
                            client: Some(k),
                            kind: EventKind::TrainDone,
                        });
                    }
                }
                EventKind::TrainDone => {
                    if slot.phase == Phase::Active && ev.time == slot.expect {
                        if lc {
                            lifecycle::emit(ClientEvent::new(t, k, LcEvent::TrainEnd, ev.time));
                        }
                        slot.leg = FaultLeg::Upload;
                        slot.expect = ev.time + su.tu;
                        q.schedule(Event {
                            time: slot.expect,
                            client: Some(k),
                            kind: EventKind::UploadDone,
                        });
                    }
                }
                EventKind::UploadDone => {
                    if slot.phase == Phase::Active && ev.time == slot.expect {
                        slot.phase = Phase::Done;
                        if let (Some(b), Some(f)) = (payload, fabric) {
                            retx_up += b * f.extra_up_attempts(t, k) as f64;
                        }
                        if lc {
                            lifecycle::emit(ClientEvent::new(t, k, LcEvent::Upload, ev.time));
                        }
                        scratch.arrivals.push((
                            pos,
                            Arrival {
                                client: k,
                                time: ev.time,
                            },
                        ));
                    }
                }
                EventKind::GoOffline => {
                    // A churn drop is always hard — it also kills a
                    // client waiting out a fault recovery.
                    if slot.phase == Phase::Active
                        || (slot.phase == Phase::Idle && slot.cut_hit)
                    {
                        slot.phase = Phase::Failed;
                        let done = ((ev.time - slot.start) / slot.duration).clamp(0.0, 1.0);
                        scratch.failures[pos] = Some((FailReason::Crash, done));
                        last_drop = last_drop.max(ev.time);
                    }
                }
                EventKind::RoundDeadline => unreachable!(),
            }
        }
        drop(pop_span);

        // Deadline sweep: anyone still working (or waiting out a
        // recovery that retries past T_lim) goes overtime.
        parallel::for_each_chunk2(
            &mut scratch.fslots,
            &mut scratch.failures,
            SWEEP_GRAIN,
            |_, slots, failures| {
                for (slot, failure) in slots.iter().zip(failures.iter_mut()) {
                    if matches!(slot.phase, Phase::Active | Phase::Idle) {
                        let partial = ((t_lim - slot.start) / slot.duration).clamp(0.0, 1.0);
                        *failure = Some((FailReason::Overtime, partial));
                    }
                }
            },
        );

        sort_arrivals_into(&mut scratch.arrivals, &mut out.arrivals);
        for (pos, &k) in participants.iter().enumerate() {
            if let Some((reason, partial)) = scratch.failures[pos] {
                if lc {
                    let mut ev = ClientEvent::new(t, k, LcEvent::Crashed, t_lim)
                        .reason(fail_reason_name(reason));
                    if scratch.fslots[pos].cut_failed {
                        ev = ev.phase(scratch.fslots[pos].leg.name());
                    }
                    lifecycle::emit(ev);
                }
                out.failures.push((k, reason, partial));
            }
        }
        out.online_time = online_time;
        out.offline_time = p as f64 * t_lim - online_time;
        out.last_drop = last_drop;
        out.retx_bytes_down = retx_down;
        out.retx_bytes_up = retx_up;
    }

    /// Simulate one round over in-flight jobs (SAFA / FedAsync
    /// continuation semantics): `jobs[i]` is the remaining work for
    /// `participants[i]`. Drop-in replacement for the seed's
    /// `simulate_continuation` loop. Participant ids must be distinct.
    pub fn run_continuation(
        &mut self,
        t: usize,
        cfg: &ExperimentConfig,
        participants: &[usize],
        jobs: &[f64],
        round_rng: &Pcg64,
    ) -> ContinuationSim {
        let mut out = ContinuationSim::default();
        self.run_continuation_into(t, cfg, participants, jobs, round_rng, &mut out);
        out
    }

    /// [`FleetEngine::run_continuation`] writing into a caller-owned,
    /// buffer-reusing record.
    pub fn run_continuation_into(
        &mut self,
        t: usize,
        cfg: &ExperimentConfig,
        participants: &[usize],
        jobs: &[f64],
        round_rng: &Pcg64,
        out: &mut ContinuationSim,
    ) {
        assert_eq!(participants.len(), jobs.len());
        self.refresh_bernoulli(cfg);
        let fleet = participants.iter().copied().max().map_or(0, |k| k + 1);
        self.ensure_fleet(fleet);
        let p = participants.len();
        out.arrivals.clear();
        out.arrivals.reserve(p);
        out.crashed.clear();
        out.crashed.reserve(p);
        out.stragglers.clear();
        out.stragglers.reserve(p);
        out.crash_info.clear();
        out.upload_crashed = 0;
        out.retx_bytes_up = 0.0;
        if self.scenario.is_none() && self.avail.is_event_free() {
            self.run_continuation_direct(t, cfg, participants, jobs, round_rng, out);
        } else {
            self.run_continuation_event(t, cfg, participants, jobs, round_rng, out);
        }
    }

    /// Event-free fast path for continuation rounds (see
    /// [`FleetEngine::run_round_direct`]).
    fn run_continuation_direct(
        &mut self,
        t: usize,
        cfg: &ExperimentConfig,
        participants: &[usize],
        jobs: &[f64],
        round_rng: &Pcg64,
        out: &mut ContinuationSim,
    ) {
        let t_lim = cfg.train.t_lim;
        let p = participants.len();
        let avail = &self.avail;
        let scratch = &mut self.scratch;
        scratch.direct_cont.clear();
        scratch.direct_cont.resize(p, (0.0, ContOutcome::Crashed));
        parallel::for_each_chunk(&mut scratch.direct_cont, DRAW_GRAIN, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                let pos = base + i;
                let k = participants[pos];
                let mut crng = round_rng.split(k as u64);
                let mut state = None;
                let w = avail.window(&mut state, &mut crng, t, k, t_lim);
                let outcome = if !w.online_at_start {
                    // Offline: the job pauses (no legacy second draw in
                    // continuation mode).
                    ContOutcome::Crashed
                } else if jobs[pos] <= t_lim {
                    ContOutcome::Arrived(jobs[pos])
                } else {
                    // Online through the deadline but the job spans
                    // rounds (covers infinite = no job).
                    ContOutcome::Straggler
                };
                *slot = (w.online_seconds(t_lim), outcome);
            }
        });

        scratch.pos_of.clear();
        scratch.pos_of.resize(self.m, None);
        scratch.arrivals.clear();
        scratch.arrivals.reserve(p);
        let lc = lifecycle::active();
        let mut online_time = 0.0;
        for (pos, &k) in participants.iter().enumerate() {
            assert!(scratch.pos_of[k].is_none(), "duplicate participant {k}");
            scratch.pos_of[k] = Some(pos);
            let (secs, outcome) = scratch.direct_cont[pos];
            online_time += secs;
            hist::record_secs_as_ms(HistMetric::ClientDwellMs, secs);
            match outcome {
                ContOutcome::Arrived(time) => {
                    if lc {
                        lifecycle::emit(ClientEvent::new(t, k, LcEvent::Upload, time));
                    }
                    scratch.arrivals.push((pos, Arrival { client: k, time }))
                }
                ContOutcome::Crashed => {
                    if lc {
                        lifecycle::emit(
                            ClientEvent::new(t, k, LcEvent::Crashed, t_lim).reason("crash"),
                        );
                    }
                    out.crashed.push(k)
                }
                ContOutcome::Straggler => out.stragglers.push(k),
            }
        }
        sort_arrivals_into(&mut scratch.arrivals, &mut out.arrivals);
        out.online_time = online_time;
        out.offline_time = p as f64 * t_lim - online_time;
    }

    /// Full event path for continuation rounds.
    fn run_continuation_event(
        &mut self,
        t: usize,
        cfg: &ExperimentConfig,
        participants: &[usize],
        jobs: &[f64],
        round_rng: &Pcg64,
        out: &mut ContinuationSim,
    ) {
        let t_lim = cfg.train.t_lim;
        self.begin_round(t, t_lim, round_rng, participants);
        let p = participants.len();
        let m = self.m;
        let scratch = &mut self.scratch;

        // Fleet-chunked parallel precompute (see run_round_event): each
        // participant's resumed-upload / drop schedule is a pure
        // function of its own draw and remaining job.
        scratch.setup_cont.clear();
        scratch.setup_cont.resize(p, EMPTY_CONT_SETUP);
        parallel::for_each_chunk2(
            &mut scratch.setup_cont,
            &mut scratch.draws,
            DRAW_GRAIN,
            |base, setups, draws| {
                for (i, (su, draw)) in setups.iter_mut().zip(draws.iter_mut()).enumerate() {
                    let remaining = jobs[base + i];
                    let (w, _) = draw.take().expect("window drawn for participant");
                    let online_secs = w.online_seconds(t_lim);
                    *su = if w.online_at_start {
                        ContSetup {
                            online_secs,
                            offline_at: w.goes_offline_at,
                            upload_at: remaining.is_finite().then_some(remaining),
                            late_start: false,
                            crashed: false,
                        }
                    } else if let Some(on) = w.comes_online_at {
                        ContSetup {
                            online_secs,
                            // None under the legacy models (bit-for-bit);
                            // a scenario recover-then-drop window pauses
                            // the job again at its second transition.
                            offline_at: w.goes_offline_at,
                            upload_at: remaining.is_finite().then_some(on + remaining),
                            late_start: true,
                            crashed: false,
                        }
                    } else {
                        ContSetup {
                            online_secs,
                            offline_at: None,
                            upload_at: None,
                            late_start: false,
                            crashed: true,
                        }
                    };
                }
            },
        );

        scratch.pos_of.clear();
        scratch.pos_of.resize(m, None);
        scratch.outcome.clear();
        scratch.outcome.resize(p, ContState::Pending);
        scratch.late_start.clear();
        scratch.late_start.resize(p, false);
        scratch.arrivals.clear();
        scratch.arrivals.reserve(p);
        scratch.queue.clear();
        scratch.queue.reserve(2 * p + 2);
        let q = &mut scratch.queue;
        let mut online_time = 0.0;

        // Serial scheduling in participant order (queue pop order stays
        // authoritative; see run_round_event).
        let lc = lifecycle::active();
        for (pos, &k) in participants.iter().enumerate() {
            assert!(scratch.pos_of[k].is_none(), "duplicate participant {k}");
            scratch.pos_of[k] = Some(pos);
            let su = scratch.setup_cont[pos];
            online_time += su.online_secs;
            hist::record_secs_as_ms(HistMetric::ClientDwellMs, su.online_secs);
            scratch.late_start[pos] = su.late_start;
            if su.crashed {
                scratch.outcome[pos] = ContState::Crashed;
            }
            // Crash first so an exact drop/upload tie favours the drop.
            if let Some(off) = su.offline_at {
                q.schedule(Event {
                    time: off,
                    client: Some(k),
                    kind: EventKind::GoOffline,
                });
            }
            if let Some(up) = su.upload_at {
                q.schedule(Event {
                    time: up,
                    client: Some(k),
                    kind: EventKind::UploadDone,
                });
            }
        }
        q.schedule_deadline(Event {
            time: t_lim,
            client: None,
            kind: EventKind::RoundDeadline,
        });

        let pop_span = crate::telemetry::span(crate::telemetry::Phase::EventPop);
        while let Some(ev) = q.pop() {
            if ev.kind == EventKind::RoundDeadline {
                break;
            }
            let k = ev.client.expect("client event without a client");
            let pos = scratch.pos_of[k].expect("event for a non-participant");
            match ev.kind {
                EventKind::UploadDone => {
                    if scratch.outcome[pos] == ContState::Pending {
                        scratch.outcome[pos] = ContState::Arrived;
                        if lc {
                            lifecycle::emit(ClientEvent::new(t, k, LcEvent::Upload, ev.time));
                        }
                        scratch.arrivals.push((
                            pos,
                            Arrival {
                                client: k,
                                time: ev.time,
                            },
                        ));
                    }
                }
                EventKind::GoOffline => {
                    if scratch.outcome[pos] == ContState::Pending {
                        // The job pauses; this round's partial progress is
                        // conservatively dropped (see module docs).
                        scratch.outcome[pos] = ContState::Crashed;
                    }
                }
                _ => {}
            }
        }
        drop(pop_span);
        // Fleet-chunked resolution of still-pending participants.
        parallel::for_each_chunk2(
            &mut scratch.outcome,
            &mut scratch.late_start,
            SWEEP_GRAIN,
            |_, outcomes, late| {
                for (o, &started_late) in outcomes.iter_mut().zip(late.iter()) {
                    if *o == ContState::Pending {
                        // Online through the deadline but the job spans
                        // rounds: a straggler — unless it started late,
                        // in which case it counts as paused this round.
                        *o = if started_late {
                            ContState::Crashed
                        } else {
                            ContState::Straggler
                        };
                    }
                }
            },
        );

        sort_arrivals_into(&mut scratch.arrivals, &mut out.arrivals);
        for (pos, &k) in participants.iter().enumerate() {
            match scratch.outcome[pos] {
                ContState::Crashed => {
                    if lc {
                        lifecycle::emit(
                            ClientEvent::new(t, k, LcEvent::Crashed, t_lim).reason("crash"),
                        );
                    }
                    out.crashed.push(k)
                }
                ContState::Straggler => out.stragglers.push(k),
                _ => {}
            }
        }
        out.online_time = online_time;
        out.offline_time = p as f64 * t_lim - online_time;
    }

    /// Faults event path for continuation rounds: in-flight jobs become
    /// cancellable, an injector cut mid-job pauses it with **partial-
    /// progress credit** (`ContinuationSim::crash_info` reports the
    /// seconds completed, so a job crashed at epoch *k* resumes from
    /// *k*), and a cut inside the job's trailing upload leg is retried
    /// after backoff when the interruption recovers in-round.
    ///
    /// `tails[i]` is the upload-leg length at the end of
    /// `participants[i]`'s job (0.0 when unknown): it classifies a cut
    /// as mid-upload vs mid-train — mid-upload crashes are SAFA's
    /// "picked client crashed before its update landed" count
    /// (`ContinuationSim::upload_crashed`) — and prices the retried
    /// upload. A retried upload restarts the whole leg
    /// (`resume + backoff + tail`); a mid-train cut resumes with the
    /// remaining work shifted by the downtime, for free.
    #[allow(clippy::too_many_arguments)]
    pub fn run_continuation_faults_into(
        &mut self,
        t: usize,
        cfg: &ExperimentConfig,
        participants: &[usize],
        jobs: &[f64],
        tails: &[f64],
        fabric: Option<&FabricRuntime>,
        fr: &FaultRuntime,
        round_rng: &Pcg64,
        out: &mut ContinuationSim,
    ) {
        assert_eq!(participants.len(), jobs.len());
        assert_eq!(participants.len(), tails.len());
        self.refresh_bernoulli(cfg);
        let fleet = participants.iter().copied().max().map_or(0, |k| k + 1);
        self.ensure_fleet(fleet);
        let p = participants.len();
        out.arrivals.clear();
        out.arrivals.reserve(p);
        out.crashed.clear();
        out.crashed.reserve(p);
        out.stragglers.clear();
        out.stragglers.reserve(p);
        out.crash_info.clear();
        out.upload_crashed = 0;
        out.retx_bytes_up = 0.0;
        if !(fr.active() && fr.plan().any_injector()) {
            // Neutral plan: identical to the legacy continuation paths.
            if self.scenario.is_none() && self.avail.is_event_free() {
                self.run_continuation_direct(t, cfg, participants, jobs, round_rng, out);
            } else {
                self.run_continuation_event(t, cfg, participants, jobs, round_rng, out);
            }
            return;
        }

        let t_lim = cfg.train.t_lim;
        self.begin_round(t, t_lim, round_rng, participants);
        let m = self.m;
        let retry_max = fr.plan().retry_max;
        let payload = fabric.map(|f| f.payload_bytes());
        let scratch = &mut self.scratch;

        scratch.setup_cfaults.clear();
        scratch.setup_cfaults.resize(p, EMPTY_CONT_FAULT_SETUP);
        parallel::for_each_chunk2(
            &mut scratch.setup_cfaults,
            &mut scratch.draws,
            DRAW_GRAIN,
            |base, setups, draws| {
                for (i, (su, draw)) in setups.iter_mut().zip(draws.iter_mut()).enumerate() {
                    let pos = base + i;
                    let k = participants[pos];
                    let remaining = jobs[pos];
                    let (w, _) = draw.take().expect("window drawn for participant");
                    let online_secs = w.online_seconds(t_lim);
                    if !w.online_at_start && w.comes_online_at.is_none() {
                        *su = ContFaultSetup {
                            online_secs,
                            offline_all: true,
                            ..EMPTY_CONT_FAULT_SETUP
                        };
                    } else {
                        let (start, late) = match w.comes_online_at {
                            Some(on) if !w.online_at_start => (on, true),
                            _ => (0.0, false),
                        };
                        let upload_at = if remaining.is_finite() {
                            if late {
                                start + remaining
                            } else {
                                remaining
                            }
                        } else {
                            f64::INFINITY
                        };
                        let (fault_at, fault_resume) = match fr.interrupt(t, k, t_lim) {
                            Some(iv) if iv.at >= start => {
                                (iv.at, iv.resume.unwrap_or(f64::NAN))
                            }
                            _ => (f64::INFINITY, f64::NAN),
                        };
                        *su = ContFaultSetup {
                            online_secs,
                            start,
                            upload_at,
                            offline_at: w.goes_offline_at.unwrap_or(f64::INFINITY),
                            fault_at,
                            fault_resume,
                            tail: tails[pos],
                            late,
                            offline_all: false,
                        };
                    }
                }
            },
        );

        scratch.pos_of.clear();
        scratch.pos_of.resize(m, None);
        scratch.outcome.clear();
        scratch.outcome.resize(p, ContState::Pending);
        scratch.late_start.clear();
        scratch.late_start.resize(p, false);
        scratch.cfslots.clear();
        scratch.cfslots.resize(p, EMPTY_CONT_FAULT_SLOT);
        scratch.arrivals.clear();
        scratch.arrivals.reserve(p);
        scratch.queue.clear();
        scratch.queue.reserve(3 * p + 2);
        let q = &mut scratch.queue;
        let mut online_time = 0.0;
        let mut retx_up = 0.0f64;

        let lc = lifecycle::active();
        for (pos, &k) in participants.iter().enumerate() {
            assert!(scratch.pos_of[k].is_none(), "duplicate participant {k}");
            scratch.pos_of[k] = Some(pos);
            let su = scratch.setup_cfaults[pos];
            online_time += su.online_secs;
            hist::record_secs_as_ms(HistMetric::ClientDwellMs, su.online_secs);
            scratch.late_start[pos] = su.late;
            if su.offline_all {
                scratch.outcome[pos] = ContState::Crashed;
                continue;
            }
            // Hard drop first, then the cut, then the completion, so
            // exact ties resolve hard-first (legacy tie rule).
            if su.offline_at.is_finite() {
                q.schedule(Event {
                    time: su.offline_at,
                    client: Some(k),
                    kind: EventKind::GoOffline,
                });
            }
            if su.fault_at.is_finite() {
                telemetry::count(telemetry::Counter::FaultsInjected, 1);
                q.schedule(Event {
                    time: su.fault_at,
                    client: Some(k),
                    kind: EventKind::ClientCrash,
                });
            }
            if su.upload_at.is_finite() {
                scratch.cfslots[pos].expect = su.upload_at;
                q.schedule(Event {
                    time: su.upload_at,
                    client: Some(k),
                    kind: EventKind::UploadDone,
                });
            }
        }
        q.schedule_deadline(Event {
            time: t_lim,
            client: None,
            kind: EventKind::RoundDeadline,
        });

        let pop_span = crate::telemetry::span(crate::telemetry::Phase::EventPop);
        while let Some(ev) = q.pop() {
            if ev.kind == EventKind::RoundDeadline {
                break;
            }
            let k = ev.client.expect("client event without a client");
            let pos = scratch.pos_of[k].expect("event for a non-participant");
            if scratch.outcome[pos] != ContState::Pending {
                continue;
            }
            let su = scratch.setup_cfaults[pos];
            let slot = &mut scratch.cfslots[pos];
            match ev.kind {
                EventKind::ClientCrash => {
                    if !slot.was_cut {
                        slot.was_cut = true;
                        slot.cut_at = ev.time;
                        slot.done_at_cut = ev.time - su.start;
                        slot.upload_leg = su.upload_at.is_finite()
                            && (su.upload_at - ev.time) <= su.tail;
                        let resumable = su.fault_resume.is_finite()
                            && (!slot.upload_leg || retry_max >= 1);
                        if resumable {
                            slot.waiting = true;
                            q.schedule(Event {
                                time: su.fault_resume,
                                client: Some(k),
                                kind: EventKind::ComeOnline,
                            });
                        } else {
                            scratch.outcome[pos] = ContState::Crashed;
                        }
                    }
                }
                EventKind::ComeOnline => {
                    if slot.waiting {
                        slot.waiting = false;
                        if slot.upload_leg {
                            // Bounded retry: the upload restarts whole
                            // after backoff.
                            telemetry::count(telemetry::Counter::Retries, 1);
                            if lc {
                                lifecycle::emit(
                                    ClientEvent::new(t, k, LcEvent::Retry, ev.time)
                                        .phase(FaultLeg::Upload.name()),
                                );
                            }
                            if let Some(b) = payload {
                                retx_up += b;
                            }
                            slot.expect = ev.time + fr.backoff(1) + su.tail;
                        } else {
                            // Training resumes: remaining work shifted
                            // by the downtime, no penalty.
                            slot.expect = ev.time + (su.upload_at - slot.cut_at);
                        }
                        q.schedule(Event {
                            time: slot.expect,
                            client: Some(k),
                            kind: EventKind::UploadDone,
                        });
                    }
                }
                EventKind::UploadDone => {
                    if !slot.waiting && ev.time == slot.expect {
                        scratch.outcome[pos] = ContState::Arrived;
                        if lc {
                            lifecycle::emit(ClientEvent::new(t, k, LcEvent::Upload, ev.time));
                        }
                        scratch.arrivals.push((
                            pos,
                            Arrival {
                                client: k,
                                time: ev.time,
                            },
                        ));
                    }
                }
                EventKind::GoOffline => {
                    // Churn pause stays hard (legacy semantics); any
                    // fault-cut credit already banked still applies.
                    scratch.outcome[pos] = ContState::Crashed;
                }
                _ => {}
            }
        }
        drop(pop_span);

        sort_arrivals_into(&mut scratch.arrivals, &mut out.arrivals);
        for (pos, &k) in participants.iter().enumerate() {
            let slot = scratch.cfslots[pos];
            let outcome = match scratch.outcome[pos] {
                // Still pending at the deadline: a job that spans
                // rounds is a straggler — unless it started late or was
                // cut (its retry/resume missed T_lim), which count as
                // paused-for-the-round.
                ContState::Pending => {
                    if scratch.late_start[pos] || slot.was_cut {
                        ContState::Crashed
                    } else {
                        ContState::Straggler
                    }
                }
                o => o,
            };
            match outcome {
                ContState::Crashed => {
                    if lc {
                        let mut ev = ClientEvent::new(t, k, LcEvent::Crashed, t_lim)
                            .reason("crash");
                        if slot.was_cut {
                            ev = ev.phase(if slot.upload_leg {
                                FaultLeg::Upload.name()
                            } else {
                                FaultLeg::Train.name()
                            });
                        }
                        lifecycle::emit(ev);
                    }
                    out.crashed.push(k);
                    if slot.was_cut {
                        // Partial-progress credit: the work done before
                        // the cut persists on the device.
                        out.crash_info.push((k, slot.done_at_cut));
                        if slot.upload_leg {
                            out.upload_crashed += 1;
                        }
                    }
                }
                ContState::Straggler => out.stragglers.push(k),
                _ => {}
            }
        }
        out.online_time = online_time;
        out.offline_time = p as f64 * t_lim - online_time;
        out.retx_bytes_up = retx_up;
    }
}

/// Order arrivals by (time, participant position) — identical to the
/// legacy stable sort of a participant-ordered vector (positions are
/// distinct, so the unstable in-place sort is total and allocation-free)
/// — and append them to `out`.
fn sort_arrivals_into(tmp: &mut [(usize, Arrival)], out: &mut Vec<Arrival>) {
    tmp.sort_unstable_by(|a, b| {
        a.1.time
            .partial_cmp(&b.1.time)
            .unwrap()
            .then(a.0.cmp(&b.0))
    });
    out.extend(tmp.iter().map(|&(_, a)| a));
}
