//! The fleet engine: event-driven execution of one federated round.
//!
//! Every participant's round is a chain of typed events on one virtual
//! clock — `DownloadDone → TrainDone → UploadDone` for fresh jobs
//! ([`FleetEngine::run_round`]) or a single resumed `UploadDone` for
//! SAFA's in-flight jobs ([`FleetEngine::run_continuation`]) — preempted
//! by `GoOffline` / `ComeOnline` churn events and closed by the
//! `RoundDeadline`. Outputs are the same [`RoundSim`] / [`ContinuationSim`]
//! records the protocols already consume.
//!
//! # Equivalence guarantee
//!
//! Under [`AvailabilityModel::BernoulliPerRound`] the engine consumes the
//! per-(round, client) RNG streams in exactly the legacy order (crash
//! draw, then crash-partial draw) and accumulates finish times with the
//! same operation order, so arrivals, times and failure sets are
//! **bit-for-bit identical** to the seed implementation (asserted by the
//! property and preset tests in this module).
//!
//! # Churn semantics (Markov / trace models)
//!
//! * A client offline at round start that never recovers is a `Crash`
//!   failure with zero partial progress (it never trained).
//! * A mid-round `GoOffline` before the upload lands is a `Crash` with
//!   partial progress equal to the fraction of the job done at the drop.
//!   In continuation mode the paused job conservatively keeps its full
//!   remaining time (progress in a partially-online round is dropped).
//! * A `ComeOnline` recovery lets the client start (or resume) late; jobs
//!   that still fit before `T_lim` commit. A late starter that misses the
//!   deadline is an `Overtime` failure in [`FleetEngine::run_round`]
//!   (fresh jobs are round-scoped), while in
//!   [`FleetEngine::run_continuation`] it counts as crashed-for-the-round
//!   rather than a straggler, because the client was not online for the
//!   round's full span.
//! * Ties between a drop and an upload at the same instant resolve in
//!   favour of the drop (the crash event is scheduled first).

use super::availability::{AvailabilityModel, ClientWindow};
use super::event::{Event, EventKind, EventQueue};
use crate::client::ClientState;
use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::net::NetworkModel;
use crate::sim::{Arrival, ContinuationSim, FailReason, RoundSim};
use crate::util::rng::Pcg64;

/// Shared references a [`FleetEngine::run_round`] call needs (bundled to
/// keep the call site readable and the argument list short).
pub struct RoundCtx<'a> {
    pub cfg: &'a ExperimentConfig,
    pub net: &'a NetworkModel,
    pub clients: &'a [ClientState],
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Offline, waiting for a `ComeOnline` recovery.
    Idle,
    /// Online and working through its event chain.
    Active,
    Done,
    Failed,
}

struct Slot {
    /// When this participant's job (re)starts (0.0, or the recovery time).
    start: f64,
    /// Full job duration from `start` (download + train + upload).
    duration: f64,
    phase: Phase,
    synced: bool,
}

/// Discrete-event simulator for a fleet of clients under an availability
/// model. One engine instance should drive all rounds of a run so that
/// Markov churn state persists across rounds; the availability draws use
/// per-(round, client) streams, so patterns are identical across
/// protocols for the same seed regardless of which protocol runs.
pub struct FleetEngine {
    avail: AvailabilityModel,
    /// Fleet size. Windows are drawn for the *whole* fleet every round so
    /// Markov state advances identically no matter which subset a
    /// protocol selects.
    m: usize,
    /// Persisted per-client on/off state (Markov churn).
    churn_state: Vec<Option<bool>>,
}

impl FleetEngine {
    pub fn new(avail: AvailabilityModel, m: usize) -> FleetEngine {
        FleetEngine {
            avail,
            m,
            churn_state: vec![None; m],
        }
    }

    /// Build from the experiment config (`env.churn`); loads the trace
    /// file for trace replay.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<FleetEngine> {
        Ok(FleetEngine::new(
            AvailabilityModel::from_env(&cfg.env)?,
            cfg.env.m,
        ))
    }

    pub fn availability(&self) -> &AvailabilityModel {
        &self.avail
    }

    fn ensure_fleet(&mut self, m: usize) {
        if m > self.m {
            self.m = m;
            self.churn_state.resize(m, None);
        }
    }

    /// Draw this round's availability windows, returning each drawn
    /// client's window plus its RNG stream positioned after the
    /// availability draw (the Bernoulli crash-partial draw continues
    /// from there, exactly like the legacy simulator).
    ///
    /// Markov churn advances the *whole* fleet so the on/off pattern is
    /// identical no matter which subset a protocol selects; the
    /// stateless models (Bernoulli, trace) draw participants only —
    /// per-client streams are independent splits, so skipping
    /// non-participants changes nothing they observe.
    fn begin_round(
        &mut self,
        t: usize,
        horizon: f64,
        round_rng: &Pcg64,
        participants: &[usize],
    ) -> Vec<Option<(ClientWindow, Pcg64)>> {
        let mut windows: Vec<Option<(ClientWindow, Pcg64)>> = vec![None; self.m];
        if matches!(self.avail, AvailabilityModel::Markov { .. }) {
            for k in 0..self.m {
                windows[k] = Some(self.draw_window(k, t, horizon, round_rng));
            }
        } else {
            for &k in participants {
                if windows[k].is_none() {
                    windows[k] = Some(self.draw_window(k, t, horizon, round_rng));
                }
            }
        }
        windows
    }

    /// Draw one client's window on its per-(round, client) stream,
    /// returning the stream positioned after the availability draw.
    fn draw_window(
        &mut self,
        k: usize,
        t: usize,
        horizon: f64,
        round_rng: &Pcg64,
    ) -> (ClientWindow, Pcg64) {
        let mut crng = round_rng.split(k as u64);
        let w = self
            .avail
            .window(&mut self.churn_state[k], &mut crng, t, k, horizon);
        (w, crng)
    }

    /// The paper's crash probability is late-bound in the legacy
    /// simulator (read from the config at every call); keep that
    /// contract so tests and sweeps may adjust `cfg.env.crash_prob`
    /// between rounds.
    fn refresh_bernoulli(&mut self, cfg: &ExperimentConfig) {
        if let AvailabilityModel::BernoulliPerRound { crash_prob } = &mut self.avail {
            *crash_prob = cfg.env.crash_prob;
        }
    }

    /// Simulate the training phase of round `t` where every participant
    /// starts a fresh job (FedAvg / FedCS / fully-local semantics, and
    /// SAFA's forced syncs). Drop-in replacement for the seed's
    /// `simulate_round` loop. Participant ids must be distinct (events
    /// route per client, so a duplicate has no well-defined outcome).
    pub fn run_round(
        &mut self,
        t: usize,
        ctx: RoundCtx<'_>,
        participants: &[usize],
        synced: &[bool],
        round_rng: &Pcg64,
    ) -> RoundSim {
        assert_eq!(participants.len(), synced.len());
        let t_lim = ctx.cfg.train.t_lim;
        let epochs = ctx.cfg.train.epochs;
        self.refresh_bernoulli(ctx.cfg);
        self.ensure_fleet(ctx.clients.len());
        let mut windows = self.begin_round(t, t_lim, round_rng, participants);

        let mut q = EventQueue::new();
        let mut slots: Vec<Slot> = Vec::with_capacity(participants.len());
        let mut pos_of: Vec<Option<usize>> = vec![None; self.m];
        let mut failures: Vec<Option<(FailReason, f64)>> = vec![None; participants.len()];
        let mut arrivals: Vec<(usize, Arrival)> = Vec::new();
        let mut online_time = 0.0;
        let mut last_drop = 0.0f64;

        for (pos, (&k, &was_synced)) in participants.iter().zip(synced).enumerate() {
            assert!(pos_of[k].is_none(), "duplicate participant {k}");
            let (w, mut crng) = windows[k].take().expect("window drawn for participant");
            online_time += w.online_seconds(t_lim);
            pos_of[k] = Some(pos);
            let t_train = ctx.clients[k].t_train(epochs);
            let duration = if was_synced { ctx.net.t_down() } else { 0.0 } + t_train + ctx.net.t_up();
            if w.online_at_start {
                slots.push(Slot {
                    start: 0.0,
                    duration,
                    phase: Phase::Active,
                    synced: was_synced,
                });
                // Crash first so an exact drop/upload tie favours the drop.
                if let Some(off) = w.goes_offline_at {
                    q.schedule(Event {
                        time: off,
                        client: Some(k),
                        kind: EventKind::GoOffline,
                    });
                }
                let head = if was_synced {
                    Event {
                        time: ctx.net.t_down(),
                        client: Some(k),
                        kind: EventKind::DownloadDone,
                    }
                } else {
                    Event {
                        time: t_train,
                        client: Some(k),
                        kind: EventKind::TrainDone,
                    }
                };
                q.schedule(head);
            } else if let Some(on) = w.comes_online_at {
                slots.push(Slot {
                    start: on,
                    duration,
                    phase: Phase::Idle,
                    synced: was_synced,
                });
                q.schedule(Event {
                    time: on,
                    client: Some(k),
                    kind: EventKind::ComeOnline,
                });
            } else {
                // Offline for the whole round. Under Bernoulli this is
                // the paper's crash: the device trained into the round
                // and dropped uniformly through its work (legacy second
                // draw); under churn models it never started.
                let partial = if self.avail.is_bernoulli() {
                    crng.next_f64()
                } else {
                    0.0
                };
                slots.push(Slot {
                    start: 0.0,
                    duration,
                    phase: Phase::Failed,
                    synced: was_synced,
                });
                failures[pos] = Some((FailReason::Crash, partial));
            }
        }
        q.schedule_deadline(Event {
            time: t_lim,
            client: None,
            kind: EventKind::RoundDeadline,
        });

        while let Some(ev) = q.pop() {
            if ev.kind == EventKind::RoundDeadline {
                break;
            }
            let k = ev.client.expect("client event without a client");
            let pos = pos_of[k].expect("event for a non-participant");
            let slot = &mut slots[pos];
            match ev.kind {
                EventKind::ComeOnline => {
                    if slot.phase == Phase::Idle {
                        slot.phase = Phase::Active;
                        let t_train = ctx.clients[k].t_train(epochs);
                        let head = if slot.synced {
                            Event {
                                time: ev.time + ctx.net.t_down(),
                                client: Some(k),
                                kind: EventKind::DownloadDone,
                            }
                        } else {
                            Event {
                                time: ev.time + t_train,
                                client: Some(k),
                                kind: EventKind::TrainDone,
                            }
                        };
                        q.schedule(head);
                    }
                }
                EventKind::DownloadDone => {
                    if slot.phase == Phase::Active {
                        q.schedule(Event {
                            time: ev.time + ctx.clients[k].t_train(epochs),
                            client: Some(k),
                            kind: EventKind::TrainDone,
                        });
                    }
                }
                EventKind::TrainDone => {
                    if slot.phase == Phase::Active {
                        q.schedule(Event {
                            time: ev.time + ctx.net.t_up(),
                            client: Some(k),
                            kind: EventKind::UploadDone,
                        });
                    }
                }
                EventKind::UploadDone => {
                    if slot.phase == Phase::Active {
                        slot.phase = Phase::Done;
                        arrivals.push((
                            pos,
                            Arrival {
                                client: k,
                                time: ev.time,
                            },
                        ));
                    }
                }
                EventKind::GoOffline => {
                    // Only Active slots can drop: a window carries at
                    // most one transition, so an Idle (offline-at-start)
                    // client never schedules a GoOffline.
                    if slot.phase == Phase::Active {
                        slot.phase = Phase::Failed;
                        let done = ((ev.time - slot.start) / slot.duration).clamp(0.0, 1.0);
                        failures[pos] = Some((FailReason::Crash, done));
                        last_drop = last_drop.max(ev.time);
                    }
                }
                EventKind::RoundDeadline => unreachable!(),
            }
        }

        // Deadline: anyone still working goes overtime (the paper counts
        // them as crashed too, §III-B), credited with the fraction of the
        // job done by T_lim.
        for (pos, slot) in slots.iter().enumerate() {
            if matches!(slot.phase, Phase::Active | Phase::Idle) {
                let partial = ((t_lim - slot.start) / slot.duration).clamp(0.0, 1.0);
                failures[pos] = Some((FailReason::Overtime, partial));
            }
        }

        RoundSim {
            arrivals: sort_arrivals(arrivals),
            failures: participants
                .iter()
                .enumerate()
                .filter_map(|(pos, &k)| failures[pos].map(|(r, p)| (k, r, p)))
                .collect(),
            online_time,
            offline_time: participants.len() as f64 * t_lim - online_time,
            last_drop,
        }
    }

    /// Simulate one round over in-flight jobs (SAFA / FedAsync
    /// continuation semantics): `jobs[i]` is the remaining work for
    /// `participants[i]`. Drop-in replacement for the seed's
    /// `simulate_continuation` loop. Participant ids must be distinct.
    pub fn run_continuation(
        &mut self,
        t: usize,
        cfg: &ExperimentConfig,
        participants: &[usize],
        jobs: &[f64],
        round_rng: &Pcg64,
    ) -> ContinuationSim {
        assert_eq!(participants.len(), jobs.len());
        let t_lim = cfg.train.t_lim;
        self.refresh_bernoulli(cfg);
        let fleet = participants.iter().copied().max().map_or(0, |k| k + 1);
        self.ensure_fleet(fleet);
        let mut windows = self.begin_round(t, t_lim, round_rng, participants);

        #[derive(Clone, Copy, PartialEq, Eq)]
        enum Outcome {
            Pending,
            Arrived,
            Crashed,
            Straggler,
        }
        let mut q = EventQueue::new();
        let mut outcome = vec![Outcome::Pending; participants.len()];
        let mut late_start = vec![false; participants.len()];
        let mut pos_of: Vec<Option<usize>> = vec![None; self.m];
        let mut arrivals: Vec<(usize, Arrival)> = Vec::new();
        let mut online_time = 0.0;

        for (pos, (&k, &remaining)) in participants.iter().zip(jobs).enumerate() {
            assert!(pos_of[k].is_none(), "duplicate participant {k}");
            let (w, _) = windows[k].take().expect("window drawn for participant");
            online_time += w.online_seconds(t_lim);
            pos_of[k] = Some(pos);
            if w.online_at_start {
                // Crash first so an exact drop/upload tie favours the drop.
                if let Some(off) = w.goes_offline_at {
                    q.schedule(Event {
                        time: off,
                        client: Some(k),
                        kind: EventKind::GoOffline,
                    });
                }
                if remaining.is_finite() {
                    q.schedule(Event {
                        time: remaining,
                        client: Some(k),
                        kind: EventKind::UploadDone,
                    });
                }
            } else if let Some(on) = w.comes_online_at {
                late_start[pos] = true;
                if remaining.is_finite() {
                    q.schedule(Event {
                        time: on + remaining,
                        client: Some(k),
                        kind: EventKind::UploadDone,
                    });
                }
            } else {
                outcome[pos] = Outcome::Crashed;
            }
        }
        q.schedule_deadline(Event {
            time: t_lim,
            client: None,
            kind: EventKind::RoundDeadline,
        });

        while let Some(ev) = q.pop() {
            if ev.kind == EventKind::RoundDeadline {
                break;
            }
            let k = ev.client.expect("client event without a client");
            let pos = pos_of[k].expect("event for a non-participant");
            match ev.kind {
                EventKind::UploadDone => {
                    if outcome[pos] == Outcome::Pending {
                        outcome[pos] = Outcome::Arrived;
                        arrivals.push((
                            pos,
                            Arrival {
                                client: k,
                                time: ev.time,
                            },
                        ));
                    }
                }
                EventKind::GoOffline => {
                    if outcome[pos] == Outcome::Pending {
                        // The job pauses; this round's partial progress is
                        // conservatively dropped (see module docs).
                        outcome[pos] = Outcome::Crashed;
                    }
                }
                _ => {}
            }
        }
        for (pos, o) in outcome.iter_mut().enumerate() {
            if *o == Outcome::Pending {
                // Online through the deadline but the job spans rounds:
                // a straggler — unless it started late, in which case it
                // counts as paused for this round.
                *o = if late_start[pos] {
                    Outcome::Crashed
                } else {
                    Outcome::Straggler
                };
            }
        }

        ContinuationSim {
            arrivals: sort_arrivals(arrivals),
            crashed: participants
                .iter()
                .enumerate()
                .filter(|&(pos, _)| outcome[pos] == Outcome::Crashed)
                .map(|(_, &k)| k)
                .collect(),
            stragglers: participants
                .iter()
                .enumerate()
                .filter(|&(pos, _)| outcome[pos] == Outcome::Straggler)
                .map(|(_, &k)| k)
                .collect(),
            online_time,
            offline_time: participants.len() as f64 * t_lim - online_time,
        }
    }
}

/// Order arrivals by (time, participant position) — identical to the
/// legacy stable sort of a participant-ordered vector.
fn sort_arrivals(mut arrivals: Vec<(usize, Arrival)>) -> Vec<Arrival> {
    arrivals.sort_by(|a, b| {
        a.1.time
            .partial_cmp(&b.1.time)
            .unwrap()
            .then(a.0.cmp(&b.0))
    });
    arrivals.into_iter().map(|(_, a)| a).collect()
}
