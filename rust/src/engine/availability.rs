//! Pluggable client-availability models for the fleet engine.
//!
//! Three models, all driven by the per-(round, client) RNG streams the
//! legacy simulator already uses (`round_rng.split(k)`), so availability
//! patterns are identical across protocols for the same experiment seed:
//!
//! * [`AvailabilityModel::BernoulliPerRound`] — the paper's §IV-A model:
//!   one i.i.d. Bernoulli(cr) draw per (round, client); an offline client
//!   is offline for the whole round. Consumes exactly one draw per
//!   client, which is what makes the engine bit-for-bit equivalent to the
//!   seed implementation.
//! * [`AvailabilityModel::Markov`] — a two-state on/off process with
//!   exponential dwell times (seconds). State persists across rounds (a
//!   client that flaps off stays off until its recovery fires); at most
//!   one transition is sampled per round window, which yields the
//!   `GoOffline` / `ComeOnline` mid-round events. Like the paper's
//!   Bernoulli model, churn is **round-indexed**: every round draws one
//!   window over `[0, T_lim]` and advances the on/off state by one
//!   window, regardless of how early the protocol closes the round.
//!   Dwell times therefore shape *where in the window* transitions land,
//!   not a wall-clock rate across protocols with different round
//!   lengths — which is what keeps the (round, client) availability
//!   pattern identical across protocols for a given seed, the property
//!   every cross-protocol comparison in the paper relies on.
//! * [`AvailabilityModel::Trace`] — deterministic replay of a recorded
//!   online/offline matrix (round-major), loaded from a file named in the
//!   config; traces shorter than the run cycle.

use crate::config::{ChurnModel, EnvConfig};
use crate::error::{Result, SafaError};
use crate::util::rng::{Bernoulli, Distribution, Exponential, Pcg64};

/// A client's availability over one round window `[0, horizon]`.
///
/// At most one transition per window: either the client starts online and
/// possibly drops at `goes_offline_at`, or it starts offline and possibly
/// recovers at `comes_online_at`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientWindow {
    pub online_at_start: bool,
    /// Mid-round drop time (seconds from round start), strictly inside
    /// the window when present.
    pub goes_offline_at: Option<f64>,
    /// Mid-round recovery time, strictly inside the window when present.
    pub comes_online_at: Option<f64>,
}

impl ClientWindow {
    pub const ALWAYS_ON: ClientWindow = ClientWindow {
        online_at_start: true,
        goes_offline_at: None,
        comes_online_at: None,
    };

    /// Seconds spent online within `[0, horizon]`.
    pub fn online_seconds(&self, horizon: f64) -> f64 {
        if self.online_at_start {
            self.goes_offline_at.unwrap_or(horizon).min(horizon)
        } else {
            match self.comes_online_at {
                Some(t) => (horizon - t).max(0.0),
                None => 0.0,
            }
        }
    }
}

/// Which availability process governs the fleet.
#[derive(Debug, Clone)]
pub enum AvailabilityModel {
    /// Paper parity: i.i.d. per-round crash draws.
    BernoulliPerRound { crash_prob: f64 },
    /// Two-state on/off churn with exponential dwell times (seconds).
    Markov {
        mean_uptime_s: f64,
        mean_downtime_s: f64,
    },
    /// Deterministic replay: `rounds[r][k]` = client `k` online in round
    /// `r+1`. Cycles when the run is longer than the trace.
    Trace { rounds: Vec<Vec<bool>> },
}

impl AvailabilityModel {
    /// Build the model named by the environment config (loads the trace
    /// file for [`ChurnModel::Trace`]).
    pub fn from_env(env: &EnvConfig) -> Result<AvailabilityModel> {
        match &env.churn {
            ChurnModel::Bernoulli => Ok(AvailabilityModel::BernoulliPerRound {
                crash_prob: env.crash_prob,
            }),
            ChurnModel::Markov {
                mean_uptime_s,
                mean_downtime_s,
            } => Ok(AvailabilityModel::Markov {
                mean_uptime_s: *mean_uptime_s,
                mean_downtime_s: *mean_downtime_s,
            }),
            ChurnModel::Trace { path } => {
                let text = std::fs::read_to_string(path).map_err(|e| {
                    SafaError::Config(format!("cannot read churn trace '{path}': {e}"))
                })?;
                Ok(AvailabilityModel::Trace {
                    rounds: parse_trace(&text)?,
                })
            }
        }
    }

    pub fn is_bernoulli(&self) -> bool {
        matches!(self, AvailabilityModel::BernoulliPerRound { .. })
    }

    /// True when the model never produces mid-round transitions (every
    /// window is whole-round online or whole-round offline) and carries
    /// no cross-round state. For such models the engine skips the event
    /// queue entirely: each participant's outcome is independent, so the
    /// round computes as a parallel per-client map — bit-for-bit equal
    /// to the event path (and to the seed loop it reproduces).
    pub fn is_event_free(&self) -> bool {
        matches!(
            self,
            AvailabilityModel::BernoulliPerRound { .. } | AvailabilityModel::Trace { .. }
        )
    }

    /// Draw client `k`'s window for round `t` (1-based).
    ///
    /// `persisted` carries the client's on/off state across rounds
    /// (Markov only); `crng` must be the per-(round, client) stream
    /// `round_rng.split(k)` so patterns match the legacy simulator.
    pub fn window(
        &self,
        persisted: &mut Option<bool>,
        crng: &mut Pcg64,
        t: usize,
        client: usize,
        horizon: f64,
    ) -> ClientWindow {
        match self {
            AvailabilityModel::BernoulliPerRound { crash_prob } => {
                let offline = Bernoulli::new(*crash_prob).draw(crng);
                ClientWindow {
                    online_at_start: !offline,
                    goes_offline_at: None,
                    comes_online_at: None,
                }
            }
            AvailabilityModel::Markov {
                mean_uptime_s,
                mean_downtime_s,
            } => {
                let stationary_up = mean_uptime_s / (mean_uptime_s + mean_downtime_s);
                let online = *persisted.get_or_insert_with(|| crng.next_f64() < stationary_up);
                if online {
                    let dwell = Exponential::new(1.0 / mean_uptime_s).sample(crng);
                    if dwell < horizon {
                        *persisted = Some(false);
                        ClientWindow {
                            online_at_start: true,
                            goes_offline_at: Some(dwell),
                            comes_online_at: None,
                        }
                    } else {
                        *persisted = Some(true);
                        ClientWindow::ALWAYS_ON
                    }
                } else {
                    let wake = Exponential::new(1.0 / mean_downtime_s).sample(crng);
                    if wake < horizon {
                        *persisted = Some(true);
                        ClientWindow {
                            online_at_start: false,
                            goes_offline_at: None,
                            comes_online_at: Some(wake),
                        }
                    } else {
                        *persisted = Some(false);
                        ClientWindow {
                            online_at_start: false,
                            goes_offline_at: None,
                            comes_online_at: None,
                        }
                    }
                }
            }
            AvailabilityModel::Trace { rounds } => {
                if rounds.is_empty() {
                    return ClientWindow::ALWAYS_ON;
                }
                let row = &rounds[t.saturating_sub(1) % rounds.len()];
                let online = row.get(client).copied().unwrap_or(true);
                ClientWindow {
                    online_at_start: online,
                    goes_offline_at: None,
                    comes_online_at: None,
                }
            }
        }
    }
}

/// Parse a trace: one line per round, one `0`/`1` character per client
/// (whitespace and blank lines ignored).
pub fn parse_trace(text: &str) -> Result<Vec<Vec<bool>>> {
    let mut rounds = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut row = Vec::with_capacity(line.len());
        for c in line.chars() {
            match c {
                '1' => row.push(true),
                '0' => row.push(false),
                c if c.is_whitespace() => {}
                other => {
                    return Err(SafaError::Config(format!(
                        "churn trace line {}: unexpected character '{other}'",
                        lineno + 1
                    )))
                }
            }
        }
        rounds.push(row);
    }
    if rounds.is_empty() {
        return Err(SafaError::Config("churn trace is empty".into()));
    }
    Ok(rounds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bernoulli_consumes_one_draw_and_matches_rate() {
        let model = AvailabilityModel::BernoulliPerRound { crash_prob: 0.3 };
        let mut offline = 0;
        let n = 20_000;
        for k in 0..n {
            let mut crng = Pcg64::new(77).split(k);
            let mut state = None;
            let w = model.window(&mut state, &mut crng, 1, k as usize, 830.0);
            assert_eq!(w.goes_offline_at, None);
            assert_eq!(w.comes_online_at, None);
            if !w.online_at_start {
                offline += 1;
            }
            // The next value must be the stream's second output (the
            // engine uses it for the legacy crash-partial draw).
            let mut fresh = Pcg64::new(77).split(k);
            fresh.next_f64();
            assert_eq!(crng.next_f64(), fresh.next_f64());
        }
        let rate = offline as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.02, "offline rate {rate}");
    }

    #[test]
    fn markov_state_persists_across_rounds() {
        let model = AvailabilityModel::Markov {
            mean_uptime_s: 400.0,
            mean_downtime_s: 200.0,
        };
        // A client that drops mid-round must start the next round offline.
        let root = Pcg64::new(5);
        let mut found = false;
        for k in 0..200u64 {
            let mut state = None;
            let w1 = model.window(&mut state, &mut root.split(k), 1, k as usize, 830.0);
            if w1.online_at_start && w1.goes_offline_at.is_some() {
                assert_eq!(state, Some(false));
                let w2 =
                    model.window(&mut state, &mut root.split(1000 + k), 2, k as usize, 830.0);
                assert!(!w2.online_at_start, "dropped client must start round 2 offline");
                found = true;
                break;
            }
        }
        assert!(found, "no mid-round drop sampled in 200 clients");
    }

    #[test]
    fn markov_windows_are_deterministic_per_stream() {
        let model = AvailabilityModel::Markov {
            mean_uptime_s: 300.0,
            mean_downtime_s: 100.0,
        };
        for k in 0..50u64 {
            let (mut s1, mut s2) = (None, None);
            let a = model.window(&mut s1, &mut Pcg64::new(9).split(k), 1, k as usize, 830.0);
            let b = model.window(&mut s2, &mut Pcg64::new(9).split(k), 1, k as usize, 830.0);
            assert_eq!(a, b);
            assert_eq!(s1, s2);
        }
    }

    #[test]
    fn trace_replays_and_cycles() {
        let rounds = parse_trace("101\n010\n").unwrap();
        let model = AvailabilityModel::Trace { rounds };
        let mut crng = Pcg64::new(1);
        let mut state = None;
        // Round 1 = "101".
        assert!(model.window(&mut state, &mut crng, 1, 0, 10.0).online_at_start);
        assert!(!model.window(&mut state, &mut crng, 1, 1, 10.0).online_at_start);
        assert!(model.window(&mut state, &mut crng, 1, 2, 10.0).online_at_start);
        // Clients beyond the row default to online.
        assert!(model.window(&mut state, &mut crng, 1, 9, 10.0).online_at_start);
        // Round 3 cycles back to "101".
        assert!(!model.window(&mut state, &mut crng, 3, 1, 10.0).online_at_start);
    }

    #[test]
    fn trace_parser_rejects_garbage() {
        assert!(parse_trace("10x1").is_err());
        assert!(parse_trace("").is_err());
        assert!(parse_trace("\n  \n").is_err());
        assert_eq!(parse_trace(" 1 0 \n11\n").unwrap(), vec![
            vec![true, false],
            vec![true, true]
        ]);
    }

    #[test]
    fn online_seconds_accounting() {
        let w = ClientWindow::ALWAYS_ON;
        assert_eq!(w.online_seconds(100.0), 100.0);
        let w = ClientWindow {
            online_at_start: true,
            goes_offline_at: Some(30.0),
            comes_online_at: None,
        };
        assert_eq!(w.online_seconds(100.0), 30.0);
        let w = ClientWindow {
            online_at_start: false,
            goes_offline_at: None,
            comes_online_at: Some(70.0),
        };
        assert_eq!(w.online_seconds(100.0), 30.0);
        let w = ClientWindow {
            online_at_start: false,
            goes_offline_at: None,
            comes_online_at: None,
        };
        assert_eq!(w.online_seconds(100.0), 0.0);
    }
}
