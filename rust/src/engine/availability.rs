//! Pluggable client-availability models for the fleet engine.
//!
//! Three models, all driven by the per-(round, client) RNG streams the
//! legacy simulator already uses (`round_rng.split(k)`), so availability
//! patterns are identical across protocols for the same experiment seed:
//!
//! * [`AvailabilityModel::BernoulliPerRound`] — the paper's §IV-A model:
//!   one i.i.d. Bernoulli(cr) draw per (round, client); an offline client
//!   is offline for the whole round. Consumes exactly one draw per
//!   client, which is what makes the engine bit-for-bit equivalent to the
//!   seed implementation.
//! * [`AvailabilityModel::Markov`] — a two-state on/off process with
//!   exponential dwell times (seconds). State persists across rounds (a
//!   client that flaps off stays off until its recovery fires); at most
//!   one transition is sampled per round window, which yields the
//!   `GoOffline` / `ComeOnline` mid-round events. Like the paper's
//!   Bernoulli model, churn is **round-indexed**: every round draws one
//!   window over `[0, T_lim]` and advances the on/off state by one
//!   window, regardless of how early the protocol closes the round.
//!   Dwell times therefore shape *where in the window* transitions land,
//!   not a wall-clock rate across protocols with different round
//!   lengths — which is what keeps the (round, client) availability
//!   pattern identical across protocols for a given seed, the property
//!   every cross-protocol comparison in the paper relies on.
//! * [`AvailabilityModel::Trace`] — deterministic replay of a recorded
//!   online/offline matrix (round-major), loaded from a file named in the
//!   config; traces shorter than the run cycle.

use crate::config::{ChurnModel, EnvConfig};
use crate::error::{Result, SafaError};
use crate::scenario::{ScenarioEventKind, ScenarioSpec};
use crate::util::parallel;
use crate::util::rng::{Bernoulli, Distribution, Exponential, Pcg64};

/// A client's availability over one round window `[0, horizon]`.
///
/// The legacy models produce at most one transition per window: either
/// the client starts online and possibly drops at `goes_offline_at`, or
/// it starts offline and possibly recovers at `comes_online_at`. The
/// continuous [`ScenarioTimeline`] additionally produces the two-
/// transition offline-start shape (recover at `comes_online_at`, drop
/// again at `goes_offline_at` with `comes < goes`); further in-window
/// flips are folded into these two for job scheduling (the timeline's
/// cross-round cursor still walks every flip exactly).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientWindow {
    pub online_at_start: bool,
    /// Mid-round drop time (seconds from round start), strictly inside
    /// the window when present.
    pub goes_offline_at: Option<f64>,
    /// Mid-round recovery time, strictly inside the window when present.
    pub comes_online_at: Option<f64>,
}

impl ClientWindow {
    pub const ALWAYS_ON: ClientWindow = ClientWindow {
        online_at_start: true,
        goes_offline_at: None,
        comes_online_at: None,
    };

    pub const ALWAYS_OFF: ClientWindow = ClientWindow {
        online_at_start: false,
        goes_offline_at: None,
        comes_online_at: None,
    };

    /// Seconds spent online within `[0, horizon]`.
    pub fn online_seconds(&self, horizon: f64) -> f64 {
        if self.online_at_start {
            self.goes_offline_at.unwrap_or(horizon).min(horizon)
        } else {
            match (self.comes_online_at, self.goes_offline_at) {
                // Recover-then-drop (scenario timeline only).
                (Some(on), Some(off)) => (off.min(horizon) - on).max(0.0),
                (Some(on), None) => (horizon - on).max(0.0),
                (None, _) => 0.0,
            }
        }
    }
}

/// Which availability process governs the fleet.
#[derive(Debug, Clone)]
pub enum AvailabilityModel {
    /// Paper parity: i.i.d. per-round crash draws.
    BernoulliPerRound { crash_prob: f64 },
    /// Two-state on/off churn with exponential dwell times (seconds).
    Markov {
        mean_uptime_s: f64,
        mean_downtime_s: f64,
    },
    /// Deterministic replay: `rounds[r][k]` = client `k` online in round
    /// `r+1`. Cycles when the run is longer than the trace.
    Trace { rounds: Vec<Vec<bool>> },
}

impl AvailabilityModel {
    /// Build the model named by the environment config (loads the trace
    /// file for [`ChurnModel::Trace`]).
    pub fn from_env(env: &EnvConfig) -> Result<AvailabilityModel> {
        match &env.churn {
            ChurnModel::Bernoulli => Ok(AvailabilityModel::BernoulliPerRound {
                crash_prob: env.crash_prob,
            }),
            ChurnModel::Markov {
                mean_uptime_s,
                mean_downtime_s,
            } => Ok(AvailabilityModel::Markov {
                mean_uptime_s: *mean_uptime_s,
                mean_downtime_s: *mean_downtime_s,
            }),
            ChurnModel::Trace { path } => {
                let text = std::fs::read_to_string(path).map_err(|e| {
                    SafaError::Config(format!("cannot read churn trace '{path}': {e}"))
                })?;
                Ok(AvailabilityModel::Trace {
                    rounds: parse_trace(&text)?,
                })
            }
        }
    }

    pub fn is_bernoulli(&self) -> bool {
        matches!(self, AvailabilityModel::BernoulliPerRound { .. })
    }

    /// True when the model never produces mid-round transitions (every
    /// window is whole-round online or whole-round offline) and carries
    /// no cross-round state. For such models the engine skips the event
    /// queue entirely: each participant's outcome is independent, so the
    /// round computes as a parallel per-client map — bit-for-bit equal
    /// to the event path (and to the seed loop it reproduces).
    pub fn is_event_free(&self) -> bool {
        matches!(
            self,
            AvailabilityModel::BernoulliPerRound { .. } | AvailabilityModel::Trace { .. }
        )
    }

    /// Draw client `k`'s window for round `t` (1-based).
    ///
    /// `persisted` carries the client's on/off state across rounds
    /// (Markov only); `crng` must be the per-(round, client) stream
    /// `round_rng.split(k)` so patterns match the legacy simulator.
    pub fn window(
        &self,
        persisted: &mut Option<bool>,
        crng: &mut Pcg64,
        t: usize,
        client: usize,
        horizon: f64,
    ) -> ClientWindow {
        match self {
            AvailabilityModel::BernoulliPerRound { crash_prob } => {
                let offline = Bernoulli::new(*crash_prob).draw(crng);
                ClientWindow {
                    online_at_start: !offline,
                    goes_offline_at: None,
                    comes_online_at: None,
                }
            }
            AvailabilityModel::Markov {
                mean_uptime_s,
                mean_downtime_s,
            } => {
                let stationary_up = mean_uptime_s / (mean_uptime_s + mean_downtime_s);
                let online = *persisted.get_or_insert_with(|| crng.next_f64() < stationary_up);
                if online {
                    let dwell = Exponential::new(1.0 / mean_uptime_s).sample(crng);
                    if dwell < horizon {
                        *persisted = Some(false);
                        ClientWindow {
                            online_at_start: true,
                            goes_offline_at: Some(dwell),
                            comes_online_at: None,
                        }
                    } else {
                        *persisted = Some(true);
                        ClientWindow::ALWAYS_ON
                    }
                } else {
                    let wake = Exponential::new(1.0 / mean_downtime_s).sample(crng);
                    if wake < horizon {
                        *persisted = Some(true);
                        ClientWindow {
                            online_at_start: false,
                            goes_offline_at: None,
                            comes_online_at: Some(wake),
                        }
                    } else {
                        *persisted = Some(false);
                        ClientWindow {
                            online_at_start: false,
                            goes_offline_at: None,
                            comes_online_at: None,
                        }
                    }
                }
            }
            AvailabilityModel::Trace { rounds } => {
                if rounds.is_empty() {
                    return ClientWindow::ALWAYS_ON;
                }
                let row = &rounds[t.saturating_sub(1) % rounds.len()];
                let online = row.get(client).copied().unwrap_or(true);
                ClientWindow {
                    online_at_start: online,
                    goes_offline_at: None,
                    comes_online_at: None,
                }
            }
        }
    }
}

/// Dedicated RNG stream id for the scenario timeline's dwell draws
/// (disjoint from faults `0xfa17`, round sim `0xc4a5`, selection
/// `0xfeda`, fleet `0xf1ee`, fabric `0xfab_11c`/`0xfab_71c`, ...).
pub const SCENARIO_STREAM: u64 = 0x5ce0;

/// Floor on a sampled dwell (seconds): bounds the flip rate so a round
/// window can never hold an unbounded number of transitions.
const MIN_DWELL_S: f64 = 1.0;
/// Floor on the diurnal modulation factor: dwell means never collapse
/// below 5% of their base.
const DIURNAL_FLOOR: f64 = 0.05;
/// Natural flips recorded per round window for window extraction. The
/// cursor walks *every* flip exactly (cross-round state is never
/// approximated); only the in-window effective-signal sweep caps its
/// edge list, which is ample for any validated dwell configuration.
const MAX_FLIPS: usize = 64;
/// Flip edges + join/leave + outage edges.
const MAX_EDGES: usize = MAX_FLIPS + 8;
/// Per-client chunk grain for the parallel cursor walk (matches the
/// fleet engine's draw grain).
const SCEN_GRAIN: usize = 64;

/// Per-client cursor on the continuous timeline.
#[derive(Debug, Clone, Copy)]
struct ScenCursor {
    /// Natural on/off state (ignoring membership and outages).
    online: bool,
    /// Absolute sim-time of the next natural flip.
    next_flip_s: f64,
    /// Transition index: draw `i` comes from `stream.split(k).split(i)`,
    /// so the walk is a pure function of `(client, index)` — path-
    /// independent, width-invariant and resumable.
    idx: u64,
}

/// Immutable walk parameters, split out of [`ScenarioTimeline`] so the
/// parallel cursor pass can borrow them while the cursors and windows
/// are chunked mutably.
struct ScenParams<'a> {
    stream: &'a Pcg64,
    base_up_s: f64,
    base_down_s: f64,
    amp: f64,
    period_s: f64,
    regions: usize,
    join_at: &'a [f64],
    leave_at: &'a [f64],
    outages: &'a [(usize, f64, f64)],
}

impl ScenParams<'_> {
    /// Sample the next dwell for a client that just flipped to `online`
    /// at absolute time `tau`. Diurnal modulation stretches online
    /// dwells at the sine peak and offline dwells in the trough
    /// (anti-phase), so fleet availability swings over the period.
    fn dwell(&self, rng: &mut Pcg64, online: bool, tau: f64) -> f64 {
        let base = if online { self.base_up_s } else { self.base_down_s };
        let mean = if self.amp > 0.0 {
            let s = (core::f64::consts::TAU * tau / self.period_s).sin();
            let f = if online {
                1.0 + self.amp * s
            } else {
                1.0 - self.amp * s
            };
            base * f.max(DIURNAL_FLOOR)
        } else {
            base
        };
        Exponential::new(1.0 / mean).sample(rng).max(MIN_DWELL_S)
    }

    fn region_of(&self, k: usize) -> usize {
        if self.regions == 0 {
            0
        } else {
            k % self.regions
        }
    }
}

/// Walk client `k`'s cursor through the round window `[s, e)`,
/// optionally extracting its effective [`ClientWindow`] (natural signal
/// masked by fleet membership and regional outages). Pure per client —
/// safe to fan out across the thread pool.
fn walk_client(
    p: &ScenParams<'_>,
    k: usize,
    cur: &mut ScenCursor,
    s: f64,
    e: f64,
    out: Option<&mut ClientWindow>,
) {
    let nat_start = cur.online;
    let mut flips = [0.0f64; MAX_FLIPS];
    let mut nf = 0usize;
    while cur.next_flip_s < e {
        let tau = cur.next_flip_s;
        cur.online = !cur.online;
        cur.idx += 1;
        if nf < MAX_FLIPS {
            flips[nf] = tau;
            nf += 1;
        }
        let mut r = p.stream.split(k as u64).split(cur.idx);
        cur.next_flip_s = tau + p.dwell(&mut r, cur.online, tau);
    }
    let Some(w) = out else { return };

    // Candidate times where the effective signal can change: natural
    // flips, the client's join/leave instants, and its region's outage
    // edges — all strictly inside (s, e).
    let join = p.join_at[k];
    let leave = p.leave_at[k];
    let region = p.region_of(k);
    let mut edges = [0.0f64; MAX_EDGES];
    let mut ne = 0usize;
    for &f in &flips[..nf] {
        if f > s && f < e && ne < MAX_EDGES {
            edges[ne] = f;
            ne += 1;
        }
    }
    for b in [join, leave] {
        if b > s && b < e && ne < MAX_EDGES {
            edges[ne] = b;
            ne += 1;
        }
    }
    for &(r, os, oe) in p.outages {
        if r == region {
            for b in [os, oe] {
                if b > s && b < e && ne < MAX_EDGES {
                    edges[ne] = b;
                    ne += 1;
                }
            }
        }
    }
    edges[..ne].sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());

    let nat_at = |tau: f64| -> bool {
        let mut on = nat_start;
        for &f in &flips[..nf] {
            if f <= tau {
                on = !on;
            } else {
                break;
            }
        }
        on
    };
    let eff_at = |tau: f64| -> bool {
        if !(join <= tau && tau < leave) {
            return false;
        }
        for &(r, os, oe) in p.outages {
            if r == region && os <= tau && tau < oe {
                return false;
            }
        }
        nat_at(tau)
    };

    // Sweep the edges for the first two state changes of the effective
    // signal; later changes are folded (conservative: the window shape
    // the engine schedules is start-state plus up to two transitions).
    let start_on = eff_at(s);
    let mut state = start_on;
    let (mut t1, mut t2) = (None, None);
    for &tau in &edges[..ne] {
        let v = eff_at(tau);
        if v != state {
            state = v;
            if t1.is_none() {
                t1 = Some(tau - s);
            } else {
                t2 = Some(tau - s);
                break;
            }
        }
    }
    *w = if start_on {
        // Online-start: the first drop ends the client's round (a
        // later recovery cannot restart a fresh job mid-round).
        ClientWindow {
            online_at_start: true,
            goes_offline_at: t1,
            comes_online_at: None,
        }
    } else {
        // Offline-start: recover at t1, possibly drop again at t2.
        ClientWindow {
            online_at_start: false,
            goes_offline_at: t2,
            comes_online_at: t1,
        }
    };
}

/// Continuous wall-clock availability: per-client piecewise on/off
/// transitions on absolute sim-time, spanning round boundaries.
///
/// **RNG contract.** Unlike the legacy models' per-(round, client)
/// streams, every dwell draw comes from the per-(client,
/// transition-index) stream `Pcg64::with_stream(seed, SCENARIO_STREAM)
/// .split(k).split(i)`. The walk is therefore a pure function of the
/// cursor state — independent of thread width, of which rounds were
/// observed in between, and of the protocol driving the run — which is
/// what keeps scenario runs bit-for-bit width-invariant and resumable.
///
/// The timeline overlays three signals per client: the natural dwell
/// process (optionally diurnally modulated), fleet membership (flash-
/// crowd joins/leaves compiled from the scenario events), and
/// correlated regional outages. All buffers are allocated up front;
/// [`ScenarioTimeline::prepare_round`] is allocation-free.
pub struct ScenarioTimeline {
    stream: Pcg64,
    m: usize,
    t_lim: f64,
    base_up_s: f64,
    base_down_s: f64,
    amp: f64,
    period_s: f64,
    regions: usize,
    /// Absolute join time per client (0.0 = founding member,
    /// `INFINITY` = reserved latecomer slot that never fires).
    join_at: Vec<f64>,
    /// Absolute departure time per client (`INFINITY` = never).
    leave_at: Vec<f64>,
    /// Compiled `(region, start_s, end_s)` outage bands.
    outages: Vec<(usize, f64, f64)>,
    cursors: Vec<ScenCursor>,
    windows: Vec<ClientWindow>,
    /// Last round whose windows are materialised (0 = none yet).
    prepared: usize,
}

impl ScenarioTimeline {
    /// Compile a validated continuous-process spec for a fleet of `m`
    /// clients. Flash-crowd joins take the *top* ids of the fleet
    /// (reserved latecomers, first event gets the lowest reserved ids);
    /// leaves depart the lowest-id members still active at the event.
    pub fn new(spec: &ScenarioSpec, m: usize, t_lim: f64, seed: u64) -> ScenarioTimeline {
        let stream = Pcg64::with_stream(seed, SCENARIO_STREAM);

        // Resolve event times and apply them in time order.
        let mut order: Vec<(f64, usize)> = spec
            .events
            .iter()
            .enumerate()
            .map(|(i, ev)| (ev.at.seconds(t_lim), i))
            .collect();
        order.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));

        let pool = spec.total_joins().min(m.saturating_sub(1));
        let mut join_at = vec![0.0f64; m];
        let mut leave_at = vec![f64::INFINITY; m];
        for j in &mut join_at[m - pool..] {
            *j = f64::INFINITY;
        }
        let mut next_join = m - pool;
        let mut outages = Vec::new();
        for &(at, i) in &order {
            match spec.events[i].kind {
                ScenarioEventKind::FlashCrowd { joins, leaves } => {
                    for _ in 0..joins {
                        if next_join < m {
                            join_at[next_join] = at;
                            next_join += 1;
                        }
                    }
                    let mut left = leaves;
                    for k in 0..m {
                        if left == 0 {
                            break;
                        }
                        if join_at[k] <= at && leave_at[k].is_infinite() {
                            leave_at[k] = at;
                            left -= 1;
                        }
                    }
                }
                ScenarioEventKind::RegionalOutage { region, len_s } => {
                    outages.push((region, at, at + len_s));
                }
            }
        }

        // Transition index 0 seeds each client's state and first dwell.
        let p = ScenParams {
            stream: &stream,
            base_up_s: spec.base_uptime_s,
            base_down_s: spec.base_downtime_s,
            amp: spec.diurnal_amp,
            period_s: spec.diurnal_period_s,
            regions: spec.regions,
            join_at: &join_at,
            leave_at: &leave_at,
            outages: &outages,
        };
        let stationary_up =
            spec.base_uptime_s / (spec.base_uptime_s + spec.base_downtime_s);
        let mut cursors = Vec::with_capacity(m);
        for k in 0..m {
            let mut r = stream.split(k as u64).split(0);
            let online = r.next_f64() < stationary_up;
            let first = p.dwell(&mut r, online, 0.0);
            cursors.push(ScenCursor {
                online,
                next_flip_s: first,
                idx: 0,
            });
        }

        ScenarioTimeline {
            stream,
            m,
            t_lim,
            base_up_s: spec.base_uptime_s,
            base_down_s: spec.base_downtime_s,
            amp: spec.diurnal_amp,
            period_s: spec.diurnal_period_s,
            regions: spec.regions,
            join_at,
            leave_at,
            outages,
            cursors,
            windows: vec![ClientWindow::ALWAYS_OFF; m],
            prepared: 0,
        }
    }

    pub fn fleet_size(&self) -> usize {
        self.m
    }

    /// Materialise round `t`'s windows (idempotent for the current
    /// round; walks any skipped rounds forward first). Rounds must be
    /// driven in nondecreasing order — the cursors cannot rewind.
    pub fn prepare_round(&mut self, t: usize) {
        assert!(t >= 1, "rounds are 1-based");
        if self.prepared >= t {
            assert_eq!(
                self.prepared, t,
                "scenario timeline cannot rewind (prepared round {}, asked {t})",
                self.prepared
            );
            return;
        }
        let ScenarioTimeline {
            ref stream,
            t_lim,
            base_up_s,
            base_down_s,
            amp,
            period_s,
            regions,
            ref join_at,
            ref leave_at,
            ref outages,
            ref mut cursors,
            ref mut windows,
            ..
        } = *self;
        let p = ScenParams {
            stream,
            base_up_s,
            base_down_s,
            amp,
            period_s,
            regions,
            join_at,
            leave_at,
            outages,
        };
        while self.prepared < t {
            self.prepared += 1;
            let record = self.prepared == t;
            let s = (self.prepared - 1) as f64 * t_lim;
            let e = s + t_lim;
            parallel::for_each_chunk2(
                &mut cursors[..],
                &mut windows[..],
                SCEN_GRAIN,
                |base, curs, wins| {
                    for (i, (c, w)) in curs.iter_mut().zip(wins.iter_mut()).enumerate() {
                        walk_client(
                            &p,
                            base + i,
                            c,
                            s,
                            e,
                            if record { Some(w) } else { None },
                        );
                    }
                },
            );
        }
    }

    /// Client `k`'s effective window for the prepared round (relative
    /// to the round's start). Out-of-range clients (a test growing the
    /// fleet past the compiled timeline) are treated as never-members.
    pub fn window(&self, k: usize) -> ClientWindow {
        debug_assert!(self.prepared >= 1, "prepare_round before window()");
        self.windows.get(k).copied().unwrap_or(ClientWindow::ALWAYS_OFF)
    }

    /// Whether client `k` is a fleet member at any point during round
    /// `t` (pure — usable before `prepare_round`). A client joining
    /// mid-round counts for that round; one leaving at the round's
    /// opening instant does not.
    pub fn member_in_round(&self, k: usize, t: usize) -> bool {
        if k >= self.m {
            return false;
        }
        let s = (t.max(1) - 1) as f64 * self.t_lim;
        let e = s + self.t_lim;
        self.join_at[k] < e && self.leave_at[k] > s
    }
}

/// Parse a trace: one line per round, one `0`/`1` character per client
/// (whitespace and blank lines ignored).
pub fn parse_trace(text: &str) -> Result<Vec<Vec<bool>>> {
    let mut rounds = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut row = Vec::with_capacity(line.len());
        for c in line.chars() {
            match c {
                '1' => row.push(true),
                '0' => row.push(false),
                c if c.is_whitespace() => {}
                other => {
                    return Err(SafaError::Config(format!(
                        "churn trace line {}: unexpected character '{other}'",
                        lineno + 1
                    )))
                }
            }
        }
        rounds.push(row);
    }
    if rounds.is_empty() {
        return Err(SafaError::Config("churn trace is empty".into()));
    }
    Ok(rounds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bernoulli_consumes_one_draw_and_matches_rate() {
        let model = AvailabilityModel::BernoulliPerRound { crash_prob: 0.3 };
        let mut offline = 0;
        let n = 20_000;
        for k in 0..n {
            let mut crng = Pcg64::new(77).split(k);
            let mut state = None;
            let w = model.window(&mut state, &mut crng, 1, k as usize, 830.0);
            assert_eq!(w.goes_offline_at, None);
            assert_eq!(w.comes_online_at, None);
            if !w.online_at_start {
                offline += 1;
            }
            // The next value must be the stream's second output (the
            // engine uses it for the legacy crash-partial draw).
            let mut fresh = Pcg64::new(77).split(k);
            fresh.next_f64();
            assert_eq!(crng.next_f64(), fresh.next_f64());
        }
        let rate = offline as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.02, "offline rate {rate}");
    }

    #[test]
    fn markov_state_persists_across_rounds() {
        let model = AvailabilityModel::Markov {
            mean_uptime_s: 400.0,
            mean_downtime_s: 200.0,
        };
        // A client that drops mid-round must start the next round offline.
        let root = Pcg64::new(5);
        let mut found = false;
        for k in 0..200u64 {
            let mut state = None;
            let w1 = model.window(&mut state, &mut root.split(k), 1, k as usize, 830.0);
            if w1.online_at_start && w1.goes_offline_at.is_some() {
                assert_eq!(state, Some(false));
                let w2 =
                    model.window(&mut state, &mut root.split(1000 + k), 2, k as usize, 830.0);
                assert!(!w2.online_at_start, "dropped client must start round 2 offline");
                found = true;
                break;
            }
        }
        assert!(found, "no mid-round drop sampled in 200 clients");
    }

    #[test]
    fn markov_windows_are_deterministic_per_stream() {
        let model = AvailabilityModel::Markov {
            mean_uptime_s: 300.0,
            mean_downtime_s: 100.0,
        };
        for k in 0..50u64 {
            let (mut s1, mut s2) = (None, None);
            let a = model.window(&mut s1, &mut Pcg64::new(9).split(k), 1, k as usize, 830.0);
            let b = model.window(&mut s2, &mut Pcg64::new(9).split(k), 1, k as usize, 830.0);
            assert_eq!(a, b);
            assert_eq!(s1, s2);
        }
    }

    #[test]
    fn trace_replays_and_cycles() {
        let rounds = parse_trace("101\n010\n").unwrap();
        let model = AvailabilityModel::Trace { rounds };
        let mut crng = Pcg64::new(1);
        let mut state = None;
        // Round 1 = "101".
        assert!(model.window(&mut state, &mut crng, 1, 0, 10.0).online_at_start);
        assert!(!model.window(&mut state, &mut crng, 1, 1, 10.0).online_at_start);
        assert!(model.window(&mut state, &mut crng, 1, 2, 10.0).online_at_start);
        // Clients beyond the row default to online.
        assert!(model.window(&mut state, &mut crng, 1, 9, 10.0).online_at_start);
        // Round 3 cycles back to "101".
        assert!(!model.window(&mut state, &mut crng, 3, 1, 10.0).online_at_start);
    }

    #[test]
    fn trace_parser_rejects_garbage() {
        assert!(parse_trace("10x1").is_err());
        assert!(parse_trace("").is_err());
        assert!(parse_trace("\n  \n").is_err());
        assert_eq!(parse_trace(" 1 0 \n11\n").unwrap(), vec![
            vec![true, false],
            vec![true, true]
        ]);
    }

    fn continuous_spec() -> ScenarioSpec {
        crate::scenario::Scenario::new()
            .uptime(300.0, 100.0)
            .diurnal(0.5, 2000.0)
            .regions(2)
            .at_time(450.0)
            .flash_crowd(3, 2)
            .at_time(900.0)
            .regional_outage(1, 400.0)
            .build()
            .unwrap()
    }

    #[test]
    fn timeline_windows_are_path_independent() {
        // Preparing rounds one by one (reading each) must leave the
        // same round-8 windows as jumping straight to round 8 — the
        // per-(client, transition-index) streams make the walk a pure
        // function of the cursor, not of the observation pattern.
        let spec = continuous_spec();
        let mut a = ScenarioTimeline::new(&spec, 24, 830.0, 7);
        let mut b = ScenarioTimeline::new(&spec, 24, 830.0, 7);
        for t in 1..=8 {
            a.prepare_round(t);
            for k in 0..24 {
                let _ = a.window(k); // interleaved reads
            }
        }
        b.prepare_round(8);
        for k in 0..24 {
            let wa = a.window(k);
            let wb = b.window(k);
            assert_eq!(wa, wb, "client {k} round-8 window diverged");
            assert_eq!(
                wa.online_seconds(830.0).to_bits(),
                wb.online_seconds(830.0).to_bits()
            );
        }
        // Idempotent for the prepared round.
        a.prepare_round(8);
        assert_eq!(a.window(3), b.window(3));
    }

    #[test]
    fn timeline_membership_and_outage_mask_windows() {
        let spec = continuous_spec();
        let m = 24;
        let mut tl = ScenarioTimeline::new(&spec, m, 830.0, 11);
        // 3 scheduled joins reserve the top 3 ids; they are not members
        // in round 1 and their windows are whole-round offline.
        for k in m - 3..m {
            assert!(!tl.member_in_round(k, 1), "latecomer {k} in round 1");
            assert!(tl.member_in_round(k, 2), "latecomer {k} joined at 450s");
        }
        tl.prepare_round(1);
        for k in m - 3..m {
            assert_eq!(tl.window(k), ClientWindow::ALWAYS_OFF);
        }
        // 2 leaves at 450s depart the lowest founding ids: members in
        // round 1 (the departure is mid-round), gone from round 2 on.
        assert!(tl.member_in_round(0, 1));
        assert!(!tl.member_in_round(0, 5));
        assert!(!tl.member_in_round(1, 5));
        assert!(tl.member_in_round(2, 5));
        // Out-of-range clients are never members.
        assert!(!tl.member_in_round(m + 3, 1));
        assert_eq!(tl.window(m + 3), ClientWindow::ALWAYS_OFF);
    }

    #[test]
    fn timeline_windows_respect_transition_ordering() {
        // Any two-transition window must be recover-then-drop with
        // strictly increasing in-window times — the shape the engine's
        // event paths schedule.
        let spec = continuous_spec();
        let mut tl = ScenarioTimeline::new(&spec, 40, 830.0, 3);
        for t in 1..=12 {
            tl.prepare_round(t);
            for k in 0..40 {
                let w = tl.window(k);
                if let Some(g) = w.goes_offline_at {
                    assert!(g > 0.0 && g < 830.0, "drop {g} outside window");
                }
                if let Some(c) = w.comes_online_at {
                    assert!(c > 0.0 && c < 830.0, "recovery {c} outside window");
                    assert!(!w.online_at_start, "recovery implies offline start");
                }
                if let (Some(c), Some(g)) = (w.comes_online_at, w.goes_offline_at) {
                    if !w.online_at_start {
                        assert!(c < g, "recover {c} must precede drop {g}");
                    }
                }
            }
        }
    }

    #[test]
    fn online_seconds_accounting() {
        let w = ClientWindow::ALWAYS_ON;
        assert_eq!(w.online_seconds(100.0), 100.0);
        let w = ClientWindow {
            online_at_start: true,
            goes_offline_at: Some(30.0),
            comes_online_at: None,
        };
        assert_eq!(w.online_seconds(100.0), 30.0);
        let w = ClientWindow {
            online_at_start: false,
            goes_offline_at: None,
            comes_online_at: Some(70.0),
        };
        assert_eq!(w.online_seconds(100.0), 30.0);
        let w = ClientWindow {
            online_at_start: false,
            goes_offline_at: None,
            comes_online_at: None,
        };
        assert_eq!(w.online_seconds(100.0), 0.0);
        // Scenario recover-then-drop shape.
        let w = ClientWindow {
            online_at_start: false,
            goes_offline_at: Some(80.0),
            comes_online_at: Some(20.0),
        };
        assert_eq!(w.online_seconds(100.0), 60.0);
    }
}
