//! Discrete-event fleet engine: a binary-heap event scheduler over one
//! global virtual clock, typed events (`DownloadDone`, `TrainDone`,
//! `UploadDone`, `GoOffline`, `ComeOnline`, `RoundDeadline`) and
//! pluggable client-availability models.
//!
//! The engine is the single execution substrate for every protocol:
//! SAFA, FedAvg, FedCS, the fully-local baseline and the FedAsync
//! baseline all drive their rounds through [`FleetEngine`] (held by
//! `protocol::FedEnv`). Three availability models plug in:
//!
//! * per-round Bernoulli crashes (paper parity — bit-for-bit equivalent
//!   to the seed's `simulate_round` / `simulate_continuation` loops),
//! * two-state Markov on/off churn with exponential dwell times and
//!   mid-round `GoOffline` / `ComeOnline` events,
//! * deterministic trace replay loaded from a file named in the config.
//!
//! All availability draws come from the existing per-(round, client) RNG
//! streams (`round_rng.split(k)`), so crash/churn patterns are
//! reproducible and identical across protocols for a given seed.
//!
//! Execution is pooled and parallel where it can be without changing a
//! single bit: per-round storage lives in a reused scratch pool
//! (steady-state rounds are allocation-free), event-free models
//! (Bernoulli, trace) compute rounds as chunked parallel per-client
//! maps, and Markov rounds fan their window draws across
//! `util::parallel`'s scoped pool — see `fleet.rs` for the determinism
//! argument and `tests/determinism.rs` for the width-invariance
//! assertions.

mod availability;
mod event;
mod fleet;

pub use availability::{
    parse_trace, AvailabilityModel, ClientWindow, ScenarioTimeline, SCENARIO_STREAM,
};
pub use event::{Event, EventKind, EventQueue};
pub use fleet::{FleetEngine, RoundCtx};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, ChurnModel, ExperimentConfig};
    use crate::net::NetworkModel;
    use crate::protocol::FedEnv;
    use crate::sim::{
        reference_continuation, reference_round, simulate_continuation, simulate_round,
        ContinuationSim, RoundSim,
    };
    use crate::util::proptest::property;
    use crate::util::rng::Pcg64;

    fn assert_round_eq(engine: &RoundSim, reference: &RoundSim, ctx: &str) {
        assert_eq!(
            engine.arrivals.len(),
            reference.arrivals.len(),
            "{ctx}: arrival count"
        );
        for (a, b) in engine.arrivals.iter().zip(&reference.arrivals) {
            assert_eq!(a.client, b.client, "{ctx}: arrival order");
            assert_eq!(a.time.to_bits(), b.time.to_bits(), "{ctx}: arrival time");
        }
        assert_eq!(engine.failures.len(), reference.failures.len(), "{ctx}: failures");
        for (&(ka, ra, pa), &(kb, rb, pb)) in engine.failures.iter().zip(&reference.failures) {
            assert_eq!(ka, kb, "{ctx}: failed client");
            assert_eq!(ra, rb, "{ctx}: failure reason");
            assert_eq!(pa.to_bits(), pb.to_bits(), "{ctx}: failure partial");
        }
        // Bernoulli crashes are opt-outs at round start — never a
        // detected mid-round drop.
        assert_eq!(engine.last_drop.to_bits(), reference.last_drop.to_bits(), "{ctx}: last_drop");
    }

    fn assert_cont_eq(engine: &ContinuationSim, reference: &ContinuationSim, ctx: &str) {
        assert_eq!(
            engine.arrivals.len(),
            reference.arrivals.len(),
            "{ctx}: arrival count"
        );
        for (a, b) in engine.arrivals.iter().zip(&reference.arrivals) {
            assert_eq!(a.client, b.client, "{ctx}: arrival order");
            assert_eq!(a.time.to_bits(), b.time.to_bits(), "{ctx}: arrival time");
        }
        assert_eq!(engine.crashed, reference.crashed, "{ctx}: crashed set");
        assert_eq!(engine.stragglers, reference.stragglers, "{ctx}: stragglers");
    }

    /// Acceptance: under Bernoulli availability the engine reproduces the
    /// seed implementation exactly on the tiny and task1 presets, seeds
    /// 1–5, across sync patterns and rounds.
    #[test]
    fn engine_matches_seed_implementation_on_presets() {
        for preset_name in ["tiny", "task1"] {
            for seed in 1..=5u64 {
                let mut cfg = presets::preset(preset_name).unwrap();
                cfg.seed = seed;
                cfg.env.crash_prob = 0.3;
                let env = FedEnv::new(&cfg).unwrap();
                let m = env.m();
                let parts: Vec<usize> = (0..m).collect();
                let patterns: Vec<Vec<bool>> = vec![
                    vec![true; m],
                    vec![false; m],
                    (0..m).map(|k| k % 2 == 0).collect(),
                ];
                for t in 1..=4 {
                    let rng = env.round_rng(t, 0xc4a5);
                    for synced in &patterns {
                        let ctx = format!("{preset_name} seed={seed} t={t}");
                        let e = simulate_round(&cfg, &env.net, &env.clients, &parts, synced, &rng);
                        let r = reference_round(&cfg, &env.net, &env.clients, &parts, synced, &rng);
                        assert_round_eq(&e, &r, &ctx);
                    }
                    // Continuation over realistic in-flight job times.
                    let jobs: Vec<f64> = env
                        .clients
                        .iter()
                        .map(|c| {
                            env.net.t_down() + c.t_train(cfg.train.epochs) + env.net.t_up()
                        })
                        .collect();
                    let e = simulate_continuation(&cfg, &parts, &jobs, &rng);
                    let r = reference_continuation(&cfg, &parts, &jobs, &rng);
                    assert_cont_eq(&e, &r, &format!("{preset_name} seed={seed} t={t} cont"));
                }
            }
        }
    }

    /// Property: equivalence holds across random configs, fleet shapes,
    /// crash rates and deadlines.
    #[test]
    fn engine_equivalence_property() {
        property("engine == seed simulate_round", 40, |g| {
            let mut cfg = presets::preset("tiny").unwrap();
            cfg.env.crash_prob = g.f64_range(0.0, 1.0);
            cfg.train.t_lim = *g.choose(&[10.0, 300.0, 830.0, 1e9]);
            cfg.env.m = g.usize_range(1, 8);
            let net = NetworkModel::new(&cfg.env);
            let clients: Vec<crate::client::ClientState> = (0..cfg.env.m)
                .map(|id| crate::client::ClientState {
                    id,
                    perf: g.f64_range(1e-3, 4.0),
                    batches_per_epoch: g.usize_range(1, 40),
                    n_k: 10,
                    local_model: crate::model::ParamVec::zeros(1),
                    version: 0,
                    base_version: 0,
                    committed_last: true,
                    picked_last: false,
                    pending_partial: 0.0,
                    job: None,
                })
                .collect();
            let parts: Vec<usize> = (0..cfg.env.m).collect();
            let synced: Vec<bool> = (0..cfg.env.m).map(|_| g.bool()).collect();
            let rng = Pcg64::new(g.u64());
            let e = simulate_round(&cfg, &net, &clients, &parts, &synced, &rng);
            let r = reference_round(&cfg, &net, &clients, &parts, &synced, &rng);
            assert_round_eq(&e, &r, "property");

            let jobs: Vec<f64> = (0..cfg.env.m)
                .map(|_| g.f64_range(1.0, 2.0 * cfg.train.t_lim))
                .collect();
            let e = simulate_continuation(&cfg, &parts, &jobs, &rng);
            let r = reference_continuation(&cfg, &parts, &jobs, &rng);
            assert_cont_eq(&e, &r, "property cont");
        });
    }

    fn markov_cfg() -> ExperimentConfig {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.env.churn = ChurnModel::Markov {
            mean_uptime_s: 400.0,
            mean_downtime_s: 200.0,
        };
        cfg
    }

    /// Satellite: Markov churn preserves the per-seed determinism the
    /// Bernoulli model guarantees (`crash_pattern_is_per_round_stream`).
    #[test]
    fn markov_churn_is_per_round_stream_deterministic() {
        let cfg = markov_cfg();
        let env = FedEnv::new(&cfg).unwrap();
        let parts: Vec<usize> = (0..env.m()).collect();
        let synced = vec![false; parts.len()];
        let run = |seed: u64| -> Vec<Vec<usize>> {
            let mut engine = FleetEngine::from_config(&cfg).unwrap();
            (1..=6usize)
                .map(|t| {
                    let rng = Pcg64::new(seed).split(t as u64);
                    let ctx = RoundCtx {
                        cfg: &cfg,
                        net: &env.net,
                        clients: &env.clients,
                        fabric: None,
                        faults: None,
                    };
                    engine
                        .run_round(t, ctx, &parts, &synced, &rng)
                        .failures
                        .iter()
                        .map(|&(k, _, _)| k)
                        .collect()
                })
                .collect()
        };
        let a = run(42);
        let b = run(42);
        assert_eq!(a, b, "same seed must yield the same churn pattern");
        let c = run(43);
        assert_ne!(a, c, "different seeds should (a.s.) differ");
    }

    /// Markov mid-round drops surface as crashes with in-progress partial
    /// work, and state persistence keeps dropped clients offline.
    #[test]
    fn markov_mid_round_drop_has_partial_progress() {
        let cfg = markov_cfg();
        let env = FedEnv::new(&cfg).unwrap();
        let parts: Vec<usize> = (0..env.m()).collect();
        let synced = vec![true; parts.len()];
        let mut engine = FleetEngine::from_config(&cfg).unwrap();
        let mut saw_partial = false;
        for t in 1..=40 {
            let rng = env.round_rng(t, 0xc4a5);
            let ctx = RoundCtx {
                cfg: &cfg,
                net: &env.net,
                clients: &env.clients,
                fabric: None,
                faults: None,
            };
            let sim = engine.run_round(t, ctx, &parts, &synced, &rng);
            for &(_, reason, partial) in &sim.failures {
                assert!((0.0..=1.0).contains(&partial));
                if reason == crate::sim::FailReason::Crash && partial > 0.0 && partial < 1.0 {
                    saw_partial = true;
                }
            }
            assert!(sim.online_time >= 0.0);
            assert!(sim.offline_time >= -1e-9);
        }
        assert!(saw_partial, "40 Markov rounds produced no mid-round drop");
    }

    /// `last_drop` reflects detected mid-round disconnects (and only
    /// those), so the synchronous close rule can wait for them.
    #[test]
    fn last_drop_tracks_mid_round_drops() {
        let cfg = markov_cfg();
        let env = FedEnv::new(&cfg).unwrap();
        let parts: Vec<usize> = (0..env.m()).collect();
        let synced = vec![true; parts.len()];
        let mut engine = FleetEngine::from_config(&cfg).unwrap();
        let mut saw_drop = false;
        for t in 1..=40 {
            let rng = env.round_rng(t, 0xc4a5);
            let ctx = RoundCtx {
                cfg: &cfg,
                net: &env.net,
                clients: &env.clients,
                fabric: None,
                faults: None,
            };
            let sim = engine.run_round(t, ctx, &parts, &synced, &rng);
            let mid_round_crash = sim
                .failures
                .iter()
                .any(|&(_, r, p)| r == crate::sim::FailReason::Crash && p > 0.0 && p < 1.0);
            if mid_round_crash {
                saw_drop = true;
                assert!(
                    sim.last_drop > 0.0 && sim.last_drop <= cfg.train.t_lim,
                    "t={t}: last_drop {} out of (0, T_lim]",
                    sim.last_drop
                );
            }
        }
        assert!(saw_drop, "40 Markov rounds produced no mid-round drop");
    }

    /// Trace replay is exact: the offline matrix maps straight onto
    /// failures, and the trace cycles past its end.
    #[test]
    fn trace_replay_drives_failures() {
        let mut cfg = presets::preset("tiny").unwrap(); // m = 4
        cfg.env.crash_prob = 0.0;
        let env = FedEnv::new(&cfg).unwrap();
        let parts: Vec<usize> = (0..env.m()).collect();
        let synced = vec![false; parts.len()];
        let rounds = parse_trace("0111\n1011\n1111\n").unwrap();
        let mut engine = FleetEngine::new(AvailabilityModel::Trace { rounds }, env.m());
        let mut offline_per_round = Vec::new();
        for t in 1..=4 {
            let rng = env.round_rng(t, 0xc4a5);
            let ctx = RoundCtx {
                cfg: &cfg,
                net: &env.net,
                clients: &env.clients,
                fabric: None,
                faults: None,
            };
            let sim = engine.run_round(t, ctx, &parts, &synced, &rng);
            offline_per_round.push(
                sim.failures
                    .iter()
                    .filter(|&&(_, r, _)| r == crate::sim::FailReason::Crash)
                    .map(|&(k, _, _)| k)
                    .collect::<Vec<_>>(),
            );
        }
        assert_eq!(offline_per_round[0], vec![0]);
        assert_eq!(offline_per_round[1], vec![1]);
        assert_eq!(offline_per_round[2], Vec::<usize>::new());
        // Round 4 cycles back to the first trace row.
        assert_eq!(offline_per_round[3], vec![0]);
    }
}
