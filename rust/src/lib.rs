//! # SAFA — Semi-Asynchronous Federated Averaging
//!
//! A production-quality reproduction of *"SAFA: a Semi-Asynchronous
//! Protocol for Fast Federated Learning with Low Overhead"* (Wu et al.,
//! IEEE TC 2020) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated coordinator: SAFA's lag-tolerant
//!   model distribution (Eq. 3), post-training CFCFM client selection
//!   (Alg. 1) and three-step discriminative aggregation (Eqs. 6–8), plus
//!   FedAvg / FedCS / FedAsync / fully-local baselines, a discrete-event
//!   fleet engine ([`engine`]) with pluggable client-churn models
//!   (Bernoulli / Markov on-off / trace replay) and the paper's full
//!   metric suite.
//! * **L2/L1 (python/, build-time only)** — JAX task models whose hot
//!   spot is a Pallas fused-linear kernel, AOT-lowered once to HLO text.
//! * **Runtime bridge** — [`runtime`] loads those artifacts with the
//!   `xla` crate's PJRT CPU client and executes them from the Rust hot
//!   path; Python never runs at experiment time.
//!
//! See `DESIGN.md` for the system inventory and per-experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod bench_harness;
pub mod client;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod error;
pub mod experiments;
pub mod faults;
pub mod metrics;
pub mod model;
pub mod net;
pub mod protocol;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod telemetry;
pub mod util;

pub use error::{Result, SafaError};
