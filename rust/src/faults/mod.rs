//! Deterministic fault injection for the fleet engine.
//!
//! [`FaultPlan`] is the load-time configuration (TOML `faults.*` keys,
//! `--faults*` CLI flags, the `chaos` preset); [`FaultRuntime`] turns it
//! into pure per-`(round, client)` queries the engine consults while
//! scheduling transfers and training through the event queue:
//!
//! * **Crash hazard** — a per-round Bernoulli over each participant; a
//!   hit interrupts the client at a uniform point inside `[0, T_lim]`,
//!   cancelling whatever leg (download / train / upload) is in flight.
//! * **Flapping** — with probability `flap_prob` an interruption is a
//!   flap rather than a hard crash: the client comes back after
//!   `flap_downtime_s` and the server retries the cancelled leg under
//!   the bounded-backoff policy ([`FaultPlan::retry_max`],
//!   [`FaultRuntime::backoff`]). Flap downtime lives on the continuous
//!   wall clock: a flap cut near the end of a round spills its leftover
//!   downtime into the next round ([`FaultRuntime::flap_carry`]) instead
//!   of silently truncating at `T_lim`.
//! * **Correlated regional outages** — clients are sharded into
//!   `regions` contiguous id bands; with probability `outage_prob` per
//!   round a whole region goes dark for an `outage_len_s` time band.
//! * **Link degradation** — with probability `degrade_prob` a client's
//!   transfer legs are scaled by `degrade_factor` for the round
//!   (EcNode-style `NetworkCondition` window covering the round).
//!
//! **RNG salting contract.** All draws come from one dedicated stream,
//! `Pcg64::with_stream(seed, FAULTS_STREAM)`, re-split per round
//! (`.split(t)`) and then per consumer (`.split(SALT_* + k)` for client
//! `k`, `.split(SALT_OUTAGE + region)` for a region). Every query is a
//! pure function of `(t, k)` — no shared mutable cursor — so results are
//! identical at any thread width and independent of evaluation order.
//! The stream id and salts are disjoint from every other subsystem
//! (round sim `0xc4a5`, selection `0xfeda`, fleet `0xf1ee`, fabric
//! `0xfab_11c`/`0xfab_71c`, ...).
//!
//! Everything is default-off: a [`FaultPlan::default`] (or `mode =
//! "off"`) never constructs a runtime, and the engine's legacy paths are
//! bit-for-bit untouched.

use crate::config::ExperimentConfig;
use crate::error::{Result, SafaError};
use crate::util::rng::Pcg64;

/// Dedicated RNG stream id for all fault-injection draws.
pub const FAULTS_STREAM: u64 = 0xfa17;
/// Per-client salt for the crash-hazard / flap draws.
const SALT_CRASH: u64 = 0x4000_0000;
/// Per-client salt for the link-degradation draw.
const SALT_DEGRADE: u64 = 0x5000_0000;
/// Per-region salt for the correlated-outage draws.
const SALT_OUTAGE: u64 = 0x6000_0000;

/// Load-time fault-injection plan (strict-validated, default off).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Master switch; `false` means the engine never consults faults.
    pub enabled: bool,
    /// Per-(round, client) probability of a mid-round interruption.
    pub crash_hazard: f64,
    /// Probability an interruption is a flap (recovers) vs a hard crash.
    pub flap_prob: f64,
    /// Downtime before a flapped client comes back online.
    pub flap_downtime_s: f64,
    /// Number of contiguous client-id shards for correlated outages
    /// (0 disables regional outages).
    pub regions: usize,
    /// Per-(round, region) probability the region goes dark for a band.
    pub outage_prob: f64,
    /// Length of a regional dark band (clipped to the round horizon).
    pub outage_len_s: f64,
    /// Per-(round, client) probability of link degradation this round.
    pub degrade_prob: f64,
    /// Multiplier (>= 1) on transfer seconds while degraded.
    pub degrade_factor: f64,
    /// Bounded retry budget for a cancelled transfer leg (0 = never
    /// retry; flaps then behave like hard crashes for transfers).
    pub retry_max: u32,
    /// Base backoff before retry attempt 1; doubles per attempt.
    pub retry_backoff_s: f64,
    /// Cap on the exponential backoff.
    pub retry_backoff_cap_s: f64,
    /// Credit interrupted continuation jobs with the work they finished
    /// (crashed-at-epoch-k resumes from k, not zero).
    pub partial_credit: bool,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            enabled: false,
            crash_hazard: 0.0,
            flap_prob: 0.0,
            flap_downtime_s: 0.0,
            regions: 0,
            outage_prob: 0.0,
            outage_len_s: 0.0,
            degrade_prob: 0.0,
            degrade_factor: 1.0,
            retry_max: 1,
            retry_backoff_s: 5.0,
            retry_backoff_cap_s: 60.0,
            partial_credit: true,
        }
    }
}

impl FaultPlan {
    /// Build a plan from raw TOML/CLI parts with the same strictness as
    /// `ChurnModel::from_parts` / `FabricConfig::from_parts`: `mode`
    /// must be `off` or `on`, and supplying any other `faults.*`
    /// parameter while `mode = "off"` is a hard error rather than a
    /// silent no-op.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        mode: &str,
        crash_hazard: Option<f64>,
        flap_prob: Option<f64>,
        flap_downtime_s: Option<f64>,
        regions: Option<i64>,
        outage_prob: Option<f64>,
        outage_len_s: Option<f64>,
        degrade_prob: Option<f64>,
        degrade_factor: Option<f64>,
        retry_max: Option<i64>,
        retry_backoff_s: Option<f64>,
        retry_backoff_cap_s: Option<f64>,
        partial_credit: Option<bool>,
    ) -> Result<FaultPlan> {
        let err = |msg: String| Err(SafaError::Config(msg));
        match mode.to_ascii_lowercase().as_str() {
            "off" => {
                let any = crash_hazard.is_some()
                    || flap_prob.is_some()
                    || flap_downtime_s.is_some()
                    || regions.is_some()
                    || outage_prob.is_some()
                    || outage_len_s.is_some()
                    || degrade_prob.is_some()
                    || degrade_factor.is_some()
                    || retry_max.is_some()
                    || retry_backoff_s.is_some()
                    || retry_backoff_cap_s.is_some()
                    || partial_credit.is_some();
                if any {
                    return err(
                        "faults parameters require faults.mode != \"off\"".into(),
                    );
                }
                Ok(FaultPlan::default())
            }
            "on" => {
                let d = FaultPlan::default();
                let regions = match regions {
                    None => 0,
                    Some(r) if r >= 0 => r as usize,
                    Some(r) => {
                        return err(format!("faults.regions must be >= 0, got {r}"))
                    }
                };
                let retry_max = match retry_max {
                    None => d.retry_max,
                    Some(r) if (0..=64).contains(&r) => r as u32,
                    Some(r) => {
                        return err(format!(
                            "faults.retry_max must be in 0..=64, got {r}"
                        ))
                    }
                };
                let plan = FaultPlan {
                    enabled: true,
                    crash_hazard: crash_hazard.unwrap_or(0.0),
                    flap_prob: flap_prob.unwrap_or(0.0),
                    flap_downtime_s: flap_downtime_s.unwrap_or(d.flap_downtime_s),
                    regions,
                    outage_prob: outage_prob.unwrap_or(0.0),
                    outage_len_s: outage_len_s.unwrap_or(d.outage_len_s),
                    degrade_prob: degrade_prob.unwrap_or(0.0),
                    degrade_factor: degrade_factor.unwrap_or(d.degrade_factor),
                    retry_max,
                    retry_backoff_s: retry_backoff_s.unwrap_or(d.retry_backoff_s),
                    retry_backoff_cap_s: retry_backoff_cap_s
                        .unwrap_or(d.retry_backoff_cap_s),
                    partial_credit: partial_credit.unwrap_or(d.partial_credit),
                };
                plan.validate()?;
                Ok(plan)
            }
            other => err(format!(
                "unknown faults.mode {other:?} (expected \"off\" or \"on\")"
            )),
        }
    }

    /// Reject NaN/inf/out-of-range knobs (used at TOML + CLI load time
    /// and from `ExperimentConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        let e = |msg: String| Err(SafaError::Config(msg));
        for (name, v) in [
            ("faults.crash_hazard", self.crash_hazard),
            ("faults.flap_prob", self.flap_prob),
            ("faults.outage_prob", self.outage_prob),
            ("faults.degrade_prob", self.degrade_prob),
        ] {
            if !v.is_finite() || !(0.0..=1.0).contains(&v) {
                return e(format!("{name} must be a probability in [0, 1], got {v}"));
            }
        }
        for (name, v) in [
            ("faults.flap_downtime_s", self.flap_downtime_s),
            ("faults.outage_len_s", self.outage_len_s),
            ("faults.retry_backoff_s", self.retry_backoff_s),
            ("faults.retry_backoff_cap_s", self.retry_backoff_cap_s),
        ] {
            if !v.is_finite() || v < 0.0 {
                return e(format!("{name} must be finite and >= 0, got {v}"));
            }
        }
        if !self.degrade_factor.is_finite() || self.degrade_factor < 1.0 {
            return e(format!(
                "faults.degrade_factor must be finite and >= 1, got {}",
                self.degrade_factor
            ));
        }
        Ok(())
    }

    /// Whether any injector can actually fire (used by the engine to
    /// skip the faults path for an enabled-but-neutral plan would be
    /// wrong: policy knobs like retries only matter when an injector
    /// fires, so activity is keyed on the injectors alone).
    pub fn any_injector(&self) -> bool {
        self.crash_hazard > 0.0
            || (self.regions > 0 && self.outage_prob > 0.0)
            || self.degrade_prob > 0.0
    }
}

/// A scheduled interruption for one client in one round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interrupt {
    /// Sim-time the client is cut off (within `[0, horizon)`).
    pub at: f64,
    /// Sim-time it comes back online (flap / outage end), `None` for a
    /// hard crash or a recovery that lands past the horizon.
    pub resume: Option<f64>,
}

/// Runtime fault injector: pure per-`(round, client)` queries over the
/// dedicated `FAULTS_STREAM` RNG. Cheap to query from parallel setup
/// passes (no shared state, no allocation).
#[derive(Debug, Clone)]
pub struct FaultRuntime {
    plan: FaultPlan,
    m: usize,
    stream: Pcg64,
}

impl FaultRuntime {
    pub fn new(cfg: &ExperimentConfig) -> Self {
        FaultRuntime {
            plan: cfg.env.faults.clone(),
            m: cfg.env.m,
            stream: Pcg64::with_stream(cfg.seed, FAULTS_STREAM),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Whether the engine should route this run through the faults
    /// event path at all.
    pub fn active(&self) -> bool {
        self.plan.enabled
    }

    fn round(&self, t: usize) -> Pcg64 {
        self.stream.split(t as u64)
    }

    /// Contiguous-id-shard region of client `k`.
    pub fn region_of(&self, k: usize) -> usize {
        if self.plan.regions == 0 {
            0
        } else {
            (k * self.plan.regions) / self.m.max(1)
        }
    }

    /// Correlated outage band `[start, end)` for `region` in round `t`,
    /// if one fires. Pure in `(t, region)`.
    pub fn outage(&self, t: usize, region: usize, horizon: f64) -> Option<(f64, f64)> {
        if self.plan.regions == 0 || self.plan.outage_prob <= 0.0 {
            return None;
        }
        let mut rng = self.round(t).split(SALT_OUTAGE + region as u64);
        if rng.next_f64() >= self.plan.outage_prob {
            return None;
        }
        let start = rng.next_f64() * horizon;
        Some((start, start + self.plan.outage_len_s))
    }

    /// Raw crash/flap draw for `(t, k)`: the cut time and whether it is
    /// a flap, when the hazard fires. Consumes exactly the same RNG
    /// values as the public [`FaultRuntime::crash`] query, so later
    /// rounds can replay earlier rounds' draws when computing
    /// cross-round flap carry-over without any stored state.
    fn crash_raw(&self, t: usize, k: usize, horizon: f64) -> Option<(f64, bool)> {
        if self.plan.crash_hazard <= 0.0 {
            return None;
        }
        let mut rng = self.round(t).split(SALT_CRASH + k as u64);
        if rng.next_f64() >= self.plan.crash_hazard {
            return None;
        }
        let at = rng.next_f64() * horizon;
        let flap = self.plan.flap_prob > 0.0 && rng.next_f64() < self.plan.flap_prob;
        Some((at, flap))
    }

    /// Individual crash/flap interruption for client `k` in round `t`,
    /// if one fires. Pure in `(t, k)`.
    pub fn crash(&self, t: usize, k: usize, horizon: f64) -> Option<Interrupt> {
        self.crash_raw(t, k, horizon).map(|(at, flap)| {
            let resume = if flap {
                let r = at + self.plan.flap_downtime_s;
                (r < horizon).then_some(r)
            } else {
                None
            };
            Interrupt { at, resume }
        })
    }

    /// A flap whose downtime began in an earlier round and is still
    /// running when round `t` opens. Flap downtime lives on the
    /// continuous wall clock — round boundaries are bookkeeping, not
    /// recovery points — so the leftover downtime spills into round `t`
    /// as an interruption at `0.0` (resuming in-round when the leftover
    /// is shorter than the horizon). Pure in `(t, k)`: earlier rounds'
    /// draws are replayed via [`FaultRuntime::crash_raw`], never stored,
    /// which keeps the query width-invariant and order-free.
    pub fn flap_carry(&self, t: usize, k: usize, horizon: f64) -> Option<Interrupt> {
        if self.plan.flap_prob <= 0.0
            || self.plan.flap_downtime_s <= 0.0
            || horizon <= 0.0
            || t <= 1
        {
            return None;
        }
        // A flap cut j rounds back reaches round t only when its
        // downtime exceeds (j - 1) full horizons, so the replay window
        // is bounded by the downtime itself.
        let reach = (self.plan.flap_downtime_s / horizon).ceil() as usize + 1;
        let mut latest: Option<f64> = None;
        for j in 1..=reach.min(t - 1) {
            if let Some((at, true)) = self.crash_raw(t - j, k, horizon) {
                // Leftover downtime expressed on round t's clock.
                let left = at + self.plan.flap_downtime_s - j as f64 * horizon;
                if left > 0.0 {
                    latest = Some(latest.map_or(left, |b| b.max(left)));
                }
            }
        }
        latest.map(|left| Interrupt {
            at: 0.0,
            resume: (left < horizon).then_some(left),
        })
    }

    /// The earliest interruption hitting client `k` in round `t`: a
    /// cross-round flap still in its downtime (which cuts at `0.0` and
    /// therefore always wins), else the individual crash/flap composed
    /// with the client's regional outage. One interruption is modelled
    /// per (round, client); a same-time tie favours the individual
    /// crash (hard failures win).
    pub fn interrupt(&self, t: usize, k: usize, horizon: f64) -> Option<Interrupt> {
        if let Some(carry) = self.flap_carry(t, k, horizon) {
            return Some(carry);
        }
        let crash = self.crash(t, k, horizon);
        let outage = self.outage(t, self.region_of(k), horizon).map(|(s, e)| Interrupt {
            at: s,
            resume: (e < horizon).then_some(e),
        });
        match (crash, outage) {
            (None, o) => o,
            (c, None) => c,
            (Some(c), Some(o)) => Some(if o.at < c.at { o } else { c }),
        }
    }

    /// Transfer-seconds multiplier for client `k` in round `t` (1.0 or
    /// `degrade_factor`). Pure in `(t, k)`.
    pub fn degrade(&self, t: usize, k: usize) -> f64 {
        if self.plan.degrade_prob <= 0.0 {
            return 1.0;
        }
        let mut rng = self.round(t).split(SALT_DEGRADE + k as u64);
        if rng.next_f64() < self.plan.degrade_prob {
            self.plan.degrade_factor
        } else {
            1.0
        }
    }

    /// Capped exponential backoff before retry `attempt` (1-based):
    /// `min(retry_backoff_s * 2^(attempt-1), retry_backoff_cap_s)`.
    pub fn backoff(&self, attempt: u32) -> f64 {
        let exp = 2f64.powi(attempt.saturating_sub(1).min(60) as i32);
        (self.plan.retry_backoff_s * exp).min(self.plan.retry_backoff_cap_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_off_and_valid() {
        let p = FaultPlan::default();
        assert!(!p.enabled);
        assert!(!p.any_injector());
        p.validate().unwrap();
    }

    #[test]
    fn from_parts_mirrors_churn_strictness() {
        // Orphan parameter with mode off is a hard error.
        let e = FaultPlan::from_parts(
            "off",
            Some(0.1),
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
        );
        assert!(e.is_err(), "orphan faults param must be rejected");
        // Unknown mode is rejected.
        assert!(FaultPlan::from_parts(
            "maybe", None, None, None, None, None, None, None, None, None, None, None,
            None
        )
        .is_err());
        // A clean "on" build round-trips the knobs.
        let p = FaultPlan::from_parts(
            "on",
            Some(0.2),
            Some(0.5),
            Some(30.0),
            Some(4),
            Some(0.1),
            Some(90.0),
            Some(0.25),
            Some(2.5),
            Some(3),
            Some(2.0),
            Some(16.0),
            Some(false),
        )
        .unwrap();
        assert!(p.enabled && p.any_injector());
        assert_eq!(p.regions, 4);
        assert_eq!(p.retry_max, 3);
        assert!(!p.partial_credit);
    }

    #[test]
    fn validate_rejects_bad_fields() {
        let base = || FaultPlan {
            enabled: true,
            ..FaultPlan::default()
        };
        let mut p = base();
        p.crash_hazard = f64::NAN;
        assert!(p.validate().is_err(), "NaN hazard");
        let mut p = base();
        p.outage_prob = 1.5;
        assert!(p.validate().is_err(), "prob > 1");
        let mut p = base();
        p.flap_downtime_s = -1.0;
        assert!(p.validate().is_err(), "negative downtime");
        let mut p = base();
        p.degrade_factor = 0.5;
        assert!(p.validate().is_err(), "speed-up factor");
        let mut p = base();
        p.retry_backoff_cap_s = f64::INFINITY;
        assert!(p.validate().is_err(), "infinite cap");
    }

    fn runtime(plan: FaultPlan, m: usize) -> FaultRuntime {
        FaultRuntime {
            plan,
            m,
            stream: Pcg64::with_stream(42, FAULTS_STREAM),
        }
    }

    #[test]
    fn queries_are_pure_and_order_free() {
        let rt = runtime(
            FaultPlan {
                enabled: true,
                crash_hazard: 0.5,
                flap_prob: 0.5,
                flap_downtime_s: 20.0,
                regions: 4,
                outage_prob: 0.3,
                outage_len_s: 100.0,
                degrade_prob: 0.4,
                ..FaultPlan::default()
            },
            64,
        );
        // Same (t, k) twice — including after interleaved other queries
        // — must return bit-identical results.
        let a = rt.interrupt(3, 17, 600.0);
        let _ = rt.interrupt(3, 16, 600.0);
        let _ = rt.degrade(4, 17);
        let b = rt.interrupt(3, 17, 600.0);
        assert_eq!(a, b);
        assert_eq!(rt.degrade(3, 17).to_bits(), rt.degrade(3, 17).to_bits());
        assert_eq!(rt.outage(5, 2, 600.0), rt.outage(5, 2, 600.0));
    }

    #[test]
    fn regions_shard_contiguously() {
        let rt = runtime(
            FaultPlan {
                enabled: true,
                regions: 4,
                ..FaultPlan::default()
            },
            100,
        );
        assert_eq!(rt.region_of(0), 0);
        assert_eq!(rt.region_of(24), 0);
        assert_eq!(rt.region_of(25), 1);
        assert_eq!(rt.region_of(99), 3);
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let rt = runtime(
            FaultPlan {
                enabled: true,
                retry_backoff_s: 2.0,
                retry_backoff_cap_s: 10.0,
                ..FaultPlan::default()
            },
            8,
        );
        assert_eq!(rt.backoff(1), 2.0);
        assert_eq!(rt.backoff(2), 4.0);
        assert_eq!(rt.backoff(3), 8.0);
        assert_eq!(rt.backoff(4), 10.0, "cap honoured");
        assert_eq!(rt.backoff(40), 10.0, "huge attempts stay capped");
    }

    #[test]
    fn hard_crash_has_no_resume_and_flap_resumes_in_round() {
        let rt = runtime(
            FaultPlan {
                enabled: true,
                crash_hazard: 1.0,
                flap_prob: 0.0,
                ..FaultPlan::default()
            },
            8,
        );
        let i = rt.crash(1, 0, 600.0).expect("hazard 1.0 must fire");
        assert!(i.resume.is_none());
        assert!((0.0..600.0).contains(&i.at));
        let rt = runtime(
            FaultPlan {
                enabled: true,
                crash_hazard: 1.0,
                flap_prob: 1.0,
                flap_downtime_s: 1e-6,
                ..FaultPlan::default()
            },
            8,
        );
        let i = rt.crash(1, 0, 600.0).expect("hazard 1.0 must fire");
        let r = i.resume.expect("flap with tiny downtime resumes in round");
        assert!(r > i.at && r < 600.0);
    }

    #[test]
    fn flap_downtime_spans_round_boundaries() {
        // Every client flaps every round; downtime is 1.5 horizons, so
        // whatever the cut time, the downtime always crosses into the
        // next round.
        let horizon = 100.0;
        let rt = runtime(
            FaultPlan {
                enabled: true,
                crash_hazard: 1.0,
                flap_prob: 1.0,
                flap_downtime_s: 150.0,
                ..FaultPlan::default()
            },
            8,
        );
        for k in 0..8 {
            let (at, flap) = rt.crash_raw(1, k, horizon).expect("hazard 1.0");
            assert!(flap);
            let carry = rt
                .flap_carry(2, k, horizon)
                .expect("downtime 1.5x horizon must reach round 2");
            assert_eq!(carry.at, 0.0, "carried flap cuts at round start");
            let left = at + 150.0 - horizon;
            if left < horizon {
                assert_eq!(carry.resume, Some(left), "exact leftover downtime");
            } else {
                assert_eq!(carry.resume, None, "still down at next round end");
            }
            // The carry is the earliest cut, so interrupt() reports it.
            assert_eq!(rt.interrupt(2, k, horizon), Some(carry));
        }
        // Round 1 has no history to carry from.
        assert_eq!(rt.flap_carry(1, 0, horizon), None);
    }

    #[test]
    fn flap_carry_is_pure_and_bounded() {
        let rt = runtime(
            FaultPlan {
                enabled: true,
                crash_hazard: 0.4,
                flap_prob: 0.7,
                flap_downtime_s: 40.0,
                ..FaultPlan::default()
            },
            32,
        );
        for t in 2..10 {
            for k in 0..32 {
                let a = rt.flap_carry(t, k, 600.0);
                let _ = rt.interrupt(t, k - (k % 3), 600.0); // interleave
                assert_eq!(a, rt.flap_carry(t, k, 600.0), "pure in (t, k)");
                // Downtime (40s) < horizon (600s): a carried flap must
                // resume within the first 40 seconds of the round.
                if let Some(c) = a {
                    assert_eq!(c.at, 0.0);
                    let r = c.resume.expect("short downtime always resumes");
                    assert!(r > 0.0 && r < 40.0, "leftover {r} out of range");
                }
            }
        }
        // No flapping configured: never a carry.
        let hard = runtime(
            FaultPlan {
                enabled: true,
                crash_hazard: 1.0,
                flap_prob: 0.0,
                flap_downtime_s: 1e9,
                ..FaultPlan::default()
            },
            8,
        );
        assert_eq!(hard.flap_carry(5, 0, 100.0), None);
    }
}
