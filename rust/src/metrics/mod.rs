//! The paper's metric suite: per-round records and run-level summaries of
//! EUR (Eq. 4), SR (Eq. 9), VV (Eq. 10), futility percentage, round
//! length and model quality.

use crate::model::EvalResult;
use crate::util::json::Json;
use crate::util::stats;

/// Everything measured in one federated round.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    /// Round length T (Eq. 17 realization), seconds.
    pub round_len: f64,
    /// Server distribution overhead T_dist (Eq. 19), seconds.
    pub t_dist: f64,
    /// Number of clients forced to synchronize (downloads).
    pub m_sync: usize,
    /// |P(t)| — picked clients whose updates enter this aggregation.
    pub n_picked: usize,
    /// Picked clients that crashed before delivering (Eq. 4's `K_c ∩ P`
    /// term). Structurally 0 for the five current protocols — they all
    /// select from completed or surviving clients — but recorded so
    /// selection-ahead-of-training variants feed EUR correctly.
    pub n_picked_crashed: usize,
    /// Failed participants (crash + overtime).
    pub n_crashed: usize,
    /// Successfully committed updates (picked + undrafted).
    pub n_committed: usize,
    /// |Q(t)| — undrafted (committed but bypassed).
    pub n_undrafted: usize,
    /// Variance of the client model-version distribution after the round.
    pub version_variance: f64,
    /// Wasted training work destroyed by forced synchronization this
    /// round (futility numerator contribution).
    pub futility_wasted: f64,
    /// Attempted training work this round (denominator contribution).
    pub futility_total: f64,
    /// Client-seconds the participants spent online within the round's
    /// deadline window (fleet-engine availability accounting).
    pub online_time: f64,
    /// Client-seconds spent offline within the deadline window.
    pub offline_time: f64,
    /// Staleness (in rounds) of each update applied to the global model
    /// this round: 0 = trained on w(t-1). Sync protocols log zeros;
    /// FedAsync and SAFA log the real lag of what they merged.
    pub staleness: Vec<u32>,
    /// Downlink bytes the server spent distributing the global model
    /// this round (m_sync × model size).
    pub bytes_down: f64,
    /// Uplink bytes of client updates that reached the server this round.
    pub bytes_up: f64,
    /// Bytes the network fabric's update compression saved this round
    /// relative to uncompressed transfers (0 without a fabric codec).
    pub bytes_saved: f64,
    /// Mean training loss over committed updates (NaN-free; 0 if none).
    pub train_loss: f64,
    /// Global model quality, when evaluated this round.
    pub eval: Option<EvalResult>,
}

impl RoundRecord {
    /// Effective Update Ratio for this round (Eq. 4): picked minus
    /// picked-and-crashed over all clients. Picked clients that crashed
    /// can only exist in selection-ahead-of-training protocols, so
    /// `n_picked_crashed` is 0 for every current protocol.
    pub fn eur(&self, m: usize) -> f64 {
        self.n_picked.saturating_sub(self.n_picked_crashed) as f64 / m as f64
    }

    /// Synchronization ratio for this round.
    pub fn sr(&self, m: usize) -> f64 {
        self.m_sync as f64 / m as f64
    }

    /// Per-round JSON record (the entries of `RunResult::to_json`'s
    /// `rounds` array; also the core of the `SAFA_TRACE` JSONL lines).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("round", Json::Num(self.round as f64));
        j.set("round_len", Json::Num(self.round_len));
        j.set("t_dist", Json::Num(self.t_dist));
        j.set("m_sync", Json::Num(self.m_sync as f64));
        j.set("picked", Json::Num(self.n_picked as f64));
        j.set("picked_crashed", Json::Num(self.n_picked_crashed as f64));
        j.set("committed", Json::Num(self.n_committed as f64));
        j.set("crashed", Json::Num(self.n_crashed as f64));
        j.set("undrafted", Json::Num(self.n_undrafted as f64));
        j.set("vv", Json::Num(self.version_variance));
        j.set("futility_wasted", Json::Num(self.futility_wasted));
        j.set("futility_total", Json::Num(self.futility_total));
        j.set("online_time", Json::Num(self.online_time));
        j.set("offline_time", Json::Num(self.offline_time));
        j.set(
            "staleness",
            Json::Arr(
                self.staleness
                    .iter()
                    .map(|&s| Json::Num(s as f64))
                    .collect(),
            ),
        );
        j.set("bytes_down", Json::Num(self.bytes_down));
        j.set("bytes_up", Json::Num(self.bytes_up));
        j.set("bytes_saved", Json::Num(self.bytes_saved));
        if let Some(e) = self.eval {
            j.set("loss", Json::Num(e.loss));
            j.set("acc", Json::Num(e.accuracy));
        }
        j
    }
}

/// A full run: config echo plus per-round records.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub protocol: String,
    pub task: String,
    pub c_fraction: f64,
    pub crash_prob: f64,
    pub tau: usize,
    pub seed: u64,
    pub m: usize,
    pub rounds: Vec<RoundRecord>,
    /// Quality of the final global model (after `finalize`, which matters
    /// for the fully-local baseline).
    pub final_eval: Option<EvalResult>,
}

impl RunResult {
    /// Average federated round length (Tables IV/VI/VIII).
    pub fn avg_round_len(&self) -> f64 {
        stats::mean_iter(self.rounds.iter().map(|r| r.round_len))
    }

    /// Average model-distribution overhead (Tables V/VII/IX).
    pub fn avg_t_dist(&self) -> f64 {
        stats::mean_iter(self.rounds.iter().map(|r| r.t_dist))
    }

    /// Synchronization Ratio over the run (Eq. 9).
    pub fn sync_ratio(&self) -> f64 {
        stats::mean_iter(self.rounds.iter().map(|r| r.sr(self.m)))
    }

    /// Mean Effective Update Ratio (Eq. 4 averaged over rounds).
    pub fn eur(&self) -> f64 {
        stats::mean_iter(self.rounds.iter().map(|r| r.eur(self.m)))
    }

    /// Mean Version Variance (Eq. 10).
    pub fn version_variance(&self) -> f64 {
        stats::mean_iter(self.rounds.iter().map(|r| r.version_variance))
    }

    /// Mean downlink bytes per round (server → clients distribution).
    pub fn avg_bytes_down(&self) -> f64 {
        stats::mean_iter(self.rounds.iter().map(|r| r.bytes_down))
    }

    /// Mean uplink bytes per round (client updates reaching the server).
    pub fn avg_bytes_up(&self) -> f64 {
        stats::mean_iter(self.rounds.iter().map(|r| r.bytes_up))
    }

    /// Mean bytes per round saved by fabric update compression.
    pub fn avg_bytes_saved(&self) -> f64 {
        stats::mean_iter(self.rounds.iter().map(|r| r.bytes_saved))
    }

    /// Fraction of client-time spent online across the run (1.0 when the
    /// engine recorded no availability windows).
    pub fn avg_online_fraction(&self) -> f64 {
        let online: f64 = self.rounds.iter().map(|r| r.online_time).sum();
        let total: f64 = self
            .rounds
            .iter()
            .map(|r| r.online_time + r.offline_time)
            .sum();
        if total > 0.0 {
            online / total
        } else {
            1.0
        }
    }

    /// Histogram of applied-update staleness over the run: index `s`
    /// counts updates that were `s` rounds stale when merged.
    pub fn staleness_histogram(&self) -> Vec<usize> {
        let mut hist: Vec<usize> = Vec::new();
        for r in &self.rounds {
            for &s in &r.staleness {
                let s = s as usize;
                if hist.len() <= s {
                    hist.resize(s + 1, 0);
                }
                hist[s] += 1;
            }
        }
        hist
    }

    /// Futility percentage: wasted / attempted local work
    /// (Tables XI/XIII/XV).
    pub fn futility(&self) -> f64 {
        let wasted: f64 = self.rounds.iter().map(|r| r.futility_wasted).sum();
        let total: f64 = self.rounds.iter().map(|r| r.futility_total).sum();
        if total > 0.0 {
            wasted / total
        } else {
            0.0
        }
    }

    /// Best (minimum) global loss over evaluated rounds.
    pub fn best_loss(&self) -> Option<f64> {
        let mut best: Option<f64> = None;
        for r in &self.rounds {
            if let Some(e) = r.eval {
                best = Some(best.map_or(e.loss, |b: f64| b.min(e.loss)));
            }
        }
        if let Some(e) = self.final_eval {
            best = Some(best.map_or(e.loss, |b: f64| b.min(e.loss)));
        }
        best
    }

    /// Best (maximum) accuracy over evaluated rounds
    /// (Tables X/XII/XIV).
    pub fn best_accuracy(&self) -> Option<f64> {
        let mut best: Option<f64> = None;
        for r in &self.rounds {
            if let Some(e) = r.eval {
                best = Some(best.map_or(e.accuracy, |b: f64| b.max(e.accuracy)));
            }
        }
        if let Some(e) = self.final_eval {
            best = Some(best.map_or(e.accuracy, |b: f64| b.max(e.accuracy)));
        }
        best
    }

    /// Per-round loss trace (Figs. 6–8); rounds without evaluation carry
    /// the previous value forward so traces stay aligned.
    pub fn loss_trace(&self) -> Vec<f64> {
        let mut trace = Vec::with_capacity(self.rounds.len());
        let mut last = f64::NAN;
        for r in &self.rounds {
            if let Some(e) = r.eval {
                last = e.loss;
            }
            trace.push(last);
        }
        trace
    }

    /// Serialize the run for `results/`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("protocol", Json::Str(self.protocol.clone()));
        o.set("task", Json::Str(self.task.clone()));
        o.set("C", Json::Num(self.c_fraction));
        o.set("cr", Json::Num(self.crash_prob));
        o.set("tau", Json::Num(self.tau as f64));
        o.set("seed", Json::Num(self.seed as f64));
        o.set("avg_round_len", Json::Num(self.avg_round_len()));
        o.set("avg_t_dist", Json::Num(self.avg_t_dist()));
        o.set("sync_ratio", Json::Num(self.sync_ratio()));
        o.set("eur", Json::Num(self.eur()));
        o.set("version_variance", Json::Num(self.version_variance()));
        o.set("avg_bytes_down", Json::Num(self.avg_bytes_down()));
        o.set("avg_bytes_up", Json::Num(self.avg_bytes_up()));
        o.set("avg_bytes_saved", Json::Num(self.avg_bytes_saved()));
        o.set("futility", Json::Num(self.futility()));
        o.set("online_fraction", Json::Num(self.avg_online_fraction()));
        o.set(
            "staleness_histogram",
            Json::Arr(
                self.staleness_histogram()
                    .into_iter()
                    .map(|c| Json::Num(c as f64))
                    .collect(),
            ),
        );
        if let Some(l) = self.best_loss() {
            o.set("best_loss", Json::Num(l));
        }
        if let Some(a) = self.best_accuracy() {
            o.set("best_accuracy", Json::Num(a));
        }
        let rounds: Vec<Json> = self.rounds.iter().map(RoundRecord::to_json).collect();
        o.set("rounds", Json::Arr(rounds));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: usize, len: f64, picked: usize, sync: usize) -> RoundRecord {
        RoundRecord {
            round,
            round_len: len,
            t_dist: 1.0,
            m_sync: sync,
            n_picked: picked,
            n_picked_crashed: 0,
            n_crashed: 0,
            n_committed: picked,
            n_undrafted: 0,
            version_variance: 0.5,
            futility_wasted: 0.1,
            futility_total: 1.0,
            online_time: 80.0,
            offline_time: 20.0,
            staleness: vec![0, 2],
            bytes_down: sync as f64 * 1e7,
            bytes_up: picked as f64 * 1e7,
            bytes_saved: 0.0,
            train_loss: 0.0,
            eval: Some(EvalResult {
                loss: 1.0 / (round + 1) as f64,
                accuracy: 0.5 + 0.1 * round as f64,
            }),
        }
    }

    fn run() -> RunResult {
        RunResult {
            protocol: "SAFA".into(),
            task: "regression".into(),
            c_fraction: 0.3,
            crash_prob: 0.1,
            tau: 5,
            seed: 1,
            m: 10,
            rounds: vec![record(0, 100.0, 3, 9), record(1, 200.0, 4, 7)],
            final_eval: None,
        }
    }

    #[test]
    fn summaries() {
        let r = run();
        assert_eq!(r.avg_round_len(), 150.0);
        assert_eq!(r.avg_t_dist(), 1.0);
        assert!((r.sync_ratio() - 0.8).abs() < 1e-12);
        assert!((r.eur() - 0.35).abs() < 1e-12);
        assert!((r.futility() - 0.1).abs() < 1e-12);
        assert_eq!(r.best_loss(), Some(0.5));
        assert_eq!(r.best_accuracy(), Some(0.6));
        assert!((r.avg_online_fraction() - 0.8).abs() < 1e-12);
        // Two rounds, each logging staleness [0, 2].
        assert_eq!(r.staleness_histogram(), vec![2, 0, 2]);
        // Per-round bytes (sync·1e7 down, picked·1e7 up) averaged:
        // down (9+7)/2 = 8 copies, up (3+4)/2 = 3.5 copies.
        assert!((r.avg_bytes_down() - 8e7).abs() < 1e-3);
        assert!((r.avg_bytes_up() - 3.5e7).abs() < 1e-3);
    }

    #[test]
    fn eur_subtracts_picked_and_crashed() {
        // Hand-computed Eq. 4 round: m = 20, 8 picked of which 3 crashed
        // before delivering => EUR = (8 - 3) / 20 = 0.25.
        let mut rec = record(0, 100.0, 8, 5);
        rec.n_picked_crashed = 3;
        assert!((rec.eur(20) - 0.25).abs() < 1e-12);
        // No picked-and-crashed clients (every current protocol):
        // EUR = picked / m.
        assert!((record(0, 100.0, 8, 5).eur(20) - 0.4).abs() < 1e-12);
        // Saturates rather than going negative on inconsistent counts.
        rec.n_picked_crashed = 99;
        assert_eq!(rec.eur(20), 0.0);
    }

    #[test]
    fn round_json_carries_comm_cost() {
        let j = record(1, 100.0, 3, 9).to_json();
        assert_eq!(j.get("m_sync").and_then(Json::as_f64), Some(9.0));
        assert_eq!(j.get("bytes_down").and_then(Json::as_f64), Some(9e7));
        assert_eq!(j.get("bytes_up").and_then(Json::as_f64), Some(3e7));
        assert_eq!(j.get("bytes_saved").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn online_fraction_defaults_to_one_without_windows() {
        let mut r = run();
        for rec in r.rounds.iter_mut() {
            rec.online_time = 0.0;
            rec.offline_time = 0.0;
        }
        assert_eq!(r.avg_online_fraction(), 1.0);
        assert!(r.to_json().get("staleness_histogram").is_some());
    }

    #[test]
    fn loss_trace_carries_forward() {
        let mut r = run();
        r.rounds.push(RoundRecord {
            eval: None,
            ..record(2, 50.0, 1, 1)
        });
        let trace = r.loss_trace();
        assert_eq!(trace.len(), 3);
        assert_eq!(trace[2], trace[1]);
    }

    #[test]
    fn json_has_summary_fields() {
        let j = run().to_json();
        assert!(j.get("avg_round_len").is_some());
        assert!(j.get("best_accuracy").is_some());
        assert_eq!(j.get("rounds").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn final_eval_counts_toward_best() {
        let mut r = run();
        r.final_eval = Some(EvalResult {
            loss: 0.01,
            accuracy: 0.99,
        });
        assert_eq!(r.best_loss(), Some(0.01));
        assert_eq!(r.best_accuracy(), Some(0.99));
    }
}
