//! Closed-form analyses from the paper: the selection-bias model
//! (§III-E / Appendix A, Fig. 5) and the theoretical EUR (Eq. 5).
//!
//! **Erratum note.** The paper's printed closed form for σ^(k) (Eq. 15)
//! is inconsistent with its own recurrence (Eqs. 22/24): e.g. at k=1 it
//! yields σ = 2−cr > 1, which cannot be a probability complement. We
//! therefore evaluate the bias model from the *recurrences* (Eqs. 22–25 /
//! 28–31), which are well-defined, converge, and produce Fig. 5's
//! qualitative shape. [`sigma_paper`] keeps the printed formula for
//! reference, and a regression test documents the discrepancy.

/// The three client-selection regimes of §III-E.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BiasCase {
    /// C ≥ 1−R: selection deficit — every committed update is picked.
    Case1,
    /// (1−C)(1−R) ≤ C < 1−R.
    Case2,
    /// C < (1−C)(1−R): quota met entirely by prioritized clients.
    Case3,
}

/// Classify (C, R) per §III-E.
pub fn classify_case(c: f64, r: f64) -> BiasCase {
    if c >= 1.0 - r {
        BiasCase::Case1
    } else if c >= (1.0 - c) * (1.0 - r) {
        BiasCase::Case2
    } else {
        BiasCase::Case3
    }
}

/// The paper's printed closed form (Eq. 15) — kept verbatim for
/// reference; see the module-level erratum note. Do not use for
/// probabilities.
pub fn sigma_paper(cr: f64, k: u32) -> f64 {
    (2.0 * cr - (cr - 1.0).powi(k as i32 + 1) - 3.0) / (cr - 2.0)
}

/// Direct-to-cache and via-bypass probabilities for client A at round r
/// (Eqs. 22/23 evaluated by recurrence; 1-based r).
pub fn p_a_parts(case: BiasCase, cr_a: f64, r: u32) -> (f64, f64) {
    match case {
        BiasCase::Case1 | BiasCase::Case2 => (1.0 - cr_a, 0.0),
        BiasCase::Case3 => {
            // P_D^(1) = 1 - cr; P_D^(r) = (1-cr)(1 - P_D^(r-1));
            // P_S^(r) = cr·(1 - P_D^(r-1) - cr).
            let mut p_d = 1.0 - cr_a;
            if r <= 1 {
                return (p_d, 0.0);
            }
            let mut p_d_prev = p_d;
            for _ in 2..=r {
                p_d_prev = p_d;
                p_d = (1.0 - cr_a) * (1.0 - p_d_prev);
            }
            let p_s = (cr_a * (1.0 - p_d_prev - cr_a)).max(0.0);
            (p_d, p_s)
        }
    }
}

/// Direct and bypass probabilities for client B (Eqs. 24/25).
pub fn p_b_parts(case: BiasCase, cr_b: f64, r: u32) -> (f64, f64) {
    match case {
        BiasCase::Case1 => (1.0 - cr_b, 0.0),
        BiasCase::Case2 => {
            let mut p_d = 1.0 - cr_b;
            if r <= 1 {
                return (p_d, 0.0);
            }
            let mut p_d_prev = p_d;
            for _ in 2..=r {
                p_d_prev = p_d;
                p_d = (1.0 - cr_b) * (1.0 - p_d_prev);
            }
            let p_s = (cr_b * (1.0 - p_d_prev - cr_b)).max(0.0);
            (p_d, p_s)
        }
        // Case 3: B is never picked directly; its work reaches the cache
        // only through the bypass.
        BiasCase::Case3 => (0.0, 1.0 - cr_b),
    }
}

/// P^(r)(A) = P_D + P_S (Eq. 20).
pub fn p_a(case: BiasCase, cr_a: f64, r: u32) -> f64 {
    let (d, s) = p_a_parts(case, cr_a, r);
    d + s
}

/// P^(r)(B) = P_D + P_S (Eq. 21).
pub fn p_b(case: BiasCase, cr_b: f64, r: u32) -> f64 {
    let (d, s) = p_b_parts(case, cr_b, r);
    d + s
}

/// FedAvg bias between clients A and B (Eq. 12) — constant in r.
pub fn bias_fedavg(cr_a: f64, cr_b: f64) -> f64 {
    (1.0 - cr_a) / (1.0 - cr_b)
}

/// SAFA bias at round r, **corrected** (Eq. 11 with recurrence-evaluated
/// Eqs. 20/21; all quantities are valid probabilities).
pub fn bias_safa(case: BiasCase, cr_a: f64, cr_b: f64, r: u32) -> f64 {
    p_a(case, cr_a, r) / p_b(case, cr_b, r)
}

/// SAFA bias at round r, **paper-verbatim** (Eqs. 13/14/16 with the
/// printed σ of Eq. 15). Reproduces Fig. 5 exactly as published — note
/// P^(r) exceeds 1 in the σ branches, which is the erratum documented in
/// the module header; the figure's *shape* (case 2 below FedAvg, case 3
/// above, convergence in a few rounds) comes from these formulas.
pub fn bias_safa_paper(case: BiasCase, cr_a: f64, cr_b: f64, r: u32) -> f64 {
    let k = r.saturating_sub(1);
    let pa = match case {
        BiasCase::Case1 | BiasCase::Case2 => 1.0 - cr_a,
        BiasCase::Case3 => sigma_paper(cr_a, k) - cr_a * cr_a,
    };
    let pb = match case {
        BiasCase::Case1 | BiasCase::Case3 => 1.0 - cr_b,
        BiasCase::Case2 => sigma_paper(cr_b, k) - cr_b * cr_b,
    };
    pa / pb
}

/// Theoretical SAFA Effective Update Ratio (Eq. 5):
/// EUR = 1−R if C ≥ 1−R else C.
pub fn eur_safa_theory(c: f64, r: f64) -> f64 {
    if c >= 1.0 - r {
        1.0 - r
    } else {
        c
    }
}

/// Theoretical FedAvg EUR: C·(1−R) (§III-B).
pub fn eur_fedavg_theory(c: f64, r: f64) -> f64 {
    c * (1.0 - r)
}

/// The Fig. 5 series (paper-verbatim formulas): bias as a function of
/// round for FedAvg and the three SAFA cases, with cr_A = cr_B = cr.
pub fn fig5_series(cr: f64, rounds: u32) -> (Vec<f64>, [Vec<f64>; 3]) {
    let fedavg: Vec<f64> = (1..=rounds).map(|_| bias_fedavg(cr, cr)).collect();
    let mk = |case: BiasCase| -> Vec<f64> {
        (1..=rounds)
            .map(|r| bias_safa_paper(case, cr, cr, r))
            .collect()
    };
    (
        fedavg,
        [mk(BiasCase::Case1), mk(BiasCase::Case2), mk(BiasCase::Case3)],
    )
}

/// The corrected Fig. 5 series (recurrence-evaluated probabilities).
pub fn fig5_series_corrected(cr: f64, rounds: u32) -> (Vec<f64>, [Vec<f64>; 3]) {
    let fedavg: Vec<f64> = (1..=rounds).map(|_| bias_fedavg(cr, cr)).collect();
    let mk = |case: BiasCase| -> Vec<f64> {
        (1..=rounds).map(|r| bias_safa(case, cr, cr, r)).collect()
    };
    (
        fedavg,
        [mk(BiasCase::Case1), mk(BiasCase::Case2), mk(BiasCase::Case3)],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn case_classification() {
        // C large vs survivors -> case 1.
        assert_eq!(classify_case(0.9, 0.3), BiasCase::Case1);
        // Mid region -> case 2: C=0.5, R=0.3: 1-R=0.7, (1-C)(1-R)=0.35.
        assert_eq!(classify_case(0.5, 0.3), BiasCase::Case2);
        // Small C -> case 3: C=0.1 < 0.9*0.7=0.63.
        assert_eq!(classify_case(0.1, 0.3), BiasCase::Case3);
    }

    #[test]
    fn paper_closed_form_is_inconsistent_with_recurrence() {
        // Documents the erratum: Eq. 15's printed σ^(1) = 2 − cr exceeds
        // 1 for every cr < 1, so it cannot equal 1 − P_D^(1).
        let cr = 0.3;
        let sigma1 = sigma_paper(cr, 1);
        assert!(
            sigma1 > 1.0,
            "if this fails the printed formula was fixed; update the module docs"
        );
        // The recurrence value is a valid probability complement.
        let (p_d, _) = p_a_parts(BiasCase::Case3, cr, 1);
        let sigma_rec = 1.0 - p_d;
        assert!((0.0..=1.0).contains(&sigma_rec));
        assert!((sigma1 - sigma_rec).abs() > 0.5);
    }

    #[test]
    fn probabilities_stay_in_unit_interval() {
        property("bias model probabilities valid", 100, |g| {
            let cr = g.f64_range(0.01, 0.95);
            let case = *g.choose(&[BiasCase::Case1, BiasCase::Case2, BiasCase::Case3]);
            for r in 1..12u32 {
                for p in [p_a(case, cr, r), p_b(case, cr, r)] {
                    assert!(
                        (0.0..=1.0 + 1e-9).contains(&p),
                        "case {case:?} cr={cr} r={r}: p={p}"
                    );
                }
            }
        });
    }

    #[test]
    fn equal_crash_rates_give_unit_fedavg_bias() {
        assert!((bias_fedavg(0.3, 0.3) - 1.0).abs() < 1e-12);
        // Case 1 SAFA matches FedAvg exactly (paper Fig. 5).
        assert!((bias_safa(BiasCase::Case1, 0.3, 0.3, 5) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paper_fig5_shape_case2_below_case3_above() {
        // Fig. 5's published shape with cr_A = cr_B = 0.3: case 1 equals
        // FedAvg (=1), case 2 sits below it, case 3 above it.
        let (fedavg, [c1, c2, c3]) = fig5_series(0.3, 20);
        assert!(fedavg.iter().all(|&b| (b - 1.0).abs() < 1e-12));
        assert!(c1.iter().all(|&b| (b - 1.0).abs() < 1e-12));
        for r in 5..20 {
            assert!(c2[r] < 1.0, "paper case2 bias {} !< 1 at r={r}", c2[r]);
            assert!(c3[r] > 1.0, "paper case3 bias {} !> 1 at r={r}", c3[r]);
        }
    }

    #[test]
    fn corrected_case3_flips_against_the_paper_figure() {
        // Part of the erratum: evaluating the paper's own recurrences
        // with valid probabilities, case 3's steady state gives
        // P(A) = σ* − cr² + ... < 1 − cr = P(B), i.e. bias < 1 — the
        // OPPOSITE side of Fig. 5, which was produced with P(B) > 1
        // pseudo-probabilities. We pin both behaviours.
        let corrected = bias_safa(BiasCase::Case3, 0.3, 0.3, 40);
        assert!(corrected < 1.0, "corrected case-3 bias {corrected}");
        let paper = bias_safa_paper(BiasCase::Case3, 0.3, 0.3, 40);
        assert!(paper > 1.0, "paper case-3 bias {paper}");
    }

    #[test]
    fn bias_converges_within_few_rounds() {
        // Fig. 5: all series converge (damped oscillation, rate |cr−1|).
        for series_fn in [fig5_series, fig5_series_corrected] {
            let (_, [c1, c2, c3]) = series_fn(0.3, 60);
            for series in [c1, c2, c3] {
                let tail: Vec<f64> = series[40..].to_vec();
                let spread = tail.iter().cloned().fold(f64::MIN, f64::max)
                    - tail.iter().cloned().fold(f64::MAX, f64::min);
                assert!(spread < 1e-3, "series did not converge: spread {spread}");
            }
        }
    }

    #[test]
    fn eur_theory() {
        assert!((eur_safa_theory(0.3, 0.5) - 0.3).abs() < 1e-12);
        assert!((eur_safa_theory(0.9, 0.5) - 0.5).abs() < 1e-12);
        assert!((eur_fedavg_theory(0.5, 0.3) - 0.35).abs() < 1e-12);
        // SAFA EUR dominates FedAvg EUR everywhere.
        property("EUR safa >= fedavg", 100, |g| {
            let c = g.f64_range(0.01, 1.0);
            let r = g.f64_range(0.0, 0.99);
            assert!(eur_safa_theory(c, r) >= eur_fedavg_theory(c, r) - 1e-12);
        });
    }
}
