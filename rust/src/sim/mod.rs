//! Round-simulation records and the legacy simulation entry points.
//!
//! The actual execution lives in the discrete-event fleet engine
//! ([`crate::engine`]): a binary-heap scheduler over one virtual clock
//! with typed events and pluggable availability models. This module keeps
//! the output records ([`RoundSim`] / [`ContinuationSim`]) and the seed's
//! two entry points, [`simulate_round`] and [`simulate_continuation`],
//! which are now thin engine wrappers fixed to the paper's per-round
//! Bernoulli crash model. Protocols route through the engine held by
//! `FedEnv` instead, which additionally honours the configured churn
//! model (`env.churn`); under the default Bernoulli model both paths are
//! bit-for-bit identical to the seed implementation.

use crate::client::ClientState;
use crate::config::ExperimentConfig;
use crate::engine::{AvailabilityModel, FleetEngine, RoundCtx};
use crate::net::NetworkModel;
use crate::util::rng::Pcg64;

/// One committed update arriving at the server.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    pub client: usize,
    /// Virtual time (seconds from round start, after T_dist) at which the
    /// upload completes.
    pub time: f64,
}

/// Why a participant failed to commit this round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailReason {
    /// Drew the per-round crash (opt-out / drop-offline) event, or went
    /// offline mid-round under a churn model.
    Crash,
    /// Would finish after the round deadline T_lim — the paper reckons
    /// such clients crashed too (§III-B).
    Overtime,
}

/// Outcome of simulating one round's local-training phase.
///
/// `Default` gives an empty record whose buffers the engine's `_into`
/// entry points clear and refill, so one record can serve a whole run
/// without reallocating.
#[derive(Debug, Clone, Default)]
pub struct RoundSim {
    /// Committed updates ordered by arrival time.
    pub arrivals: Vec<Arrival>,
    /// (client, reason, partial-progress) for each failed participant.
    /// Partial progress is the fraction of the round's training work done
    /// before the failure (uniform at crash; capped at deadline fraction
    /// for overtime clients).
    pub failures: Vec<(usize, FailReason, f64)>,
    /// Client-seconds the participants spent online within the deadline
    /// window (availability accounting for the churn metrics).
    pub online_time: f64,
    /// Client-seconds spent offline within the deadline window.
    pub offline_time: f64,
    /// Latest mid-round drop (`GoOffline`) time among failed
    /// participants — the moment a synchronous server *detects* the last
    /// disconnect. 0.0 when every crash is an opt-out at round start
    /// (the Bernoulli model), so Bernoulli behavior is unchanged.
    pub last_drop: f64,
    /// Downlink bytes re-sent this round (fabric loss retransmits on
    /// completed legs plus server retry copies) — accounted only on the
    /// faults event path, exactly 0.0 otherwise, so adding it to the
    /// flat books is bit-neutral with faults off.
    pub retx_bytes_down: f64,
    /// Uplink bytes re-sent this round (see `retx_bytes_down`).
    pub retx_bytes_up: f64,
}

impl RoundSim {
    pub fn committed(&self) -> impl Iterator<Item = usize> + '_ {
        self.arrivals.iter().map(|a| a.client)
    }

    pub fn crashed_set(&self) -> Vec<usize> {
        self.failures.iter().map(|&(k, _, _)| k).collect()
    }

    /// Time of the last arrival (0.0 when nothing arrived).
    pub fn last_arrival(&self) -> f64 {
        self.arrivals.last().map(|a| a.time).unwrap_or(0.0)
    }
}

/// Simulate the training phase of round `t`.
///
/// * `participants` — client ids that train this round (must be
///   distinct; the engine routes events per client).
/// * `synced` — per participant, whether it downloaded the global model
///   at round start (adds T_down to its finish time).
/// * Crash draws come from a per-(round, client) RNG stream derived from
///   `round_rng`, so the crash pattern is identical across protocols run
///   with the same experiment seed.
///
/// This wrapper always uses the paper's per-round Bernoulli model; churn
/// models need the round index and run through `FedEnv`'s engine.
pub fn simulate_round(
    cfg: &ExperimentConfig,
    net: &NetworkModel,
    clients: &[ClientState],
    participants: &[usize],
    synced: &[bool],
    round_rng: &Pcg64,
) -> RoundSim {
    let mut engine = FleetEngine::new(
        AvailabilityModel::BernoulliPerRound {
            crash_prob: cfg.env.crash_prob,
        },
        clients.len(),
    );
    engine.run_round(
        0,
        RoundCtx {
            cfg,
            net,
            clients,
            fabric: None,
            faults: None,
        },
        participants,
        synced,
        round_rng,
    )
}

/// Outcome of simulating one round under SAFA's continuation semantics.
/// (`Default` = empty reusable record, as for [`RoundSim`].)
#[derive(Debug, Clone, Default)]
pub struct ContinuationSim {
    /// Jobs completing this round (remaining ≤ T_lim), by arrival time.
    pub arrivals: Vec<Arrival>,
    /// Clients offline this round (crash draw or churn) — jobs paused,
    /// no loss.
    pub crashed: Vec<usize>,
    /// Alive clients whose jobs exceed even T_lim — they keep running
    /// into the next round (the paper's stragglers).
    pub stragglers: Vec<usize>,
    /// Client-seconds online within the deadline window.
    pub online_time: f64,
    /// Client-seconds offline within the deadline window.
    pub offline_time: f64,
    /// `(client, seconds-of-work-completed)` for jobs interrupted by a
    /// fault injector this round — the graceful-degradation policy
    /// credits them so a crashed-at-epoch-k job resumes from k, not
    /// zero. Empty off the faults path.
    pub crash_info: Vec<(usize, f64)>,
    /// How many of those fault-cut jobs were cancelled inside their
    /// trailing *upload* leg — SAFA's "picked client crashed before its
    /// update landed" count. 0 off the faults path.
    pub upload_crashed: usize,
    /// Uplink bytes re-sent for retried continuation uploads (faults
    /// path only; 0.0 otherwise).
    pub retx_bytes_up: f64,
}

impl ContinuationSim {
    pub fn last_arrival(&self) -> f64 {
        self.arrivals.last().map(|a| a.time).unwrap_or(0.0)
    }
}

/// Simulate one SAFA round over in-flight jobs.
///
/// `jobs[i]` is the remaining work (seconds) for `participants[i]`'s
/// current job. A crashed client pauses (no progress, no commit); an
/// alive client whose remaining fits inside T_lim arrives at that time;
/// anything longer is a straggler that continues next round. Crash draws
/// use the same per-(round, client) streams as [`simulate_round`], so
/// SAFA and the baselines face identical crash patterns per seed.
pub fn simulate_continuation(
    cfg: &ExperimentConfig,
    participants: &[usize],
    jobs: &[f64],
    round_rng: &Pcg64,
) -> ContinuationSim {
    let m = participants.iter().copied().max().map_or(0, |k| k + 1);
    let mut engine = FleetEngine::new(
        AvailabilityModel::BernoulliPerRound {
            crash_prob: cfg.env.crash_prob,
        },
        m,
    );
    engine.run_continuation(0, cfg, participants, jobs, round_rng)
}

/// The seed's original loop implementation of [`simulate_round`], kept
/// verbatim as the oracle for the engine equivalence tests.
#[cfg(test)]
pub(crate) fn reference_round(
    cfg: &ExperimentConfig,
    net: &NetworkModel,
    clients: &[ClientState],
    participants: &[usize],
    synced: &[bool],
    round_rng: &Pcg64,
) -> RoundSim {
    use crate::util::rng::Bernoulli;
    assert_eq!(participants.len(), synced.len());
    let crash = Bernoulli::new(cfg.env.crash_prob);
    let mut arrivals = Vec::with_capacity(participants.len());
    let mut failures = Vec::new();
    for (&k, &was_synced) in participants.iter().zip(synced) {
        let mut crng = round_rng.split(k as u64);
        let c = &clients[k];
        let t_train = c.t_train(cfg.train.epochs);
        let finish = if was_synced { net.t_down() } else { 0.0 } + t_train + net.t_up();
        if crash.draw(&mut crng) {
            let partial = crng.next_f64();
            failures.push((k, FailReason::Crash, partial));
        } else if finish > cfg.train.t_lim {
            let partial = (cfg.train.t_lim / finish).clamp(0.0, 1.0);
            failures.push((k, FailReason::Overtime, partial));
        } else {
            arrivals.push(Arrival {
                client: k,
                time: finish,
            });
        }
    }
    arrivals.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap());
    RoundSim {
        arrivals,
        failures,
        ..RoundSim::default()
    }
}

/// The seed's original loop implementation of [`simulate_continuation`],
/// kept verbatim as the oracle for the engine equivalence tests.
#[cfg(test)]
pub(crate) fn reference_continuation(
    cfg: &ExperimentConfig,
    participants: &[usize],
    jobs: &[f64],
    round_rng: &Pcg64,
) -> ContinuationSim {
    use crate::util::rng::Bernoulli;
    assert_eq!(participants.len(), jobs.len());
    let crash = Bernoulli::new(cfg.env.crash_prob);
    let mut arrivals = Vec::new();
    let mut crashed = Vec::new();
    let mut stragglers = Vec::new();
    for (&k, &remaining) in participants.iter().zip(jobs) {
        let mut crng = round_rng.split(k as u64);
        if crash.draw(&mut crng) {
            crashed.push(k);
        } else if remaining <= cfg.train.t_lim {
            arrivals.push(Arrival {
                client: k,
                time: remaining,
            });
        } else {
            stragglers.push(k);
        }
    }
    arrivals.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap());
    ContinuationSim {
        arrivals,
        crashed,
        stragglers,
        ..ContinuationSim::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::data::{partition_gaussian, synth, FedData};
    use crate::model::ParamVec;

    fn setup(crash: f64) -> (ExperimentConfig, Vec<ClientState>, NetworkModel) {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.env.crash_prob = crash;
        let (train, test) = synth::generate(cfg.task.kind, cfg.task.n, cfg.task.n_test, 1);
        let mut rng = Pcg64::new(1);
        let partitions = partition_gaussian(train.n, cfg.env.m, 0.3, &mut rng);
        let data = FedData {
            train,
            test,
            partitions,
        };
        let clients =
            crate::client::build_clients(&cfg, &data, &ParamVec::zeros(1), &mut rng);
        let net = NetworkModel::new(&cfg.env);
        (cfg, clients, net)
    }

    #[test]
    fn no_crash_all_fast_clients_arrive_sorted() {
        let (mut cfg, mut clients, net) = setup(0.0);
        cfg.train.t_lim = 1e9;
        for c in clients.iter_mut() {
            c.perf = 1.0 + c.id as f64; // deterministic distinct speeds
            c.batches_per_epoch = 10; // equalize work so speed decides
        }
        let parts: Vec<usize> = (0..clients.len()).collect();
        let synced = vec![true; parts.len()];
        let sim = simulate_round(&cfg, &net, &clients, &parts, &synced, &Pcg64::new(2));
        assert_eq!(sim.arrivals.len(), parts.len());
        assert!(sim.failures.is_empty());
        for w in sim.arrivals.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
        // Fastest client (highest perf) arrives first.
        assert_eq!(sim.arrivals[0].client, clients.len() - 1);
    }

    #[test]
    fn crash_prob_one_fails_everyone() {
        let (cfg, clients, net) = setup(1.0);
        let parts: Vec<usize> = (0..clients.len()).collect();
        let synced = vec![false; parts.len()];
        let sim = simulate_round(&cfg, &net, &clients, &parts, &synced, &Pcg64::new(3));
        assert!(sim.arrivals.is_empty());
        assert_eq!(sim.failures.len(), parts.len());
        for &(_, reason, partial) in &sim.failures {
            assert_eq!(reason, FailReason::Crash);
            assert!((0.0..1.0).contains(&partial));
        }
        // Everyone offline the whole round.
        assert_eq!(sim.online_time, 0.0);
        assert!(sim.offline_time > 0.0);
    }

    #[test]
    fn slow_clients_go_overtime() {
        let (mut cfg, mut clients, net) = setup(0.0);
        cfg.train.t_lim = 10.0; // everything times out (t_up alone is 57 s)
        for c in clients.iter_mut() {
            c.perf = 1.0;
        }
        let parts = vec![0usize];
        let sim = simulate_round(&cfg, &net, &clients, &parts, &[false], &Pcg64::new(4));
        assert!(sim.arrivals.is_empty());
        assert_eq!(sim.failures[0].1, FailReason::Overtime);
        assert!(sim.failures[0].2 < 1.0);
    }

    #[test]
    fn sync_adds_download_time() {
        let (mut cfg, mut clients, net) = setup(0.0);
        cfg.train.t_lim = 1e9;
        clients[0].perf = 1.0;
        let a = simulate_round(&cfg, &net, &clients, &[0], &[false], &Pcg64::new(5));
        let b = simulate_round(&cfg, &net, &clients, &[0], &[true], &Pcg64::new(5));
        assert!((b.arrivals[0].time - a.arrivals[0].time - net.t_down()).abs() < 1e-9);
    }

    #[test]
    fn continuation_partitions_participants() {
        let (mut cfg, _clients, _net) = setup(0.0);
        cfg.train.t_lim = 100.0;
        let parts = vec![0usize, 1, 2];
        let jobs = vec![50.0, 150.0, 99.9];
        let sim = simulate_continuation(&cfg, &parts, &jobs, &Pcg64::new(8));
        assert_eq!(sim.arrivals.len(), 2);
        assert_eq!(sim.arrivals[0].client, 0);
        assert_eq!(sim.arrivals[1].client, 2);
        assert_eq!(sim.stragglers, vec![1]);
        assert!(sim.crashed.is_empty());
        assert!((sim.last_arrival() - 99.9).abs() < 1e-9);
    }

    #[test]
    fn continuation_crash_pauses_everyone() {
        let (cfg, _clients, _net) = setup(1.0);
        let parts = vec![0usize, 1];
        let jobs = vec![10.0, 20.0];
        let sim = simulate_continuation(&cfg, &parts, &jobs, &Pcg64::new(9));
        assert!(sim.arrivals.is_empty());
        assert_eq!(sim.crashed, vec![0, 1]);
        assert!(sim.stragglers.is_empty());
    }

    #[test]
    fn continuation_and_round_share_crash_pattern() {
        // Same (round_rng, client) streams drive both simulators.
        let (cfg, clients, net) = setup(0.5);
        let parts: Vec<usize> = (0..clients.len()).collect();
        let rr = Pcg64::new(10);
        let a = simulate_round(&cfg, &net, &clients, &parts, &vec![false; parts.len()], &rr);
        let b = simulate_continuation(&cfg, &parts, &vec![1.0; parts.len()], &rr);
        let crashed_a: Vec<usize> = a
            .failures
            .iter()
            .filter(|&&(_, r, _)| r == FailReason::Crash)
            .map(|&(k, _, _)| k)
            .collect();
        assert_eq!(crashed_a, b.crashed);
    }

    #[test]
    fn crash_pattern_is_per_round_stream() {
        let (cfg, clients, net) = setup(0.5);
        let parts: Vec<usize> = (0..clients.len()).collect();
        let synced = vec![false; parts.len()];
        let r1 = simulate_round(&cfg, &net, &clients, &parts, &synced, &Pcg64::new(6));
        let r1b = simulate_round(&cfg, &net, &clients, &parts, &synced, &Pcg64::new(6));
        let r2 = simulate_round(&cfg, &net, &clients, &parts, &synced, &Pcg64::new(7));
        assert_eq!(r1.crashed_set(), r1b.crashed_set());
        // Different round stream -> (almost surely) different pattern.
        assert_ne!(
            (r1.crashed_set(), r1.arrivals.len()),
            (r2.crashed_set(), r2.arrivals.len())
        );
    }
}
