//! Federated data partitioning.
//!
//! The paper assumes local data sizes follow N(mu, 0.3*mu) with mu = n/m
//! (§IV-A). We sample raw sizes from that Gaussian, clamp to >= 1,
//! renormalize so they sum exactly to n, and deal shuffled sample indices
//! accordingly — every sample belongs to exactly one client.

use crate::util::rng::{Distribution, Normal, Pcg64};

/// One client's shard: indices into the global training set.
#[derive(Debug, Clone)]
pub struct Partition {
    pub client: usize,
    pub indices: Vec<usize>,
}

/// Partition `n` samples across `m` clients with Gaussian-distributed
/// shard sizes (relative std `rel_std`, the paper uses 0.3).
pub fn partition_gaussian(n: usize, m: usize, rel_std: f64, rng: &mut Pcg64) -> Vec<Partition> {
    assert!(m > 0 && n >= m, "need n >= m >= 1");
    let mu = n as f64 / m as f64;
    let dist = Normal::new(mu, rel_std * mu);

    // Draw raw sizes, clamp at 1.
    let mut sizes: Vec<f64> = (0..m).map(|_| dist.sample(rng).max(1.0)).collect();
    // Scale so they sum to n, then round with largest-remainder to keep
    // the total exact and every shard >= 1.
    let total: f64 = sizes.iter().sum();
    for s in sizes.iter_mut() {
        *s *= n as f64 / total;
    }
    let mut int_sizes: Vec<usize> = sizes.iter().map(|&s| s.floor().max(1.0) as usize).collect();
    let mut assigned: usize = int_sizes.iter().sum();
    // Distribute the remainder by largest fractional part.
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| {
        let fa = sizes[a] - sizes[a].floor();
        let fb = sizes[b] - sizes[b].floor();
        fb.partial_cmp(&fa).unwrap()
    });
    let mut i = 0;
    while assigned < n {
        int_sizes[order[i % m]] += 1;
        assigned += 1;
        i += 1;
    }
    // If clamping overshot (rare), trim from the largest shards.
    while assigned > n {
        let (argmax, _) = int_sizes
            .iter()
            .enumerate()
            .max_by_key(|(_, &s)| s)
            .unwrap();
        if int_sizes[argmax] > 1 {
            int_sizes[argmax] -= 1;
            assigned -= 1;
        } else {
            break;
        }
    }

    // Deal shuffled indices.
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut parts = Vec::with_capacity(m);
    let mut cursor = 0;
    for (client, &size) in int_sizes.iter().enumerate() {
        let end = (cursor + size).min(n);
        parts.push(Partition {
            client,
            indices: idx[cursor..end].to_vec(),
        });
        cursor = end;
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn partitions_conserve_mass() {
        let mut rng = Pcg64::new(5);
        let parts = partition_gaussian(506, 5, 0.3, &mut rng);
        assert_eq!(parts.len(), 5);
        let total: usize = parts.iter().map(|p| p.indices.len()).sum();
        assert_eq!(total, 506);
        // No duplicates across clients.
        let mut all: Vec<usize> = parts.iter().flat_map(|p| p.indices.clone()).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 506);
    }

    #[test]
    fn sizes_are_heterogeneous() {
        let mut rng = Pcg64::new(7);
        let parts = partition_gaussian(10_000, 100, 0.3, &mut rng);
        let sizes: Vec<f64> = parts.iter().map(|p| p.indices.len() as f64).collect();
        let mean = crate::util::stats::mean(&sizes);
        let std = crate::util::stats::variance(&sizes).sqrt();
        assert!((mean - 100.0).abs() < 1.0);
        // Relative std should be near 0.3 (loose bound: clamping skews it).
        assert!(std / mean > 0.15 && std / mean < 0.45, "rel std {}", std / mean);
    }

    #[test]
    fn property_mass_and_minimum_shard() {
        property("partition mass conserved", 100, |g| {
            let m = g.usize_range(1, 40);
            let n = m + g.usize_range(0, 2_000);
            let rel = g.f64_range(0.05, 0.6);
            let parts = partition_gaussian(n, m, rel, g.rng());
            assert_eq!(parts.len(), m);
            let total: usize = parts.iter().map(|p| p.indices.len()).sum();
            assert_eq!(total, n);
            assert!(parts.iter().all(|p| !p.indices.is_empty()));
            assert!(parts.iter().all(|p| p.indices.iter().all(|&i| i < n)));
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let a = partition_gaussian(1000, 10, 0.3, &mut Pcg64::new(42));
        let b = partition_gaussian(1000, 10, 0.3, &mut Pcg64::new(42));
        for (pa, pb) in a.iter().zip(&b) {
            assert_eq!(pa.indices, pb.indices);
        }
    }
}
