//! Synthetic dataset generators.
//!
//! The environment is offline, so the paper's public datasets are replaced
//! by synthetic equivalents with the same shapes and learnability
//! characteristics (see DESIGN.md §3 for the substitution argument):
//!
//! * [`boston_like`] — Task 1: 13 features with Boston-housing-like scales
//!   and a positive, linear-plus-noise median-value target.
//! * [`digits_like`] — Task 2: 28×28 grayscale digit images rendered from
//!   seven-segment stroke templates with per-sample jitter, shift and
//!   noise; 10 balanced classes.
//! * [`kdd_like`] — Task 3: 35-feature TCP-connection-like records, binary
//!   normal/intrusion labels (±1), linearly separable with overlap and a
//!   heavy-tailed minority of outliers.

use super::Dataset;
use crate::config::TaskKind;
use crate::util::rng::{Bernoulli, Distribution, Exponential, Normal, Pcg64, Uniform};

/// Task 1 generator: Boston-housing-like regression.
///
/// Features mimic the real table's scales (crime rate, rooms, tax, ...);
/// the target is a linear combination with feature-dependent signs plus
/// Gaussian noise, shifted to stay positive (the paper's accuracy formula
/// divides by max(y, ŷ) and needs positive targets).
pub fn boston_like(n: usize, rng: &mut Pcg64) -> Dataset {
    const D: usize = 13;
    // (mean, std) per feature, loosely matching Boston column statistics.
    const SCALES: [(f64, f64); D] = [
        (3.6, 8.6),    // CRIM
        (11.4, 23.3),  // ZN
        (11.1, 6.9),   // INDUS
        (0.07, 0.25),  // CHAS
        (0.55, 0.12),  // NOX
        (6.28, 0.70),  // RM
        (68.6, 28.1),  // AGE
        (3.8, 2.1),    // DIS
        (9.5, 8.7),    // RAD
        (408.2, 168.5),// TAX
        (18.5, 2.2),   // PTRATIO
        (356.7, 91.3), // B
        (12.7, 7.1),   // LSTAT
    ];
    // Ground-truth weights on standardized features (rooms up, crime down,
    // lstat down — the qualitative structure of the real regression).
    const W: [f64; D] = [
        -1.0, 0.3, -0.2, 0.5, -0.8, 3.5, -0.1, -1.2, 0.4, -0.9, -1.5, 0.6, -3.2,
    ];
    let noise = Normal::new(0.0, 1.5);
    let mut x = Vec::with_capacity(n * D);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut target = 22.5; // mean house value in $1000s
        for j in 0..D {
            let (mu, sd) = SCALES[j];
            let z = Normal::new(0.0, 1.0).sample(rng);
            let feat = mu + sd * z;
            x.push(feat as f32);
            target += W[j] * z;
        }
        target += noise.sample(rng);
        // Median values in the real data live in [5, 50].
        y.push(target.clamp(5.0, 50.0) as f32);
    }
    let mut ds = Dataset::new(TaskKind::Regression, x, y, D);
    standardize_features(&mut ds);
    ds
}

/// Standardize features to zero mean / unit variance (columnwise).
/// Mirrors the preprocessing any sane regression on Boston does; the
/// Python model applies no further scaling.
pub fn standardize_features(ds: &mut Dataset) {
    let (n, d) = (ds.n, ds.d);
    for j in 0..d {
        let mut mean = 0.0f64;
        for i in 0..n {
            mean += ds.x[i * d + j] as f64;
        }
        mean /= n as f64;
        let mut var = 0.0f64;
        for i in 0..n {
            let diff = ds.x[i * d + j] as f64 - mean;
            var += diff * diff;
        }
        var /= n as f64;
        let std = var.sqrt().max(1e-6);
        for i in 0..n {
            ds.x[i * d + j] = ((ds.x[i * d + j] as f64 - mean) / std) as f32;
        }
    }
}

/// Seven-segment layouts for digits 0–9.
/// Segments: 0=top, 1=top-left, 2=top-right, 3=middle, 4=bottom-left,
/// 5=bottom-right, 6=bottom.
const SEGMENTS: [[bool; 7]; 10] = [
    [true, true, true, false, true, true, true],    // 0
    [false, false, true, false, false, true, false],// 1
    [true, false, true, true, true, false, true],   // 2
    [true, false, true, true, false, true, true],   // 3
    [false, true, true, true, false, true, false],  // 4
    [true, true, false, true, false, true, true],   // 5
    [true, true, false, true, true, true, true],    // 6
    [true, false, true, false, false, true, false], // 7
    [true, true, true, true, true, true, true],     // 8
    [true, true, true, true, false, true, true],    // 9
];

/// Task 2 generator: MNIST-like 28×28 digit images.
///
/// Each sample renders its class's seven-segment template into a 28×28
/// canvas with random shift (±3 px), stroke thickness jitter, amplitude
/// jitter and additive Gaussian noise — enough intra-class variance that
/// the CNN has something non-trivial to learn, while staying solvable to
/// ~98% like MNIST.
pub fn digits_like(n: usize, rng: &mut Pcg64) -> Dataset {
    const SIDE: usize = 28;
    const D: usize = SIDE * SIDE;
    let mut x = vec![0.0f32; n * D];
    let mut y = Vec::with_capacity(n);
    let shift = Uniform::new(-3.0, 3.0);
    let noise = Normal::new(0.0, 0.08);
    for i in 0..n {
        let class = rng.index(10);
        y.push(class as f32);
        let dx = shift.sample(rng).round() as isize;
        let dy = shift.sample(rng).round() as isize;
        let thick = 1 + rng.index(2) as isize; // stroke half-width 1..2
        let amp = 0.75 + 0.25 * rng.next_f64() as f64;
        let img = &mut x[i * D..(i + 1) * D];
        draw_digit(img, SIDE, class, dx, dy, thick, amp as f32);
        for px in img.iter_mut() {
            *px = (*px + noise.sample(rng) as f32).clamp(0.0, 1.0);
        }
    }
    Dataset::new(TaskKind::Cnn, x, y, D)
}

/// Render digit `class` into `img` (side×side) with the given offset,
/// stroke half-width and amplitude.
fn draw_digit(img: &mut [f32], side: usize, class: usize, dx: isize, dy: isize, thick: isize, amp: f32) {
    // Segment geometry on a 28×28 canvas (x: 8..20, y: 4..24).
    let (x0, x1) = (8isize, 19isize);
    let (y0, ym, y1) = (4isize, 13isize, 23isize);
    let segs = &SEGMENTS[class];
    let mut stroke = |xa: isize, ya: isize, xb: isize, yb: isize| {
        // Thick line from (xa,ya) to (xb,yb), axis-aligned.
        let steps = (xb - xa).abs().max((yb - ya).abs()).max(1);
        for s in 0..=steps {
            let cx = xa + (xb - xa) * s / steps + dx;
            let cy = ya + (yb - ya) * s / steps + dy;
            for ox in -thick..=thick {
                for oy in -thick..=thick {
                    let px = cx + ox;
                    let py = cy + oy;
                    if px >= 0 && py >= 0 && (px as usize) < side && (py as usize) < side {
                        let falloff = 1.0 - 0.25 * (ox.abs().max(oy.abs()) as f32 / thick as f32);
                        let v = amp * falloff;
                        let cell = &mut img[py as usize * side + px as usize];
                        *cell = cell.max(v);
                    }
                }
            }
        }
    };
    if segs[0] {
        stroke(x0, y0, x1, y0);
    }
    if segs[1] {
        stroke(x0, y0, x0, ym);
    }
    if segs[2] {
        stroke(x1, y0, x1, ym);
    }
    if segs[3] {
        stroke(x0, ym, x1, ym);
    }
    if segs[4] {
        stroke(x0, ym, x0, y1);
    }
    if segs[5] {
        stroke(x1, ym, x1, y1);
    }
    if segs[6] {
        stroke(x0, y1, x1, y1);
    }
}

/// Task 3 generator: KDD-Cup'99-like intrusion detection records.
///
/// 35 features: a mix of Gaussian "traffic volume" features whose means
/// differ by class, exponential heavy-tailed counters, and a few
/// near-constant flag-like columns. Labels are ±1 (intrusion / normal)
/// with a configurable class skew similar to the real extract (~60/40).
/// The classes are linearly separable up to ~0.5% overlap, matching the
/// >99% SVM accuracy in the paper's Table XIV.
pub fn kdd_like(n: usize, rng: &mut Pcg64) -> Dataset {
    const D: usize = 35;
    let mut x = Vec::with_capacity(n * D);
    let mut y = Vec::with_capacity(n);
    let class_prior = Bernoulli::new(0.4); // P(intrusion)
    let gauss = Normal::new(0.0, 1.0);
    let heavy = Exponential::new(0.8);
    let flip = Bernoulli::new(0.004); // label noise -> ~99.5% ceiling

    // Class-mean offsets for the informative features (first 20).
    let mut offsets = [0.0f64; D];
    let mut o_rng = rng.split(0x0ffe7);
    for off in offsets.iter_mut().take(20) {
        *off = 1.2 + 0.8 * o_rng.next_f64();
    }

    for _ in 0..n {
        let intrusion = class_prior.draw(rng);
        let sign = if intrusion { 1.0 } else { -1.0 };
        for (j, off) in offsets.iter().enumerate().take(D) {
            let v = if j < 20 {
                // Informative Gaussian features.
                sign * off + gauss.sample(rng)
            } else if j < 30 {
                // Heavy-tailed counters, weakly informative.
                let base = heavy.sample(rng);
                if intrusion {
                    base * 1.3
                } else {
                    base
                }
            } else {
                // Flag-like: mostly zero.
                if rng.next_f64() < 0.05 {
                    1.0
                } else {
                    0.0
                }
            };
            x.push(v as f32);
        }
        let label = if flip.draw(rng) { -sign } else { sign };
        y.push(label as f32);
    }
    let mut ds = Dataset::new(TaskKind::Svm, x, y, D);
    standardize_features(&mut ds);
    ds
}

/// Generate the train+test datasets for a task from one seed.
pub fn generate(task: TaskKind, n_train: usize, n_test: usize, seed: u64) -> (Dataset, Dataset) {
    let mut rng = Pcg64::with_stream(seed, 0xda7a);
    let mut test_rng = rng.split(1);
    match task {
        TaskKind::Regression => (boston_like(n_train, &mut rng), boston_like(n_test, &mut test_rng)),
        TaskKind::Cnn => (digits_like(n_train, &mut rng), digits_like(n_test, &mut test_rng)),
        TaskKind::Svm => (kdd_like(n_train, &mut rng), kdd_like(n_test, &mut test_rng)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boston_like_shapes_and_targets() {
        let mut rng = Pcg64::new(1);
        let ds = boston_like(506, &mut rng);
        assert_eq!(ds.n, 506);
        assert_eq!(ds.d, 13);
        assert!(ds.y.iter().all(|&v| (5.0..=50.0).contains(&v)));
        // Standardized features: column means ~ 0.
        for j in 0..13 {
            let mean: f32 = (0..ds.n).map(|i| ds.x[i * 13 + j]).sum::<f32>() / ds.n as f32;
            assert!(mean.abs() < 1e-3, "col {j} mean {mean}");
        }
    }

    #[test]
    fn digits_like_valid_images() {
        let mut rng = Pcg64::new(2);
        let ds = digits_like(200, &mut rng);
        assert_eq!(ds.d, 784);
        assert!(ds.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(ds.y.iter().all(|&c| (0.0..10.0).contains(&c)));
        // All 10 classes appear in 200 samples (w.h.p.).
        let mut seen = [false; 10];
        for &c in &ds.y {
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "classes seen: {seen:?}");
        // Images are mostly dark with a bright stroke region.
        let lit = ds.x.iter().filter(|&&v| v > 0.5).count() as f64 / ds.x.len() as f64;
        assert!(lit > 0.02 && lit < 0.5, "lit fraction {lit}");
    }

    #[test]
    fn digit_classes_are_distinguishable() {
        // Templates of different digits must differ in many pixels.
        for a in 0..10usize {
            for b in (a + 1)..10 {
                let mut ia = vec![0.0f32; 784];
                let mut ib = vec![0.0f32; 784];
                draw_digit(&mut ia, 28, a, 0, 0, 1, 1.0);
                draw_digit(&mut ib, 28, b, 0, 0, 1, 1.0);
                let diff = ia
                    .iter()
                    .zip(&ib)
                    .filter(|(p, q)| (**p - **q).abs() > 0.5)
                    .count();
                // Closest pair (3 vs 9) differs in one vertical segment
                // minus shared corners ≈ 18 px.
                assert!(diff >= 12, "digits {a} and {b} differ in {diff} px");
            }
        }
    }

    #[test]
    fn kdd_like_labels_and_balance() {
        let mut rng = Pcg64::new(3);
        let ds = kdd_like(5000, &mut rng);
        assert_eq!(ds.d, 35);
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
        let pos = ds.y.iter().filter(|&&v| v == 1.0).count() as f64 / ds.n as f64;
        assert!((pos - 0.4).abs() < 0.05, "positive rate {pos}");
    }

    #[test]
    fn kdd_like_is_nearly_linearly_separable() {
        // A few epochs of perceptron should exceed 95% train accuracy.
        let mut rng = Pcg64::new(4);
        let ds = kdd_like(2000, &mut rng);
        let mut w = vec![0.0f32; ds.d + 1];
        for _ in 0..5 {
            for i in 0..ds.n {
                let row = ds.row(i);
                let score: f32 =
                    row.iter().zip(&w[..ds.d]).map(|(a, b)| a * b).sum::<f32>() + w[ds.d];
                if ds.y[i] * score <= 0.0 {
                    for j in 0..ds.d {
                        w[j] += 0.1 * ds.y[i] * row[j];
                    }
                    w[ds.d] += 0.1 * ds.y[i];
                }
            }
        }
        let correct = (0..ds.n)
            .filter(|&i| {
                let row = ds.row(i);
                let score: f32 =
                    row.iter().zip(&w[..ds.d]).map(|(a, b)| a * b).sum::<f32>() + w[ds.d];
                ds.y[i] * score > 0.0
            })
            .count();
        let acc = correct as f64 / ds.n as f64;
        assert!(acc > 0.95, "perceptron accuracy {acc}");
    }

    #[test]
    fn generate_is_deterministic_and_split() {
        let (tr1, te1) = generate(TaskKind::Svm, 100, 50, 9);
        let (tr2, te2) = generate(TaskKind::Svm, 100, 50, 9);
        assert_eq!(tr1.x, tr2.x);
        assert_eq!(te1.y, te2.y);
        assert_eq!(tr1.n, 100);
        assert_eq!(te1.n, 50);
        // Train and test are different draws.
        assert_ne!(tr1.x[..35], te1.x[..35]);
    }
}
