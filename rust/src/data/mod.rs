//! Datasets, synthetic generators and the federated partitioner.

pub mod partition;
pub mod synth;

pub use partition::{partition_gaussian, Partition};

use crate::config::TaskKind;

/// A dense dataset: `n` rows of `d` f32 features plus one label per row.
///
/// Labels are stored as f32: the regression target for Task 1, the class
/// index (0..10) for Task 2, and ±1 for the SVM Task 3.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub n: usize,
    pub d: usize,
    pub task: TaskKind,
}

impl Dataset {
    pub fn new(task: TaskKind, x: Vec<f32>, y: Vec<f32>, d: usize) -> Dataset {
        assert!(d > 0, "d must be positive");
        assert_eq!(x.len() % d, 0, "x length not a multiple of d");
        let n = x.len() / d;
        assert_eq!(y.len(), n, "label count mismatch");
        Dataset { x, y, n, d, task }
    }

    /// Row `i` as a feature slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Gather a subset of rows into a new dense block (used to feed the
    /// XLA runtime, which wants contiguous buffers).
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let mut x = Vec::with_capacity(idx.len() * self.d);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        (x, y)
    }
}

/// Train + test split plus the per-client index partition.
#[derive(Debug, Clone)]
pub struct FedData {
    pub train: Dataset,
    pub test: Dataset,
    pub partitions: Vec<Partition>,
}

impl FedData {
    /// Samples held by client `k`.
    pub fn client_size(&self, k: usize) -> usize {
        self.partitions[k].indices.len()
    }

    /// Total training samples across clients (= n when fully assigned).
    pub fn total_size(&self) -> usize {
        self.partitions.iter().map(|p| p.indices.len()).sum()
    }

    /// Number of mini-batches client `k` processes per epoch.
    pub fn client_batches(&self, k: usize, batch_size: usize) -> usize {
        self.client_size(k).div_ceil(batch_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_shape_checks() {
        let ds = Dataset::new(
            TaskKind::Regression,
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            vec![0.5, 1.5],
            3,
        );
        assert_eq!(ds.n, 2);
        assert_eq!(ds.row(1), &[4.0, 5.0, 6.0]);
        let (x, y) = ds.gather(&[1, 0]);
        assert_eq!(x, vec![4.0, 5.0, 6.0, 1.0, 2.0, 3.0]);
        assert_eq!(y, vec![1.5, 0.5]);
    }

    #[test]
    #[should_panic(expected = "label count mismatch")]
    fn mismatched_labels_panic() {
        Dataset::new(TaskKind::Svm, vec![1.0, 2.0], vec![1.0, -1.0, 1.0], 2);
    }
}
