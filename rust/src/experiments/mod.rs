//! Experiment drivers shared by the bench suite (`rust/benches/`) and the
//! CLI: protocol × cr × C grids in the paper's table layout, loss-trace
//! figures and the lag-tolerance sweep.
//!
//! Scale policy: timing/overhead/SR/futility grids run the paper's exact
//! Table II profiles on the Null backend (their metrics are independent
//! of gradient numerics); accuracy grids and loss traces run real
//! training on scaled configs sized for one core (see DESIGN.md §6 and
//! the preset docs). `SAFA_PRESET=paper` upgrades everything to paper
//! scale.

use crate::bench_harness::{Series, Table};
use crate::config::{presets, Backend, CnnArch, ExperimentConfig, ProtocolKind, TaskKind};
use crate::coordinator::run_with_data;
use crate::data::{partition_gaussian, synth, FedData};
use crate::metrics::RunResult;
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// The paper's evaluation grid.
pub const CRS: [f64; 4] = [0.1, 0.3, 0.5, 0.7];
pub const CS: [f64; 5] = [0.1, 0.3, 0.5, 0.7, 1.0];

/// Which scalar a grid cell reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    RoundLen,
    TDist,
    BestAccuracy,
    SyncRatio,
    Futility,
    Eur,
    VersionVariance,
    BestLoss,
}

impl Metric {
    pub fn extract(&self, r: &RunResult) -> f64 {
        match self {
            Metric::RoundLen => r.avg_round_len(),
            Metric::TDist => r.avg_t_dist(),
            Metric::BestAccuracy => r.best_accuracy().unwrap_or(f64::NAN),
            Metric::SyncRatio => r.sync_ratio(),
            Metric::Futility => r.futility(),
            Metric::Eur => r.eur(),
            Metric::VersionVariance => r.version_variance(),
            Metric::BestLoss => r.best_loss().unwrap_or(f64::NAN),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Metric::RoundLen => "avg_round_len_s",
            Metric::TDist => "avg_t_dist_s",
            Metric::BestAccuracy => "best_accuracy",
            Metric::SyncRatio => "sync_ratio",
            Metric::Futility => "futility",
            Metric::Eur => "eur",
            Metric::VersionVariance => "version_variance",
            Metric::BestLoss => "best_loss",
        }
    }
}

/// Timing-grid config: the paper's exact environment profile with the
/// Null trainer (round length / T_dist / SR / EUR / futility are
/// invariant to gradient numerics).
pub fn timing_cfg(task: usize) -> ExperimentConfig {
    let mut cfg = match task {
        1 => presets::task1(),
        2 => presets::task2(),
        3 => presets::task3(),
        _ => panic!("task must be 1..=3"),
    };
    cfg.backend = Backend::Null;
    cfg.eval_every = 1_000_000;
    if fast_mode() {
        cfg.train.rounds = cfg.train.rounds.min(15);
    }
    cfg
}

/// Accuracy-grid config: real training, scaled to finish a full
/// 4-protocol grid on one core. `SAFA_PRESET=paper` restores Table II.
pub fn accuracy_cfg(task: usize) -> ExperimentConfig {
    if std::env::var("SAFA_PRESET").as_deref() == Ok("paper") {
        let mut cfg = match task {
            1 => presets::task1(),
            2 => presets::task2(),
            3 => presets::task3(),
            _ => panic!("task must be 1..=3"),
        };
        cfg.backend = Backend::Native;
        return cfg;
    }
    let mut cfg = match task {
        1 => presets::task1(), // already laptop-sized: run at paper scale
        2 => {
            let mut c = presets::task2_scaled();
            // Further reduction for the 80-run grid (documented in
            // EXPERIMENTS.md): protocol ordering is preserved, absolute
            // accuracies are lower than the paper's MNIST numbers.
            c.env.m = 10;
            c.task.n = 600;
            c.task.n_test = 200;
            c.task.cnn = CnnArch {
                c1: 6,
                c2: 12,
                hidden: 48,
            };
            c.train.batch_size = 20;
            c.train.epochs = 3;
            c.train.rounds = 8;
            c.train.lr = 5e-3;
            c
        }
        3 => {
            let mut c = presets::task3_scaled();
            c.env.m = 50;
            c.task.n = 5_000;
            c.task.n_test = 2_000;
            c.train.rounds = 15;
            c
        }
        _ => panic!("task must be 1..=3"),
    };
    cfg.backend = Backend::Native;
    if fast_mode() {
        cfg.train.rounds = cfg.train.rounds.min(6);
    }
    cfg
}

fn fast_mode() -> bool {
    std::env::var("SAFA_BENCH_FAST").as_deref() == Ok("1")
}

/// Share one dataset + partition across a grid (the paper holds data
/// fixed while varying protocol/C/cr).
pub fn shared_data(cfg: &ExperimentConfig) -> Arc<FedData> {
    let (train, test) = synth::generate(cfg.task.kind, cfg.task.n, cfg.task.n_test, cfg.seed);
    let mut rng = Pcg64::with_stream(cfg.seed, 0x9a57);
    let partitions = partition_gaussian(train.n, cfg.env.m, cfg.env.partition_rel_std, &mut rng);
    Arc::new(FedData {
        train,
        test,
        partitions,
    })
}

/// Run a full cr × C grid for each protocol and return the paper-layout
/// table.
pub fn grid_table(
    title: &str,
    base: &ExperimentConfig,
    protocols: &[ProtocolKind],
    metric: Metric,
) -> Table {
    let data = shared_data(base);
    let mut table = Table::new(title, &CRS, &CS);
    table.precision = match metric {
        Metric::RoundLen | Metric::TDist => 2,
        _ => 4,
    };
    for proto in protocols {
        let mut rows = Vec::new();
        for &cr in &CRS {
            let mut row = Vec::new();
            for &c in &CS {
                let mut cfg = base.clone();
                cfg.protocol.kind = *proto;
                cfg.protocol.c_fraction = c;
                cfg.env.crash_prob = cr;
                let result = run_with_data(&cfg, Arc::clone(&data))
                    .unwrap_or_else(|e| panic!("{title} {proto:?} cr={cr} C={c}: {e}"));
                row.push(metric.extract(&result));
            }
            rows.push(row);
        }
        table.add_block(proto.name(), rows);
    }
    table
}

/// Figs. 6–8: loss traces at C = 0.3 for each crash probability, every
/// protocol (the paper's four plus the FedAsync baseline as an extra
/// line).
pub fn loss_trace_figure(task: usize, title: &str) -> Vec<Series> {
    let base = accuracy_cfg(task);
    let data = shared_data(&base);
    let mut figures = Vec::new();
    for &cr in &CRS {
        let x: Vec<f64> = (1..=base.train.rounds).map(|r| r as f64).collect();
        let mut s = Series::new(&format!("{title} (cr={cr}, C=0.3)"), "round", x);
        for proto in ProtocolKind::ALL {
            let mut cfg = base.clone();
            cfg.protocol.kind = proto;
            cfg.protocol.c_fraction = 0.3;
            cfg.env.crash_prob = cr;
            let result = run_with_data(&cfg, Arc::clone(&data))
                .unwrap_or_else(|e| panic!("{title} {proto:?} cr={cr}: {e}"));
            let trace: Vec<f64> = result
                .loss_trace()
                .into_iter()
                .map(|l| if l.is_nan() { 0.0 } else { l })
                .collect();
            s.add_line(proto.name(), trace);
        }
        figures.push(s);
    }
    figures
}

/// Figs. 3–4: the lag-tolerance sweep on Task 1 — best loss, SR, EUR and
/// VV as functions of tau for (C, cr) combinations.
pub struct TauSweep {
    pub taus: Vec<usize>,
    /// (label, best_loss, sr, eur, vv) per (C, cr) combo, indexed by tau.
    pub lines: Vec<(String, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>)>,
}

pub fn tau_sweep() -> TauSweep {
    let mut base = accuracy_cfg(1);
    debug_assert_eq!(base.task.kind, TaskKind::Regression);
    base.protocol.kind = ProtocolKind::Safa;
    if fast_mode() {
        base.train.rounds = base.train.rounds.min(20);
    }
    let data = shared_data(&base);
    let taus: Vec<usize> = (1..=10).collect();
    let mut lines = Vec::new();
    for &c in &[0.1, 0.5, 1.0] {
        for &cr in &[0.3, 0.7] {
            let mut loss = Vec::new();
            let mut sr = Vec::new();
            let mut eur = Vec::new();
            let mut vv = Vec::new();
            for &tau in &taus {
                let mut cfg = base.clone();
                cfg.protocol.c_fraction = c;
                cfg.env.crash_prob = cr;
                cfg.protocol.tau = tau;
                let r = run_with_data(&cfg, Arc::clone(&data))
                    .unwrap_or_else(|e| panic!("tau sweep tau={tau}: {e}"));
                loss.push(r.best_loss().unwrap_or(f64::NAN));
                sr.push(r.sync_ratio());
                eur.push(r.eur());
                vv.push(r.version_variance());
            }
            lines.push((format!("C={c},cr={cr}"), loss, sr, eur, vv));
        }
    }
    TauSweep { taus, lines }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_cfg_uses_paper_profiles() {
        let t2 = timing_cfg(2);
        assert_eq!(t2.env.m, 100);
        assert_eq!(t2.backend, Backend::Null);
        assert_eq!(t2.train.t_lim, 5600.0);
    }

    #[test]
    fn tiny_grid_runs() {
        let mut base = timing_cfg(1);
        base.train.rounds = 3;
        let table = grid_table(
            "smoke",
            &base,
            &[ProtocolKind::FedAvg, ProtocolKind::Safa],
            Metric::RoundLen,
        );
        assert_eq!(table.blocks.len(), 2);
        assert_eq!(table.blocks[0].1.len(), CRS.len());
        assert!(table
            .blocks
            .iter()
            .all(|(_, rows)| rows.iter().all(|r| r.iter().all(|v| v.is_finite()))));
    }
}
