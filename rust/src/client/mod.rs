//! Simulated end devices (clients).
//!
//! Each client carries the environment attributes the paper draws once
//! per experiment — performance (batches/s, Exp(λ=1)) and shard size —
//! plus the protocol-visible state: its local model, the model's version
//! lineage, whether it committed last round (Def. 1), whether it was
//! picked last round (CFCFM priority) and the crash-partial accounting
//! used for the futility metric.

use crate::config::ExperimentConfig;
use crate::data::FedData;
use crate::model::ParamVec;
use crate::util::rng::{Distribution, Exponential, Pcg64};

/// An in-flight local-training job (SAFA's continuation semantics).
///
/// SAFA clients keep training across round boundaries: a crash pauses the
/// job for the rest of the round (device offline — no progress, nothing
/// lost), and a job whose remaining time exceeds the round keeps running
/// into the next round. The job's base model content is the client's
/// `local_model` (unchanged until commit), so only timing state lives
/// here. Forced synchronization (up-to-date or deprecated) abandons the
/// job — that destroyed progress is what the futility metric charges.
#[derive(Debug, Clone, Copy)]
pub struct Job {
    /// Seconds of work left (download already included at job start).
    pub remaining: f64,
    /// Full job duration, for progress-fraction accounting.
    pub total: f64,
    /// Global version of the base model this job trains on.
    pub base_version: i64,
    /// Seconds of trailing *upload* leg inside `total` (0.0 when the
    /// job has no modelled upload tail). The fault engine uses this to
    /// classify a mid-job cut as an upload-leg crash vs a training cut.
    pub tail_up: f64,
}

impl Job {
    /// Fraction of the job already done.
    pub fn progress(&self) -> f64 {
        if self.total <= 0.0 {
            0.0
        } else {
            (1.0 - self.remaining / self.total).clamp(0.0, 1.0)
        }
    }
}

/// Per-client simulation + protocol state.
#[derive(Debug, Clone)]
pub struct ClientState {
    pub id: usize,
    /// Speed in batches/second (drawn once, Exp(λ)).
    pub perf: f64,
    /// Mini-batches per local epoch (from shard size and B).
    pub batches_per_epoch: usize,
    /// Shard size n_k (aggregation weight numerator).
    pub n_k: usize,
    /// Current local model.
    pub local_model: ParamVec,
    /// Local model version v_k (lineage: base version + 1 after training).
    pub version: i64,
    /// Version of the global model this client's current/ongoing training
    /// is based on.
    pub base_version: i64,
    /// Did this client successfully commit in the previous round?
    /// (Definition 1's "up-to-date" test.)
    pub committed_last: bool,
    /// Was this client picked (P set) in the previous round?
    /// (Algorithm 1 prioritizes clients NOT in P(t-1).)
    pub picked_last: bool,
    /// Accumulated crash-partial training work not yet committed or
    /// destroyed (futility accounting; see DESIGN.md §7). Used by the
    /// selection-ahead protocols (FedAvg/FedCS), whose servers discard
    /// late work.
    pub pending_partial: f64,
    /// In-flight training job (SAFA continuation semantics).
    pub job: Option<Job>,
    /// Round the client joined the fleet (scenario flash crowds);
    /// `None` = founding member. Lifecycle bookkeeping only — windows
    /// and membership masks come from the scenario timeline.
    pub joined_round: Option<usize>,
    /// Round the client departed the fleet (scenario flash leaves);
    /// `None` = still a member.
    pub departed_round: Option<usize>,
}

impl ClientState {
    /// Local training time for E epochs (paper Eq. 18).
    pub fn t_train(&self, epochs: usize) -> f64 {
        crate::net::t_train(self.batches_per_epoch, epochs, self.perf)
    }

    /// Version lag relative to the current global version.
    pub fn lag(&self, global_version: i64) -> i64 {
        global_version - self.version
    }

    /// Begin a fresh training job of `total` seconds based on global
    /// version `base_version` (replaces any in-flight job).
    pub fn start_job(&mut self, total: f64, base_version: i64) {
        self.job = Some(Job {
            remaining: total,
            total,
            base_version,
            tail_up: 0.0,
        });
    }

    /// Global version of the base model the client's current training
    /// builds on: the in-flight job's base if one exists, else the base
    /// of the last (re)synchronization.
    pub fn job_base_version(&self) -> i64 {
        self.job.map(|j| j.base_version).unwrap_or(self.base_version)
    }
}

/// Build the client fleet for an experiment. Performance draws use a
/// dedicated RNG stream so fleets are identical across protocols for the
/// same seed (apples-to-apples comparisons, as in the paper's tables).
pub fn build_clients(
    cfg: &ExperimentConfig,
    data: &FedData,
    init_model: &ParamVec,
    rng: &mut Pcg64,
) -> Vec<ClientState> {
    let perf_dist = Exponential::new(cfg.env.perf_lambda);
    (0..cfg.env.m)
        .map(|id| {
            // Floor performance: the paper's Exp(1) draws can be
            // arbitrarily close to zero, which models permanently
            // straggling devices; the tiny floor only avoids inf times.
            let perf = perf_dist.sample(rng).max(1e-4);
            let n_k = data.client_size(id);
            ClientState {
                id,
                perf,
                batches_per_epoch: data.client_batches(id, cfg.train.batch_size),
                n_k,
                local_model: init_model.clone(),
                version: 0,
                base_version: 0,
                committed_last: true, // everyone starts in sync with w(0)
                picked_last: false,
                pending_partial: 0.0,
                job: None,
                joined_round: None,
                departed_round: None,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::data::{partition_gaussian, synth, FedData};

    fn env() -> (ExperimentConfig, FedData) {
        let cfg = presets::preset("tiny").unwrap();
        let (train, test) = synth::generate(cfg.task.kind, cfg.task.n, cfg.task.n_test, 3);
        let mut rng = Pcg64::new(3);
        let partitions = partition_gaussian(train.n, cfg.env.m, 0.3, &mut rng);
        (
            cfg,
            FedData {
                train,
                test,
                partitions,
            },
        )
    }

    #[test]
    fn fleet_construction() {
        let (cfg, data) = env();
        let init = ParamVec::zeros(14);
        let mut rng = Pcg64::new(7);
        let clients = build_clients(&cfg, &data, &init, &mut rng);
        assert_eq!(clients.len(), cfg.env.m);
        for (k, c) in clients.iter().enumerate() {
            assert_eq!(c.id, k);
            assert!(c.perf > 0.0);
            assert_eq!(c.n_k, data.client_size(k));
            assert_eq!(
                c.batches_per_epoch,
                data.client_size(k).div_ceil(cfg.train.batch_size)
            );
            assert_eq!(c.version, 0);
            assert!(c.committed_last);
        }
    }

    #[test]
    fn t_train_scales_inversely_with_perf() {
        let (cfg, data) = env();
        let init = ParamVec::zeros(14);
        let mut rng = Pcg64::new(9);
        let mut clients = build_clients(&cfg, &data, &init, &mut rng);
        clients[0].perf = 2.0;
        clients[0].batches_per_epoch = 10;
        assert!((clients[0].t_train(4) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn fleet_is_deterministic() {
        let (cfg, data) = env();
        let init = ParamVec::zeros(14);
        let a = build_clients(&cfg, &data, &init, &mut Pcg64::new(11));
        let b = build_clients(&cfg, &data, &init, &mut Pcg64::new(11));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.perf, y.perf);
        }
    }
}
