//! Network timing model (paper Eqs. 17–19).
//!
//! * Client links: a stable `client_bw_bps` (1.40 Mbps in the paper,
//!   following the FedCS setup) gives per-client model download/upload
//!   times `T_down` / `T_up`.
//! * Server distribution: `T_dist = m_sync · model_size / server_bw`
//!   (Eq. 19) — the cost of pushing the new global model to every client
//!   the protocol forces to synchronize.
//! * Round length (Eq. 17): the paper's tables add `T_dist` on top of the
//!   deadline-capped client term (e.g. Table VI FedAvg shows
//!   5600 + T_dist exactly), i.e.
//!   `T = T_dist + min(T_lim, max_k(T_down + T_train + T_up))`.
//!   We implement that form; see EXPERIMENTS.md §Notes on the Eq. 17
//!   discrepancy.

pub mod fabric;

use crate::config::EnvConfig;

/// Precomputed network timing for one experiment.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Seconds to move one model over a client link (each direction).
    pub t_link: f64,
    /// Seconds to distribute one model copy from the server.
    pub t_per_model: f64,
    /// Serialized model size in bytes (comm-cost accounting).
    pub model_bytes: f64,
}

impl NetworkModel {
    pub fn new(env: &EnvConfig) -> NetworkModel {
        NetworkModel {
            t_link: env.model_size_bits / env.client_bw_bps,
            t_per_model: env.model_size_bits / env.server_bw_bps,
            model_bytes: env.model_size_bits / 8.0,
        }
    }

    /// Model download time for a client (T_down).
    #[inline]
    pub fn t_down(&self) -> f64 {
        self.t_link
    }

    /// Model upload time for a client (T_up).
    #[inline]
    pub fn t_up(&self) -> f64 {
        self.t_link
    }

    /// Server-side distribution overhead for `m_sync` copies (Eq. 19).
    #[inline]
    pub fn t_dist(&self, m_sync: usize) -> f64 {
        m_sync as f64 * self.t_per_model
    }

    /// Downlink bytes to distribute the global model to `m_sync` clients.
    #[inline]
    pub fn bytes_down(&self, m_sync: usize) -> f64 {
        m_sync as f64 * self.model_bytes
    }

    /// Uplink bytes for `n_uploads` client model uploads reaching the
    /// server.
    #[inline]
    pub fn bytes_up(&self, n_uploads: usize) -> f64 {
        n_uploads as f64 * self.model_bytes
    }
}

/// Local training time (Eq. 18): `batches_per_epoch · E / perf` where
/// `perf` is the client's speed in batches/second. Positive `perf` is a
/// load-time invariant (`EnvConfig` validation rejects non-positive
/// `perf_lambda` and `client::build_clients` floors each draw), so this
/// divides directly — no silent clamp hiding a misconfigured fleet.
#[inline]
pub fn t_train(batches_per_epoch: usize, epochs: usize, perf: f64) -> f64 {
    debug_assert!(perf > 0.0, "non-positive client perf {perf} reached t_train");
    (batches_per_epoch * epochs) as f64 / perf
}

/// Round length (Eq. 17 as realized in the paper's tables):
/// `T = T_dist + min(T_lim, slowest_relevant_client_time)`.
/// `client_term` is the max over the clients the protocol waits for; pass
/// 0.0 when it waits for nobody (e.g. everyone crashed).
#[inline]
pub fn round_length(t_dist: f64, client_term: f64, t_lim: f64) -> f64 {
    t_dist + client_term.min(t_lim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn link_times_match_paper_constants() {
        let env = presets::preset("task1").unwrap().env;
        let net = NetworkModel::new(&env);
        // 10 MB over 1.40 Mbps ≈ 57.1 s per direction.
        assert!((net.t_down() - 80e6 / 1.40e6).abs() < 1e-6);
        assert!((net.t_up() - net.t_down()).abs() < 1e-12);
    }

    #[test]
    fn tdist_is_linear_in_msync() {
        let env = presets::preset("task3").unwrap().env;
        let net = NetworkModel::new(&env);
        // Table IX: FedAvg C=1.0 distributes 500 copies in ~202 s.
        let t = net.t_dist(500);
        assert!((t - 202.0).abs() < 1.0, "t_dist(500)={t}");
        assert_eq!(net.t_dist(0), 0.0);
        assert!((net.t_dist(10) - 10.0 * net.t_per_model).abs() < 1e-9);
    }

    #[test]
    fn comm_bytes_scale_with_model_and_count() {
        let env = presets::preset("task1").unwrap().env;
        let net = NetworkModel::new(&env);
        // 10 MB model => 1e7 bytes per copy.
        assert!((net.model_bytes - 1e7).abs() < 1e-3);
        assert_eq!(net.bytes_down(0), 0.0);
        assert!((net.bytes_down(3) - 3e7).abs() < 1e-3);
        assert!((net.bytes_up(5) - 5e7).abs() < 1e-3);
    }

    #[test]
    fn t_train_formula() {
        // 20 batches/epoch, 5 epochs, 2 batches/s => 50 s.
        assert!((t_train(20, 5, 2.0) - 50.0).abs() < 1e-12);
        // Slow-but-valid clients stay finite; non-positive perf is
        // rejected at config load, not clamped here.
        assert!(t_train(1, 1, 1e-4).is_finite());
    }

    #[test]
    fn round_length_caps_at_deadline() {
        assert_eq!(round_length(2.0, 100.0, 830.0), 102.0);
        assert_eq!(round_length(2.0, 9999.0, 830.0), 832.0);
        assert_eq!(round_length(0.5, 0.0, 830.0), 0.5);
    }
}
