//! Model-update compression codecs.
//!
//! Both codecs operate on the *delta* a client uploads — the difference
//! between its trained parameters and the base model it trained from
//! (which the server already holds, so only the delta crosses the wire).
//! [`apply`] compresses that delta losslessly in shape: the
//! reconstructed parameters overwrite the input in place, exactly as the
//! server would decode them, so every downstream consumer (aggregation,
//! the distribution cache, lag-tolerant bypass) sees the same values the
//! wire carried.
//!
//! Payload-size accounting lives in [`Compression::ratio`]; the fabric
//! scales transfer seconds and byte counters by it. The ratios are the
//! standard idealized ones: top-k ships `k` (value, index) pairs — two
//! words per survivor — and `bits`-bit quantization ships `bits/32` of
//! the raw payload (scale metadata is O(1) and ignored).

use crate::model::ParamVec;
use crate::util::rng::Pcg64;

/// Update compression strategy (part of
/// [`super::FabricConfig`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Compression {
    /// Ship the full-precision delta.
    None,
    /// Keep only the `fraction · dim` largest-magnitude delta
    /// coordinates (ties broken by lower index); the rest revert to the
    /// base value. Deterministic — no RNG draws.
    TopK { fraction: f64 },
    /// Unbiased stochastic uniform quantization of each delta coordinate
    /// to `bits`-bit levels spanning `[-max|delta|, +max|delta|]`. One
    /// draw per coordinate from the caller's per-(round, client) stream.
    Quantize { bits: u32 },
}

impl Compression {
    /// Fraction of the uncompressed payload that crosses the wire.
    pub fn ratio(self) -> f64 {
        match self {
            Compression::None => 1.0,
            // k (value, index) pairs = 2 words per kept coordinate.
            Compression::TopK { fraction } => (2.0 * fraction).min(1.0),
            Compression::Quantize { bits } => bits as f64 / 32.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Compression::None => "none",
            Compression::TopK { .. } => "topk",
            Compression::Quantize { .. } => "quantize",
        }
    }
}

/// Compress `params`' delta against `base` in place (encode + decode in
/// one step — see module docs). `rng` must be a stream dedicated to this
/// (round, client) update so draw counts cannot shift any other stream.
pub fn apply(codec: Compression, base: &ParamVec, params: &mut ParamVec, rng: &mut Pcg64) {
    debug_assert_eq!(base.dim(), params.dim());
    match codec {
        Compression::None => {}
        Compression::TopK { fraction } => top_k(fraction, base, params),
        Compression::Quantize { bits } => quantize(bits, base, params, rng),
    }
}

fn top_k(fraction: f64, base: &ParamVec, params: &mut ParamVec) {
    let dim = params.dim();
    if dim == 0 {
        return;
    }
    let keep = ((fraction * dim as f64).ceil() as usize).clamp(1, dim);
    if keep == dim {
        return;
    }
    // Rank coordinates by |delta| descending, index ascending on ties —
    // a total order, so the survivor set is unique and deterministic.
    let mut order: Vec<(f32, u32)> = params
        .0
        .iter()
        .zip(&base.0)
        .enumerate()
        .map(|(i, (&p, &b))| ((p - b).abs(), i as u32))
        .collect();
    order.select_nth_unstable_by(keep - 1, |a, b| {
        b.0.total_cmp(&a.0).then(a.1.cmp(&b.1))
    });
    // Everything past the pivot was dropped from the payload: the server
    // reconstructs those coordinates as "no change".
    for &(_, i) in &order[keep..] {
        params.0[i as usize] = base.0[i as usize];
    }
}

fn quantize(bits: u32, base: &ParamVec, params: &mut ParamVec, rng: &mut Pcg64) {
    debug_assert!((1..=32).contains(&bits));
    // 2^bits - 1 intervals between the lowest and highest level.
    let levels = ((1u64 << bits.min(63)) - 1) as f64;
    let max_abs = params
        .0
        .iter()
        .zip(&base.0)
        .map(|(&p, &b)| (p - b).abs())
        .fold(0.0f32, f32::max);
    if max_abs == 0.0 {
        return;
    }
    let step = 2.0 * max_abs as f64 / levels;
    for (p, &b) in params.0.iter_mut().zip(&base.0) {
        let delta = (*p - b) as f64;
        // Position on the level grid, in [0, levels].
        let pos = (delta + max_abs as f64) / step;
        let lo = pos.floor();
        // Stochastic rounding: round up with probability equal to the
        // fractional part, so E[quantized] == delta (unbiased).
        let level = if rng.next_f64() < pos - lo { lo + 1.0 } else { lo };
        *p = b + (level * step - max_abs as f64) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta_vec(base: &ParamVec, params: &ParamVec) -> Vec<f32> {
        params
            .0
            .iter()
            .zip(&base.0)
            .map(|(&p, &b)| p - b)
            .collect()
    }

    #[test]
    fn ratios() {
        assert_eq!(Compression::None.ratio(), 1.0);
        assert_eq!(Compression::TopK { fraction: 0.1 }.ratio(), 0.2);
        // Dense top-k never claims to beat shipping the raw vector.
        assert_eq!(Compression::TopK { fraction: 0.9 }.ratio(), 1.0);
        assert_eq!(Compression::Quantize { bits: 8 }.ratio(), 0.25);
        assert_eq!(Compression::Quantize { bits: 32 }.ratio(), 1.0);
    }

    #[test]
    fn none_is_identity() {
        let base = ParamVec(vec![1.0, -2.0, 3.0]);
        let mut p = ParamVec(vec![0.5, 0.0, 9.0]);
        let orig = p.clone();
        let mut rng = Pcg64::new(1);
        apply(Compression::None, &base, &mut p, &mut rng);
        assert_eq!(p, orig);
    }

    #[test]
    fn top_k_keeps_largest_magnitudes_and_reverts_rest() {
        let base = ParamVec::zeros(5);
        let mut p = ParamVec(vec![0.1, -5.0, 0.2, 4.0, -0.3]);
        let mut rng = Pcg64::new(1);
        apply(Compression::TopK { fraction: 0.4 }, &base, &mut p, &mut rng);
        // ceil(0.4 * 5) = 2 survivors: the ±5.0 and ±4.0 coordinates.
        assert_eq!(p.0, vec![0.0, -5.0, 0.0, 4.0, 0.0]);
    }

    #[test]
    fn top_k_breaks_ties_by_lower_index() {
        let base = ParamVec::zeros(4);
        let mut p = ParamVec(vec![1.0, -1.0, 1.0, 1.0]);
        let mut rng = Pcg64::new(1);
        apply(Compression::TopK { fraction: 0.5 }, &base, &mut p, &mut rng);
        assert_eq!(p.0, vec![1.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn top_k_full_fraction_is_identity() {
        let base = ParamVec(vec![1.0, 2.0]);
        let mut p = ParamVec(vec![3.0, -4.0]);
        let orig = p.clone();
        let mut rng = Pcg64::new(1);
        apply(Compression::TopK { fraction: 1.0 }, &base, &mut p, &mut rng);
        assert_eq!(p, orig);
    }

    #[test]
    fn quantize_is_bounded_and_roughly_unbiased() {
        let dim = 400;
        let base = ParamVec::zeros(dim);
        let raw: Vec<f32> = (0..dim).map(|i| ((i as f32) * 0.37).sin()).collect();
        let mut p = ParamVec(raw.clone());
        let mut rng = Pcg64::new(7);
        apply(Compression::Quantize { bits: 4 }, &base, &mut p, &mut rng);
        let max_abs = raw.iter().map(|d| d.abs()).fold(0.0f32, f32::max);
        let step = 2.0 * max_abs / 15.0;
        let mut bias = 0.0f64;
        for (q, d) in delta_vec(&base, &p).iter().zip(&raw) {
            assert!((q - d).abs() <= step + 1e-6, "level jump > one step");
            bias += (q - d) as f64;
        }
        // Stochastic rounding: the mean error shrinks with dim.
        assert!(
            (bias / dim as f64).abs() < step as f64 / 4.0,
            "quantization bias {bias} too large"
        );
    }

    #[test]
    fn quantize_zero_delta_is_identity() {
        let base = ParamVec(vec![1.0, -2.0]);
        let mut p = base.clone();
        let mut rng = Pcg64::new(3);
        apply(Compression::Quantize { bits: 2 }, &base, &mut p, &mut rng);
        assert_eq!(p, base);
    }

    #[test]
    fn quantize_is_deterministic_per_stream() {
        let base = ParamVec::zeros(64);
        let raw: Vec<f32> = (0..64).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut a = ParamVec(raw.clone());
        let mut b = ParamVec(raw);
        apply(
            Compression::Quantize { bits: 6 },
            &base,
            &mut a,
            &mut Pcg64::new(11),
        );
        apply(
            Compression::Quantize { bits: 6 },
            &base,
            &mut b,
            &mut Pcg64::new(11),
        );
        assert_eq!(a, b);
    }
}
