//! Event-driven network fabric: contended distribution, heterogeneous
//! client links, lossy transfers and update compression.
//!
//! The base [`crate::net::NetworkModel`] prices communication with the
//! paper's closed-form arithmetic (Eqs. 17–19) over dedicated, identical
//! links. This module generalizes that into a first-class experimental
//! axis:
//!
//! * **Contention** ([`Contention`]) — the server's downlink is a shared
//!   resource. Distribution of `m_sync` copies becomes `m_sync` transfer
//!   slots scheduled FIFO (fully serialized) or fair-share (waves of `g`
//!   concurrent streams); each synced client picks up a queueing delay
//!   ([`FabricRuntime::dist_wait`]) before its own download starts.
//! * **Heterogeneous links** ([`LinkDist`]) — per-client link speed
//!   factors drawn once per experiment from a fixed / uniform / lognormal
//!   distribution on a dedicated RNG stream, so the same fleet sees the
//!   same links at any thread width.
//! * **Lossy transport** — per-transfer latency, uniform jitter and
//!   Bernoulli loss with bounded retransmit. The transport is eventually
//!   reliable: the final attempt always delivers, so loss inflates
//!   transfer *time* without destroying updates (arrival/failure sets
//!   keep their structure; the deadline still reaps stragglers).
//! * **Compression** ([`Compression`], [`compress`]) — top-k
//!   sparsification or stochastic quantization of model deltas shrinks
//!   every payload (bytes *and* transfer seconds) and perturbs the
//!   uploaded updates, opening the accuracy-vs-bandwidth tradeoff.
//!
//! Determinism contract: the fabric adds **no draws** to the engine's
//! existing availability/crash streams. The link table uses its own
//! `Pcg64::with_stream(seed, …)` stream; per-transfer perturbation and
//! quantization draws come from pure functions of (round, client,
//! direction), so fabric-on runs are bit-identical at any thread width.
//! With the neutral config (no contention, fixed links, zero
//! latency/jitter/loss, no compression) every produced number is
//! bit-for-bit the closed-form value, which `tests/net_fabric.rs` locks
//! in as a regression test.

pub mod compress;

pub use compress::Compression;

use crate::config::EnvConfig;
use crate::error::{Result, SafaError};
use crate::telemetry::{self, Counter};
use crate::util::rng::{Distribution, Normal, Pcg64};

/// Dedicated stream id for the static per-client link table.
const LINK_TABLE_STREAM: u64 = 0xfab_11c;
/// Dedicated stream id for per-transfer perturbation draws.
const TRANSFER_STREAM: u64 = 0xfab_71c;
/// Per-(round, client) sub-stream salts by payload direction / purpose.
/// Client ids stay far below these offsets, so streams cannot collide.
const SALT_DOWN: u64 = 0x1000_0000;
const SALT_UP: u64 = 0x2000_0000;
const SALT_CODEC: u64 = 0x3000_0000;

/// How the shared server downlink schedules the `m_sync` copies of one
/// round's distribution phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Contention {
    /// Dedicated capacity per copy (the paper's implicit model): every
    /// synced client's download starts immediately. Zero queueing delay.
    None,
    /// Fully serialized: copy `i` starts only after copies `0..i` have
    /// been pushed, so sync position `i` waits `i · t_per_model`.
    Fifo,
    /// Wave-batched fair sharing: the server serves `streams` copies
    /// concurrently; wave `w` starts once the previous waves' copies have
    /// drained the shared pipe (`w · streams · t_per_model`). With
    /// `streams = 1` this degenerates to FIFO.
    FairShare { streams: usize },
}

impl Contention {
    pub fn name(self) -> &'static str {
        match self {
            Contention::None => "none",
            Contention::Fifo => "fifo",
            Contention::FairShare { .. } => "fair",
        }
    }
}

/// Distribution of the static per-client link speed factor (multiplies
/// `client_bw_bps`; 1.0 = the homogeneous baseline).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LinkDist {
    /// Every client gets exactly `client_bw_bps` (the paper's model).
    Fixed,
    /// Speed factor uniform on `[1 - spread, 1 + spread]`, `spread < 1`.
    Uniform { spread: f64 },
    /// Speed factor `exp(sigma · N(0,1))` (median 1, right-skewed — a few
    /// clients on much faster links, a long tail of slow ones).
    LogNormal { sigma: f64 },
}

impl LinkDist {
    pub fn name(self) -> &'static str {
        match self {
            LinkDist::Fixed => "fixed",
            LinkDist::Uniform { .. } => "uniform",
            LinkDist::LogNormal { .. } => "lognormal",
        }
    }

    /// Draw one client's speed factor. `Fixed` consumes no randomness.
    fn sample(self, rng: &mut Pcg64) -> f64 {
        match self {
            LinkDist::Fixed => 1.0,
            LinkDist::Uniform { spread } => 1.0 - spread + 2.0 * spread * rng.next_f64(),
            LinkDist::LogNormal { sigma } => {
                (sigma * Normal::new(0.0, 1.0).sample(rng)).exp()
            }
        }
    }
}

/// Complete fabric description (part of [`EnvConfig`]).
#[derive(Debug, Clone, PartialEq)]
pub struct FabricConfig {
    /// Master switch. Off = every protocol uses the closed-form
    /// `NetworkModel` arithmetic untouched.
    pub enabled: bool,
    pub contention: Contention,
    pub link_dist: LinkDist,
    /// Fixed per-attempt propagation latency (seconds).
    pub latency_s: f64,
    /// Uniform per-attempt jitter amplitude (seconds): each attempt adds
    /// `U[0, jitter_s)`.
    pub jitter_s: f64,
    /// Per-attempt Bernoulli loss probability. A lost attempt is
    /// retransmitted (bounded by `max_retries`); the final attempt always
    /// delivers, so loss only stretches transfer time.
    pub loss_prob: f64,
    /// Retransmission budget per transfer (attempts = retries + 1).
    pub max_retries: u32,
    pub compression: Compression,
}

impl FabricConfig {
    /// Default fair-share concurrency when `fabric = "fair"` gives none.
    pub const DEFAULT_FAIR_STREAMS: usize = 4;
    /// Default retransmission budget.
    pub const DEFAULT_MAX_RETRIES: u32 = 3;

    /// Build a config from parsed front-end parts (shared by the TOML and
    /// CLI parsers so they cannot drift, mirroring
    /// [`crate::config::ChurnModel::from_parts`]). `mode` selects the
    /// fabric: `off` (disabled — every other part must be absent),
    /// `none` (enabled, uncontended), `fifo` or `fair`. Parameters that
    /// do not apply to the chosen mode/codec are rejected — silently
    /// ignoring them would hide a misconfigured run.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        mode: &str,
        streams: Option<i64>,
        link: Option<&str>,
        link_spread: Option<f64>,
        latency_s: Option<f64>,
        jitter_s: Option<f64>,
        loss_prob: Option<f64>,
        max_retries: Option<i64>,
        compression: Option<&str>,
        topk_fraction: Option<f64>,
        quantize_bits: Option<i64>,
    ) -> Result<FabricConfig> {
        let err = |msg: String| Err(SafaError::Config(msg));
        let contention = match mode.to_ascii_lowercase().as_str() {
            "off" => {
                let any = streams.is_some()
                    || link.is_some()
                    || link_spread.is_some()
                    || latency_s.is_some()
                    || jitter_s.is_some()
                    || loss_prob.is_some()
                    || max_retries.is_some()
                    || compression.is_some()
                    || topk_fraction.is_some()
                    || quantize_bits.is_some();
                if any {
                    return err(
                        "fabric parameters require fabric = \"none\", \"fifo\" or \"fair\" \
                         (fabric = \"off\" disables the fabric entirely)"
                            .into(),
                    );
                }
                return Ok(FabricConfig::default());
            }
            "none" => {
                if streams.is_some() {
                    return err(
                        "fabric_streams only applies to fabric = \"fair\" \
                         (did you mean fabric = \"fair\"?)"
                            .into(),
                    );
                }
                Contention::None
            }
            "fifo" => {
                if streams.is_some() {
                    return err(
                        "fifo contention is fully serialized and takes no stream count \
                         (did you mean fabric = \"fair\"?)"
                            .into(),
                    );
                }
                Contention::Fifo
            }
            "fair" => Contention::FairShare {
                streams: match streams {
                    Some(s) if s >= 1 => s as usize,
                    Some(s) => return err(format!("fabric_streams {s} must be >= 1")),
                    None => Self::DEFAULT_FAIR_STREAMS,
                },
            },
            other => {
                return err(format!(
                    "unknown fabric mode '{other}' (expected off|none|fifo|fair)"
                ))
            }
        };
        let link_dist = match link.map(str::to_ascii_lowercase).as_deref() {
            None | Some("fixed") => {
                if link_spread.is_some() {
                    return err(
                        "fabric_link_spread only applies to uniform or lognormal links \
                         (did you mean fabric_link = \"uniform\"?)"
                            .into(),
                    );
                }
                LinkDist::Fixed
            }
            Some("uniform") => LinkDist::Uniform {
                spread: link_spread.unwrap_or(0.5),
            },
            Some("lognormal") => LinkDist::LogNormal {
                sigma: link_spread.unwrap_or(0.5),
            },
            Some(other) => {
                return err(format!(
                    "unknown fabric link distribution '{other}' \
                     (expected fixed|uniform|lognormal)"
                ))
            }
        };
        let compression = match compression.map(str::to_ascii_lowercase).as_deref() {
            None | Some("none") => {
                if topk_fraction.is_some() || quantize_bits.is_some() {
                    return err(
                        "fabric_topk_fraction / fabric_quantize_bits require \
                         fabric_compression = \"topk\" or \"quantize\""
                            .into(),
                    );
                }
                Compression::None
            }
            Some("topk") => {
                if quantize_bits.is_some() {
                    return err(
                        "fabric_quantize_bits only applies to fabric_compression = \"quantize\""
                            .into(),
                    );
                }
                Compression::TopK {
                    fraction: topk_fraction.unwrap_or(0.1),
                }
            }
            Some("quantize") => {
                if topk_fraction.is_some() {
                    return err(
                        "fabric_topk_fraction only applies to fabric_compression = \"topk\""
                            .into(),
                    );
                }
                Compression::Quantize {
                    bits: match quantize_bits {
                        Some(b) if (1..=32).contains(&b) => b as u32,
                        Some(b) => {
                            return err(format!("fabric_quantize_bits {b} outside 1..=32"))
                        }
                        None => 8,
                    },
                }
            }
            Some(other) => {
                return err(format!(
                    "unknown compression '{other}' (expected none|topk|quantize)"
                ))
            }
        };
        let cfg = FabricConfig {
            enabled: true,
            contention,
            link_dist,
            latency_s: latency_s.unwrap_or(0.0),
            jitter_s: jitter_s.unwrap_or(0.0),
            loss_prob: loss_prob.unwrap_or(0.0),
            max_retries: match max_retries {
                Some(r) if r >= 0 => r as u32,
                Some(r) => return err(format!("fabric_max_retries {r} must be >= 0")),
                None => Self::DEFAULT_MAX_RETRIES,
            },
            compression,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Validate field invariants (called by
    /// [`crate::config::ExperimentConfig::validate`], finiteness first so
    /// NaN cannot slip past the range checks).
    pub fn validate(&self) -> Result<()> {
        let e = |msg: String| Err(SafaError::Config(msg));
        if !self.enabled {
            return Ok(());
        }
        if let Contention::FairShare { streams } = self.contention {
            if streams == 0 {
                return e("fair-share fabric needs streams >= 1".into());
            }
        }
        match self.link_dist {
            LinkDist::Fixed => {}
            LinkDist::Uniform { spread } => {
                if !spread.is_finite() || !(0.0..1.0).contains(&spread) {
                    return e(format!(
                        "uniform link spread {spread} outside [0,1) (a spread of 1 \
                         would allow zero-speed links)"
                    ));
                }
            }
            LinkDist::LogNormal { sigma } => {
                if !sigma.is_finite() || sigma <= 0.0 {
                    return e(format!("lognormal link sigma {sigma} must be positive and finite"));
                }
            }
        }
        if !self.latency_s.is_finite() || self.latency_s < 0.0 {
            return e(format!(
                "fabric latency {} must be >= 0 and finite",
                self.latency_s
            ));
        }
        if !self.jitter_s.is_finite() || self.jitter_s < 0.0 {
            return e(format!(
                "fabric jitter {} must be >= 0 and finite",
                self.jitter_s
            ));
        }
        if !self.loss_prob.is_finite() || !(0.0..1.0).contains(&self.loss_prob) {
            return e(format!(
                "fabric loss probability {} outside [0,1)",
                self.loss_prob
            ));
        }
        match self.compression {
            Compression::None => {}
            Compression::TopK { fraction } => {
                if !fraction.is_finite() || !(0.0..=1.0).contains(&fraction) || fraction == 0.0 {
                    return e(format!("top-k fraction {fraction} outside (0,1]"));
                }
            }
            Compression::Quantize { bits } => {
                if bits == 0 || bits > 32 {
                    return e(format!("quantization bits {bits} outside 1..=32"));
                }
            }
        }
        Ok(())
    }
}

impl Default for FabricConfig {
    /// Disabled, and neutral even if force-enabled: no contention,
    /// homogeneous fixed links, zero latency/jitter/loss, no compression
    /// — the configuration calibrated to reproduce Eqs. 17–19 bit-for-bit.
    fn default() -> FabricConfig {
        FabricConfig {
            enabled: false,
            contention: Contention::None,
            link_dist: LinkDist::Fixed,
            latency_s: 0.0,
            jitter_s: 0.0,
            loss_prob: 0.0,
            max_retries: Self::DEFAULT_MAX_RETRIES,
            compression: Compression::None,
        }
    }
}

/// Instantiated fabric for one experiment: the static link table plus
/// everything needed to price a transfer as a pure function of
/// (round, client, direction).
#[derive(Debug, Clone)]
pub struct FabricRuntime {
    cfg: FabricConfig,
    /// Per-client one-direction link transfer seconds for one (possibly
    /// compressed) payload. With fixed links and no compression this is
    /// exactly `NetworkModel::t_link` for every client.
    link_s: Vec<f64>,
    /// Server-side seconds per distributed copy (compression-scaled
    /// `NetworkModel::t_per_model`).
    per_copy: f64,
    /// Bytes per payload actually crossing a link (compression-scaled).
    payload_bytes: f64,
    /// Uncompressed serialized model bytes (bytes-saved accounting).
    model_bytes: f64,
    /// Any per-transfer randomness at all? False for the common
    /// latency = jitter = loss = 0 case, where transfers are priced
    /// straight from the link table with no RNG construction.
    perturb: bool,
    /// Base generator for per-(round, client, direction) transfer streams.
    stream: Pcg64,
}

impl FabricRuntime {
    /// Build the runtime from the experiment environment. The link table
    /// and all transfer streams hang off `seed` via dedicated stream ids,
    /// so the fabric never consumes a draw from any pre-existing stream.
    pub fn new(env: &EnvConfig, seed: u64) -> FabricRuntime {
        let cfg = env.fabric.clone();
        let ratio = cfg.compression.ratio();
        // `ratio == 1.0` multiplications are exact, so the neutral fabric
        // reproduces the closed-form times bit-for-bit.
        let payload_bits = env.model_size_bits * ratio;
        let table_rng = Pcg64::with_stream(seed, LINK_TABLE_STREAM);
        let link_s = (0..env.m)
            .map(|k| {
                let factor = cfg.link_dist.sample(&mut table_rng.split(k as u64));
                payload_bits / (env.client_bw_bps * factor)
            })
            .collect();
        FabricRuntime {
            link_s,
            per_copy: (env.model_size_bits / env.server_bw_bps) * ratio,
            payload_bytes: (env.model_size_bits / 8.0) * ratio,
            model_bytes: env.model_size_bits / 8.0,
            perturb: cfg.latency_s > 0.0 || cfg.jitter_s > 0.0 || cfg.loss_prob > 0.0,
            stream: Pcg64::with_stream(seed, TRANSFER_STREAM),
            cfg,
        }
    }

    pub fn config(&self) -> &FabricConfig {
        &self.cfg
    }

    /// Download seconds for client `k` in round `t` (queueing delay not
    /// included — see [`FabricRuntime::dist_wait`]). Pure in (t, k).
    pub fn t_down(&self, t: usize, k: usize) -> f64 {
        self.transfer_time(t, k, SALT_DOWN)
    }

    /// Upload seconds for client `k` in round `t`. Pure in (t, k).
    pub fn t_up(&self, t: usize, k: usize) -> f64 {
        self.transfer_time(t, k, SALT_UP)
    }

    fn transfer_time(&self, t: usize, k: usize, salt: u64) -> f64 {
        telemetry::count(Counter::Transfers, 1);
        let base = self.link_s[k];
        if !self.perturb {
            return base;
        }
        let mut rng = self.stream.split(t as u64).split(salt + k as u64);
        let mut total = 0.0;
        let mut attempts = 0u64;
        loop {
            let jitter = if self.cfg.jitter_s > 0.0 {
                self.cfg.jitter_s * rng.next_f64()
            } else {
                0.0
            };
            total += self.cfg.latency_s + jitter + base;
            // The final attempt always delivers (eventually-reliable
            // transport): loss inflates time, never drops the update.
            let lost = self.cfg.loss_prob > 0.0
                && attempts < self.cfg.max_retries as u64
                && rng.next_f64() < self.cfg.loss_prob;
            if !lost {
                break;
            }
            attempts += 1;
        }
        if attempts > 0 {
            telemetry::count(Counter::Retransmits, attempts);
        }
        total
    }

    /// Retransmitted (lost-then-retried) attempts inside the download
    /// leg priced by [`FabricRuntime::t_down`]. Pure in (t, k): replays
    /// the same per-transfer stream without touching any counter, so
    /// the faults event path can book retransmitted bytes exactly where
    /// the pricing put them.
    pub fn extra_down_attempts(&self, t: usize, k: usize) -> u64 {
        self.extra_attempts(t, k, SALT_DOWN)
    }

    /// Retransmitted attempts inside the upload leg priced by
    /// [`FabricRuntime::t_up`]. Pure in (t, k).
    pub fn extra_up_attempts(&self, t: usize, k: usize) -> u64 {
        self.extra_attempts(t, k, SALT_UP)
    }

    fn extra_attempts(&self, t: usize, k: usize, salt: u64) -> u64 {
        if !self.perturb || self.cfg.loss_prob <= 0.0 {
            return 0;
        }
        let mut rng = self.stream.split(t as u64).split(salt + k as u64);
        let mut attempts = 0u64;
        loop {
            if self.cfg.jitter_s > 0.0 {
                rng.next_f64();
            }
            let lost = attempts < self.cfg.max_retries as u64
                && rng.next_f64() < self.cfg.loss_prob;
            if !lost {
                break;
            }
            attempts += 1;
        }
        attempts
    }

    /// Bytes one model copy puts on the wire (after compression).
    pub fn payload_bytes(&self) -> f64 {
        self.payload_bytes
    }

    /// Contention geometry for event-driven distribution scheduling:
    /// `(concurrent server streams, seconds one copy occupies its
    /// stream)`. Streams = 0 when the policy is uncontended. The slot
    /// model reproduces [`FabricRuntime::dist_wait`] exactly when no
    /// copy is cancelled: FIFO is one stream serving copies back to
    /// back; fair-share is `streams` lanes each serving a copy in
    /// `streams * per_copy` seconds (a wave).
    pub fn contention_slots(&self) -> (usize, f64) {
        match self.cfg.contention {
            Contention::None => (0, 0.0),
            Contention::Fifo => (1, self.per_copy),
            Contention::FairShare { streams } => {
                let s = streams.max(1);
                (s, s as f64 * self.per_copy)
            }
        }
    }

    /// Does the configured contention policy produce nonzero queueing
    /// delays? (Engine/protocols skip the serial wait pass when not.)
    pub fn has_dist_wait(&self) -> bool {
        !matches!(self.cfg.contention, Contention::None)
    }

    /// Queueing delay before the server starts pushing sync copy `i`
    /// (0-based position in the round's sync order) of `m_sync` total.
    pub fn dist_wait(&self, i: usize, m_sync: usize) -> f64 {
        debug_assert!(i < m_sync.max(1));
        match self.cfg.contention {
            Contention::None => 0.0,
            Contention::Fifo => i as f64 * self.per_copy,
            Contention::FairShare { streams } => {
                let wave = i / streams.max(1);
                (wave * streams.max(1)) as f64 * self.per_copy
            }
        }
    }

    /// Server-side distribution overhead (Eq. 19 over the compressed
    /// payload; bit-identical to `NetworkModel::t_dist` when
    /// uncompressed — the copies are uniform, so both FIFO and fair-share
    /// drain the pipe at the same total).
    pub fn t_dist(&self, m_sync: usize) -> f64 {
        m_sync as f64 * self.per_copy
    }

    /// Downlink bytes actually sent for `m_sync` distributed copies.
    pub fn bytes_down(&self, m_sync: usize) -> f64 {
        m_sync as f64 * self.payload_bytes
    }

    /// Uplink bytes actually sent for `n_uploads` arrived updates.
    pub fn bytes_up(&self, n_uploads: usize) -> f64 {
        n_uploads as f64 * self.payload_bytes
    }

    /// Bytes compression saved this round versus uncompressed transfers.
    pub fn bytes_saved(&self, m_sync: usize, n_uploads: usize) -> f64 {
        (m_sync + n_uploads) as f64 * (self.model_bytes - self.payload_bytes)
    }

    /// Is a lossy codec configured (i.e. does `compress_update` do
    /// anything)?
    pub fn compresses_updates(&self) -> bool {
        self.cfg.compression != Compression::None
    }

    /// Apply the configured codec to client `k`'s round-`t` uploaded
    /// update in place: the delta against `base` (the model the client
    /// trained from, which the server knows) is compressed and the
    /// reconstruction written back. Pure in (t, k) — safe to run from
    /// parallel per-update workers.
    pub fn compress_update(
        &self,
        t: usize,
        k: usize,
        base: &crate::model::ParamVec,
        params: &mut crate::model::ParamVec,
    ) {
        if !self.compresses_updates() {
            return;
        }
        let mut rng = self.stream.split(t as u64).split(SALT_CODEC + k as u64);
        compress::apply(self.cfg.compression, base, params, &mut rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn env_with(fabric: FabricConfig) -> EnvConfig {
        let mut env = presets::preset("tiny").unwrap().env;
        env.fabric = fabric;
        env
    }

    fn enabled_neutral() -> FabricConfig {
        FabricConfig {
            enabled: true,
            ..FabricConfig::default()
        }
    }

    #[test]
    fn neutral_fabric_reproduces_closed_form_times_bitwise() {
        let env = env_with(enabled_neutral());
        let net = crate::net::NetworkModel::new(&env);
        let fab = FabricRuntime::new(&env, 42);
        for k in 0..env.m {
            assert_eq!(fab.t_down(3, k), net.t_down());
            assert_eq!(fab.t_up(3, k), net.t_up());
        }
        for m_sync in [0, 1, 3, env.m] {
            assert_eq!(fab.t_dist(m_sync), net.t_dist(m_sync));
            assert_eq!(fab.bytes_down(m_sync), net.bytes_down(m_sync));
            assert_eq!(fab.bytes_up(m_sync), net.bytes_up(m_sync));
            assert_eq!(fab.bytes_saved(m_sync, m_sync), 0.0);
        }
        assert!(!fab.has_dist_wait());
        assert_eq!(fab.dist_wait(0, 4), 0.0);
    }

    #[test]
    fn contention_schedules_match_the_policy() {
        let mut cfg = enabled_neutral();
        cfg.contention = Contention::Fifo;
        let env = env_with(cfg);
        let fab = FabricRuntime::new(&env, 1);
        let per = fab.per_copy;
        assert!(fab.has_dist_wait());
        for i in 0..4 {
            assert_eq!(fab.dist_wait(i, 4), i as f64 * per);
        }

        let mut cfg = enabled_neutral();
        cfg.contention = Contention::FairShare { streams: 2 };
        let env = env_with(cfg);
        let fab = FabricRuntime::new(&env, 1);
        // Waves of 2: positions 0,1 start at 0; 2,3 after 2 copies; ...
        assert_eq!(fab.dist_wait(0, 5), 0.0);
        assert_eq!(fab.dist_wait(1, 5), 0.0);
        assert_eq!(fab.dist_wait(2, 5), 2.0 * per);
        assert_eq!(fab.dist_wait(3, 5), 2.0 * per);
        assert_eq!(fab.dist_wait(4, 5), 4.0 * per);
    }

    #[test]
    fn heterogeneous_links_are_deterministic_and_spread() {
        let mut cfg = enabled_neutral();
        cfg.link_dist = LinkDist::LogNormal { sigma: 0.6 };
        let env = env_with(cfg);
        let a = FabricRuntime::new(&env, 7);
        let b = FabricRuntime::new(&env, 7);
        assert_eq!(a.link_s, b.link_s, "same seed, same link table");
        let c = FabricRuntime::new(&env, 8);
        assert_ne!(a.link_s, c.link_s, "different seed, different links");
        let min = a.link_s.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = a.link_s.iter().cloned().fold(0.0, f64::max);
        assert!(min > 0.0 && max > min, "links spread: [{min}, {max}]");
    }

    #[test]
    fn perturbed_transfers_are_pure_in_round_and_client() {
        let mut cfg = enabled_neutral();
        cfg.latency_s = 0.05;
        cfg.jitter_s = 0.02;
        cfg.loss_prob = 0.3;
        let env = env_with(cfg);
        let fab = FabricRuntime::new(&env, 3);
        let base = fab.link_s[0];
        // Same (t, k) -> same time, regardless of call order/count.
        assert_eq!(fab.t_down(5, 0), fab.t_down(5, 0));
        assert_eq!(fab.t_up(5, 0), fab.t_up(5, 0));
        // Down and up use distinct streams.
        assert!(fab.t_down(5, 0) >= base + 0.05);
        // At 30% loss some (t, k) must retransmit within a small scan.
        let mut saw_retx = false;
        for t in 1..40 {
            if fab.t_down(t, 0) > 2.0 * base {
                saw_retx = true;
                break;
            }
        }
        assert!(saw_retx, "no retransmit observed at loss 0.3");
    }

    #[test]
    fn retransmits_are_bounded_by_budget() {
        let mut cfg = enabled_neutral();
        cfg.loss_prob = 0.999;
        cfg.max_retries = 2;
        let env = env_with(cfg);
        let fab = FabricRuntime::new(&env, 3);
        let base = fab.link_s[0];
        for t in 1..20 {
            let t_dl = fab.t_down(t, 0);
            // At most retries+1 = 3 attempts, and always delivers.
            assert!(t_dl <= 3.0 * base + 1e-9, "t_dl={t_dl} base={base}");
            assert!(t_dl.is_finite());
        }
    }

    #[test]
    fn extra_attempts_re_derive_the_priced_retransmits() {
        // With zero jitter the priced time is exactly
        // (1 + extra) * (latency + base), so the pure re-derivation can
        // be checked against the pricing bit-for-bit.
        let mut cfg = enabled_neutral();
        cfg.latency_s = 0.05;
        cfg.loss_prob = 0.6;
        cfg.max_retries = 4;
        let env = env_with(cfg);
        let fab = FabricRuntime::new(&env, 3);
        let mut saw_nonzero = false;
        for t in 1..30 {
            for k in 0..4 {
                let base = fab.link_s[k];
                let down = fab.extra_down_attempts(t, k);
                let up = fab.extra_up_attempts(t, k);
                saw_nonzero |= down > 0 || up > 0;
                // Accumulation order differs (repeated add vs multiply),
                // so compare with a tight relative tolerance.
                let dl = (down + 1) as f64 * (0.05 + base);
                let ul = (up + 1) as f64 * (0.05 + base);
                assert!((fab.t_down(t, k) - dl).abs() < 1e-12 * dl.max(1.0));
                assert!((fab.t_up(t, k) - ul).abs() < 1e-12 * ul.max(1.0));
            }
        }
        assert!(saw_nonzero, "no retransmit at loss 0.6 over 116 legs");
        // Loss off: no extra attempts, no RNG consumed.
        let fab = FabricRuntime::new(&env_with(enabled_neutral()), 3);
        assert_eq!(fab.extra_down_attempts(1, 0), 0);
    }

    #[test]
    fn contention_slots_reproduce_dist_wait() {
        for (contention, m_sync) in [
            (Contention::Fifo, 5),
            (Contention::FairShare { streams: 2 }, 5),
            (Contention::FairShare { streams: 3 }, 7),
        ] {
            let mut cfg = enabled_neutral();
            cfg.contention = contention;
            let fab = FabricRuntime::new(&env_with(cfg), 1);
            let (streams, service) = fab.contention_slots();
            assert!(streams > 0);
            // Simulate the slot model with no cancellations: copy i
            // starts when the earliest-free stream frees up.
            let mut free = vec![0.0f64; streams];
            for i in 0..m_sync {
                let j = (0..streams)
                    .min_by(|&a, &b| free[a].total_cmp(&free[b]))
                    .unwrap();
                assert_eq!(free[j], fab.dist_wait(i, m_sync), "copy {i}");
                free[j] += service;
            }
        }
        let fab = FabricRuntime::new(&env_with(enabled_neutral()), 1);
        assert_eq!(fab.contention_slots(), (0, 0.0));
    }

    #[test]
    fn compression_scales_bytes_and_times() {
        let mut cfg = enabled_neutral();
        cfg.compression = Compression::Quantize { bits: 8 };
        let env = env_with(cfg);
        let net = crate::net::NetworkModel::new(&env);
        let fab = FabricRuntime::new(&env, 1);
        // 8/32 bits -> quarter payload in bytes and seconds.
        assert!((fab.bytes_down(4) - net.bytes_down(4) * 0.25).abs() < 1e-6);
        assert!((fab.t_dist(4) - net.t_dist(4) * 0.25).abs() < 1e-12);
        assert!((fab.t_down(1, 0) - net.t_down() * 0.25).abs() < 1e-12);
        assert!((fab.bytes_saved(4, 2) - 6.0 * net.model_bytes * 0.75).abs() < 1e-3);
    }

    #[test]
    fn from_parts_mirrors_churn_strictness() {
        // "off" with any parameter is an error; bare "off" is the default.
        assert_eq!(
            FabricConfig::from_parts(
                "off", None, None, None, None, None, None, None, None, None, None
            )
            .unwrap(),
            FabricConfig::default()
        );
        assert!(FabricConfig::from_parts(
            "off",
            None,
            Some("uniform"),
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None
        )
        .is_err());
        // Streams only apply to fair.
        assert!(FabricConfig::from_parts(
            "fifo",
            Some(2),
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None
        )
        .is_err());
        let fair = FabricConfig::from_parts(
            "fair", None, None, None, None, None, None, None, None, None, None,
        )
        .unwrap();
        assert_eq!(
            fair.contention,
            Contention::FairShare {
                streams: FabricConfig::DEFAULT_FAIR_STREAMS
            }
        );
        // Spread requires a spread-bearing link distribution.
        assert!(FabricConfig::from_parts(
            "none",
            None,
            Some("fixed"),
            Some(0.3),
            None,
            None,
            None,
            None,
            None,
            None,
            None
        )
        .is_err());
        // Codec parameters must match the codec.
        assert!(FabricConfig::from_parts(
            "none",
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            Some("topk"),
            None,
            Some(8)
        )
        .is_err());
        assert!(FabricConfig::from_parts(
            "none",
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            Some(0.1),
            None
        )
        .is_err());
        let full = FabricConfig::from_parts(
            "fifo",
            None,
            Some("lognormal"),
            Some(0.6),
            Some(0.05),
            Some(0.02),
            Some(0.02),
            Some(3),
            Some("topk"),
            Some(0.25),
            None,
        )
        .unwrap();
        assert!(full.enabled);
        assert_eq!(full.contention, Contention::Fifo);
        assert_eq!(full.link_dist, LinkDist::LogNormal { sigma: 0.6 });
        assert_eq!(full.compression, Compression::TopK { fraction: 0.25 });
        // Unknown modes fail.
        assert!(FabricConfig::from_parts(
            "token-ring",
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None
        )
        .is_err());
    }

    #[test]
    fn validate_rejects_bad_fields() {
        let cases: Vec<FabricConfig> = vec![
            FabricConfig {
                contention: Contention::FairShare { streams: 0 },
                ..enabled_neutral()
            },
            FabricConfig {
                link_dist: LinkDist::Uniform { spread: 1.0 },
                ..enabled_neutral()
            },
            FabricConfig {
                link_dist: LinkDist::LogNormal { sigma: f64::NAN },
                ..enabled_neutral()
            },
            FabricConfig {
                latency_s: -1.0,
                ..enabled_neutral()
            },
            FabricConfig {
                jitter_s: f64::INFINITY,
                ..enabled_neutral()
            },
            FabricConfig {
                loss_prob: 1.0,
                ..enabled_neutral()
            },
            FabricConfig {
                compression: Compression::TopK { fraction: 0.0 },
                ..enabled_neutral()
            },
            FabricConfig {
                compression: Compression::Quantize { bits: 33 },
                ..enabled_neutral()
            },
        ];
        for bad in cases {
            assert!(bad.validate().is_err(), "accepted {bad:?}");
        }
        assert!(enabled_neutral().validate().is_ok());
        // A disabled fabric skips field validation entirely.
        let disabled = FabricConfig {
            enabled: false,
            loss_prob: 1.0,
            ..FabricConfig::default()
        };
        assert!(disabled.validate().is_ok());
    }
}
