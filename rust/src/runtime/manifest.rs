//! The AOT artifact manifest: shapes, file names and the parameter
//! initialization recipe, emitted by `python/compile/aot.py` so the Rust
//! runtime never hard-codes Python-side layout decisions.

use crate::error::{Result, SafaError};
use crate::model::ParamVec;
use crate::util::json::Json;
use crate::util::rng::{Distribution, Normal, Pcg64};
use std::collections::BTreeMap;
use std::path::Path;

/// One parameter block of the flat layout: `len` values initialized as
/// N(0, std) (std = 0 → zeros, used for biases).
#[derive(Debug, Clone, PartialEq)]
pub struct InitBlock {
    pub len: usize,
    pub std: f64,
}

/// Artifact description for one task.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskArtifact {
    pub name: String,
    pub train_hlo: String,
    pub eval_hlo: String,
    pub param_dim: usize,
    pub d: usize,
    pub batch_size: usize,
    pub max_batches: usize,
    pub n_test: usize,
    pub lr: f64,
    pub init: Vec<InitBlock>,
}

impl TaskArtifact {
    /// Initialize parameters per the manifest recipe (same family as the
    /// native backend: Gaussian weights, zero biases).
    pub fn init_params(&self, rng: &mut Pcg64) -> ParamVec {
        let mut v = Vec::with_capacity(self.param_dim);
        for block in &self.init {
            if block.std == 0.0 {
                v.extend(std::iter::repeat(0.0f32).take(block.len));
            } else {
                let dist = Normal::new(0.0, block.std);
                v.extend((0..block.len).map(|_| dist.sample(rng) as f32));
            }
        }
        assert_eq!(v.len(), self.param_dim, "init blocks disagree with param_dim");
        ParamVec(v)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub tasks: BTreeMap<String, TaskArtifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = Path::new(dir).join("manifest.json");
        if !path.exists() {
            return Err(SafaError::Artifact(format!(
                "missing {}; run `make artifacts` first",
                path.display()
            )));
        }
        let text = std::fs::read_to_string(&path)?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let doc = Json::parse(text)?;
        let tasks_json = doc
            .get("tasks")
            .ok_or_else(|| SafaError::Artifact("manifest missing 'tasks'".into()))?;
        let obj = match tasks_json {
            Json::Obj(m) => m,
            _ => return Err(SafaError::Artifact("'tasks' is not an object".into())),
        };
        let mut tasks = BTreeMap::new();
        for (name, t) in obj {
            let get_num = |key: &str| -> Result<usize> {
                t.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| SafaError::Artifact(format!("task {name}: missing '{key}'")))
            };
            let get_str = |key: &str| -> Result<String> {
                t.get(key)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| SafaError::Artifact(format!("task {name}: missing '{key}'")))
            };
            let init_json = t
                .get("init")
                .and_then(Json::as_arr)
                .ok_or_else(|| SafaError::Artifact(format!("task {name}: missing 'init'")))?;
            let mut init = Vec::new();
            for b in init_json {
                init.push(InitBlock {
                    len: b
                        .get("len")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| SafaError::Artifact("init block missing len".into()))?,
                    std: b
                        .get("std")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| SafaError::Artifact("init block missing std".into()))?,
                });
            }
            let artifact = TaskArtifact {
                name: name.clone(),
                train_hlo: get_str("train_hlo")?,
                eval_hlo: get_str("eval_hlo")?,
                param_dim: get_num("param_dim")?,
                d: get_num("d")?,
                batch_size: get_num("batch_size")?,
                max_batches: get_num("max_batches")?,
                n_test: get_num("n_test")?,
                lr: t
                    .get("lr")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| SafaError::Artifact(format!("task {name}: missing 'lr'")))?,
                init,
            };
            let total: usize = artifact.init.iter().map(|b| b.len).sum();
            if total != artifact.param_dim {
                return Err(SafaError::Artifact(format!(
                    "task {name}: init blocks sum to {total} != param_dim {}",
                    artifact.param_dim
                )));
            }
            tasks.insert(name.clone(), artifact);
        }
        Ok(Manifest { tasks })
    }

    pub fn task(&self, name: &str) -> Result<&TaskArtifact> {
        self.tasks.get(name).ok_or_else(|| {
            SafaError::Artifact(format!(
                "task '{name}' not in manifest (have: {:?}); rebuild artifacts",
                self.tasks.keys().collect::<Vec<_>>()
            ))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "tasks": {
        "regression": {
          "train_hlo": "regression_train.hlo.txt",
          "eval_hlo": "regression_eval.hlo.txt",
          "param_dim": 14,
          "d": 13,
          "batch_size": 5,
          "max_batches": 32,
          "n_test": 100,
          "lr": 0.0001,
          "init": [{"len": 13, "std": 0.01}, {"len": 1, "std": 0}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let t = m.task("regression").unwrap();
        assert_eq!(t.param_dim, 14);
        assert_eq!(t.max_batches, 32);
        assert_eq!(t.init.len(), 2);
        assert!((t.lr - 1e-4).abs() < 1e-12);
        assert!(m.task("cnn").is_err());
    }

    #[test]
    fn init_params_respects_blocks() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let t = m.task("regression").unwrap();
        let mut rng = Pcg64::new(1);
        let p = t.init_params(&mut rng);
        assert_eq!(p.dim(), 14);
        // Bias block (last value) must be exactly zero.
        assert_eq!(p.0[13], 0.0);
        // Weight block is random (not all zero).
        assert!(p.0[..13].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn rejects_inconsistent_init() {
        let bad = SAMPLE.replace("\"param_dim\": 14", "\"param_dim\": 15");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn missing_dir_is_a_clear_error() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(format!("{err}").contains("make artifacts"));
    }
}
