//! Stub [`XlaTrainer`] for builds without the `xla` feature.
//!
//! Keeps every call site (CLI `--backend xla`, the mnist example, the
//! artifact-gated integration tests) compiling in the dependency-free
//! offline build; constructing the trainer reports how to get the real
//! one instead.

use crate::config::ExperimentConfig;
use crate::data::FedData;
use crate::error::{Result, SafaError};
use crate::model::{EvalResult, LocalUpdate, ParamVec, Trainer};
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// Placeholder with the same constructor surface as the PJRT trainer.
/// Cannot actually be instantiated — `new` always errors.
pub struct XlaTrainer {
    _unconstructible: (),
}

impl XlaTrainer {
    /// Always fails: this build carries no PJRT runtime.
    pub fn new(_cfg: &ExperimentConfig, _data: Arc<FedData>) -> Result<XlaTrainer> {
        Err(SafaError::Runtime(
            "this build has no XLA runtime; vendor the `xla` crate and rebuild with \
             `--features xla` (or use --backend native)"
                .into(),
        ))
    }
}

impl Trainer for XlaTrainer {
    fn dim(&self) -> usize {
        unreachable!("stub XlaTrainer cannot be constructed")
    }

    fn init_params(&self, _rng: &mut Pcg64) -> ParamVec {
        unreachable!("stub XlaTrainer cannot be constructed")
    }

    fn local_update(&mut self, _base: &ParamVec, _client: usize, _rng: &mut Pcg64) -> LocalUpdate {
        unreachable!("stub XlaTrainer cannot be constructed")
    }

    fn evaluate(&mut self, _params: &ParamVec) -> EvalResult {
        unreachable!("stub XlaTrainer cannot be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::data::{partition_gaussian, synth, FedData};

    #[test]
    fn stub_reports_missing_feature() {
        let cfg = presets::preset("tiny").unwrap();
        let (train, test) = synth::generate(cfg.task.kind, cfg.task.n, cfg.task.n_test, 1);
        let mut rng = Pcg64::new(1);
        let partitions = partition_gaussian(train.n, cfg.env.m, 0.3, &mut rng);
        let data = Arc::new(FedData {
            train,
            test,
            partitions,
        });
        let err = XlaTrainer::new(&cfg, data).unwrap_err();
        assert!(err.to_string().contains("--features xla"), "{err}");
    }
}
