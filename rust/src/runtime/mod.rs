//! Runtime bridge to the AOT-compiled JAX/Pallas artifacts.
//!
//! The real PJRT executor lives behind the `xla` cargo feature because
//! the offline image ships no `xla` crate: the default build substitutes
//! a stub [`XlaTrainer`] whose constructor returns a clear error, so the
//! CLI, examples and tests compile (and the artifact-gated integration
//! tests skip) without the native XLA toolchain. Enable `--features xla`
//! after vendoring the `xla` crate to get the full PJRT path described in
//! the crate docs.
//!
//! The artifact [`Manifest`] parser is dependency-free and always
//! available.

mod manifest;

pub use manifest::{Manifest, TaskArtifact};

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{compile_hlo, XlaTrainer, MASK_SENTINEL};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::XlaTrainer;
