//! PJRT runtime: loads the HLO artifacts produced by `python/compile/`
//! (JAX model + Pallas kernels, lowered once at build time) and executes
//! them from the Rust hot path. Python never runs at experiment time.
//!
//! Artifact contract (see `python/compile/aot.py`):
//! * `artifacts/manifest.json` — per-task shapes and hyper-parameters.
//! * `<task>_train.hlo.txt` — ONE epoch of masked minibatch SGD:
//!   `(params[p], x[mb, B, d], y[mb, B], mask[mb, B]) ->
//!    (new_params[p], mean_loss[])`.
//!   The Rust side loops E epochs, reshuffling batches between calls
//!   (exactly what the native backend does, so backends agree).
//! * `<task>_eval.hlo.txt` — `(params[p], x[n, d], y[n]) ->
//!   (loss[], accuracy[])` with the paper's Table III accuracy formula.
//!
//! HLO **text** is the interchange format: the crate's xla_extension
//! 0.5.1 rejects jax≥0.5 serialized protos (64-bit instruction ids); the
//! text parser reassigns ids (see /opt/xla-example/README.md).

use super::manifest::{Manifest, TaskArtifact};
use crate::config::ExperimentConfig;
use crate::data::FedData;
use crate::error::{Result, SafaError};
use crate::model::{EvalResult, LocalUpdate, ParamVec, Trainer};
use crate::util::rng::Pcg64;
use std::path::Path;
use std::sync::Arc;

/// A compiled pair of train/eval executables for one task.
pub struct XlaTrainer {
    data: Arc<FedData>,
    spec: TaskArtifact,
    epochs: usize,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
    /// Pre-staged test-set literals (built once; eval is called per
    /// round).
    test_x: xla::Literal,
    test_y: xla::Literal,
}

impl XlaTrainer {
    /// Load artifacts for the configured task and compile them on the
    /// PJRT CPU client.
    pub fn new(cfg: &ExperimentConfig, data: Arc<FedData>) -> Result<XlaTrainer> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let spec = manifest.task(cfg.task.kind.name())?.clone();
        // Guard: artifacts are compiled for specific shapes.
        if spec.d != data.train.d {
            return Err(SafaError::Artifact(format!(
                "artifact d={} but dataset d={}; rebuild with `make artifacts`",
                spec.d, data.train.d
            )));
        }
        if spec.batch_size != cfg.train.batch_size {
            return Err(SafaError::Artifact(format!(
                "artifact B={} but config B={}; rebuild with `make artifacts`",
                spec.batch_size, cfg.train.batch_size
            )));
        }
        let max_shard = data
            .partitions
            .iter()
            .map(|p| p.indices.len())
            .max()
            .unwrap_or(0);
        let max_batches_needed = max_shard.div_ceil(cfg.train.batch_size);
        if max_batches_needed > spec.max_batches {
            return Err(SafaError::Artifact(format!(
                "largest shard needs {max_batches_needed} batches but artifact supports {}",
                spec.max_batches
            )));
        }
        if data.test.n > spec.n_test {
            return Err(SafaError::Artifact(format!(
                "test set n={} exceeds artifact capacity {}",
                data.test.n,
                spec.n_test
            )));
        }

        let client = xla::PjRtClient::cpu()?;
        let dir = Path::new(&cfg.artifacts_dir);
        let train_exe = compile_hlo(&client, &dir.join(&spec.train_hlo))?;
        let eval_exe = compile_hlo(&client, &dir.join(&spec.eval_hlo))?;

        // Stage the test set (pad to the artifact's n_test with repeats
        // of row 0 and weight... eval graph uses a mask too).
        let (test_x, test_y) = stage_eval_set(&data, &spec);

        Ok(XlaTrainer {
            data,
            spec,
            epochs: cfg.train.epochs,
            train_exe,
            eval_exe,
            test_x,
            test_y,
        })
    }

    /// One epoch through the train executable.
    fn run_epoch(&self, params: &ParamVec, order: &[usize]) -> Result<(ParamVec, f64)> {
        let spec = &self.spec;
        let (mb, b, d) = (spec.max_batches, spec.batch_size, spec.d);
        let mut x = vec![0.0f32; mb * b * d];
        let mut y = vec![0.0f32; mb * b];
        let mut mask = vec![0.0f32; mb * b];
        for (slot, &i) in order.iter().enumerate() {
            debug_assert!(slot < mb * b, "shard exceeds artifact capacity");
            x[slot * d..(slot + 1) * d].copy_from_slice(self.data.train.row(i));
            y[slot] = self.data.train.y[i];
            mask[slot] = 1.0;
        }
        let p_lit = xla::Literal::vec1(params.as_slice());
        let x_lit =
            xla::Literal::vec1(&x).reshape(&[mb as i64, b as i64, d as i64])?;
        let y_lit = xla::Literal::vec1(&y).reshape(&[mb as i64, b as i64])?;
        let m_lit = xla::Literal::vec1(&mask).reshape(&[mb as i64, b as i64])?;
        let result = self
            .train_exe
            .execute::<xla::Literal>(&[p_lit, x_lit, y_lit, m_lit])?[0][0]
            .to_literal_sync()?;
        let (new_params, loss) = result.to_tuple2()?;
        Ok((
            ParamVec(new_params.to_vec::<f32>()?),
            loss.get_first_element::<f32>()? as f64,
        ))
    }
}

/// Compile one HLO text file on a PJRT client.
pub fn compile_hlo(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let path_str = path
        .to_str()
        .ok_or_else(|| SafaError::Artifact(format!("non-UTF8 path {path:?}")))?;
    if !path.exists() {
        return Err(SafaError::Artifact(format!(
            "missing artifact {path_str}; run `make artifacts` first"
        )));
    }
    let proto = xla::HloModuleProto::from_text_file(path_str)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

/// Build padded test-set literals: x[n_test, d], y packs labels with a
/// trailing validity mask folded into y via NaN-free padding — the eval
/// graph receives an explicit mask instead, appended as the last feature
/// row? No: we keep it simple and pad with repeats of row 0 whose
/// contribution the eval graph cancels through the mask input.
fn stage_eval_set(data: &FedData, spec: &TaskArtifact) -> (xla::Literal, xla::Literal) {
    let (n_art, d) = (spec.n_test, spec.d);
    let mut x = vec![0.0f32; n_art * d];
    let mut y = vec![0.0f32; n_art];
    for i in 0..data.test.n.min(n_art) {
        x[i * d..(i + 1) * d].copy_from_slice(data.test.row(i));
        y[i] = data.test.y[i];
    }
    // Mask is communicated as y = MASK_SENTINEL on padding rows; the
    // Python eval graph weights rows by (y != MASK_SENTINEL).
    for item in y.iter_mut().skip(data.test.n) {
        *item = MASK_SENTINEL;
    }
    let x_lit = xla::Literal::vec1(&x)
        .reshape(&[n_art as i64, d as i64])
        .expect("eval reshape");
    let y_lit = xla::Literal::vec1(&y);
    (x_lit, y_lit)
}

/// Label sentinel marking padded eval rows (labels are house prices in
/// [5,50], digits 0..9 or ±1 — never this value).
pub const MASK_SENTINEL: f32 = -1.0e9;

impl Trainer for XlaTrainer {
    fn dim(&self) -> usize {
        self.spec.param_dim
    }

    fn init_params(&self, rng: &mut Pcg64) -> ParamVec {
        // Initialization family matches the native backend (and therefore
        // the documented Python family): He-normal weights, zero biases,
        // delegated so all backends share one code path.
        self.spec.init_params(rng)
    }

    fn local_update(&mut self, base: &ParamVec, client: usize, rng: &mut Pcg64) -> LocalUpdate {
        let shard = self.data.partitions[client].indices.clone();
        let mut params = base.clone();
        let mut last_loss = 0.0;
        for _ in 0..self.epochs {
            let mut order = shard.clone();
            rng.shuffle(&mut order);
            match self.run_epoch(&params, &order) {
                Ok((p, loss)) => {
                    params = p;
                    last_loss = loss;
                }
                Err(e) => {
                    // Surfacing errors through the Trainer trait would
                    // poison every protocol path for what is always a
                    // build/config problem; fail fast instead.
                    panic!("XLA local_update failed: {e}");
                }
            }
        }
        LocalUpdate {
            params,
            train_loss: last_loss,
        }
    }

    fn evaluate(&mut self, params: &ParamVec) -> EvalResult {
        let p_lit = xla::Literal::vec1(params.as_slice());
        let result = (|| -> Result<(f64, f64)> {
            let out = self
                .eval_exe
                .execute::<xla::Literal>(&[
                    p_lit,
                    clone_literal(&self.test_x),
                    clone_literal(&self.test_y),
                ])?[0][0]
                .to_literal_sync()?;
            let (loss, acc) = out.to_tuple2()?;
            Ok((
                loss.get_first_element::<f32>()? as f64,
                acc.get_first_element::<f32>()? as f64,
            ))
        })();
        match result {
            Ok((loss, accuracy)) => EvalResult { loss, accuracy },
            Err(e) => panic!("XLA evaluate failed: {e}"),
        }
    }
}

/// The xla crate's Literal is not Clone; round-trip through raw bytes.
fn clone_literal(lit: &xla::Literal) -> xla::Literal {
    let shape = lit.array_shape().expect("literal shape");
    let data = lit.to_vec::<f32>().expect("literal data");
    let dims: Vec<i64> = shape.dims().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&data)
        .reshape(&dims)
        .expect("literal clone reshape")
}

#[cfg(test)]
mod tests {
    // XlaTrainer needs built artifacts; its integration tests live in
    // rust/tests/xla_runtime.rs and skip gracefully when artifacts are
    // absent. Here we only test the pure helpers.
    use super::*;

    #[test]
    fn mask_sentinel_cannot_collide_with_labels() {
        for label in [-1.0f32, 1.0, 0.0, 9.0, 5.0, 50.0] {
            assert!(label != MASK_SENTINEL);
        }
    }

    #[test]
    fn missing_artifact_yields_clear_error() {
        let client = xla::PjRtClient::cpu().unwrap();
        let err = match compile_hlo(&client, Path::new("/nonexistent/foo.hlo.txt")) {
            Err(e) => e,
            Ok(_) => panic!("expected a missing-artifact error"),
        };
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
    }
}
