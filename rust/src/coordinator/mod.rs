//! The federation coordinator: owns the round loop (Alg. 2's server
//! process), drives the configured protocol against the environment and
//! collects the paper's metrics into a [`RunResult`].

use crate::config::ExperimentConfig;
use crate::data::FedData;
use crate::error::Result;
use crate::metrics::{RoundRecord, RunResult};
use crate::model::Trainer;
use crate::protocol::{make_protocol, FedEnv, Protocol};
use std::sync::Arc;

/// Orchestrates a full federated-learning run.
pub struct Coordinator {
    pub env: FedEnv,
    pub protocol: Box<dyn Protocol>,
}

impl Coordinator {
    /// Build everything from a config (data synthesis included).
    pub fn new(cfg: &ExperimentConfig) -> Result<Coordinator> {
        let env = FedEnv::new(cfg)?;
        let protocol = make_protocol(&env);
        Ok(Coordinator { env, protocol })
    }

    /// Build with shared data (benchmark grids reuse one dataset).
    pub fn with_data(cfg: &ExperimentConfig, data: Arc<FedData>) -> Result<Coordinator> {
        let env = FedEnv::with_data(cfg, data)?;
        let protocol = make_protocol(&env);
        Ok(Coordinator { env, protocol })
    }

    /// Build with an injected trainer (the XLA runtime path).
    pub fn with_trainer(
        cfg: &ExperimentConfig,
        data: Arc<FedData>,
        trainer: Box<dyn Trainer>,
    ) -> Result<Coordinator> {
        let env = FedEnv::with_trainer(cfg, data, trainer)?;
        let protocol = make_protocol(&env);
        Ok(Coordinator { env, protocol })
    }

    /// Scenario flash crowds: diff fleet membership against the previous
    /// round, stamp join/departure rounds on the client states and emit
    /// `join` / `leave` lifecycle trace events. No-op (and branch-free
    /// beyond one check) without a scenario timeline, so legacy runs are
    /// untouched. Serial, before the protocol's round — line order in
    /// the trace is deterministic.
    fn refresh_membership(&mut self, t: usize) {
        if !self.env.dynamic_membership() {
            return;
        }
        use crate::telemetry::lifecycle::{self, ClientEvent, Event as LcEvent};
        let lc = lifecycle::active();
        for k in 0..self.env.m() {
            let now = self.env.is_member(t, k);
            let before = t > 1 && self.env.is_member(t - 1, k);
            if now == before {
                continue;
            }
            let c = &mut self.env.clients[k];
            if now {
                // Round-1 members are founding members, not joiners.
                if t > 1 {
                    c.joined_round = Some(t);
                    c.departed_round = None;
                    if lc {
                        lifecycle::emit(ClientEvent::new(t, k, LcEvent::Join, 0.0));
                    }
                }
            } else {
                c.departed_round = Some(t);
                if lc {
                    lifecycle::emit(ClientEvent::new(t, k, LcEvent::Leave, 0.0));
                }
            }
        }
    }

    /// Run all configured rounds and return the metric record.
    pub fn run(&mut self) -> RunResult {
        let cfg = self.env.cfg.clone();
        let mut rounds: Vec<RoundRecord> = Vec::with_capacity(cfg.train.rounds);
        // SAFA_TRACE: per-round JSONL lines (round record + telemetry
        // delta). Snapshotting only when tracing keeps the default path
        // free of even the cheap shard merge.
        let tracing = crate::telemetry::trace_active();
        if tracing {
            // SAFA_TRACE v2 header: one meta line so `safa report` (and
            // external tooling) can label the run without side-channel
            // state.
            use crate::util::json::Json;
            let mut meta = Json::obj();
            meta.set("type", Json::Str("meta".into()));
            meta.set("v", Json::Num(2.0));
            meta.set("schema", Json::Str("safa-trace".into()));
            meta.set("protocol", Json::Str(self.protocol.kind().name().into()));
            meta.set("task", Json::Str(cfg.task.kind.name().into()));
            meta.set("m", Json::Num(cfg.env.m as f64));
            meta.set("rounds", Json::Num(cfg.train.rounds as f64));
            meta.set("seed", Json::Num(cfg.seed as f64));
            meta.set(
                "sample",
                Json::Num(crate::telemetry::lifecycle::sample_stride() as f64),
            );
            crate::telemetry::trace_line(&meta);
        }
        for t in 1..=cfg.train.rounds {
            self.refresh_membership(t);
            let telemetry_before = if tracing {
                Some(crate::telemetry::snapshot())
            } else {
                None
            };
            let rec = self.protocol.run_round(t, &mut self.env);
            if let Some(before) = telemetry_before {
                let delta = crate::telemetry::snapshot().since(&before);
                let proto = self.protocol.kind().name().to_string();
                let mut line = rec.to_json();
                line.set("type", crate::util::json::Json::Str("round".into()));
                line.set("v", crate::util::json::Json::Num(2.0));
                line.set("protocol", crate::util::json::Json::Str(proto));
                line.set("telemetry", delta.to_json());
                crate::telemetry::trace_line(&line);
            }
            crate::log_debug!(
                "[{}] round {t}/{}: len={:.1}s picked={} committed={} crashed={} loss={:?}",
                self.protocol.kind().name(),
                cfg.train.rounds,
                rec.round_len,
                rec.n_picked,
                rec.n_committed,
                rec.n_crashed,
                rec.eval.map(|e| e.loss)
            );
            rounds.push(rec);
        }
        self.protocol.finalize(&mut self.env);
        if tracing {
            let dropped = crate::telemetry::trace_dropped();
            if dropped > 0 {
                crate::log_warn!(
                    "SAFA_TRACE: {dropped} trace line(s) failed to write (disk full or \
                     closed sink?); the trace file is incomplete"
                );
            }
        }
        let final_eval = Some(self.env.trainer.evaluate(self.protocol.global()));
        RunResult {
            protocol: self.protocol.kind().name().to_string(),
            task: cfg.task.kind.name().to_string(),
            c_fraction: cfg.protocol.c_fraction,
            crash_prob: cfg.env.crash_prob,
            tau: cfg.protocol.tau,
            seed: cfg.seed,
            m: cfg.env.m,
            rounds,
            final_eval,
        }
    }
}

/// Convenience: run one experiment end-to-end from a config.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunResult> {
    Ok(Coordinator::new(cfg)?.run())
}

/// Run the same config with shared data (grid sweeps).
pub fn run_with_data(cfg: &ExperimentConfig, data: Arc<FedData>) -> Result<RunResult> {
    Ok(Coordinator::with_data(cfg, data)?.run())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, ProtocolKind};

    #[test]
    fn full_run_produces_all_rounds() {
        let cfg = presets::preset("tiny").unwrap();
        let result = run_experiment(&cfg).unwrap();
        assert_eq!(result.rounds.len(), cfg.train.rounds);
        assert!(result.final_eval.is_some());
        assert_eq!(result.protocol, "SAFA");
    }

    #[test]
    fn runs_are_reproducible() {
        let cfg = presets::preset("tiny").unwrap();
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.round_len, y.round_len);
            assert_eq!(x.n_picked, y.n_picked);
            assert_eq!(x.eval.map(|e| e.loss), y.eval.map(|e| e.loss));
        }
        assert_eq!(
            a.final_eval.unwrap().accuracy,
            b.final_eval.unwrap().accuracy
        );
    }

    #[test]
    fn all_protocols_complete_under_crashes() {
        for kind in ProtocolKind::ALL {
            for crash in [0.0, 0.5, 1.0] {
                let mut cfg = presets::preset("tiny").unwrap();
                cfg.protocol.kind = kind;
                cfg.env.crash_prob = crash;
                cfg.train.rounds = 4;
                let result = run_experiment(&cfg)
                    .unwrap_or_else(|e| panic!("{kind:?} cr={crash}: {e}"));
                assert_eq!(result.rounds.len(), 4);
            }
        }
    }

    #[test]
    fn all_protocols_complete_under_markov_churn() {
        for kind in ProtocolKind::ALL {
            let mut cfg = presets::preset("tiny-churn").unwrap();
            cfg.protocol.kind = kind;
            cfg.train.rounds = 4;
            let result =
                run_experiment(&cfg).unwrap_or_else(|e| panic!("{kind:?} under churn: {e}"));
            assert_eq!(result.rounds.len(), 4);
            let f = result.avg_online_fraction();
            assert!(
                f > 0.0 && f <= 1.0,
                "{kind:?}: online fraction {f} out of range"
            );
        }
    }

    #[test]
    fn safa_converges_on_tiny_regression() {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.train.rounds = 20;
        cfg.train.lr = 5e-3;
        cfg.env.crash_prob = 0.1;
        let result = run_experiment(&cfg).unwrap();
        let first = result.rounds[0].eval.unwrap().loss;
        let best = result.best_loss().unwrap();
        assert!(best < first * 0.8, "loss {first} -> best {best}");
    }

    #[test]
    fn safa_rounds_are_faster_than_fedavg_at_small_c() {
        // The paper's efficiency headline (Tables IV/VI/VIII): with a
        // small selection fraction under crashes, SAFA's post-training
        // selection closes rounds much earlier than FedAvg's synchronous
        // wait. This is the robust, scale-independent claim — quality
        // comparisons at the paper's full configuration live in the
        // benches (EXPERIMENTS.md).
        let mut safa_len = Vec::new();
        let mut fedavg_len = Vec::new();
        for seed in 1..=3u64 {
            for kind in [ProtocolKind::Safa, ProtocolKind::FedAvg] {
                let mut cfg = presets::preset("task1").unwrap();
                cfg.backend = crate::config::Backend::Null;
                cfg.protocol.kind = kind;
                cfg.protocol.c_fraction = 0.1;
                cfg.env.crash_prob = 0.3;
                cfg.train.rounds = 50;
                cfg.seed = seed;
                let r = run_experiment(&cfg).unwrap();
                match kind {
                    ProtocolKind::Safa => safa_len.push(r.avg_round_len()),
                    _ => fedavg_len.push(r.avg_round_len()),
                }
            }
        }
        let safa: f64 = safa_len.iter().sum::<f64>() / safa_len.len() as f64;
        let fedavg: f64 = fedavg_len.iter().sum::<f64>() / fedavg_len.len() as f64;
        assert!(
            safa < fedavg,
            "SAFA avg round {safa}s should beat FedAvg {fedavg}s at C=0.1"
        );
    }

    #[test]
    fn safa_quality_competitive_with_fedavg_at_task1_config() {
        // Table X's regime: at the paper's Task-1 configuration both
        // protocols approach the accuracy ceiling; SAFA must stay within
        // a few points of FedAvg (and beats it at small C / high cr —
        // asserted by the benches, not here, for runtime reasons).
        let mut cfg = presets::preset("task1").unwrap();
        cfg.protocol.c_fraction = 0.3;
        cfg.env.crash_prob = 0.3;
        cfg.train.rounds = 100;
        cfg.seed = 2;
        cfg.protocol.kind = ProtocolKind::Safa;
        let safa = run_experiment(&cfg).unwrap().best_accuracy().unwrap();
        cfg.protocol.kind = ProtocolKind::FedAvg;
        let fedavg = run_experiment(&cfg).unwrap().best_accuracy().unwrap();
        assert!(
            safa > fedavg - 0.05,
            "SAFA accuracy {safa} vs FedAvg {fedavg}"
        );
    }
}
