//! Dense tensor kernels for the native trainers: matmul (three variants),
//! im2col/col2im convolution lowering, 2×2 max-pooling, ReLU and
//! softmax-cross-entropy.
//!
//! The matmuls use the i-k-j loop order with a contiguous axpy inner loop,
//! which LLVM auto-vectorizes; this is the native backend's hot path (see
//! EXPERIMENTS.md §Perf for measurements and the optimization log).

/// c[m,n] = a[m,k] @ b[k,n] (+= when `accumulate`).
pub fn matmul(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, accumulate: bool) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if !accumulate {
        c.fill(0.0);
    }
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (p, &a_ip) in a_row.iter().enumerate() {
            if a_ip == 0.0 {
                continue; // common after ReLU masking
            }
            let b_row = &b[p * n..(p + 1) * n];
            for (c_ij, &b_pj) in c_row.iter_mut().zip(b_row) {
                *c_ij += a_ip * b_pj;
            }
        }
    }
}

/// c[m,n] = a[k,m]^T @ b[k,n] (+= when `accumulate`). Used for dW = x^T g.
pub fn matmul_tn(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if !accumulate {
        c.fill(0.0);
    }
    for p in 0..k {
        let a_row = &a[p * m..(p + 1) * m];
        let b_row = &b[p * n..(p + 1) * n];
        for (i, &a_pi) in a_row.iter().enumerate() {
            if a_pi == 0.0 {
                continue;
            }
            let c_row = &mut c[i * n..(i + 1) * n];
            for (c_ij, &b_pj) in c_row.iter_mut().zip(b_row) {
                *c_ij += a_pi * b_pj;
            }
        }
    }
}

/// c[m,n] = a[m,k] @ b[n,k]^T (+= when `accumulate`). Used for dx = g W.
pub fn matmul_nt(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if !accumulate {
        c.fill(0.0);
    }
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (j, c_ij) in c_row.iter_mut().enumerate() {
            let b_row = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&x, &y) in a_row.iter().zip(b_row) {
                acc += x * y;
            }
            *c_ij += acc;
        }
    }
}

/// im2col for a batch: input [batch, ch, h, w] → cols
/// [batch*oh*ow, ch*kh*kw] where oh = h-kh+1, ow = w-kw+1 ("valid").
pub fn im2col(
    cols: &mut [f32],
    input: &[f32],
    batch: usize,
    ch: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
) {
    let oh = h - kh + 1;
    let ow = w - kw + 1;
    let patch_len = ch * kh * kw;
    debug_assert_eq!(cols.len(), batch * oh * ow * patch_len);
    debug_assert_eq!(input.len(), batch * ch * h * w);
    let mut row = 0usize;
    for b in 0..batch {
        let img = &input[b * ch * h * w..(b + 1) * ch * h * w];
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut cols[row * patch_len..(row + 1) * patch_len];
                let mut d = 0usize;
                for c in 0..ch {
                    let plane = &img[c * h * w..(c + 1) * h * w];
                    for ky in 0..kh {
                        let src = &plane[(oy + ky) * w + ox..(oy + ky) * w + ox + kw];
                        dst[d..d + kw].copy_from_slice(src);
                        d += kw;
                    }
                }
                row += 1;
            }
        }
    }
}

/// col2im: scatter-add the column gradient back to input layout.
/// `dcols` is [batch*oh*ow, ch*kh*kw]; `dinput` is [batch, ch, h, w].
pub fn col2im(
    dinput: &mut [f32],
    dcols: &[f32],
    batch: usize,
    ch: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
) {
    let oh = h - kh + 1;
    let ow = w - kw + 1;
    let patch_len = ch * kh * kw;
    dinput.fill(0.0);
    let mut row = 0usize;
    for b in 0..batch {
        let img = &mut dinput[b * ch * h * w..(b + 1) * ch * h * w];
        for oy in 0..oh {
            for ox in 0..ow {
                let src = &dcols[row * patch_len..(row + 1) * patch_len];
                let mut s = 0usize;
                for c in 0..ch {
                    let plane = &mut img[c * h * w..(c + 1) * h * w];
                    for ky in 0..kh {
                        let dst = &mut plane[(oy + ky) * w + ox..(oy + ky) * w + ox + kw];
                        for (d, &v) in dst.iter_mut().zip(&src[s..s + kw]) {
                            *d += v;
                        }
                        s += kw;
                    }
                }
                row += 1;
            }
        }
    }
}

/// 2×2 max-pool (stride 2) over [batch, ch, h, w]; h and w must be even.
/// Writes pooled output and the argmax index (0..4) per output cell for
/// the backward pass.
pub fn maxpool2(
    out: &mut [f32],
    argmax: &mut [u8],
    input: &[f32],
    batch: usize,
    ch: usize,
    h: usize,
    w: usize,
) {
    debug_assert!(h % 2 == 0 && w % 2 == 0);
    let (oh, ow) = (h / 2, w / 2);
    let mut o = 0usize;
    for b in 0..batch {
        for c in 0..ch {
            let plane = &input[(b * ch + c) * h * w..(b * ch + c + 1) * h * w];
            for oy in 0..oh {
                for ox in 0..ow {
                    let base = (2 * oy) * w + 2 * ox;
                    let vals = [plane[base], plane[base + 1], plane[base + w], plane[base + w + 1]];
                    let mut best = 0usize;
                    for i in 1..4 {
                        if vals[i] > vals[best] {
                            best = i;
                        }
                    }
                    out[o] = vals[best];
                    argmax[o] = best as u8;
                    o += 1;
                }
            }
        }
    }
}

/// Backward of [`maxpool2`]: route `dout` to the argmax positions.
pub fn maxpool2_back(
    dinput: &mut [f32],
    dout: &[f32],
    argmax: &[u8],
    batch: usize,
    ch: usize,
    h: usize,
    w: usize,
) {
    let (oh, ow) = (h / 2, w / 2);
    dinput.fill(0.0);
    let mut o = 0usize;
    for b in 0..batch {
        for c in 0..ch {
            let plane = &mut dinput[(b * ch + c) * h * w..(b * ch + c + 1) * h * w];
            for oy in 0..oh {
                for ox in 0..ow {
                    let base = (2 * oy) * w + 2 * ox;
                    let off = match argmax[o] {
                        0 => 0,
                        1 => 1,
                        2 => w,
                        _ => w + 1,
                    };
                    plane[base + off] += dout[o];
                    o += 1;
                }
            }
        }
    }
}

/// im2col for channels-last input [batch, h, w, ch] → cols
/// [batch*oh*ow, kh*kw*ch]. Channels-last keeps conv-as-matmul outputs
/// batch-major, which is the layout the CNN trainer uses throughout.
pub fn im2col_nhwc(
    cols: &mut [f32],
    input: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    ch: usize,
    kh: usize,
    kw: usize,
) {
    let oh = h - kh + 1;
    let ow = w - kw + 1;
    let patch_len = kh * kw * ch;
    debug_assert_eq!(cols.len(), batch * oh * ow * patch_len);
    debug_assert_eq!(input.len(), batch * h * w * ch);
    let mut row = 0usize;
    for b in 0..batch {
        let img = &input[b * h * w * ch..(b + 1) * h * w * ch];
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut cols[row * patch_len..(row + 1) * patch_len];
                let mut d = 0usize;
                for ky in 0..kh {
                    let src_base = ((oy + ky) * w + ox) * ch;
                    dst[d..d + kw * ch].copy_from_slice(&img[src_base..src_base + kw * ch]);
                    d += kw * ch;
                }
                row += 1;
            }
        }
    }
}

/// col2im for channels-last: scatter-add column gradients back to
/// [batch, h, w, ch].
pub fn col2im_nhwc(
    dinput: &mut [f32],
    dcols: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    ch: usize,
    kh: usize,
    kw: usize,
) {
    let oh = h - kh + 1;
    let ow = w - kw + 1;
    let patch_len = kh * kw * ch;
    dinput.fill(0.0);
    let mut row = 0usize;
    for b in 0..batch {
        let img = &mut dinput[b * h * w * ch..(b + 1) * h * w * ch];
        for oy in 0..oh {
            for ox in 0..ow {
                let src = &dcols[row * patch_len..(row + 1) * patch_len];
                let mut s = 0usize;
                for ky in 0..kh {
                    let dst_base = ((oy + ky) * w + ox) * ch;
                    for (d, &v) in img[dst_base..dst_base + kw * ch].iter_mut().zip(&src[s..s + kw * ch]) {
                        *d += v;
                    }
                    s += kw * ch;
                }
                row += 1;
            }
        }
    }
}

/// 2×2 max-pool (stride 2) for channels-last [batch, h, w, ch].
/// `argmax` stores the winning quadrant (0..4) per output element.
pub fn maxpool2_nhwc(
    out: &mut [f32],
    argmax: &mut [u8],
    input: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    ch: usize,
) {
    debug_assert!(h % 2 == 0 && w % 2 == 0);
    let (oh, ow) = (h / 2, w / 2);
    debug_assert_eq!(out.len(), batch * oh * ow * ch);
    let mut o = 0usize;
    for b in 0..batch {
        let img = &input[b * h * w * ch..(b + 1) * h * w * ch];
        for oy in 0..oh {
            for ox in 0..ow {
                let base = ((2 * oy) * w + 2 * ox) * ch;
                for c in 0..ch {
                    let vals = [
                        img[base + c],
                        img[base + ch + c],
                        img[base + w * ch + c],
                        img[base + (w + 1) * ch + c],
                    ];
                    let mut best = 0usize;
                    for i in 1..4 {
                        if vals[i] > vals[best] {
                            best = i;
                        }
                    }
                    out[o] = vals[best];
                    argmax[o] = best as u8;
                    o += 1;
                }
            }
        }
    }
}

/// Backward of [`maxpool2_nhwc`].
pub fn maxpool2_back_nhwc(
    dinput: &mut [f32],
    dout: &[f32],
    argmax: &[u8],
    batch: usize,
    h: usize,
    w: usize,
    ch: usize,
) {
    let (oh, ow) = (h / 2, w / 2);
    dinput.fill(0.0);
    let mut o = 0usize;
    for b in 0..batch {
        let img = &mut dinput[b * h * w * ch..(b + 1) * h * w * ch];
        for oy in 0..oh {
            for ox in 0..ow {
                let base = ((2 * oy) * w + 2 * ox) * ch;
                for c in 0..ch {
                    let off = match argmax[o] {
                        0 => c,
                        1 => ch + c,
                        2 => w * ch + c,
                        _ => (w + 1) * ch + c,
                    };
                    img[base + off] += dout[o];
                    o += 1;
                }
            }
        }
    }
}

/// In-place ReLU; returns nothing, mask recoverable from the output.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Backward ReLU: zero `grad` where the forward *output* was zero.
pub fn relu_back(grad: &mut [f32], fwd_out: &[f32]) {
    for (g, &y) in grad.iter_mut().zip(fwd_out) {
        if y <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Softmax + cross-entropy over logits [batch, classes] with integer
/// labels. Returns mean loss; writes dlogits (already divided by batch).
pub fn softmax_xent(
    dlogits: &mut [f32],
    logits: &[f32],
    labels: &[f32],
    batch: usize,
    classes: usize,
) -> f64 {
    let mut loss = 0.0f64;
    for b in 0..batch {
        let row = &logits[b * classes..(b + 1) * classes];
        let drow = &mut dlogits[b * classes..(b + 1) * classes];
        let maxv = row.iter().copied().fold(f32::MIN, f32::max);
        let mut sum = 0.0f32;
        for (d, &l) in drow.iter_mut().zip(row) {
            *d = (l - maxv).exp();
            sum += *d;
        }
        let label = labels[b] as usize;
        let p = drow[label] / sum;
        loss += -(p.max(1e-12) as f64).ln();
        for d in drow.iter_mut() {
            *d /= sum * batch as f32;
        }
        drow[label] -= 1.0 / batch as f32;
    }
    loss / batch as f64
}

/// Accuracy for logits [batch, classes] vs integer labels.
pub fn argmax_accuracy(logits: &[f32], labels: &[f32], batch: usize, classes: usize) -> f64 {
    let mut correct = 0usize;
    for b in 0..batch {
        let row = &logits[b * classes..(b + 1) * classes];
        let mut best = 0usize;
        for i in 1..classes {
            if row[i] > row[best] {
                best = i;
            }
        }
        if best == labels[b] as usize {
            correct += 1;
        }
    }
    correct as f64 / batch as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    fn matmul_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        property("matmul == naive", 50, |g| {
            let m = g.usize_range(1, 8);
            let k = g.usize_range(1, 8);
            let n = g.usize_range(1, 8);
            let a = g.vec_f32(m * k, -2.0, 2.0);
            let b = g.vec_f32(k * n, -2.0, 2.0);
            let want = matmul_naive(&a, &b, m, k, n);
            let mut c = vec![0.0; m * n];
            matmul(&mut c, &a, &b, m, k, n, false);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        });
    }

    #[test]
    fn matmul_tn_nt_match_transposes() {
        property("tn/nt variants", 50, |g| {
            let m = g.usize_range(1, 6);
            let k = g.usize_range(1, 6);
            let n = g.usize_range(1, 6);
            // tn: a stored as [k, m]
            let a_t = g.vec_f32(k * m, -2.0, 2.0);
            let b = g.vec_f32(k * n, -2.0, 2.0);
            let mut a = vec![0.0; m * k];
            for p in 0..k {
                for i in 0..m {
                    a[i * k + p] = a_t[p * m + i];
                }
            }
            let want = matmul_naive(&a, &b, m, k, n);
            let mut c = vec![0.0; m * n];
            matmul_tn(&mut c, &a_t, &b, m, k, n, false);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-4);
            }
            // nt: b stored as [n, k]
            let b_t = g.vec_f32(n * k, -2.0, 2.0);
            let mut b2 = vec![0.0; k * n];
            for j in 0..n {
                for p in 0..k {
                    b2[p * n + j] = b_t[j * k + p];
                }
            }
            let want = matmul_naive(&a, &b2, m, k, n);
            let mut c = vec![0.0; m * n];
            matmul_nt(&mut c, &a, &b_t, m, k, n, false);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn accumulate_adds() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![2.0, 3.0, 4.0, 5.0];
        let mut c = vec![1.0; 4];
        matmul(&mut c, &a, &b, 2, 2, 2, true);
        assert_eq!(c, vec![3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn im2col_col2im_roundtrip_counts() {
        // col2im(im2col(x)) multiplies each pixel by its patch coverage.
        let (b, c, h, w, k) = (2usize, 3usize, 6usize, 5usize, 3usize);
        let input: Vec<f32> = (0..b * c * h * w).map(|i| i as f32 * 0.1).collect();
        let (oh, ow) = (h - k + 1, w - k + 1);
        let mut cols = vec![0.0; b * oh * ow * c * k * k];
        im2col(&mut cols, &input, b, c, h, w, k, k);
        let mut back = vec![0.0; input.len()];
        col2im(&mut back, &cols, b, c, h, w, k, k);
        // Coverage of pixel (y,x) = #windows containing it:
        // count of o in [0, dim-k] with o <= p <= o+k-1.
        let cover1d = |p: usize, dim: usize| -> f32 {
            let lo = p.saturating_sub(k - 1);
            let hi = p.min(dim - k);
            (hi + 1 - lo) as f32
        };
        for bi in 0..b {
            for ci in 0..c {
                for y in 0..h {
                    for x in 0..w {
                        let cover = cover1d(y, h) * cover1d(x, w);
                        let idx = ((bi * c + ci) * h + y) * w + x;
                        assert!(
                            (back[idx] - cover * input[idx]).abs() < 1e-3,
                            "pixel ({y},{x}) cover {cover}: {} vs {}",
                            back[idx],
                            cover * input[idx]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn maxpool_and_backward() {
        let input = vec![
            1.0, 2.0, 5.0, 6.0, //
            3.0, 4.0, 7.0, 8.0, //
            0.0, -1.0, 1.0, 0.0, //
            -2.0, -3.0, 0.0, 0.5,
        ];
        let mut out = vec![0.0; 4];
        let mut arg = vec![0u8; 4];
        maxpool2(&mut out, &mut arg, &input, 1, 1, 4, 4);
        assert_eq!(out, vec![4.0, 8.0, 0.0, 1.0]);
        let mut dinput = vec![0.0; 16];
        maxpool2_back(&mut dinput, &[1.0, 2.0, 3.0, 4.0], &arg, 1, 1, 4, 4);
        assert_eq!(dinput[5], 1.0); // 4.0 was at (1,1)
        assert_eq!(dinput[7], 2.0); // 8.0 at (1,3)
        assert_eq!(dinput[8], 3.0); // 0.0 at (2,0)
        assert_eq!(dinput[10], 4.0); // 1.0 at (2,2)
        assert_eq!(dinput.iter().filter(|&&v| v != 0.0).count(), 4);
    }

    #[test]
    fn softmax_xent_gradient_matches_finite_difference() {
        let logits = vec![0.5, -0.2, 0.1, 2.0, 0.0, -1.0];
        let labels = vec![2.0, 0.0];
        let mut grad = vec![0.0; 6];
        let loss = softmax_xent(&mut grad, &logits, &labels, 2, 3);
        assert!(loss > 0.0);
        let eps = 1e-3f32;
        for i in 0..6 {
            let mut lp = logits.clone();
            lp[i] += eps;
            let mut lm = logits.clone();
            lm[i] -= eps;
            let mut scratch = vec![0.0; 6];
            let fp = softmax_xent(&mut scratch, &lp, &labels, 2, 3);
            let fm = softmax_xent(&mut scratch, &lm, &labels, 2, 3);
            let fd = ((fp - fm) / (2.0 * eps as f64)) as f32;
            assert!(
                (grad[i] - fd).abs() < 1e-3,
                "grad[{i}] = {} vs fd {fd}",
                grad[i]
            );
        }
    }

    #[test]
    fn nhwc_im2col_matches_nchw_for_single_channel() {
        // With ch=1, NHWC and NCHW layouts coincide.
        let (b, h, w, k) = (2usize, 6usize, 6usize, 3usize);
        let input: Vec<f32> = (0..b * h * w).map(|i| (i as f32).sin()).collect();
        let (oh, ow) = (h - k + 1, w - k + 1);
        let mut c1 = vec![0.0; b * oh * ow * k * k];
        let mut c2 = vec![0.0; b * oh * ow * k * k];
        im2col(&mut c1, &input, b, 1, h, w, k, k);
        im2col_nhwc(&mut c2, &input, b, h, w, 1, k, k);
        assert_eq!(c1, c2);
        // And col2im agrees too.
        let mut d1 = vec![0.0; input.len()];
        let mut d2 = vec![0.0; input.len()];
        col2im(&mut d1, &c1, b, 1, h, w, k, k);
        col2im_nhwc(&mut d2, &c2, b, h, w, 1, k, k);
        assert_eq!(d1, d2);
    }

    #[test]
    fn nhwc_pool_and_back() {
        // [1, 2, 2, 2]: two channels interleaved.
        let input = vec![
            1.0, 10.0, // (0,0) c0,c1
            2.0, 9.0, // (0,1)
            3.0, 12.0, // (1,0)
            0.0, 11.0, // (1,1)
        ];
        let mut out = vec![0.0; 2];
        let mut arg = vec![0u8; 2];
        maxpool2_nhwc(&mut out, &mut arg, &input, 1, 2, 2, 2);
        assert_eq!(out, vec![3.0, 12.0]);
        let mut dinput = vec![0.0; 8];
        maxpool2_back_nhwc(&mut dinput, &[5.0, 7.0], &arg, 1, 2, 2, 2);
        assert_eq!(dinput[4], 5.0); // c0 max at (1,0)
        assert_eq!(dinput[5], 7.0); // c1 max at (1,0)
        assert_eq!(dinput.iter().filter(|&&v| v != 0.0).count(), 2);
    }

    #[test]
    fn nhwc_col2im_coverage() {
        let (b, h, w, ch, k) = (1usize, 5usize, 4usize, 3usize, 2usize);
        let input: Vec<f32> = (0..b * h * w * ch).map(|i| i as f32 * 0.01 + 1.0).collect();
        let (oh, ow) = (h - k + 1, w - k + 1);
        let mut cols = vec![0.0; b * oh * ow * k * k * ch];
        im2col_nhwc(&mut cols, &input, b, h, w, ch, k, k);
        let mut back = vec![0.0; input.len()];
        col2im_nhwc(&mut back, &cols, b, h, w, ch, k, k);
        let cover1d = |p: usize, dim: usize| -> f32 {
            let lo = p.saturating_sub(k - 1);
            let hi = p.min(dim - k);
            (hi + 1 - lo) as f32
        };
        for y in 0..h {
            for x in 0..w {
                for c in 0..ch {
                    let idx = (y * w + x) * ch + c;
                    let want = cover1d(y, h) * cover1d(x, w) * input[idx];
                    assert!((back[idx] - want).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn relu_and_back() {
        let mut x = vec![-1.0, 0.0, 2.0];
        relu(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
        let mut g = vec![1.0, 1.0, 1.0];
        relu_back(&mut g, &x);
        assert_eq!(g, vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn accuracy_counts() {
        let logits = vec![0.9, 0.1, 0.2, 0.8];
        let labels = vec![0.0, 0.0];
        assert_eq!(argmax_accuracy(&logits, &labels, 2, 2), 0.5);
    }
}
