//! Model parameters, the trainer abstraction and backends.
//!
//! Protocols treat models as opaque [`ParamVec`]s; a [`Trainer`] performs
//! client-local SGD and global evaluation. Three backends exist:
//! pure-Rust [`native`] trainers (fast, used by benchmark grids), the
//! PJRT-backed [`crate::runtime::XlaTrainer`] (the paper's three-layer
//! stack), and [`NullTrainer`] (timing-only protocol studies).

pub mod native;
pub mod params;
pub mod tensor;

pub use params::{weighted_sum_into, weighted_sum_slices_into, ParamVec};

use crate::config::ExperimentConfig;
use crate::data::FedData;
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// Global-model quality on the held-out test set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    pub loss: f64,
    /// Accuracy per the paper's Table III formulation for the task.
    pub accuracy: f64,
}

/// Outcome of one client-local update (E epochs of minibatch SGD).
#[derive(Debug, Clone)]
pub struct LocalUpdate {
    pub params: ParamVec,
    /// Mean training loss over the final epoch.
    pub train_loss: f64,
}

/// A training backend.
///
/// `local_update` runs the paper's `client_update` (Alg. 2): E epochs of
/// minibatch SGD over client `k`'s shard starting from `base`. Batch
/// order is reshuffled per epoch from `rng`, which the caller derives
/// per (client, round) so runs are reproducible across backends.
pub trait Trainer {
    /// Flat parameter count.
    fn dim(&self) -> usize;

    /// Fresh parameter initialization.
    fn init_params(&self, rng: &mut Pcg64) -> ParamVec;

    /// E epochs of SGD on client `k`'s shard.
    fn local_update(&mut self, base: &ParamVec, client: usize, rng: &mut Pcg64) -> LocalUpdate;

    /// Loss + accuracy of `params` on the global test set.
    fn evaluate(&mut self, params: &ParamVec) -> EvalResult;

    /// Shared-state view for backends whose `local_update` can run
    /// from `&self`, letting the server fan client updates out across
    /// worker threads (`protocol::collect_updates`). All native
    /// backends implement it (the CNN via per-worker
    /// [`crate::util::scratch::WorkerScratch`] slots); `None` (the
    /// default) keeps the serial path for backends with exclusive
    /// device state, like the PJRT-backed XLA trainer.
    fn stateless(&self) -> Option<&dyn StatelessTrainer> {
        None
    }
}

/// A trainer whose client updates are functions of `(base, client,
/// rng)` — any scratch is per-worker, not `&mut self` — and therefore
/// safe to run from many threads at once. Implementations must return
/// bit-identical results to their `Trainer::local_update` for the same
/// inputs: the parallel fan-out path relies on that equivalence to stay
/// bit-for-bit equal to the serial server.
pub trait StatelessTrainer: Sync {
    fn local_update_shared(&self, base: &ParamVec, client: usize, rng: &mut Pcg64) -> LocalUpdate;
}

/// Timing-only backend: parameters never change. Used by the round-length
/// / T_dist / SR / EUR benches, whose metrics do not depend on numerics.
pub struct NullTrainer;

impl Trainer for NullTrainer {
    fn dim(&self) -> usize {
        1
    }

    fn init_params(&self, _rng: &mut Pcg64) -> ParamVec {
        ParamVec::zeros(1)
    }

    fn local_update(&mut self, base: &ParamVec, _client: usize, _rng: &mut Pcg64) -> LocalUpdate {
        LocalUpdate {
            params: base.clone(),
            train_loss: 0.0,
        }
    }

    fn evaluate(&mut self, _params: &ParamVec) -> EvalResult {
        EvalResult {
            loss: 0.0,
            accuracy: 0.0,
        }
    }

    fn stateless(&self) -> Option<&dyn StatelessTrainer> {
        Some(self)
    }
}

impl StatelessTrainer for NullTrainer {
    fn local_update_shared(
        &self,
        base: &ParamVec,
        _client: usize,
        _rng: &mut Pcg64,
    ) -> LocalUpdate {
        LocalUpdate {
            params: base.clone(),
            train_loss: 0.0,
        }
    }
}

/// Build the configured trainer backend.
///
/// `Backend::Xla` construction lives in [`crate::runtime`]; this factory
/// covers the two self-contained backends and is what the coordinator
/// uses unless the caller injects a trainer explicitly.
pub fn make_trainer(cfg: &ExperimentConfig, data: Arc<FedData>) -> Box<dyn Trainer> {
    use crate::config::{Backend, TaskKind};
    match cfg.backend {
        Backend::Null => Box::new(NullTrainer),
        Backend::Native | Backend::Xla => match cfg.task.kind {
            TaskKind::Regression => Box::new(native::LinRegTrainer::new(cfg, data)),
            TaskKind::Svm => Box::new(native::SvmTrainer::new(cfg, data)),
            TaskKind::Cnn => Box::new(native::CnnTrainer::new(cfg, data)),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_trainer_is_identity() {
        let mut t = NullTrainer;
        let mut rng = Pcg64::new(0);
        let p = t.init_params(&mut rng);
        let u = t.local_update(&p, 0, &mut rng);
        assert_eq!(u.params, p);
        assert_eq!(t.evaluate(&p).loss, 0.0);
    }
}
