//! Native trainers for the two linear tasks.
//!
//! * [`LinRegTrainer`] — Task 1: least-squares regression,
//!   loss = ½·mean((ŷ−y)²), accuracy = 1 − mean(|y−ŷ|/max(y,ŷ))
//!   (paper Table III, row 1).
//! * [`SvmTrainer`] — Task 3: linear SVM with hinge loss + L2,
//!   accuracy = mean(sign(y·ŷ) > 0) (paper Table III, row 3).
//!
//! Parameters are `[w(d), b]` flat.

use super::epoch_order;
use crate::config::ExperimentConfig;
use crate::data::FedData;
use crate::model::{EvalResult, LocalUpdate, ParamVec, StatelessTrainer, Trainer};
use crate::util::rng::{Distribution, Normal, Pcg64};
use std::sync::Arc;

/// L2 regularization for the SVM (standard soft-margin scaling).
const SVM_L2: f32 = 1e-4;

pub struct LinRegTrainer {
    data: Arc<FedData>,
    d: usize,
    epochs: usize,
    batch: usize,
    lr: f32,
}

impl LinRegTrainer {
    pub fn new(cfg: &ExperimentConfig, data: Arc<FedData>) -> Self {
        LinRegTrainer {
            d: data.train.d,
            data,
            epochs: cfg.train.epochs,
            batch: cfg.train.batch_size,
            lr: cfg.train.lr as f32,
        }
    }

    #[inline]
    fn predict(&self, p: &[f32], row: &[f32]) -> f32 {
        let mut acc = p[self.d];
        for (x, w) in row.iter().zip(&p[..self.d]) {
            acc += x * w;
        }
        acc
    }

    /// The actual SGD loop; `&self` only, so the parallel update path
    /// ([`StatelessTrainer`]) can share it across worker threads.
    fn update_impl(&self, base: &ParamVec, client: usize, rng: &mut Pcg64) -> LocalUpdate {
        let mut p = base.clone();
        let shard = &self.data.partitions[client].indices;
        let train = &self.data.train;
        let mut last_epoch_loss = 0.0f64;
        for _ in 0..self.epochs {
            let order = epoch_order(shard, rng);
            let mut epoch_loss = 0.0f64;
            let mut batches = 0usize;
            for chunk in order.chunks(self.batch) {
                let bsz = chunk.len() as f32;
                let mut gw = vec![0.0f32; self.d];
                let mut gb = 0.0f32;
                let mut loss = 0.0f64;
                for &i in chunk {
                    let row = train.row(i);
                    let err = self.predict(&p.0, row) - train.y[i];
                    loss += 0.5 * (err as f64) * (err as f64);
                    for (g, x) in gw.iter_mut().zip(row) {
                        *g += err * x;
                    }
                    gb += err;
                }
                let scale = self.lr / bsz;
                for (w, g) in p.0[..self.d].iter_mut().zip(&gw) {
                    *w -= scale * g;
                }
                p.0[self.d] -= scale * gb;
                epoch_loss += loss / bsz as f64;
                batches += 1;
            }
            last_epoch_loss = epoch_loss / batches.max(1) as f64;
        }
        LocalUpdate {
            params: p,
            train_loss: last_epoch_loss,
        }
    }
}

impl Trainer for LinRegTrainer {
    fn dim(&self) -> usize {
        self.d + 1
    }

    fn init_params(&self, rng: &mut Pcg64) -> ParamVec {
        // Small Gaussian init; the Python model matches this family.
        let dist = Normal::new(0.0, 0.01);
        let mut v: Vec<f32> = (0..self.d).map(|_| dist.sample(rng) as f32).collect();
        v.push(0.0); // bias starts at the origin
        ParamVec(v)
    }

    fn local_update(&mut self, base: &ParamVec, client: usize, rng: &mut Pcg64) -> LocalUpdate {
        self.update_impl(base, client, rng)
    }

    fn stateless(&self) -> Option<&dyn StatelessTrainer> {
        Some(self)
    }

    fn evaluate(&mut self, params: &ParamVec) -> EvalResult {
        let test = &self.data.test;
        let mut loss = 0.0f64;
        let mut acc = 0.0f64;
        for i in 0..test.n {
            let pred = self.predict(&params.0, test.row(i));
            let y = test.y[i];
            let err = (pred - y) as f64;
            loss += 0.5 * err * err;
            // Paper Table III: acc = 1 - mean(|y - ŷ| / max(y, ŷ)).
            let denom = (y.max(pred) as f64).max(1e-6);
            acc += 1.0 - ((y - pred).abs() as f64 / denom).min(1.0);
        }
        EvalResult {
            loss: loss / test.n as f64,
            accuracy: acc / test.n as f64,
        }
    }
}

impl StatelessTrainer for LinRegTrainer {
    fn local_update_shared(&self, base: &ParamVec, client: usize, rng: &mut Pcg64) -> LocalUpdate {
        self.update_impl(base, client, rng)
    }
}

pub struct SvmTrainer {
    data: Arc<FedData>,
    d: usize,
    epochs: usize,
    batch: usize,
    lr: f32,
}

impl SvmTrainer {
    pub fn new(cfg: &ExperimentConfig, data: Arc<FedData>) -> Self {
        SvmTrainer {
            d: data.train.d,
            data,
            epochs: cfg.train.epochs,
            batch: cfg.train.batch_size,
            lr: cfg.train.lr as f32,
        }
    }

    #[inline]
    fn score(&self, p: &[f32], row: &[f32]) -> f32 {
        let mut acc = p[self.d];
        for (x, w) in row.iter().zip(&p[..self.d]) {
            acc += x * w;
        }
        acc
    }

    /// `&self`-only SGD loop shared by the serial and parallel paths.
    fn update_impl(&self, base: &ParamVec, client: usize, rng: &mut Pcg64) -> LocalUpdate {
        let mut p = base.clone();
        let shard = &self.data.partitions[client].indices;
        let train = &self.data.train;
        let mut last_epoch_loss = 0.0f64;
        for _ in 0..self.epochs {
            let order = epoch_order(shard, rng);
            let mut epoch_loss = 0.0f64;
            let mut batches = 0usize;
            for chunk in order.chunks(self.batch) {
                let bsz = chunk.len() as f32;
                let mut gw = vec![0.0f32; self.d];
                let mut gb = 0.0f32;
                let mut loss = 0.0f64;
                for &i in chunk {
                    let row = train.row(i);
                    let y = train.y[i];
                    let margin = y * self.score(&p.0, row);
                    if margin < 1.0 {
                        loss += (1.0 - margin) as f64;
                        for (g, x) in gw.iter_mut().zip(row) {
                            *g -= y * x;
                        }
                        gb -= y;
                    }
                }
                // L2 term: grad += lambda * w (applied once per batch,
                // matching the Python model).
                let reg_norm: f32 = p.0[..self.d].iter().map(|w| w * w).sum();
                loss += 0.5 * SVM_L2 as f64 * reg_norm as f64;
                let scale = self.lr / bsz;
                for (w, g) in p.0[..self.d].iter_mut().zip(&gw) {
                    *w -= scale * g + self.lr * SVM_L2 * *w;
                }
                p.0[self.d] -= scale * gb;
                epoch_loss += loss / bsz as f64;
                batches += 1;
            }
            last_epoch_loss = epoch_loss / batches.max(1) as f64;
        }
        LocalUpdate {
            params: p,
            train_loss: last_epoch_loss,
        }
    }
}

impl Trainer for SvmTrainer {
    fn dim(&self) -> usize {
        self.d + 1
    }

    fn init_params(&self, rng: &mut Pcg64) -> ParamVec {
        let dist = Normal::new(0.0, 0.01);
        let mut v: Vec<f32> = (0..self.d).map(|_| dist.sample(rng) as f32).collect();
        v.push(0.0);
        ParamVec(v)
    }

    fn local_update(&mut self, base: &ParamVec, client: usize, rng: &mut Pcg64) -> LocalUpdate {
        self.update_impl(base, client, rng)
    }

    fn stateless(&self) -> Option<&dyn StatelessTrainer> {
        Some(self)
    }

    fn evaluate(&mut self, params: &ParamVec) -> EvalResult {
        let test = &self.data.test;
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for i in 0..test.n {
            let y = test.y[i];
            let s = self.score(&params.0, test.row(i));
            loss += (1.0 - y * s).max(0.0) as f64;
            if y * s > 0.0 {
                correct += 1;
            }
        }
        EvalResult {
            loss: loss / test.n as f64,
            accuracy: correct as f64 / test.n as f64,
        }
    }
}

impl StatelessTrainer for SvmTrainer {
    fn local_update_shared(&self, base: &ParamVec, client: usize, rng: &mut Pcg64) -> LocalUpdate {
        self.update_impl(base, client, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::data::{partition_gaussian, synth, FedData};

    fn make_data(cfg: &ExperimentConfig) -> Arc<FedData> {
        let (train, test) = synth::generate(cfg.task.kind, cfg.task.n, cfg.task.n_test, cfg.seed);
        let mut rng = Pcg64::with_stream(cfg.seed, 0x9a57);
        let partitions = partition_gaussian(train.n, cfg.env.m, cfg.env.partition_rel_std, &mut rng);
        Arc::new(FedData {
            train,
            test,
            partitions,
        })
    }

    #[test]
    fn linreg_loss_decreases_with_training() {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.train.lr = 1e-2;
        cfg.train.epochs = 10;
        let data = make_data(&cfg);
        let mut t = LinRegTrainer::new(&cfg, data);
        let mut rng = Pcg64::new(3);
        let p0 = t.init_params(&mut rng);
        let before = t.evaluate(&p0);
        let mut p = p0;
        for _ in 0..10 {
            p = t.local_update(&p, 0, &mut rng).params;
        }
        let after = t.evaluate(&p);
        assert!(
            after.loss < before.loss * 0.8,
            "loss {} -> {}",
            before.loss,
            after.loss
        );
        assert!(after.accuracy > before.accuracy);
    }

    #[test]
    fn svm_reaches_high_accuracy() {
        let mut cfg = presets::preset("task3-scaled").unwrap();
        cfg.task.n = 2000;
        cfg.task.n_test = 500;
        cfg.env.m = 4;
        cfg.train.epochs = 3;
        let data = make_data(&cfg);
        let mut t = SvmTrainer::new(&cfg, data);
        let mut rng = Pcg64::new(4);
        let mut p = t.init_params(&mut rng);
        for round in 0..10 {
            for k in 0..4 {
                // Sequential "centralized" training across shards.
                p = t.local_update(&p, k, &mut rng).params;
            }
            let _ = round;
        }
        let result = t.evaluate(&p);
        assert!(result.accuracy > 0.97, "svm accuracy {}", result.accuracy);
    }

    #[test]
    fn local_update_does_not_mutate_base() {
        let cfg = presets::preset("tiny").unwrap();
        let data = make_data(&cfg);
        let mut t = LinRegTrainer::new(&cfg, data);
        let mut rng = Pcg64::new(5);
        let base = t.init_params(&mut rng);
        let snapshot = base.clone();
        let _ = t.local_update(&base, 1, &mut rng);
        assert_eq!(base, snapshot);
    }

    #[test]
    fn update_is_deterministic_given_rng() {
        let cfg = presets::preset("tiny").unwrap();
        let data = make_data(&cfg);
        let mut t = LinRegTrainer::new(&cfg, data);
        let base = t.init_params(&mut Pcg64::new(6));
        let u1 = t.local_update(&base, 0, &mut Pcg64::new(7));
        let u2 = t.local_update(&base, 0, &mut Pcg64::new(7));
        assert_eq!(u1.params, u2.params);
        assert_eq!(u1.train_loss, u2.train_loss);
    }

    #[test]
    fn regression_accuracy_formula_bounds() {
        // acc must be <= 1 and equals 1 for perfect predictions.
        let cfg = presets::preset("tiny").unwrap();
        let data = make_data(&cfg);
        let mut t = LinRegTrainer::new(&cfg, data.clone());
        // Construct "perfect" params impossible; instead check bound.
        let p = ParamVec::zeros(t.dim());
        let r = t.evaluate(&p);
        assert!(r.accuracy <= 1.0 && r.accuracy >= 0.0);
    }
}
