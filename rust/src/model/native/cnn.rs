//! Native CNN trainer for Task 2 (the paper's MNIST model): two 5×5
//! convolutions (c1, c2 channels) each followed by ReLU and 2×2 max
//! pooling, a ReLU fully-connected layer and a softmax output (§IV-A).
//!
//! Convolutions are lowered to im2col + matmul in channels-last layout —
//! the same lowering the Pallas kernel path uses on the Python side (see
//! DESIGN.md §Hardware-Adaptation) — so the native and XLA backends are
//! operation-equivalent.
//!
//! The forward/backward [`Scratch`] buffers live in a
//! [`WorkerScratch`] pool rather than behind `&mut self`, so the
//! trainer implements [`StatelessTrainer`]: `protocol::collect_updates`
//! fans Task-2 client updates across the worker pool, each worker
//! training in its own lazily-built scratch. Every kernel zero-fills or
//! overwrites its output, so slot reuse across clients/workers cannot
//! leak state — updates stay bit-identical to the serial path.

use super::epoch_order;
use crate::config::{CnnArch, ExperimentConfig};
use crate::data::FedData;
use crate::model::tensor::*;
use crate::model::{EvalResult, LocalUpdate, ParamVec, StatelessTrainer, Trainer};
use crate::util::rng::{Distribution, Normal, Pcg64};
use crate::util::scratch::WorkerScratch;
use std::sync::Arc;

const SIDE: usize = 28;
const K: usize = 5;
const H1: usize = SIDE - K + 1; // 24
const P1: usize = H1 / 2; // 12
const H2: usize = P1 - K + 1; // 8
const P2: usize = H2 / 2; // 4
const CLASSES: usize = 10;

/// Flat parameter layout offsets for the CNN.
#[derive(Debug, Clone, Copy)]
struct Layout {
    w1: usize, // [c1, 25]
    b1: usize, // [c1]
    w2: usize, // [c2, 25*c1]
    b2: usize, // [c2]
    wh: usize, // [flat, hidden]
    bh: usize, // [hidden]
    wo: usize, // [hidden, 10]
    bo: usize, // [10]
    total: usize,
    c1: usize,
    c2: usize,
    hidden: usize,
    flat: usize,
}

impl Layout {
    fn new(arch: CnnArch) -> Layout {
        let (c1, c2, hidden) = (arch.c1, arch.c2, arch.hidden);
        let flat = P2 * P2 * c2;
        let w1 = 0;
        let b1 = w1 + c1 * K * K;
        let w2 = b1 + c1;
        let b2 = w2 + c2 * K * K * c1;
        let wh = b2 + c2;
        let bh = wh + flat * hidden;
        let wo = bh + hidden;
        let bo = wo + hidden * CLASSES;
        Layout {
            w1,
            b1,
            w2,
            b2,
            wh,
            bh,
            wo,
            bo,
            total: bo + CLASSES,
            c1,
            c2,
            hidden,
            flat,
        }
    }
}

/// Reusable forward/backward scratch sized for a max batch.
struct Scratch {
    cols1: Vec<f32>,  // [B*576, 25]
    a1: Vec<f32>,     // [B, 24, 24, c1]
    p1: Vec<f32>,     // [B, 12, 12, c1]
    arg1: Vec<u8>,
    cols2: Vec<f32>,  // [B*64, 25*c1]
    a2: Vec<f32>,     // [B, 8, 8, c2]
    p2: Vec<f32>,     // [B, 4, 4, c2] == flat [B, flat]
    arg2: Vec<u8>,
    ah: Vec<f32>,     // [B, hidden]
    zo: Vec<f32>,     // [B, 10]
    dzo: Vec<f32>,
    dah: Vec<f32>,
    dflat: Vec<f32>,
    da2: Vec<f32>,
    dcols2: Vec<f32>,
    dp1: Vec<f32>,
    da1: Vec<f32>,
    grad: Vec<f32>, // full parameter gradient
    xbatch: Vec<f32>,
    ybatch: Vec<f32>,
}

impl Scratch {
    fn new(l: &Layout, max_b: usize) -> Scratch {
        Scratch {
            cols1: vec![0.0; max_b * H1 * H1 * K * K],
            a1: vec![0.0; max_b * H1 * H1 * l.c1],
            p1: vec![0.0; max_b * P1 * P1 * l.c1],
            arg1: vec![0u8; max_b * P1 * P1 * l.c1],
            cols2: vec![0.0; max_b * H2 * H2 * K * K * l.c1],
            a2: vec![0.0; max_b * H2 * H2 * l.c2],
            p2: vec![0.0; max_b * l.flat],
            arg2: vec![0u8; max_b * l.flat],
            ah: vec![0.0; max_b * l.hidden],
            zo: vec![0.0; max_b * CLASSES],
            dzo: vec![0.0; max_b * CLASSES],
            dah: vec![0.0; max_b * l.hidden],
            dflat: vec![0.0; max_b * l.flat],
            da2: vec![0.0; max_b * H2 * H2 * l.c2],
            dcols2: vec![0.0; max_b * H2 * H2 * K * K * l.c1],
            dp1: vec![0.0; max_b * P1 * P1 * l.c1],
            da1: vec![0.0; max_b * H1 * H1 * l.c1],
            grad: vec![0.0; l.total],
            xbatch: vec![0.0; max_b * SIDE * SIDE],
            ybatch: vec![0.0; max_b],
        }
    }
}

pub struct CnnTrainer {
    data: Arc<FedData>,
    layout: Layout,
    epochs: usize,
    batch: usize,
    lr: f32,
    max_b: usize,
    /// Worker-indexed scratch slots, built lazily per claiming worker —
    /// what makes `local_update_shared` need only `&self`.
    scratch: WorkerScratch<Scratch>,
}

impl CnnTrainer {
    pub fn new(cfg: &ExperimentConfig, data: Arc<FedData>) -> Self {
        assert_eq!(data.train.d, SIDE * SIDE, "CNN expects 28x28 inputs");
        let layout = Layout::new(cfg.task.cnn);
        let max_b = cfg.train.batch_size.max(64);
        CnnTrainer {
            data,
            layout,
            epochs: cfg.train.epochs,
            batch: cfg.train.batch_size,
            lr: cfg.train.lr as f32,
            max_b,
            scratch: WorkerScratch::new(),
        }
    }

    /// Build one scratch instance sized for this trainer (a
    /// `WorkerScratch` slot initializer).
    fn fresh_scratch(&self) -> Scratch {
        Scratch::new(&self.layout, self.max_b)
    }

    /// Forward pass over `b` images already staged in `s.xbatch`.
    /// Fills activations; logits land in `s.zo`.
    fn forward(&self, s: &mut Scratch, params: &[f32], b: usize) {
        let l = self.layout;
        // conv1 (input is single-channel; NHWC == raw image layout).
        im2col_nhwc(
            &mut s.cols1[..b * H1 * H1 * K * K],
            &s.xbatch[..b * SIDE * SIDE],
            b,
            SIDE,
            SIDE,
            1,
            K,
            K,
        );
        let rows1 = b * H1 * H1;
        matmul_nt(
            &mut s.a1[..rows1 * l.c1],
            &s.cols1[..rows1 * K * K],
            &params[l.w1..l.w1 + l.c1 * K * K],
            rows1,
            K * K,
            l.c1,
            false,
        );
        add_bias(&mut s.a1[..rows1 * l.c1], &params[l.b1..l.b1 + l.c1]);
        relu(&mut s.a1[..rows1 * l.c1]);
        maxpool2_nhwc(
            &mut s.p1[..b * P1 * P1 * l.c1],
            &mut s.arg1[..b * P1 * P1 * l.c1],
            &s.a1[..rows1 * l.c1],
            b,
            H1,
            H1,
            l.c1,
        );
        // conv2.
        im2col_nhwc(
            &mut s.cols2[..b * H2 * H2 * K * K * l.c1],
            &s.p1[..b * P1 * P1 * l.c1],
            b,
            P1,
            P1,
            l.c1,
            K,
            K,
        );
        let rows2 = b * H2 * H2;
        matmul_nt(
            &mut s.a2[..rows2 * l.c2],
            &s.cols2[..rows2 * K * K * l.c1],
            &params[l.w2..l.w2 + l.c2 * K * K * l.c1],
            rows2,
            K * K * l.c1,
            l.c2,
            false,
        );
        add_bias(&mut s.a2[..rows2 * l.c2], &params[l.b2..l.b2 + l.c2]);
        relu(&mut s.a2[..rows2 * l.c2]);
        maxpool2_nhwc(
            &mut s.p2[..b * l.flat],
            &mut s.arg2[..b * l.flat],
            &s.a2[..rows2 * l.c2],
            b,
            H2,
            H2,
            l.c2,
        );
        // fc hidden.
        matmul(
            &mut s.ah[..b * l.hidden],
            &s.p2[..b * l.flat],
            &params[l.wh..l.wh + l.flat * l.hidden],
            b,
            l.flat,
            l.hidden,
            false,
        );
        add_bias(&mut s.ah[..b * l.hidden], &params[l.bh..l.bh + l.hidden]);
        relu(&mut s.ah[..b * l.hidden]);
        // output.
        matmul(
            &mut s.zo[..b * CLASSES],
            &s.ah[..b * l.hidden],
            &params[l.wo..l.wo + l.hidden * CLASSES],
            b,
            l.hidden,
            CLASSES,
            false,
        );
        add_bias(&mut s.zo[..b * CLASSES], &params[l.bo..l.bo + CLASSES]);
    }

    /// Backward pass; fills `s.grad`. Must follow `forward` with the
    /// same batch. Returns mean loss.
    fn backward(&self, s: &mut Scratch, params: &[f32], b: usize) -> f64 {
        let l = self.layout;
        let loss = softmax_xent(
            &mut s.dzo[..b * CLASSES],
            &s.zo[..b * CLASSES],
            &s.ybatch[..b],
            b,
            CLASSES,
        );
        s.grad.fill(0.0);
        // output layer.
        matmul_tn(
            &mut s.grad[l.wo..l.wo + l.hidden * CLASSES],
            &s.ah[..b * l.hidden],
            &s.dzo[..b * CLASSES],
            l.hidden,
            b,
            CLASSES,
            false,
        );
        col_sum(&mut s.grad[l.bo..l.bo + CLASSES], &s.dzo[..b * CLASSES], b, CLASSES);
        matmul_nt(
            &mut s.dah[..b * l.hidden],
            &s.dzo[..b * CLASSES],
            &params[l.wo..l.wo + l.hidden * CLASSES],
            b,
            CLASSES,
            l.hidden,
            false,
        );
        relu_back(&mut s.dah[..b * l.hidden], &s.ah[..b * l.hidden]);
        // hidden layer.
        matmul_tn(
            &mut s.grad[l.wh..l.wh + l.flat * l.hidden],
            &s.p2[..b * l.flat],
            &s.dah[..b * l.hidden],
            l.flat,
            b,
            l.hidden,
            false,
        );
        col_sum(&mut s.grad[l.bh..l.bh + l.hidden], &s.dah[..b * l.hidden], b, l.hidden);
        matmul_nt(
            &mut s.dflat[..b * l.flat],
            &s.dah[..b * l.hidden],
            &params[l.wh..l.wh + l.flat * l.hidden],
            b,
            l.hidden,
            l.flat,
            false,
        );
        // pool2 backward -> conv2 activations.
        maxpool2_back_nhwc(
            &mut s.da2[..b * H2 * H2 * l.c2],
            &s.dflat[..b * l.flat],
            &s.arg2[..b * l.flat],
            b,
            H2,
            H2,
            l.c2,
        );
        relu_back(&mut s.da2[..b * H2 * H2 * l.c2], &s.a2[..b * H2 * H2 * l.c2]);
        let rows2 = b * H2 * H2;
        matmul_tn(
            &mut s.grad[l.w2..l.w2 + l.c2 * K * K * l.c1],
            &s.da2[..rows2 * l.c2],
            &s.cols2[..rows2 * K * K * l.c1],
            l.c2,
            rows2,
            K * K * l.c1,
            false,
        );
        col_sum(&mut s.grad[l.b2..l.b2 + l.c2], &s.da2[..rows2 * l.c2], rows2, l.c2);
        matmul(
            &mut s.dcols2[..rows2 * K * K * l.c1],
            &s.da2[..rows2 * l.c2],
            &params[l.w2..l.w2 + l.c2 * K * K * l.c1],
            rows2,
            l.c2,
            K * K * l.c1,
            false,
        );
        col2im_nhwc(
            &mut s.dp1[..b * P1 * P1 * l.c1],
            &s.dcols2[..rows2 * K * K * l.c1],
            b,
            P1,
            P1,
            l.c1,
            K,
            K,
        );
        // pool1 backward -> conv1 activations.
        maxpool2_back_nhwc(
            &mut s.da1[..b * H1 * H1 * l.c1],
            &s.dp1[..b * P1 * P1 * l.c1],
            &s.arg1[..b * P1 * P1 * l.c1],
            b,
            H1,
            H1,
            l.c1,
        );
        relu_back(&mut s.da1[..b * H1 * H1 * l.c1], &s.a1[..b * H1 * H1 * l.c1]);
        let rows1 = b * H1 * H1;
        matmul_tn(
            &mut s.grad[l.w1..l.w1 + l.c1 * K * K],
            &s.da1[..rows1 * l.c1],
            &s.cols1[..rows1 * K * K],
            l.c1,
            rows1,
            K * K,
            false,
        );
        col_sum(&mut s.grad[l.b1..l.b1 + l.c1], &s.da1[..rows1 * l.c1], rows1, l.c1);
        loss
    }

    fn stage_batch(&self, s: &mut Scratch, idx: &[usize]) {
        let train = &self.data.train;
        for (slot, &i) in idx.iter().enumerate() {
            s.xbatch[slot * SIDE * SIDE..(slot + 1) * SIDE * SIDE]
                .copy_from_slice(train.row(i));
            s.ybatch[slot] = train.y[i];
        }
    }

    /// Alg. 2's `client_update` against a caller-provided scratch —
    /// the shared body under both `Trainer::local_update` and
    /// `StatelessTrainer::local_update_shared`.
    fn run_local_update(
        &self,
        s: &mut Scratch,
        base: &ParamVec,
        client: usize,
        rng: &mut Pcg64,
    ) -> LocalUpdate {
        assert_eq!(base.dim(), self.layout.total, "param dim mismatch");
        let mut p = base.clone();
        let shard = self.data.partitions[client].indices.clone();
        let mut last_epoch_loss = 0.0f64;
        for _ in 0..self.epochs {
            let order = epoch_order(&shard, rng);
            let mut epoch_loss = 0.0f64;
            let mut batches = 0usize;
            for chunk in order.chunks(self.batch) {
                let b = chunk.len();
                self.stage_batch(s, chunk);
                self.forward(s, &p.0, b);
                let loss = self.backward(s, &p.0, b);
                let lr = self.lr;
                for (w, g) in p.0.iter_mut().zip(&s.grad) {
                    *w -= lr * g;
                }
                epoch_loss += loss;
                batches += 1;
            }
            last_epoch_loss = epoch_loss / batches.max(1) as f64;
        }
        LocalUpdate {
            params: p,
            train_loss: last_epoch_loss,
        }
    }
}

/// out_rows += bias broadcast over rows of a [rows, c] matrix.
fn add_bias(x: &mut [f32], bias: &[f32]) {
    let c = bias.len();
    for row in x.chunks_mut(c) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// out[j] = Σ_rows m[row, j] over a [rows, c] matrix.
fn col_sum(out: &mut [f32], m: &[f32], rows: usize, c: usize) {
    out.fill(0.0);
    for r in 0..rows {
        for (o, &v) in out.iter_mut().zip(&m[r * c..(r + 1) * c]) {
            *o += v;
        }
    }
}

impl Trainer for CnnTrainer {
    fn dim(&self) -> usize {
        self.layout.total
    }

    fn init_params(&self, rng: &mut Pcg64) -> ParamVec {
        let l = self.layout;
        let mut v = vec![0.0f32; l.total];
        let mut fill = |range: std::ops::Range<usize>, fan_in: usize, rng: &mut Pcg64| {
            let std = (2.0 / fan_in as f64).sqrt();
            let dist = Normal::new(0.0, std);
            for x in &mut v[range] {
                *x = dist.sample(rng) as f32;
            }
        };
        fill(l.w1..l.w1 + l.c1 * K * K, K * K, rng);
        fill(l.w2..l.w2 + l.c2 * K * K * l.c1, K * K * l.c1, rng);
        fill(l.wh..l.wh + l.flat * l.hidden, l.flat, rng);
        fill(l.wo..l.wo + l.hidden * CLASSES, l.hidden, rng);
        // Biases stay zero.
        ParamVec(v)
    }

    fn local_update(&mut self, base: &ParamVec, client: usize, rng: &mut Pcg64) -> LocalUpdate {
        StatelessTrainer::local_update_shared(self, base, client, rng)
    }

    fn evaluate(&mut self, params: &ParamVec) -> EvalResult {
        self.scratch.with(
            || self.fresh_scratch(),
            |s| {
                let test = &self.data.test;
                let max_b = s.ybatch.len();
                let mut loss = 0.0f64;
                let mut acc_weighted = 0.0f64;
                let idx: Vec<usize> = (0..test.n).collect();
                for chunk in idx.chunks(max_b) {
                    let b = chunk.len();
                    for (slot, &i) in chunk.iter().enumerate() {
                        s.xbatch[slot * SIDE * SIDE..(slot + 1) * SIDE * SIDE]
                            .copy_from_slice(test.row(i));
                        s.ybatch[slot] = test.y[i];
                    }
                    self.forward(s, &params.0, b);
                    let batch_loss = softmax_xent(
                        &mut s.dzo[..b * CLASSES],
                        &s.zo[..b * CLASSES],
                        &s.ybatch[..b],
                        b,
                        CLASSES,
                    );
                    let batch_acc =
                        argmax_accuracy(&s.zo[..b * CLASSES], &s.ybatch[..b], b, CLASSES);
                    loss += batch_loss * b as f64;
                    acc_weighted += batch_acc * b as f64;
                }
                EvalResult {
                    loss: loss / test.n as f64,
                    accuracy: acc_weighted / test.n as f64,
                }
            },
        )
    }

    fn stateless(&self) -> Option<&dyn StatelessTrainer> {
        Some(self)
    }
}

impl StatelessTrainer for CnnTrainer {
    fn local_update_shared(&self, base: &ParamVec, client: usize, rng: &mut Pcg64) -> LocalUpdate {
        self.scratch.with(
            || self.fresh_scratch(),
            |s| self.run_local_update(s, base, client, rng),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::data::{partition_gaussian, synth, FedData};

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = presets::preset("task2-scaled").unwrap();
        cfg.task.n = 300;
        cfg.task.n_test = 100;
        cfg.env.m = 3;
        cfg.task.cnn = CnnArch {
            c1: 4,
            c2: 8,
            hidden: 32,
        };
        cfg.train.batch_size = 16;
        cfg.train.epochs = 1;
        cfg.train.lr = 0.05;
        cfg
    }

    fn make_data(cfg: &ExperimentConfig) -> Arc<FedData> {
        let (train, test) = synth::generate(cfg.task.kind, cfg.task.n, cfg.task.n_test, cfg.seed);
        let mut rng = Pcg64::with_stream(cfg.seed, 0x9a57);
        let partitions = partition_gaussian(train.n, cfg.env.m, cfg.env.partition_rel_std, &mut rng);
        Arc::new(FedData {
            train,
            test,
            partitions,
        })
    }

    #[test]
    fn layout_total_matches_paper_architecture() {
        let l = Layout::new(CnnArch::paper());
        // conv1 20*25+20, conv2 50*500+50, fc 800*500+500, out 500*10+10.
        assert_eq!(l.total, 520 + 25_050 + 400_500 + 5_010);
    }

    #[test]
    fn cnn_gradient_matches_finite_difference() {
        let cfg = small_cfg();
        let data = make_data(&cfg);
        let t = CnnTrainer::new(&cfg, data);
        let mut rng = Pcg64::new(11);
        let p = t.init_params(&mut rng);
        let mut s = t.fresh_scratch();
        // Stage a small fixed batch.
        let idx: Vec<usize> = (0..6).collect();
        t.stage_batch(&mut s, &idx);
        t.forward(&mut s, &p.0, 6);
        let base_loss = t.backward(&mut s, &p.0, 6);
        assert!(base_loss > 0.0);
        let grad = s.grad.clone();
        // Spot-check coordinates from every parameter block.
        let l = t.layout;
        let coords = [
            l.w1 + 3,
            l.b1,
            l.w2 + 17,
            l.b2 + 1,
            l.wh + 101,
            l.bh + 5,
            l.wo + 23,
            l.bo + 7,
        ];
        let eps = 2e-3f32;
        for &ci in &coords {
            let mut pp = p.clone();
            pp.0[ci] += eps;
            t.stage_batch(&mut s, &idx);
            t.forward(&mut s, &pp.0, 6);
            let lp = t.backward(&mut s, &pp.0, 6);
            let mut pm = p.clone();
            pm.0[ci] -= eps;
            t.stage_batch(&mut s, &idx);
            t.forward(&mut s, &pm.0, 6);
            let lm = t.backward(&mut s, &pm.0, 6);
            let fd = (lp - lm) / (2.0 * eps as f64);
            // f32 activations + ReLU/maxpool kinks make central
            // differences noisy; 6% relative agreement is the realistic
            // bound here (the exact check lives in the Python tests where
            // the oracle runs in f64).
            assert!(
                (grad[ci] as f64 - fd).abs() < 6e-2 * (1.0 + fd.abs()),
                "coord {ci}: analytic {} vs fd {fd}",
                grad[ci]
            );
        }
        // Functional check: one gradient step must reduce the loss.
        let mut stepped = p.clone();
        for (w, g) in stepped.0.iter_mut().zip(&grad) {
            *w -= 0.02 * g;
        }
        t.stage_batch(&mut s, &idx);
        t.forward(&mut s, &stepped.0, 6);
        let new_loss = t.backward(&mut s, &stepped.0, 6);
        assert!(
            new_loss < base_loss,
            "gradient step increased loss: {base_loss} -> {new_loss}"
        );
    }

    #[test]
    fn cnn_learns_synthetic_digits() {
        let cfg = small_cfg();
        let data = make_data(&cfg);
        let mut t = CnnTrainer::new(&cfg, data);
        let mut rng = Pcg64::new(13);
        let mut p = t.init_params(&mut rng);
        let before = t.evaluate(&p);
        for _ in 0..6 {
            for k in 0..3 {
                p = t.local_update(&p, k, &mut rng).params;
            }
        }
        let after = t.evaluate(&p);
        assert!(
            after.accuracy > 0.6 && after.accuracy > before.accuracy,
            "accuracy {} -> {}",
            before.accuracy,
            after.accuracy
        );
        assert!(after.loss < before.loss);
    }

    #[test]
    fn local_update_deterministic_and_base_immutable() {
        let cfg = small_cfg();
        let data = make_data(&cfg);
        let mut t = CnnTrainer::new(&cfg, data);
        let base = t.init_params(&mut Pcg64::new(17));
        let snap = base.clone();
        let u1 = t.local_update(&base, 0, &mut Pcg64::new(19));
        let u2 = t.local_update(&base, 0, &mut Pcg64::new(19));
        assert_eq!(base, snap);
        assert_eq!(u1.params, u2.params);
        // The shared (pool fan-out) entry point is the same computation.
        let u3 = StatelessTrainer::local_update_shared(&t, &base, 0, &mut Pcg64::new(19));
        assert_eq!(u1.params, u3.params);
        assert_eq!(u1.train_loss.to_bits(), u3.train_loss.to_bits());
    }
}
