//! Pure-Rust trainer backends for the three tasks.
//!
//! These mirror the JAX models in `python/compile/model.py` operation-for-
//! operation (same losses, same update rule, same initialization family),
//! so the integration tests can check numeric agreement between the
//! native and XLA paths on identical batches.

mod cnn;
mod linear;

pub use cnn::CnnTrainer;
pub use linear::{LinRegTrainer, SvmTrainer};

use crate::util::rng::Pcg64;

/// Shuffle a client's sample indices for one epoch and iterate batches.
/// Returns the shuffled copy; callers slice it in `batch_size` chunks.
pub(crate) fn epoch_order(indices: &[usize], rng: &mut Pcg64) -> Vec<usize> {
    let mut order = indices.to_vec();
    rng.shuffle(&mut order);
    order
}
