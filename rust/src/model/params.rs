//! Flat model-parameter vectors and the linear algebra the aggregation
//! step needs (weighted averaging, axpy — the L3 hot path).

/// A model's parameters as one flat f32 vector.
///
/// All protocols treat models as opaque vectors; only the trainer knows
/// the segment layout. Keeping them flat makes the cache/bypass
/// structures and Eq. (7)'s weighted average simple and fast.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamVec(pub Vec<f32>);

impl ParamVec {
    pub fn zeros(dim: usize) -> ParamVec {
        ParamVec(vec![0.0; dim])
    }

    pub fn dim(&self) -> usize {
        self.0.len()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }

    /// self += alpha * other (fused multiply-add over the flat vector).
    pub fn axpy(&mut self, alpha: f32, other: &ParamVec) {
        debug_assert_eq!(self.dim(), other.dim());
        for (a, b) in self.0.iter_mut().zip(&other.0) {
            *a += alpha * b;
        }
    }

    /// self *= alpha.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.0.iter_mut() {
            *a *= alpha;
        }
    }

    /// Reset to zeros without reallocating.
    pub fn clear(&mut self) {
        self.0.fill(0.0);
    }

    /// Copy `other` into self without reallocating.
    pub fn copy_from(&mut self, other: &ParamVec) {
        debug_assert_eq!(self.dim(), other.dim());
        self.0.copy_from_slice(&other.0);
    }

    /// Euclidean norm (useful in tests and divergence diagnostics).
    pub fn norm(&self) -> f64 {
        self.0.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// L2 distance to another vector.
    pub fn dist(&self, other: &ParamVec) -> f64 {
        debug_assert_eq!(self.dim(), other.dim());
        self.0
            .iter()
            .zip(&other.0)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }
}

/// Weighted average of entries: out = Σ w_k * entries_k, writing into a
/// reusable output buffer (Eq. 7's aggregation — the per-round hot path;
/// avoids allocating a fresh vector every round).
pub fn weighted_sum_into(out: &mut ParamVec, entries: &[(f32, &ParamVec)]) {
    out.clear();
    for &(w, p) in entries {
        out.axpy(w, p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn axpy_scale_basics() {
        let mut a = ParamVec(vec![1.0, 2.0]);
        let b = ParamVec(vec![10.0, 20.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.0, vec![6.0, 12.0]);
        a.scale(2.0);
        assert_eq!(a.0, vec![12.0, 24.0]);
        a.clear();
        assert_eq!(a.0, vec![0.0, 0.0]);
    }

    #[test]
    fn weighted_sum_is_convex_combination() {
        property("weighted sum within min/max", 100, |g| {
            let dim = g.usize_range(1, 32);
            let k = g.usize_range(1, 8);
            let entries: Vec<ParamVec> = (0..k)
                .map(|_| ParamVec(g.vec_f32(dim, -5.0, 5.0)))
                .collect();
            // Convex weights.
            let raw: Vec<f64> = (0..k).map(|_| g.f64_range(0.01, 1.0)).collect();
            let total: f64 = raw.iter().sum();
            let weights: Vec<f32> = raw.iter().map(|&w| (w / total) as f32).collect();
            let pairs: Vec<(f32, &ParamVec)> =
                weights.iter().copied().zip(entries.iter()).collect();
            let mut out = ParamVec::zeros(dim);
            weighted_sum_into(&mut out, &pairs);
            for i in 0..dim {
                let lo = entries.iter().map(|e| e.0[i]).fold(f32::MAX, f32::min);
                let hi = entries.iter().map(|e| e.0[i]).fold(f32::MIN, f32::max);
                assert!(
                    out.0[i] >= lo - 1e-4 && out.0[i] <= hi + 1e-4,
                    "coordinate {i} out of hull: {} not in [{lo}, {hi}]",
                    out.0[i]
                );
            }
        });
    }

    #[test]
    fn norms() {
        let a = ParamVec(vec![3.0, 4.0]);
        assert!((a.norm() - 5.0).abs() < 1e-9);
        let b = ParamVec(vec![0.0, 0.0]);
        assert!((a.dist(&b) - 5.0).abs() < 1e-9);
    }
}
