//! Flat model-parameter vectors and the linear algebra the aggregation
//! step needs (weighted averaging, axpy — the L3 hot path).
//!
//! # Kernel notes
//!
//! The element-wise kernels (`axpy`, `scale`, `copy_from` and the
//! weighted sums) are manually unrolled 4-wide and, above a size
//! threshold, chunked across the scoped pool (`util::parallel`). Both
//! transformations preserve bit-for-bit results: unrolling element-wise
//! ops does not reorder any per-element arithmetic, and parallel chunks
//! partition the index space so each element's update sequence is
//! unchanged. In particular [`weighted_sum_into`] folds the entries in
//! a *fixed order per element* (entry 0, 1, 2, …), so Eq. 7 aggregation
//! is identical across thread counts — asserted by
//! `tests/determinism.rs`.
//!
//! The reductions (`norm`, `dist`) use four independent accumulators to
//! unlock autovectorization; that *does* reorder the f64 sum, so they
//! are only tolerance-comparable to the naive loop (property-tested at
//! 1e-5 relative error). Nothing protocol-visible depends on their bit
//! patterns.

use crate::util::parallel;

/// Minimum elements per worker before an element-wise kernel forks.
/// One fork costs a few spawns (~tens of µs), so it only pays above
/// ~10^5 elements — the 431k-dim CNN regime, not the unit-test vectors.
const ELEMWISE_GRAIN: usize = 65_536;

/// Minimum *output* elements per worker for the weighted sums. The whole
/// m-entry reduction runs inside one fork, so the spawn amortizes over
/// `m × grain` flops and a finer grain is worthwhile.
const SUM_GRAIN: usize = 4_096;

/// out[i] += alpha * src[i], 4-wide unrolled (per-element ops only —
/// bit-identical to the naive loop).
#[inline]
fn axpy_slice(out: &mut [f32], alpha: f32, src: &[f32]) {
    debug_assert_eq!(out.len(), src.len());
    let mut oc = out.chunks_exact_mut(4);
    let mut sc = src.chunks_exact(4);
    for (o, s) in oc.by_ref().zip(sc.by_ref()) {
        o[0] += alpha * s[0];
        o[1] += alpha * s[1];
        o[2] += alpha * s[2];
        o[3] += alpha * s[3];
    }
    for (o, s) in oc.into_remainder().iter_mut().zip(sc.remainder()) {
        *o += alpha * s;
    }
}

/// out[i] *= alpha, 4-wide unrolled.
#[inline]
fn scale_slice(out: &mut [f32], alpha: f32) {
    let mut oc = out.chunks_exact_mut(4);
    for o in oc.by_ref() {
        o[0] *= alpha;
        o[1] *= alpha;
        o[2] *= alpha;
        o[3] *= alpha;
    }
    for o in oc.into_remainder() {
        *o *= alpha;
    }
}

/// A model's parameters as one flat f32 vector.
///
/// All protocols treat models as opaque vectors; only the trainer knows
/// the segment layout. Keeping them flat makes the cache/bypass
/// structures and Eq. (7)'s weighted average simple and fast.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamVec(pub Vec<f32>);

impl ParamVec {
    pub fn zeros(dim: usize) -> ParamVec {
        ParamVec(vec![0.0; dim])
    }

    pub fn dim(&self) -> usize {
        self.0.len()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }

    /// self += alpha * other (fused multiply-add over the flat vector).
    pub fn axpy(&mut self, alpha: f32, other: &ParamVec) {
        debug_assert_eq!(self.dim(), other.dim());
        let src = &other.0;
        parallel::for_each_chunk(&mut self.0, ELEMWISE_GRAIN, |off, chunk| {
            axpy_slice(chunk, alpha, &src[off..off + chunk.len()]);
        });
    }

    /// self *= alpha.
    pub fn scale(&mut self, alpha: f32) {
        parallel::for_each_chunk(&mut self.0, ELEMWISE_GRAIN, |_, chunk| {
            scale_slice(chunk, alpha);
        });
    }

    /// Reset to zeros without reallocating.
    pub fn clear(&mut self) {
        self.0.fill(0.0);
    }

    /// Copy `other` into self without reallocating.
    pub fn copy_from(&mut self, other: &ParamVec) {
        debug_assert_eq!(self.dim(), other.dim());
        let src = &other.0;
        parallel::for_each_chunk(&mut self.0, ELEMWISE_GRAIN, |off, chunk| {
            chunk.copy_from_slice(&src[off..off + chunk.len()]);
        });
    }

    /// Euclidean norm (useful in tests and divergence diagnostics).
    pub fn norm(&self) -> f64 {
        let mut acc = [0.0f64; 4];
        let mut c = self.0.chunks_exact(4);
        for q in c.by_ref() {
            acc[0] += (q[0] as f64) * (q[0] as f64);
            acc[1] += (q[1] as f64) * (q[1] as f64);
            acc[2] += (q[2] as f64) * (q[2] as f64);
            acc[3] += (q[3] as f64) * (q[3] as f64);
        }
        let mut total = (acc[0] + acc[1]) + (acc[2] + acc[3]);
        for &x in c.remainder() {
            total += (x as f64) * (x as f64);
        }
        total.sqrt()
    }

    /// L2 distance to another vector.
    pub fn dist(&self, other: &ParamVec) -> f64 {
        debug_assert_eq!(self.dim(), other.dim());
        let mut acc = [0.0f64; 4];
        let mut a = self.0.chunks_exact(4);
        let mut b = other.0.chunks_exact(4);
        for (qa, qb) in a.by_ref().zip(b.by_ref()) {
            let d0 = (qa[0] - qb[0]) as f64;
            let d1 = (qa[1] - qb[1]) as f64;
            let d2 = (qa[2] - qb[2]) as f64;
            let d3 = (qa[3] - qb[3]) as f64;
            acc[0] += d0 * d0;
            acc[1] += d1 * d1;
            acc[2] += d2 * d2;
            acc[3] += d3 * d3;
        }
        let mut total = (acc[0] + acc[1]) + (acc[2] + acc[3]);
        for (&x, &y) in a.remainder().iter().zip(b.remainder()) {
            let d = (x - y) as f64;
            total += d * d;
        }
        total.sqrt()
    }
}

/// Weighted average of entries: out = Σ w_k * entries_k, writing into a
/// reusable output buffer (Eq. 7's aggregation — the per-round hot path;
/// avoids allocating a fresh vector every round).
///
/// Chunked over the output dimension: each worker owns a contiguous
/// coordinate range and folds *all* entries over it in index order, so
/// the result is bit-identical to the serial clear-then-axpy loop at any
/// thread count (and far more cache-friendly — each output chunk stays
/// resident while the m entries stream through).
pub fn weighted_sum_into(out: &mut ParamVec, entries: &[(f32, &ParamVec)]) {
    for &(_, p) in entries {
        debug_assert_eq!(out.dim(), p.dim());
    }
    parallel::for_each_chunk(&mut out.0, SUM_GRAIN, |off, chunk| {
        chunk.fill(0.0);
        for &(w, p) in entries {
            axpy_slice(chunk, w, &p.0[off..off + chunk.len()]);
        }
    });
}

/// [`weighted_sum_into`] over parallel weight/entry slices — the
/// zero-allocation form SAFA's Eq. 7 uses every round (no per-round
/// `(f32, &ParamVec)` pair vector to build).
pub fn weighted_sum_slices_into(out: &mut ParamVec, weights: &[f32], entries: &[ParamVec]) {
    assert_eq!(
        weights.len(),
        entries.len(),
        "weighted_sum_slices_into: weight/entry count mismatch"
    );
    for p in entries {
        debug_assert_eq!(out.dim(), p.dim());
    }
    parallel::for_each_chunk(&mut out.0, SUM_GRAIN, |off, chunk| {
        chunk.fill(0.0);
        for (&w, p) in weights.iter().zip(entries) {
            axpy_slice(chunk, w, &p.0[off..off + chunk.len()]);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parallel::with_thread_count;
    use crate::util::proptest::property;

    #[test]
    fn axpy_scale_basics() {
        let mut a = ParamVec(vec![1.0, 2.0]);
        let b = ParamVec(vec![10.0, 20.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.0, vec![6.0, 12.0]);
        a.scale(2.0);
        assert_eq!(a.0, vec![12.0, 24.0]);
        a.clear();
        assert_eq!(a.0, vec![0.0, 0.0]);
    }

    #[test]
    fn weighted_sum_is_convex_combination() {
        property("weighted sum within min/max", 100, |g| {
            let dim = g.usize_range(1, 32);
            let k = g.usize_range(1, 8);
            let entries: Vec<ParamVec> = (0..k)
                .map(|_| ParamVec(g.vec_f32(dim, -5.0, 5.0)))
                .collect();
            // Convex weights.
            let raw: Vec<f64> = (0..k).map(|_| g.f64_range(0.01, 1.0)).collect();
            let total: f64 = raw.iter().sum();
            let weights: Vec<f32> = raw.iter().map(|&w| (w / total) as f32).collect();
            let pairs: Vec<(f32, &ParamVec)> =
                weights.iter().copied().zip(entries.iter()).collect();
            let mut out = ParamVec::zeros(dim);
            weighted_sum_into(&mut out, &pairs);
            for i in 0..dim {
                let lo = entries.iter().map(|e| e.0[i]).fold(f32::MAX, f32::min);
                let hi = entries.iter().map(|e| e.0[i]).fold(f32::MIN, f32::max);
                assert!(
                    out.0[i] >= lo - 1e-4 && out.0[i] <= hi + 1e-4,
                    "coordinate {i} out of hull: {} not in [{lo}, {hi}]",
                    out.0[i]
                );
            }
        });
    }

    #[test]
    fn slices_form_matches_pairs_form() {
        property("weighted_sum_slices == weighted_sum pairs", 50, |g| {
            let dim = g.usize_range(1, 67);
            let k = g.usize_range(1, 9);
            let entries: Vec<ParamVec> = (0..k)
                .map(|_| ParamVec(g.vec_f32(dim, -3.0, 3.0)))
                .collect();
            let weights: Vec<f32> = (0..k).map(|_| g.f64_range(-1.0, 1.0) as f32).collect();
            let pairs: Vec<(f32, &ParamVec)> =
                weights.iter().copied().zip(entries.iter()).collect();
            let mut a = ParamVec::zeros(dim);
            let mut b = ParamVec::zeros(dim);
            weighted_sum_into(&mut a, &pairs);
            weighted_sum_slices_into(&mut b, &weights, &entries);
            assert_eq!(a, b);
        });
    }

    /// Satellite: the unrolled/chunked kernels agree with byte-naive
    /// reference loops — exactly for the element-wise ops, within 1e-5
    /// relative error for the reordered reductions.
    #[test]
    fn unrolled_kernels_match_naive_reference() {
        property("kernels vs naive loops", 60, |g| {
            let dim = g.usize_range(1, 130); // covers remainders 0..3
            let alpha = g.f64_range(-2.0, 2.0) as f32;
            let xs = g.vec_f32(dim, -10.0, 10.0);
            let ys = g.vec_f32(dim, -10.0, 10.0);

            // axpy: exact.
            let mut fast = ParamVec(xs.clone());
            fast.axpy(alpha, &ParamVec(ys.clone()));
            let naive: Vec<f32> = xs.iter().zip(&ys).map(|(&a, &b)| a + alpha * b).collect();
            assert_eq!(fast.0, naive, "axpy diverged");

            // scale: exact.
            let mut fast = ParamVec(xs.clone());
            fast.scale(alpha);
            let naive: Vec<f32> = xs.iter().map(|&a| a * alpha).collect();
            assert_eq!(fast.0, naive, "scale diverged");

            // dist/norm: 4-accumulator reduction, tolerance-compared.
            let a = ParamVec(xs.clone());
            let b = ParamVec(ys.clone());
            let naive_dist = xs
                .iter()
                .zip(&ys)
                .map(|(&x, &y)| {
                    let d = (x - y) as f64;
                    d * d
                })
                .sum::<f64>()
                .sqrt();
            let rel = (a.dist(&b) - naive_dist).abs() / naive_dist.max(1e-12);
            assert!(rel < 1e-5, "dist rel err {rel}");
            let naive_norm = xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            let rel = (a.norm() - naive_norm).abs() / naive_norm.max(1e-12);
            assert!(rel < 1e-5, "norm rel err {rel}");
        });
    }

    /// Element-wise kernels are bit-identical across fork widths (the
    /// chunking never reorders per-element arithmetic).
    #[test]
    fn elementwise_kernels_are_width_invariant() {
        // Above ELEMWISE_GRAIN so widths >= 2 genuinely fork (the width
        // is work-capped at dim / ELEMWISE_GRAIN = 3 workers here).
        let dim = 3 * ELEMWISE_GRAIN + 17;
        let xs: Vec<f32> = (0..dim).map(|i| (i as f32).sin()).collect();
        let ys: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.7).cos()).collect();
        let reference = with_thread_count(1, || {
            let mut v = ParamVec(xs.clone());
            v.axpy(0.3, &ParamVec(ys.clone()));
            v.scale(1.1);
            v
        });
        for width in [2, 3, 8] {
            let got = with_thread_count(width, || {
                let mut v = ParamVec(xs.clone());
                v.axpy(0.3, &ParamVec(ys.clone()));
                v.scale(1.1);
                v
            });
            assert_eq!(got, reference, "width {width} diverged");
        }
    }

    #[test]
    fn norms() {
        let a = ParamVec(vec![3.0, 4.0]);
        assert!((a.norm() - 5.0).abs() < 1e-9);
        let b = ParamVec(vec![0.0, 0.0]);
        assert!((a.dist(&b) - 5.0).abs() < 1e-9);
    }
}
