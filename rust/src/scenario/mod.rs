//! Scenario DSL: continuous wall-clock availability scripts.
//!
//! A [`ScenarioSpec`] describes client availability on one absolute
//! sim-time axis spanning the whole run, instead of the legacy
//! round-indexed `[0, T_lim]` windows. It is the load-time half of the
//! scenario engine: the TOML `env.scenario*` keys, the `--scenario*`
//! CLI flags and the fluent [`Scenario`] builder all compile to a spec,
//! and the fleet engine turns an enabled spec into a
//! `ScenarioTimeline` (see `engine::availability`) that walks per-client
//! piecewise on/off transitions across round boundaries.
//!
//! Three processes:
//!
//! * [`ScenarioProcess::Continuous`] — the tentpole: exponential on/off
//!   dwells on the continuous clock, optionally modulated by a diurnal
//!   sine wave, plus scripted events (flash crowds that mass-join/leave
//!   the fleet, correlated regional outages). Multiple transitions per
//!   round are allowed, and a dwell spans round boundaries.
//! * [`ScenarioProcess::Bernoulli`] / [`ScenarioProcess::Markov`] —
//!   per-round single-window reductions: the spec compiles back to the
//!   legacy availability models, bit-for-bit identical to configuring
//!   `env.churn` directly. They pin the RNG-stream contract: reductions
//!   stay on the per-(round, client) streams while only the continuous
//!   process uses the per-(client, transition-index) streams.
//!
//! Everything is default-off: a [`ScenarioSpec::default`] never touches
//! the engine, so scenario-off runs are bit-identical to builds that
//! predate this module.

use crate::error::{Result, SafaError};

/// When a scripted event fires: an absolute sim-time, or the instant a
/// 1-based round opens (resolved as `(round - 1) * T_lim` once the
/// timeline knows the round horizon).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScenarioAt {
    Time(f64),
    Round(usize),
}

impl ScenarioAt {
    /// Resolve to absolute seconds given the round horizon.
    pub fn seconds(&self, t_lim: f64) -> f64 {
        match *self {
            ScenarioAt::Time(s) => s,
            ScenarioAt::Round(r) => (r.max(1) - 1) as f64 * t_lim,
        }
    }
}

/// A scripted scenario event on the continuous timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScenarioEventKind {
    /// Mass membership change: `joins` clients enter the fleet and
    /// `leaves` current members depart at the event time.
    FlashCrowd { joins: usize, leaves: usize },
    /// One region (clients sharded by `id % regions`) goes dark for
    /// `len_s` seconds starting at the event time.
    RegionalOutage { region: usize, len_s: f64 },
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScenarioEvent {
    pub at: ScenarioAt,
    pub kind: ScenarioEventKind,
}

/// Which availability process the scenario runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScenarioProcess {
    /// Continuous-clock dwell process (the scenario engine proper).
    Continuous,
    /// Reduction: the legacy per-round i.i.d. crash model.
    Bernoulli { crash_prob: f64 },
    /// Reduction: the legacy round-indexed two-state churn model.
    Markov {
        mean_uptime_s: f64,
        mean_downtime_s: f64,
    },
}

/// Load-time scenario description (strict-validated, default off).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Master switch; `false` leaves every engine path untouched.
    pub enabled: bool,
    pub process: ScenarioProcess,
    /// Mean online dwell (seconds) of the continuous process.
    pub base_uptime_s: f64,
    /// Mean offline dwell (seconds) of the continuous process.
    pub base_downtime_s: f64,
    /// Diurnal modulation amplitude in `[0, 1)`: online dwells stretch
    /// by `1 + amp * sin(2*pi*t/period)` and offline dwells by the
    /// anti-phase factor, so availability swings over the day.
    pub diurnal_amp: f64,
    /// Diurnal period in seconds.
    pub diurnal_period_s: f64,
    /// Region count for `RegionalOutage` events (client `k` belongs to
    /// region `k % regions`).
    pub regions: usize,
    /// Scripted events, applied in time order.
    pub events: Vec<ScenarioEvent>,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec {
            enabled: false,
            process: ScenarioProcess::Continuous,
            base_uptime_s: 2000.0,
            base_downtime_s: 500.0,
            diurnal_amp: 0.0,
            diurnal_period_s: 86_400.0,
            regions: 4,
            events: Vec::new(),
        }
    }
}

impl ScenarioSpec {
    /// Build a spec from raw TOML/CLI parts with the same strictness as
    /// `ChurnModel::from_parts` / `FaultPlan::from_parts`: `mode` names
    /// the process (`off`, `continuous`, `bernoulli`, `markov`), and
    /// supplying a parameter the mode cannot use is a hard error
    /// rather than a silent no-op.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        mode: &str,
        crash_prob: Option<f64>,
        uptime_s: Option<f64>,
        downtime_s: Option<f64>,
        diurnal_amp: Option<f64>,
        diurnal_period_s: Option<f64>,
        regions: Option<i64>,
        flash_at_s: Option<f64>,
        flash_joins: Option<i64>,
        flash_leaves: Option<i64>,
        outage_at_s: Option<f64>,
        outage_region: Option<i64>,
        outage_len_s: Option<f64>,
    ) -> Result<ScenarioSpec> {
        let err = |msg: String| Err(SafaError::Config(msg));
        let continuous_only = diurnal_amp.is_some()
            || diurnal_period_s.is_some()
            || regions.is_some()
            || flash_at_s.is_some()
            || flash_joins.is_some()
            || flash_leaves.is_some()
            || outage_at_s.is_some()
            || outage_region.is_some()
            || outage_len_s.is_some();
        match mode.to_ascii_lowercase().as_str() {
            "off" => {
                if crash_prob.is_some()
                    || uptime_s.is_some()
                    || downtime_s.is_some()
                    || continuous_only
                {
                    return err(
                        "scenario parameters require scenario.mode != \"off\"".into(),
                    );
                }
                Ok(ScenarioSpec::default())
            }
            "bernoulli" => {
                if continuous_only || uptime_s.is_some() || downtime_s.is_some() {
                    return err(
                        "scenario.mode = \"bernoulli\" accepts only scenario_crash_prob"
                            .into(),
                    );
                }
                let spec = ScenarioSpec {
                    enabled: true,
                    process: ScenarioProcess::Bernoulli {
                        crash_prob: crash_prob.unwrap_or(0.1),
                    },
                    ..ScenarioSpec::default()
                };
                spec.validate()?;
                Ok(spec)
            }
            "markov" => {
                if continuous_only || crash_prob.is_some() {
                    return err(
                        "scenario.mode = \"markov\" accepts only scenario_uptime_s / \
                         scenario_downtime_s"
                            .into(),
                    );
                }
                let d = ScenarioSpec::default();
                let spec = ScenarioSpec {
                    enabled: true,
                    process: ScenarioProcess::Markov {
                        mean_uptime_s: uptime_s.unwrap_or(d.base_uptime_s),
                        mean_downtime_s: downtime_s.unwrap_or(d.base_downtime_s),
                    },
                    ..d
                };
                spec.validate()?;
                Ok(spec)
            }
            "continuous" => {
                if crash_prob.is_some() {
                    return err(
                        "scenario_crash_prob requires scenario.mode = \"bernoulli\""
                            .into(),
                    );
                }
                let flash_args = flash_joins.is_some() || flash_leaves.is_some();
                if flash_args && flash_at_s.is_none() {
                    return err(
                        "scenario_flash_joins/leaves require scenario_flash_at_s".into(),
                    );
                }
                let outage_args = outage_region.is_some() || outage_len_s.is_some();
                if outage_args && outage_at_s.is_none() {
                    return err(
                        "scenario_outage_region/len_s require scenario_outage_at_s"
                            .into(),
                    );
                }
                let d = ScenarioSpec::default();
                let to_count = |name: &str, v: Option<i64>, dflt: usize| match v {
                    None => Ok(dflt),
                    Some(x) if x >= 0 => Ok(x as usize),
                    Some(x) => Err(SafaError::Config(format!(
                        "{name} must be >= 0, got {x}"
                    ))),
                };
                let mut events = Vec::new();
                if let Some(at) = flash_at_s {
                    events.push(ScenarioEvent {
                        at: ScenarioAt::Time(at),
                        kind: ScenarioEventKind::FlashCrowd {
                            joins: to_count("scenario_flash_joins", flash_joins, 0)?,
                            leaves: to_count("scenario_flash_leaves", flash_leaves, 0)?,
                        },
                    });
                }
                if let Some(at) = outage_at_s {
                    events.push(ScenarioEvent {
                        at: ScenarioAt::Time(at),
                        kind: ScenarioEventKind::RegionalOutage {
                            region: to_count("scenario_outage_region", outage_region, 0)?,
                            len_s: outage_len_s.unwrap_or(600.0),
                        },
                    });
                }
                let spec = ScenarioSpec {
                    enabled: true,
                    process: ScenarioProcess::Continuous,
                    base_uptime_s: uptime_s.unwrap_or(d.base_uptime_s),
                    base_downtime_s: downtime_s.unwrap_or(d.base_downtime_s),
                    diurnal_amp: diurnal_amp.unwrap_or(0.0),
                    diurnal_period_s: diurnal_period_s.unwrap_or(d.diurnal_period_s),
                    regions: to_count("scenario_regions", regions, d.regions)?,
                    events,
                };
                spec.validate()?;
                Ok(spec)
            }
            other => err(format!(
                "unknown scenario.mode {other:?} (expected \"off\", \"continuous\", \
                 \"bernoulli\" or \"markov\")"
            )),
        }
    }

    /// Reject NaN/inf/out-of-range knobs (used at TOML + CLI load time
    /// and from `ExperimentConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        let e = |msg: String| Err(SafaError::Config(msg));
        if !self.enabled {
            return Ok(());
        }
        match self.process {
            ScenarioProcess::Bernoulli { crash_prob } => {
                if !crash_prob.is_finite() || !(0.0..=1.0).contains(&crash_prob) {
                    return e(format!(
                        "scenario crash_prob must be a probability in [0, 1], got \
                         {crash_prob}"
                    ));
                }
                return Ok(());
            }
            ScenarioProcess::Markov {
                mean_uptime_s,
                mean_downtime_s,
            } => {
                for (name, v) in [
                    ("scenario uptime_s", mean_uptime_s),
                    ("scenario downtime_s", mean_downtime_s),
                ] {
                    if !v.is_finite() || v <= 0.0 {
                        return e(format!("{name} must be finite and > 0, got {v}"));
                    }
                }
                return Ok(());
            }
            ScenarioProcess::Continuous => {}
        }
        for (name, v) in [
            ("scenario base_uptime_s", self.base_uptime_s),
            ("scenario base_downtime_s", self.base_downtime_s),
            ("scenario diurnal_period_s", self.diurnal_period_s),
        ] {
            if !v.is_finite() || v <= 0.0 {
                return e(format!("{name} must be finite and > 0, got {v}"));
            }
        }
        if !self.diurnal_amp.is_finite() || !(0.0..1.0).contains(&self.diurnal_amp) {
            return e(format!(
                "scenario diurnal_amp must be in [0, 1), got {}",
                self.diurnal_amp
            ));
        }
        if self.regions == 0 {
            return e("scenario regions must be >= 1".into());
        }
        for ev in &self.events {
            if let ScenarioAt::Time(s) = ev.at {
                if !s.is_finite() || s < 0.0 {
                    return e(format!(
                        "scenario event time must be finite and >= 0, got {s}"
                    ));
                }
            }
            match ev.kind {
                ScenarioEventKind::FlashCrowd { joins, leaves } => {
                    if joins == 0 && leaves == 0 {
                        return e("scenario flash crowd must join or leave someone".into());
                    }
                }
                ScenarioEventKind::RegionalOutage { region, len_s } => {
                    if region >= self.regions {
                        return e(format!(
                            "scenario outage region {region} out of range (regions = {})",
                            self.regions
                        ));
                    }
                    if !len_s.is_finite() || len_s <= 0.0 {
                        return e(format!(
                            "scenario outage length must be finite and > 0, got {len_s}"
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Total clients scheduled to join via flash crowds (the timeline
    /// reserves the top ids of the fleet as latecomers).
    pub fn total_joins(&self) -> usize {
        self.events
            .iter()
            .map(|e| match e.kind {
                ScenarioEventKind::FlashCrowd { joins, .. } => joins,
                _ => 0,
            })
            .sum()
    }
}

/// Fluent scenario builder: positions a time cursor with
/// [`Scenario::at_time`] / [`Scenario::at_round`] and drops events at
/// it, compiling to a validated [`ScenarioSpec`].
///
/// ```ignore
/// let spec = Scenario::new()
///     .uptime(1200.0, 300.0)
///     .diurnal(0.6, 4.0 * 830.0)
///     .at_time(5000.0)
///     .flash_crowd(10, 0)
///     .at_round(150)
///     .regional_outage(2, 600.0)
///     .build()?;
/// ```
#[derive(Debug, Clone)]
pub struct Scenario {
    spec: ScenarioSpec,
    cursor: ScenarioAt,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario::new()
    }
}

impl Scenario {
    /// Start a continuous-process scenario with the default dwells.
    pub fn new() -> Scenario {
        Scenario {
            spec: ScenarioSpec {
                enabled: true,
                process: ScenarioProcess::Continuous,
                ..ScenarioSpec::default()
            },
            cursor: ScenarioAt::Time(0.0),
        }
    }

    /// Start a per-round Bernoulli reduction (compiles to the legacy
    /// i.i.d. crash model, bit-for-bit).
    pub fn bernoulli(crash_prob: f64) -> Scenario {
        Scenario {
            spec: ScenarioSpec {
                enabled: true,
                process: ScenarioProcess::Bernoulli { crash_prob },
                ..ScenarioSpec::default()
            },
            cursor: ScenarioAt::Time(0.0),
        }
    }

    /// Start a per-round Markov reduction (compiles to the legacy
    /// round-indexed churn model, bit-for-bit).
    pub fn markov(mean_uptime_s: f64, mean_downtime_s: f64) -> Scenario {
        Scenario {
            spec: ScenarioSpec {
                enabled: true,
                process: ScenarioProcess::Markov {
                    mean_uptime_s,
                    mean_downtime_s,
                },
                ..ScenarioSpec::default()
            },
            cursor: ScenarioAt::Time(0.0),
        }
    }

    /// Mean online/offline dwell seconds of the continuous process.
    pub fn uptime(mut self, mean_uptime_s: f64, mean_downtime_s: f64) -> Scenario {
        self.spec.base_uptime_s = mean_uptime_s;
        self.spec.base_downtime_s = mean_downtime_s;
        self
    }

    /// Diurnal sine-wave modulation of the dwell means.
    pub fn diurnal(mut self, amp: f64, period_s: f64) -> Scenario {
        self.spec.diurnal_amp = amp;
        self.spec.diurnal_period_s = period_s;
        self
    }

    /// Region count for outage sharding (`client % regions`).
    pub fn regions(mut self, regions: usize) -> Scenario {
        self.spec.regions = regions;
        self
    }

    /// Move the event cursor to an absolute sim-time.
    pub fn at_time(mut self, seconds: f64) -> Scenario {
        self.cursor = ScenarioAt::Time(seconds);
        self
    }

    /// Move the event cursor to the instant a 1-based round opens.
    pub fn at_round(mut self, round: usize) -> Scenario {
        self.cursor = ScenarioAt::Round(round);
        self
    }

    /// Mass join/leave at the cursor.
    pub fn flash_crowd(mut self, joins: usize, leaves: usize) -> Scenario {
        self.spec.events.push(ScenarioEvent {
            at: self.cursor,
            kind: ScenarioEventKind::FlashCrowd { joins, leaves },
        });
        self
    }

    /// Regional dark band of `len_s` seconds starting at the cursor.
    pub fn regional_outage(mut self, region: usize, len_s: f64) -> Scenario {
        self.spec.events.push(ScenarioEvent {
            at: self.cursor,
            kind: ScenarioEventKind::RegionalOutage { region, len_s },
        });
        self
    }

    /// Validate and return the spec.
    pub fn build(self) -> Result<ScenarioSpec> {
        self.spec.validate()?;
        Ok(self.spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_is_off_and_valid() {
        let s = ScenarioSpec::default();
        assert!(!s.enabled);
        s.validate().unwrap();
    }

    #[test]
    fn builder_compiles_events_at_the_cursor() {
        let spec = Scenario::new()
            .uptime(1200.0, 300.0)
            .diurnal(0.6, 3320.0)
            .regions(4)
            .at_time(5000.0)
            .flash_crowd(10, 0)
            .at_round(150)
            .regional_outage(2, 600.0)
            .build()
            .unwrap();
        assert!(spec.enabled);
        assert_eq!(spec.process, ScenarioProcess::Continuous);
        assert_eq!(spec.events.len(), 2);
        assert_eq!(spec.events[0].at, ScenarioAt::Time(5000.0));
        assert_eq!(
            spec.events[0].kind,
            ScenarioEventKind::FlashCrowd { joins: 10, leaves: 0 }
        );
        assert_eq!(spec.events[1].at, ScenarioAt::Round(150));
        assert_eq!(spec.events[1].at.seconds(830.0), 149.0 * 830.0);
        assert_eq!(spec.total_joins(), 10);
    }

    #[test]
    fn builder_reductions_carry_their_parameters() {
        let b = Scenario::bernoulli(0.3).build().unwrap();
        assert_eq!(b.process, ScenarioProcess::Bernoulli { crash_prob: 0.3 });
        let m = Scenario::markov(600.0, 200.0).build().unwrap();
        assert_eq!(
            m.process,
            ScenarioProcess::Markov {
                mean_uptime_s: 600.0,
                mean_downtime_s: 200.0
            }
        );
    }

    #[test]
    fn from_parts_mirrors_churn_strictness() {
        // Orphan parameter with mode off is a hard error.
        assert!(ScenarioSpec::from_parts(
            "off",
            None,
            Some(100.0),
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
        )
        .is_err());
        // Unknown mode is rejected.
        assert!(ScenarioSpec::from_parts(
            "sometimes", None, None, None, None, None, None, None, None, None, None,
            None, None,
        )
        .is_err());
        // Reductions reject continuous-only knobs.
        assert!(ScenarioSpec::from_parts(
            "bernoulli",
            Some(0.2),
            None,
            None,
            Some(0.5),
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
        )
        .is_err());
        assert!(ScenarioSpec::from_parts(
            "markov",
            Some(0.2),
            Some(100.0),
            Some(50.0),
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            None,
        )
        .is_err());
        // Flash satellites without the anchor time are orphans.
        assert!(ScenarioSpec::from_parts(
            "continuous",
            None,
            None,
            None,
            None,
            None,
            None,
            None,
            Some(5),
            None,
            None,
            None,
            None,
        )
        .is_err());
        // A clean continuous build round-trips the knobs.
        let s = ScenarioSpec::from_parts(
            "continuous",
            None,
            Some(900.0),
            Some(300.0),
            Some(0.4),
            Some(4000.0),
            Some(3),
            Some(1500.0),
            Some(8),
            Some(2),
            Some(2500.0),
            Some(1),
            Some(400.0),
        )
        .unwrap();
        assert!(s.enabled);
        assert_eq!(s.regions, 3);
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.total_joins(), 8);
    }

    #[test]
    fn validate_rejects_bad_fields() {
        let base = || Scenario::new();
        assert!(base().diurnal(1.0, 100.0).build().is_err(), "amp must be < 1");
        assert!(base().diurnal(0.5, 0.0).build().is_err(), "zero period");
        assert!(base().uptime(0.0, 100.0).build().is_err(), "zero dwell");
        assert!(base().regions(0).build().is_err(), "zero regions");
        assert!(
            base().regions(2).at_time(10.0).regional_outage(2, 60.0).build().is_err(),
            "region out of range"
        );
        assert!(
            base().at_time(10.0).flash_crowd(0, 0).build().is_err(),
            "empty flash crowd"
        );
        assert!(
            base().at_time(-5.0).flash_crowd(1, 0).build().is_err(),
            "negative event time"
        );
        assert!(Scenario::bernoulli(1.5).build().is_err(), "prob > 1");
        assert!(Scenario::markov(-1.0, 10.0).build().is_err(), "negative dwell");
    }
}
