//! Deterministic, splittable pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so this module
//! implements everything the simulator needs from scratch:
//!
//! * [`Pcg64`] — a PCG-XSL-RR 128/64 generator (O'Neill 2014). Fast, small
//!   state, excellent statistical quality for simulation purposes.
//! * Splitting: [`Pcg64::split`] derives an independent child stream via a
//!   SplitMix64 hash of the parent state and a label, so every client /
//!   round / subsystem gets its own stream and experiments are bit-
//!   reproducible regardless of iteration order.
//! * Distributions: [`Uniform`], [`Normal`] (Box–Muller), [`Exponential`]
//!   (inverse CDF) and [`Bernoulli`], which are exactly the ones the SAFA
//!   paper's environment model draws from (partition sizes ~ N(mu, 0.3mu),
//!   client speeds ~ Exp(1), crashes ~ Bernoulli(cr)).

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// SplitMix64 finalizer — used for seeding and stream derivation.
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream 0).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0)
    }

    /// Create a generator from a seed and a stream id. Different stream
    /// ids yield statistically independent sequences for the same seed.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let s0 = splitmix64(seed);
        let s1 = splitmix64(s0 ^ 0xdead_beef_cafe_f00d);
        let t0 = splitmix64(stream ^ 0x5851_f42d_4c95_7f2d);
        let t1 = splitmix64(t0 ^ seed);
        let state = ((s0 as u128) << 64) | s1 as u128;
        // The increment must be odd for the LCG to be full-period.
        let inc = ((((t0 as u128) << 64) | t1 as u128) << 1) | 1;
        let mut rng = Pcg64 { state, inc };
        // Warm up so that near-zero states decorrelate.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator labelled by `label`.
    ///
    /// Children with distinct labels (e.g. client ids, round indices) are
    /// independent of each other and of the parent's future output.
    pub fn split(&self, label: u64) -> Pcg64 {
        let hi = splitmix64((self.state >> 64) as u64 ^ label);
        let lo = splitmix64(self.state as u64 ^ label.rotate_left(32));
        Pcg64::with_stream(hi ^ lo, label.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        // XSL-RR output function.
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), uniformly.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut pool = Vec::new();
        let mut out = Vec::new();
        self.sample_indices_into(n, k, &mut pool, &mut out);
        out
    }

    /// Allocation-free form of [`Pcg64::sample_indices`] (identical
    /// draws): `pool` is caller-owned scratch rebuilt each call, `out`
    /// receives the `k` samples. Capacities are reused, so steady-state
    /// callers (the per-round selection loops) never reallocate.
    pub fn sample_indices_into(
        &mut self,
        n: usize,
        k: usize,
        pool: &mut Vec<usize>,
        out: &mut Vec<usize>,
    ) {
        assert!(k <= n, "sample_indices: k > n");
        pool.clear();
        pool.extend(0..n);
        // Partial Fisher–Yates: only the first k positions are needed.
        for i in 0..k {
            let j = i + self.index(n - i);
            pool.swap(i, j);
        }
        out.clear();
        out.extend_from_slice(&pool[..k]);
    }
}

/// A sampleable distribution over f64.
pub trait Distribution {
    fn sample(&self, rng: &mut Pcg64) -> f64;
}

/// Uniform distribution on [lo, hi).
#[derive(Debug, Clone, Copy)]
pub struct Uniform {
    pub lo: f64,
    pub hi: f64,
}

impl Uniform {
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(hi >= lo, "Uniform: hi < lo");
        Uniform { lo, hi }
    }
}

impl Distribution for Uniform {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }
}

/// Gaussian via Box–Muller (fresh pair each call; the spare is discarded
/// to keep the sampler stateless and splitting-safe).
#[derive(Debug, Clone, Copy)]
pub struct Normal {
    pub mean: f64,
    pub std: f64,
}

impl Normal {
    pub fn new(mean: f64, std: f64) -> Self {
        assert!(std >= 0.0, "Normal: negative std");
        Normal { mean, std }
    }
}

impl Distribution for Normal {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        // Box–Muller; u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - rng.next_f64();
        let u2 = rng.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        self.mean + self.std * r * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// Exponential with rate `lambda` (mean 1/lambda), via inverse CDF.
#[derive(Debug, Clone, Copy)]
pub struct Exponential {
    pub lambda: f64,
}

impl Exponential {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0, "Exponential: lambda <= 0");
        Exponential { lambda }
    }
}

impl Distribution for Exponential {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        let u = 1.0 - rng.next_f64(); // in (0, 1]
        -u.ln() / self.lambda
    }
}

/// Bernoulli trial.
#[derive(Debug, Clone, Copy)]
pub struct Bernoulli {
    pub p: f64,
}

impl Bernoulli {
    pub fn new(p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p), "Bernoulli: p outside [0,1]");
        Bernoulli { p }
    }

    #[inline]
    pub fn draw(&self, rng: &mut Pcg64) -> bool {
        rng.next_f64() < self.p
    }
}

impl Distribution for Bernoulli {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        if self.draw(rng) {
            1.0
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent_of_parent() {
        let parent = Pcg64::new(7);
        let mut c1 = parent.split(1);
        let mut c2 = parent.split(2);
        let mut c1b = parent.split(1);
        // Same label -> same stream; different label -> different stream.
        assert_eq!(c1.next_u64(), c1b.next_u64());
        let mut c1x = parent.split(1);
        c1x.next_u64();
        assert_ne!(c1x.next_u64(), c2.next_u64());
    }

    #[test]
    fn uniform_f64_in_unit_interval() {
        let mut rng = Pcg64::new(9);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_smoke() {
        let mut rng = Pcg64::new(11);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[rng.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.01, "bucket p={p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(13);
        let d = Normal::new(3.0, 2.0);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn exponential_moments() {
        let mut rng = Pcg64::new(17);
        let d = Exponential::new(1.0);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Pcg64::new(19);
        let d = Bernoulli::new(0.3);
        let n = 100_000;
        let hits = (0..n).filter(|_| d.draw(&mut rng)).count();
        let p = hits as f64 / n as f64;
        assert!((p - 0.3).abs() < 0.01, "p={p}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(23);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Pcg64::new(29);
        for _ in 0..100 {
            let ks = rng.sample_indices(50, 20);
            assert_eq!(ks.len(), 20);
            let mut s = ks.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 20);
            assert!(ks.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn sample_indices_full_set() {
        let mut rng = Pcg64::new(31);
        let mut ks = rng.sample_indices(10, 10);
        ks.sort_unstable();
        assert_eq!(ks, (0..10).collect::<Vec<_>>());
    }
}
