//! Minimal property-based testing framework (no `proptest` crate offline).
//!
//! Usage (`no_run`: doctest binaries don't inherit the xla rpath in this
//! image; the same snippet executes in the unit tests below):
//! ```no_run
//! use safa::util::proptest::{property, Gen};
//! property("sum is commutative", 200, |g: &mut Gen| {
//!     let a = g.f64_range(-1e3, 1e3);
//!     let b = g.f64_range(-1e3, 1e3);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets a fresh deterministic RNG derived from the property name
//! and case index, so failures are reproducible: the panic message reports
//! the case index, and `Gen::from_case(name, idx)` replays it exactly.
//! There is no shrinking — cases are kept small instead, which in practice
//! localizes failures well for the coordinator invariants we test.

use crate::util::rng::{Distribution, Normal, Pcg64};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Case generator handed to property bodies.
pub struct Gen {
    rng: Pcg64,
}

impl Gen {
    /// Deterministic generator for case `idx` of property `name`.
    pub fn from_case(name: &str, idx: u64) -> Gen {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3); // FNV-1a
        }
        Gen {
            rng: Pcg64::with_stream(h, idx),
        }
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn usize_range(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        assert!(hi_inclusive >= lo);
        lo + self.rng.index(hi_inclusive - lo + 1)
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        Normal::new(mean, std).sample(&mut self.rng)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_range(lo, hi)).collect()
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len)
            .map(|_| lo + (hi - lo) * self.rng.next_f32())
            .collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    /// A random subset of [0, n), each element included with prob p.
    pub fn subset(&mut self, n: usize, p: f64) -> Vec<usize> {
        (0..n).filter(|_| self.rng.next_f64() < p).collect()
    }
}

/// Run `cases` random cases of a property; panic with the failing case
/// index on the first failure.
pub fn property<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut body: F) {
    for idx in 0..cases {
        let mut g = Gen::from_case(name, idx);
        let result = catch_unwind(AssertUnwindSafe(|| body(&mut g)));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| panic.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {idx}/{cases}: {msg}\n\
                 replay with Gen::from_case({name:?}, {idx})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property("trivially true", 50, |_g| {
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn failing_property_reports_case() {
        property("always false", 10, |_g| {
            assert!(false, "intentional");
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut g1 = Gen::from_case("p", 3);
        let mut g2 = Gen::from_case("p", 3);
        assert_eq!(g1.u64(), g2.u64());
        let mut g3 = Gen::from_case("p", 4);
        assert_ne!(Gen::from_case("p", 3).u64(), g3.u64());
    }

    #[test]
    fn generators_respect_bounds() {
        property("bounds", 100, |g| {
            let x = g.usize_range(3, 9);
            assert!((3..=9).contains(&x));
            let f = g.f64_range(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&f));
            let v = g.vec_f64(5, 0.0, 1.0);
            assert_eq!(v.len(), 5);
            let s = g.subset(10, 0.5);
            assert!(s.iter().all(|&i| i < 10));
        });
    }
}
