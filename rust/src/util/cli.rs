//! Hand-rolled CLI argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands. The launcher in `main.rs` defines its commands on top
//! of this.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed arguments: options (last occurrence wins), flags and positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "CLI error: {}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse a raw argument list. `known_flags` lists long options that do
    /// NOT take a value (everything else with `--` does).
    pub fn parse<I, S>(argv: I, known_flags: &[&str]) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let mut iter = argv.into_iter().map(Into::into).peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // "--" terminates option parsing.
                    args.positional.extend(iter);
                    break;
                }
                if let Some(eq) = body.find('=') {
                    args.options
                        .insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if known_flags.contains(&body) {
                    args.flags.push(body.to_string());
                } else {
                    let val = iter
                        .next()
                        .ok_or_else(|| CliError(format!("--{body} expects a value")))?;
                    args.options.insert(body.to_string(), val);
                }
            } else {
                args.positional.push(arg);
            }
        }
        Ok(args)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: cannot parse '{s}'"))),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        Ok(self.get_parsed(name)?.unwrap_or(default))
    }

    /// Value of `--name` validated against an allowed set
    /// (case-insensitive); returns the lowercased choice.
    pub fn get_choice(&self, name: &str, allowed: &[&str]) -> Result<Option<String>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => {
                let lower = s.to_ascii_lowercase();
                if allowed.contains(&lower.as_str()) {
                    Ok(Some(lower))
                } else {
                    Err(CliError(format!(
                        "--{name}: expected one of {allowed:?}, got '{s}'"
                    )))
                }
            }
        }
    }

    /// Parse a comma-separated list option, e.g. `--cr 0.1,0.3`.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str) -> Result<Option<Vec<T>>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .split(',')
                .map(|part| {
                    part.trim()
                        .parse::<T>()
                        .map_err(|_| CliError(format!("--{name}: cannot parse '{part}'")))
                })
                .collect::<Result<Vec<T>, _>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_arguments() {
        let args = Args::parse(
            vec!["run", "--task", "task1", "--tau=5", "--verbose", "extra"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(args.positional, vec!["run", "extra"]);
        assert_eq!(args.get("task"), Some("task1"));
        assert_eq!(args.get("tau"), Some("5"));
        assert!(args.has_flag("verbose"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(vec!["--task"], &[]).is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let args = Args::parse(vec!["--a", "1", "--", "--b", "2"], &[]).unwrap();
        assert_eq!(args.get("a"), Some("1"));
        assert_eq!(args.positional, vec!["--b", "2"]);
    }

    #[test]
    fn typed_getters() {
        let args = Args::parse(vec!["--n", "42", "--f", "0.5"], &[]).unwrap();
        assert_eq!(args.get_or("n", 0usize).unwrap(), 42);
        assert_eq!(args.get_or("f", 0.0f64).unwrap(), 0.5);
        assert_eq!(args.get_or("missing", 7i32).unwrap(), 7);
        assert!(args.get_parsed::<usize>("f").is_err());
    }

    #[test]
    fn list_option() {
        let args = Args::parse(vec!["--cr", "0.1,0.3, 0.5"], &[]).unwrap();
        let crs: Vec<f64> = args.get_list("cr").unwrap().unwrap();
        assert_eq!(crs, vec![0.1, 0.3, 0.5]);
    }

    #[test]
    fn choice_option_validates() {
        let args = Args::parse(vec!["--churn", "Markov"], &[]).unwrap();
        assert_eq!(
            args.get_choice("churn", &["bernoulli", "markov", "trace"])
                .unwrap(),
            Some("markov".to_string())
        );
        assert_eq!(args.get_choice("missing", &["a"]).unwrap(), None);
        assert!(args.get_choice("churn", &["bernoulli"]).is_err());
    }

    #[test]
    fn last_occurrence_wins() {
        let args = Args::parse(vec!["--x", "1", "--x", "2"], &[]).unwrap();
        assert_eq!(args.get("x"), Some("2"));
    }
}
