//! Tiny self-contained logger (no `log` / `env_logger` offline).
//!
//! Level is taken from `SAFA_LOG` (off|error|warn|info|debug|trace),
//! default `info`. Output goes to stderr with a monotonic-ish timestamp
//! relative to process start, which is what you want when comparing
//! against the simulator's *virtual* clock printed by the coordinator.
//!
//! Use through the crate-root macros:
//!
//! ```no_run
//! safa::util::logging::init();
//! safa::log_info!("round {} done in {:.1}s", 3, 12.5);
//! ```

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    fn rank(self) -> u8 {
        match self {
            Level::Error => 1,
            Level::Warn => 2,
            Level::Info => 3,
            Level::Debug => 4,
            Level::Trace => 5,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Max enabled rank (0 = everything off). Default: info.
static MAX_RANK: AtomicU8 = AtomicU8::new(3);
static START: OnceLock<Instant> = OnceLock::new();
static ENV_LEVEL: OnceLock<()> = OnceLock::new();

/// Initialize the logger: pin the start timestamp and read `SAFA_LOG`.
/// Safe to call multiple times — the environment level is applied only
/// once, so later calls never clobber a `set_max_level` override.
pub fn init() {
    START.get_or_init(Instant::now);
    ENV_LEVEL.get_or_init(|| MAX_RANK.store(rank_from_env(), Ordering::Relaxed));
}

/// Override the enabled level (`None` disables all output). An explicit
/// override outranks `SAFA_LOG`: it also consumes the one-time
/// environment store, so a later `init()` cannot clobber it.
pub fn set_max_level(level: Option<Level>) {
    ENV_LEVEL.get_or_init(|| ());
    MAX_RANK.store(level.map_or(0, Level::rank), Ordering::Relaxed);
}

/// Is `level` currently enabled?
pub fn enabled(level: Level) -> bool {
    level.rank() <= MAX_RANK.load(Ordering::Relaxed)
}

/// Emit one record (used by the `log_*!` macros; prefer those).
pub fn log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:10.3}s {} {target}] {args}", level.tag());
}

fn rank_from_env() -> u8 {
    match std::env::var("SAFA_LOG").as_deref() {
        Ok("off") => 0,
        Ok("error") => Level::Error.rank(),
        Ok("warn") => Level::Warn.rank(),
        Ok("debug") => Level::Debug.rank(),
        Ok("trace") => Level::Trace.rank(),
        _ => Level::Info.rank(),
    }
}

/// Log at error level (crate-root macro; `safa::log_error!` from
/// binaries).
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at warn level.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at info level.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at debug level.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at trace level.
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Trace,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        crate::log_info!("logger smoke {}", 1 + 1);
    }

    #[test]
    fn levels_gate_correctly() {
        // Consume the one-time SAFA_LOG store first so a concurrent
        // init() (e.g. from init_is_idempotent) cannot land mid-test.
        init();
        // MAX_RANK is process-global; restore whatever was configured
        // (e.g. via SAFA_LOG) rather than clobbering it with a default.
        let prior = MAX_RANK.load(Ordering::Relaxed);
        set_max_level(Some(Level::Warn));
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(None);
        assert!(!enabled(Level::Error));
        MAX_RANK.store(prior, Ordering::Relaxed);
    }
}
