//! Tiny logger backend for the `log` facade (no `env_logger` offline).
//!
//! Level is taken from `SAFA_LOG` (error|warn|info|debug|trace), default
//! `info`. Output goes to stderr with a monotonic-ish timestamp relative
//! to process start, which is what you want when comparing against the
//! simulator's *virtual* clock printed by the coordinator.

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

struct SimpleLogger {
    start: Instant,
}

impl log::Log for SimpleLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:10.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<SimpleLogger> = OnceLock::new();

/// Initialize the global logger. Safe to call multiple times.
pub fn init() {
    let logger = LOGGER.get_or_init(|| SimpleLogger {
        start: Instant::now(),
    });
    if log::set_logger(logger).is_ok() {
        log::set_max_level(level_from_env());
    }
}

fn level_from_env() -> LevelFilter {
    match std::env::var("SAFA_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
