//! Per-worker scratch slots for parallel chunk bodies.
//!
//! [`WorkerScratch<T>`] hands each concurrent caller an exclusive,
//! lazily-built `T` without serializing on one shared instance: slot
//! `i` is preferred by the thread whose [`parallel::worker_id`] is `i`,
//! so in steady state every pool worker reuses the scratch it warmed up
//! — **no allocation after warm-up** — while a try-lock scan keeps
//! arbitrary extra threads (unit tests, the serial path) correct.
//!
//! This is what lets scratch-carrying trainers (the native CNN's
//! forward/backward buffers) implement
//! [`crate::model::StatelessTrainer`]: `local_update_shared(&self, ..)`
//! borrows a worker-local `Scratch` instead of `&mut self`, so
//! `protocol::collect_updates` can fan client updates across the pool.
//!
//! Contents are *scratch*: bodies must fully overwrite whatever they
//! read (every CNN kernel zero-fills or overwrites its output), because
//! which slot a call lands on is **not** part of the determinism
//! contract — only the slot's existence is.

use crate::util::parallel::{self, MAX_THREADS};
use std::sync::{Mutex, TryLockError};

/// Lazily-built, worker-indexed scratch slots (see module docs).
pub struct WorkerScratch<T> {
    slots: Box<[Mutex<Option<T>>]>,
}

impl<T: Send> WorkerScratch<T> {
    /// An empty pool: slots are built on first claim by `with`'s `init`.
    pub fn new() -> WorkerScratch<T> {
        WorkerScratch {
            slots: (0..MAX_THREADS).map(|_| Mutex::new(None)).collect(),
        }
    }

    /// Run `f` with an exclusive scratch slot, building one with `init`
    /// if the claimed slot has never been used. The current pool
    /// worker's preferred slot is claimed when free; otherwise the scan
    /// wraps to the first free slot, so concurrent non-pool callers
    /// stay correct (at worst they build one extra slot each).
    pub fn with<R>(&self, init: impl FnOnce() -> T, f: impl FnOnce(&mut T) -> R) -> R {
        let n = self.slots.len();
        let preferred = parallel::worker_id() % n;
        for probe in 0..n {
            let idx = (preferred + probe) % n;
            let mut guard = match self.slots[idx].try_lock() {
                Ok(g) => g,
                // A panic mid-use may have left this slot half-written;
                // drop the contents and rebuild below. Clearing the
                // poison makes the recovery one-shot — otherwise every
                // later claim would wipe and rebuild the slot forever.
                Err(TryLockError::Poisoned(p)) => {
                    let mut g = p.into_inner();
                    *g = None;
                    self.slots[idx].clear_poison();
                    g
                }
                Err(TryLockError::WouldBlock) => continue,
            };
            if guard.is_none() {
                *guard = Some(init());
            }
            return f(guard.as_mut().expect("slot just built"));
        }
        // More than MAX_THREADS concurrent claimants (unreachable from
        // the pool, whose width is capped below that): fall back to a
        // throwaway scratch rather than blocking.
        let mut tmp = init();
        f(&mut tmp)
    }

    /// Number of slots currently built (diagnostics/tests).
    pub fn built(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| match s.try_lock() {
                Ok(g) => g.is_some(),
                Err(_) => true, // in use => built
            })
            .count()
    }
}

impl<T: Send> Default for WorkerScratch<T> {
    fn default() -> Self {
        WorkerScratch::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parallel::{for_each_chunk, with_thread_count};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn builds_lazily_and_reuses() {
        let scratch: WorkerScratch<Vec<u8>> = WorkerScratch::new();
        assert_eq!(scratch.built(), 0);
        let builds = AtomicUsize::new(0);
        for _ in 0..5 {
            scratch.with(
                || {
                    builds.fetch_add(1, Ordering::SeqCst);
                    vec![0u8; 64]
                },
                |v| v[0] = 1,
            );
        }
        // Same (serial) caller every time: one build, then reuse.
        assert_eq!(builds.load(Ordering::SeqCst), 1);
        assert_eq!(scratch.built(), 1);
    }

    #[test]
    fn parallel_claimants_get_disjoint_slots() {
        let scratch: WorkerScratch<Vec<usize>> = WorkerScratch::new();
        with_thread_count(4, || {
            let mut data = vec![0usize; 4];
            for_each_chunk(&mut data, 1, |base, chunk| {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x = scratch.with(
                        || vec![0usize; 8],
                        |v| {
                            // Exclusive access: concurrent claimants
                            // writing a shared slot would tear this.
                            v[0] = base + i;
                            v[0]
                        },
                    );
                }
            });
            for (i, &x) in data.iter().enumerate() {
                assert_eq!(x, i);
            }
        });
        // At most one slot per concurrent claimant was ever built.
        assert!(scratch.built() <= 4, "built {}", scratch.built());
    }
}
