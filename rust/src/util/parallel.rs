//! Zero-dependency fork-join parallelism over slices.
//!
//! The offline build has no `rayon`, so this module hand-rolls the one
//! shape the simulator needs: *static chunking* of one or two equal
//! slices across a fleet of scoped threads (`std::thread::scope`), with
//! the caller's thread working the first chunk. There is no work
//! stealing and no persistent pool — a fork spawns `width - 1` OS
//! threads and joins them before returning, which keeps the module tiny
//! and makes every parallel region a strict fork-join (nothing outlives
//! the call).
//!
//! # Width selection
//!
//! [`num_threads`] resolves, in priority order:
//! 1. a scoped [`with_thread_count`] override on the current thread
//!    (tests and the thread-scaling benches),
//! 2. the `SAFA_THREADS` environment variable (parsed once),
//! 3. `std::thread::available_parallelism()`.
//!
//! A chunked call additionally degrades to serial when the slice is
//! shorter than `grain` elements per worker, so tiny inputs (unit-test
//! fleets, dim-1 Null models) never pay a spawn.
//!
//! # Determinism contract
//!
//! Every helper here applies `f` to *disjoint, contiguous* chunks whose
//! element indices are independent of the width: `f(base, chunk)` sees
//! the same `(index, element)` pairs whether the call ran on 1 thread or
//! 8. As long as `f` computes each element independently (no cross-chunk
//! reduction), results are bit-for-bit identical across widths — the
//! property the engine's determinism tests assert. Reductions must NOT
//! be accumulated across chunks in completion order; compute per-element
//! values in parallel and fold them serially in index order instead.

use std::cell::Cell;
use std::sync::OnceLock;

/// Hard cap on the fork width (a safety rail for absurd `SAFA_THREADS`
/// values; spawning is per-fork, so each extra thread costs a spawn).
pub const MAX_THREADS: usize = 256;

thread_local! {
    /// 0 = no override active.
    static WIDTH_OVERRIDE: Cell<usize> = const { Cell::new(0) };
}

/// `SAFA_THREADS`, else available parallelism (read once per process).
fn configured_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("SAFA_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n.min(MAX_THREADS);
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_THREADS)
    })
}

/// The fork width the next parallel call on this thread will use.
pub fn num_threads() -> usize {
    let o = WIDTH_OVERRIDE.with(|c| c.get());
    if o >= 1 {
        o.min(MAX_THREADS)
    } else {
        configured_threads()
    }
}

/// Pin the fork width to `n` for the duration of `f` on this thread
/// (restored on exit, including unwinds). Used by the determinism tests
/// and `benches/fleet_scale.rs` to sweep widths inside one process.
pub fn with_thread_count<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            WIDTH_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = WIDTH_OVERRIDE.with(|c| c.replace(n.clamp(1, MAX_THREADS)));
    let _restore = Restore(prev);
    f()
}

/// Width actually used for `len` elements at `grain` elements minimum
/// per worker.
fn width_for(len: usize, grain: usize) -> usize {
    let by_work = len / grain.max(1);
    num_threads().min(by_work).max(1)
}

/// Apply `f(base_index, chunk)` to contiguous chunks of `data` across
/// the pool. Serial (`f(0, data)`) when the input is shorter than
/// `2 * grain` or only one thread is configured.
pub fn for_each_chunk<T, F>(data: &mut [T], grain: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let len = data.len();
    let width = width_for(len, grain);
    if width <= 1 {
        f(0, data);
        return;
    }
    let chunk = len.div_ceil(width);
    std::thread::scope(|s| {
        let mut parts = data.chunks_mut(chunk);
        let first = parts.next().expect("width > 1 implies a first chunk");
        for (i, part) in parts.enumerate() {
            let f = &f;
            // Chunk bodies run with the width pinned to 1 so a nested
            // parallel call (e.g. `ParamVec::copy_from` inside a
            // per-client pass) degrades to serial instead of spawning
            // width² threads. Serial fallbacks above leave the width
            // untouched, so an un-forked outer loop still lets inner
            // kernels fork.
            s.spawn(move || with_thread_count(1, || f((i + 1) * chunk, part)));
        }
        // The caller's thread works the first chunk while the spawned
        // workers run; the scope joins everything before returning.
        with_thread_count(1, || f(0, first));
    });
}

/// Like [`for_each_chunk`] over two equal-length slices chunked at
/// identical boundaries: `f(base_index, a_chunk, b_chunk)`.
pub fn for_each_chunk2<A, B, F>(a: &mut [A], b: &mut [B], grain: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert_eq!(a.len(), b.len(), "for_each_chunk2: length mismatch");
    let len = a.len();
    let width = width_for(len, grain);
    if width <= 1 {
        f(0, a, b);
        return;
    }
    let chunk = len.div_ceil(width);
    std::thread::scope(|s| {
        let mut pa = a.chunks_mut(chunk);
        let mut pb = b.chunks_mut(chunk);
        let fa = pa.next().expect("width > 1 implies a first chunk");
        let fb = pb.next().expect("width > 1 implies a first chunk");
        for (i, (ca, cb)) in pa.zip(pb).enumerate() {
            let f = &f;
            // Width pinned to 1 inside chunk bodies — see for_each_chunk.
            s.spawn(move || with_thread_count(1, || f((i + 1) * chunk, ca, cb)));
        }
        with_thread_count(1, || f(0, fa, fb));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_every_index_exactly_once() {
        for width in [1, 2, 3, 8, 17] {
            with_thread_count(width, || {
                let mut data = vec![0u32; 1003];
                for_each_chunk(&mut data, 1, |base, chunk| {
                    for (i, x) in chunk.iter_mut().enumerate() {
                        *x += (base + i) as u32 + 1;
                    }
                });
                for (i, &x) in data.iter().enumerate() {
                    assert_eq!(x, i as u32 + 1, "index {i} at width {width}");
                }
            });
        }
    }

    #[test]
    fn chunk2_keeps_slices_aligned() {
        for width in [1, 3, 8] {
            with_thread_count(width, || {
                let mut a: Vec<usize> = (0..517).collect();
                let mut b = vec![0usize; 517];
                for_each_chunk2(&mut a, &mut b, 1, |base, ca, cb| {
                    for (i, (x, y)) in ca.iter_mut().zip(cb.iter_mut()).enumerate() {
                        assert_eq!(*x, base + i, "misaligned chunk at width {width}");
                        *y = *x * 2;
                    }
                });
                for (i, &y) in b.iter().enumerate() {
                    assert_eq!(y, i * 2);
                }
            });
        }
    }

    #[test]
    fn grain_forces_serial_on_small_inputs() {
        with_thread_count(8, || {
            let calls = AtomicUsize::new(0);
            let mut data = vec![0u8; 63];
            for_each_chunk(&mut data, 32, |_, _| {
                calls.fetch_add(1, Ordering::SeqCst);
            });
            // 63 / 32 = 1 worker's worth of work -> one serial call.
            assert_eq!(calls.load(Ordering::SeqCst), 1);
        });
    }

    #[test]
    fn empty_slice_is_a_single_serial_call() {
        let calls = AtomicUsize::new(0);
        let mut data: Vec<u8> = Vec::new();
        for_each_chunk(&mut data, 1, |base, chunk| {
            assert_eq!(base, 0);
            assert!(chunk.is_empty());
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn override_nests_and_restores() {
        with_thread_count(3, || {
            assert_eq!(num_threads(), 3);
            with_thread_count(7, || assert_eq!(num_threads(), 7));
            assert_eq!(num_threads(), 3);
        });
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn chunk2_rejects_mismatched_lengths() {
        let mut a = vec![0u8; 4];
        let mut b = vec![0u8; 5];
        for_each_chunk2(&mut a, &mut b, 1, |_, _, _| {});
    }
}
