//! Zero-dependency data parallelism over slices, dispatched to a
//! **persistent parked worker pool**.
//!
//! The offline build has no `rayon`, so this module hand-rolls the one
//! shape the simulator needs: *static chunking* of one or two equal
//! slices across worker threads, with the caller's thread working the
//! first chunk. There is no work stealing; every parallel region is a
//! strict fork-join (nothing outlives the call).
//!
//! # Dispatchers
//!
//! Two interchangeable dispatchers drive the same chunk bodies:
//!
//! * [`Dispatch::Pooled`] (default) — long-lived workers, spawned
//!   lazily up to `MAX_THREADS - 1` and *parked on a condvar* between
//!   regions. A fork is one generation-stamped job broadcast: the
//!   submitter publishes a stack pointer to the chunk closure, bumps the
//!   generation, wakes exactly the participating workers (per-worker
//!   condvars), works chunk 0 itself, and parks on a second condvar
//!   until every participating worker has finished. No
//!   threads are spawned and **nothing is allocated** in steady state
//!   (`tests/alloc_free.rs` asserts this with the pool active), which
//!   removes the ~15–25 µs/spawn fork tax that bounded speedup on
//!   sub-millisecond rounds.
//! * [`Dispatch::Spawn`] — the legacy spawn-per-fork dispatcher
//!   (`std::thread::scope`), kept as the measurable baseline: select it
//!   with `SAFA_DISPATCH=spawn` for A/B bench runs, or per call tree
//!   with [`with_dispatch`]. `benches/microbench_hotpath.rs` quantifies
//!   the dispatch-latency gap with an empty-body [`fork`].
//!
//! # Width selection
//!
//! [`num_threads`] resolves, in priority order:
//! 1. a scoped [`with_thread_count`] override on the current thread
//!    (tests and the thread-scaling benches),
//! 2. the `SAFA_THREADS` environment variable (parsed once; a value
//!    that is not a positive integer is rejected with a one-shot
//!    warning, matching `ChurnModel::from_parts` strictness),
//! 3. `std::thread::available_parallelism()`.
//!
//! A chunked call additionally degrades to serial when the slice is
//! shorter than `grain` elements per worker, so tiny inputs (unit-test
//! fleets, dim-1 Null models) never pay a dispatch.
//!
//! # Determinism contract
//!
//! Every helper here applies `f` to *disjoint, contiguous* chunks whose
//! element indices are independent of the width and of the dispatcher:
//! `f(base, chunk)` sees the same `(index, element)` pairs whether the
//! call ran on 1 thread or 8, pooled or spawned. As long as `f` computes
//! each element independently (no cross-chunk reduction), results are
//! bit-for-bit identical across widths — the property the engine's
//! determinism tests assert. Reductions must NOT be accumulated across
//! chunks in completion order; compute per-element values in parallel
//! and fold them serially in index order instead.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};

/// Hard cap on the fork width — also the worker-slot count of the
/// persistent pool (workers are spawned lazily, so an absurd
/// `SAFA_THREADS` costs at most this many parked threads).
pub const MAX_THREADS: usize = 256;

thread_local! {
    /// 0 = no override active.
    static WIDTH_OVERRIDE: Cell<usize> = const { Cell::new(0) };
    /// `None` = use the process-wide `SAFA_DISPATCH` mode.
    static DISPATCH_OVERRIDE: Cell<Option<Dispatch>> = const { Cell::new(None) };
    /// Pool identity: 0 for ordinary threads, `i + 1` for pool worker `i`.
    static WORKER_ID: Cell<usize> = const { Cell::new(0) };
    /// True while this thread is executing a pooled chunk body (the
    /// submitter's own chunk included). A nested [`fork`] must not
    /// re-enter [`broadcast`] — the submit lock is already held and the
    /// parked fleet may be the very threads waiting on us — so it runs
    /// its chunks in place instead (see `fork`).
    static IN_POOLED_REGION: Cell<bool> = const { Cell::new(false) };
}

/// Scoped thread-local override: set `key` to `val` for the duration
/// of `f`, restoring the prior value on exit (including unwinds). The
/// one implementation under [`with_thread_count`], [`with_dispatch`]
/// and [`enter_pooled_region`].
fn with_tls<T: Copy + 'static, R>(
    key: &'static std::thread::LocalKey<Cell<T>>,
    val: T,
    f: impl FnOnce() -> R,
) -> R {
    struct Restore<T: Copy + 'static>(&'static std::thread::LocalKey<Cell<T>>, T);
    impl<T: Copy + 'static> Drop for Restore<T> {
        fn drop(&mut self) {
            self.0.with(|c| c.set(self.1));
        }
    }
    let prev = key.with(|c| c.replace(val));
    let _restore = Restore(key, prev);
    f()
}

/// Mark this thread as inside a pooled chunk body for the duration of
/// `f` (restored on exit, including unwinds).
fn enter_pooled_region<R>(f: impl FnOnce() -> R) -> R {
    with_tls(&IN_POOLED_REGION, true, f)
}

/// `SAFA_THREADS`, else available parallelism (read once per process).
/// A set-but-invalid value (`0`, garbage) is rejected loudly — one
/// warning through the `SAFA_LOG` machinery — instead of silently
/// falling back.
fn configured_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        let fallback = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_THREADS);
        match std::env::var("SAFA_THREADS") {
            Ok(v) => match v.trim().parse::<usize>() {
                Ok(0) | Err(_) => {
                    crate::log_warn!(
                        "SAFA_THREADS={v:?} is not a positive integer; \
                         using available parallelism ({fallback})"
                    );
                    fallback
                }
                Ok(n) if n > MAX_THREADS => {
                    crate::log_warn!(
                        "SAFA_THREADS={n} exceeds the pool cap; clamping to {MAX_THREADS}"
                    );
                    MAX_THREADS
                }
                Ok(n) => n,
            },
            Err(_) => fallback,
        }
    })
}

/// The fork width the next parallel call on this thread will use.
pub fn num_threads() -> usize {
    let o = WIDTH_OVERRIDE.with(|c| c.get());
    if o >= 1 {
        o.min(MAX_THREADS)
    } else {
        configured_threads()
    }
}

/// Pin the fork width to `n` for the duration of `f` on this thread
/// (restored on exit, including unwinds). Used by the determinism tests
/// and `benches/fleet_scale.rs` to sweep widths inside one process.
pub fn with_thread_count<R>(n: usize, f: impl FnOnce() -> R) -> R {
    with_tls(&WIDTH_OVERRIDE, n.clamp(1, MAX_THREADS), f)
}

/// How parallel regions hand chunks to worker threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// Persistent parked workers, woken by a generation-stamped job
    /// broadcast (the default).
    Pooled,
    /// Legacy spawn-per-fork over `std::thread::scope` — the measurable
    /// baseline (`SAFA_DISPATCH=spawn`).
    Spawn,
}

/// `SAFA_DISPATCH` (`pooled` | `spawn`), read once per process.
fn configured_dispatch() -> Dispatch {
    static D: OnceLock<Dispatch> = OnceLock::new();
    *D.get_or_init(|| match std::env::var("SAFA_DISPATCH") {
        Ok(v) if v.eq_ignore_ascii_case("spawn") => Dispatch::Spawn,
        Ok(v) if v.eq_ignore_ascii_case("pooled") => Dispatch::Pooled,
        Ok(v) => {
            crate::log_warn!(
                "SAFA_DISPATCH={v:?} is neither \"pooled\" nor \"spawn\"; \
                 using the pooled dispatcher"
            );
            Dispatch::Pooled
        }
        Err(_) => Dispatch::Pooled,
    })
}

/// The dispatcher the next parallel call on this thread will use.
pub fn dispatch_mode() -> Dispatch {
    DISPATCH_OVERRIDE
        .with(|c| c.get())
        .unwrap_or_else(configured_dispatch)
}

/// Pin the dispatcher for the duration of `f` on this thread (restored
/// on exit, including unwinds). Lets one bench process A/B the pooled
/// and spawn dispatchers.
pub fn with_dispatch<R>(d: Dispatch, f: impl FnOnce() -> R) -> R {
    with_tls(&DISPATCH_OVERRIDE, Some(d), f)
}

/// Stable pool identity of the current thread: 0 for any ordinary
/// thread (the submitter, which works chunk 0), `i + 1` for pool worker
/// `i` — i.e. the chunk index this thread runs in a full-width fork.
/// `util::scratch` uses it to give every worker a preferred scratch
/// slot so steady-state parallel training reuses warm buffers.
pub fn worker_id() -> usize {
    WORKER_ID.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// The persistent pool.
// ---------------------------------------------------------------------------

/// Type-erased pointer to the submitting call's stack-held chunk
/// closure, plus its monomorphized call shim. Only dereferenced by pool
/// workers while the submitter blocks in [`broadcast`], so the pointee
/// is always alive.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
}

// SAFETY: see the `Job` docs — the pointee outlives every dereference
// because the submitter joins the broadcast before returning, and the
// closure is `Sync` (enforced by `broadcast`'s bound), so shared calls
// from many workers are sound.
unsafe impl Send for Job {}

struct PoolState {
    /// Bumped once per broadcast; workers park until it changes.
    generation: u64,
    job: Option<Job>,
    /// Worker indices `< active` participate in the current generation.
    active: usize,
    /// Participating workers that have not finished the current job.
    remaining: usize,
    /// First panic payload from a worker's chunk body this generation —
    /// resume-unwound on the submitter, so the Pooled dispatcher
    /// propagates the *original* panic exactly like the Spawn one
    /// (allocates only on the panic path).
    panic: Option<Box<dyn std::any::Any + Send>>,
    /// Workers spawned so far (grown on demand, never shrunk).
    spawned: usize,
}

struct Pool {
    state: Mutex<PoolState>,
    /// Per-worker park spots (one condvar each, all paired with
    /// `state`): a broadcast wakes exactly the participating workers,
    /// so narrow forks stay cheap after a wide fork has grown the
    /// fleet.
    work: Box<[Condvar]>,
    /// The submitter parks here until `remaining == 0`.
    done: Condvar,
    /// Serializes broadcasts from independent caller threads.
    submit: Mutex<()>,
}

/// Ignore mutex poisoning: pool state is only ever written under the
/// lock by non-panicking sections (worker panics are caught before the
/// re-lock), so a poisoned guard's data is still consistent.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        state: Mutex::new(PoolState {
            generation: 0,
            job: None,
            active: 0,
            remaining: 0,
            panic: None,
            spawned: 0,
        }),
        work: (0..MAX_THREADS - 1).map(|_| Condvar::new()).collect(),
        done: Condvar::new(),
        submit: Mutex::new(()),
    })
}

unsafe fn call_shim<F: Fn(usize) + Sync>(data: *const (), chunk: usize) {
    (*(data as *const F))(chunk)
}

fn worker_loop(index: usize) {
    WORKER_ID.with(|c| c.set(index + 1));
    // A pool worker only ever runs chunk bodies, so it is permanently
    // "inside a pooled region": a nested fork from its chunk must run
    // in place, never re-enter the pool.
    IN_POOLED_REGION.with(|c| c.set(true));
    let p = pool();
    let mut seen = 0u64;
    let mut state = lock(&p.state);
    loop {
        while state.generation == seen {
            state = wait(&p.work[index], state);
        }
        seen = state.generation;
        if index < state.active {
            let job = state.job.expect("active generation carries a job");
            drop(state);
            // Worker `index` owns chunk `index + 1` (the submitter works
            // chunk 0). catch_unwind keeps a panicking chunk body from
            // deadlocking the submitter; the payload is re-raised there.
            let result = catch_unwind(AssertUnwindSafe(|| unsafe {
                (job.call)(job.data, index + 1);
            }));
            state = lock(&p.state);
            if let Err(payload) = result {
                // Keep the first payload only.
                if state.panic.is_none() {
                    state.panic = Some(payload);
                }
            }
            state.remaining -= 1;
            if state.remaining == 0 {
                p.done.notify_one();
            }
        }
    }
}

/// Pooled dispatch of `f(0..width)`: one park/wake broadcast, the
/// calling thread working chunk 0, returning after every chunk
/// completes. Steady state (workers already spawned) allocates nothing.
fn broadcast<F: Fn(usize) + Sync>(width: usize, f: &F) {
    let p = pool();
    let _submit = lock(&p.submit);
    let helpers = width - 1;
    {
        let mut state = lock(&p.state);
        // Grow the fleet on demand (one-time warm-up cost per worker).
        while state.spawned < helpers {
            let index = state.spawned;
            std::thread::Builder::new()
                .name(format!("safa-pool-{index}"))
                .spawn(move || worker_loop(index))
                .expect("spawn pool worker");
            state.spawned += 1;
        }
        state.job = Some(Job {
            data: f as *const F as *const (),
            call: call_shim::<F>,
        });
        state.active = helpers;
        state.remaining = helpers;
        state.generation = state.generation.wrapping_add(1);
        drop(state);
        // Wake exactly the participants — after releasing the state
        // lock, so a woken worker never bounces straight back onto a
        // mutex the submitter still holds. Workers beyond `helpers`
        // stay parked (they skip this generation entirely — safe,
        // since only participating workers are counted in
        // `remaining`), and no wakeup can be lost: the generation was
        // bumped under the lock, and workers re-check it under the
        // lock.
        for cv in &p.work[..helpers] {
            cv.notify_one();
        }
    }

    // Join-on-drop guard: even if the submitter's own chunk panics, the
    // workers (which borrow the submitter's stack) finish before the
    // unwind can invalidate what they read.
    struct Join(&'static Pool);
    impl Drop for Join {
        fn drop(&mut self) {
            let mut state = lock(&self.0.state);
            while state.remaining != 0 {
                state = wait(&self.0.done, state);
            }
            state.job = None;
            let panic = state.panic.take();
            drop(state);
            if let Some(payload) = panic {
                // Re-raise the worker's original panic (unless already
                // unwinding from the submitter's own chunk).
                if !std::thread::panicking() {
                    std::panic::resume_unwind(payload);
                }
            }
        }
    }
    let join = Join(p);
    enter_pooled_region(|| f(0));
    drop(join);
}

/// Legacy dispatcher: spawn `width - 1` scoped threads per fork.
fn spawn_broadcast<F: Fn(usize) + Sync>(width: usize, f: &F) {
    std::thread::scope(|s| {
        for i in 1..width {
            s.spawn(move || f(i));
        }
        f(0);
    });
}

/// Dispatch `f(i)` for `i in 0..width` — `f(0)` on the calling thread —
/// through the active dispatcher, joining before returning. The raw
/// fork primitive under [`for_each_chunk`]; public so the dispatch-
/// latency microbench can time an empty-body fork. A no-op when
/// `width == 0` (the range is empty), serial when `width == 1`; panics
/// if `width > MAX_THREADS` (indices must never be silently skipped).
///
/// Re-entrancy: a `fork` issued from inside a pooled chunk body runs
/// its chunks serially in place (same indices, same coverage) instead
/// of re-entering the pool — the submit lock is held for the enclosing
/// region and the parked workers may be the very threads waiting on
/// the caller, so a nested broadcast would deadlock. (The chunked
/// helpers additionally pin the width to 1 inside bodies, so nested
/// *chunked* calls degrade before even reaching this point.) The guard
/// is **thread-local**: do not call a pooled `fork` from a thread you
/// spawned *inside* a chunk body — that thread cannot know it is
/// transitively inside the enclosing broadcast, and blocking on the
/// submit lock from there deadlocks. Chunk bodies should not spawn
/// threads at all; use nested (serial) forks on the same thread.
pub fn fork<F: Fn(usize) + Sync>(width: usize, f: F) {
    assert!(
        width <= MAX_THREADS,
        "fork width {width} exceeds MAX_THREADS ({MAX_THREADS})"
    );
    if width == 0 {
        return; // 0..0 is empty: no calls
    }
    if width == 1 {
        f(0);
        return;
    }
    // Telemetry (no-ops unless enabled): the span charges the submitter's
    // wall time across the whole dispatch — workers run concurrently, so
    // the `fork_dispatch` phase reads as time spent *inside* parallel
    // regions, not CPU time.
    let _span = crate::telemetry::span(crate::telemetry::Phase::ForkDispatch);
    crate::telemetry::count(crate::telemetry::Counter::Forks, 1);
    crate::telemetry::count(crate::telemetry::Counter::Chunks, width as u64);
    match dispatch_mode() {
        Dispatch::Pooled => {
            if IN_POOLED_REGION.with(|c| c.get()) {
                for i in 0..width {
                    f(i);
                }
            } else {
                broadcast(width, &f);
            }
        }
        Dispatch::Spawn => spawn_broadcast(width, &f),
    }
}

// ---------------------------------------------------------------------------
// Static chunking over slices.
// ---------------------------------------------------------------------------

/// Width actually used for `len` elements at `grain` elements minimum
/// per worker.
fn width_for(len: usize, grain: usize) -> usize {
    let by_work = len / grain.max(1);
    num_threads().min(by_work).max(1)
}

/// Static chunk geometry shared by [`for_each_chunk`] and
/// [`for_each_chunk2`]: contiguous chunks of `len.div_ceil(width)`
/// elements (the last possibly short), with the width shrunk to the
/// populated chunk count so no worker sees an empty slice. Boundaries
/// depend only on `(len, width)` — never on which thread runs a chunk —
/// which is what keeps results bit-for-bit width-invariant.
#[derive(Debug, Clone, Copy)]
struct Splitter {
    len: usize,
    chunk: usize,
    width: usize,
}

impl Splitter {
    fn new(len: usize, width: usize) -> Splitter {
        debug_assert!(len >= 1 && width >= 1);
        let chunk = len.div_ceil(width);
        // Ceil division can leave trailing chunks empty (len 6 at width
        // 4 → chunks of 2 → only 3 populated); shrink to match.
        Splitter {
            len,
            chunk,
            width: len.div_ceil(chunk),
        }
    }

    /// Element range of chunk `i`.
    fn bounds(&self, i: usize) -> (usize, usize) {
        let start = i * self.chunk;
        (start, (start + self.chunk).min(self.len))
    }
}

/// A `&mut`-slice base pointer that may cross threads. Sound because
/// every chunk body receives a disjoint index range (see [`Splitter`]),
/// so no two threads ever alias.
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Apply `f(base_index, chunk)` to contiguous chunks of `data` across
/// the pool. Serial (`f(0, data)`) when the input is shorter than
/// `2 * grain` or only one thread is configured.
pub fn for_each_chunk<T, F>(data: &mut [T], grain: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let len = data.len();
    let width = width_for(len, grain);
    if width <= 1 {
        f(0, data);
        return;
    }
    let split = Splitter::new(len, width);
    let ptr = SendPtr(data.as_mut_ptr());
    fork(split.width, |i| {
        let (start, end) = split.bounds(i);
        // SAFETY: chunk ranges are disjoint per index and `data`
        // outlives the fork (both dispatchers join before returning).
        let chunk = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(start), end - start) };
        // Chunk bodies run with the width pinned to 1 so a nested
        // parallel call (e.g. `ParamVec::copy_from` inside a per-client
        // pass) degrades to serial instead of re-entering the
        // dispatcher. Serial fallbacks above leave the width untouched,
        // so an un-forked outer loop still lets inner kernels fork.
        with_thread_count(1, || f(start, chunk));
    });
}

/// Like [`for_each_chunk`] over two equal-length slices chunked at
/// identical boundaries: `f(base_index, a_chunk, b_chunk)`.
pub fn for_each_chunk2<A, B, F>(a: &mut [A], b: &mut [B], grain: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert_eq!(a.len(), b.len(), "for_each_chunk2: length mismatch");
    let len = a.len();
    let width = width_for(len, grain);
    if width <= 1 {
        f(0, a, b);
        return;
    }
    let split = Splitter::new(len, width);
    let pa = SendPtr(a.as_mut_ptr());
    let pb = SendPtr(b.as_mut_ptr());
    fork(split.width, |i| {
        let (start, end) = split.bounds(i);
        // SAFETY: as in `for_each_chunk`; both slices use the same
        // disjoint ranges.
        let ca = unsafe { std::slice::from_raw_parts_mut(pa.0.add(start), end - start) };
        let cb = unsafe { std::slice::from_raw_parts_mut(pb.0.add(start), end - start) };
        // Width pinned to 1 inside chunk bodies — see for_each_chunk.
        with_thread_count(1, || f(start, ca, cb));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_every_index_exactly_once() {
        for dispatch in [Dispatch::Pooled, Dispatch::Spawn] {
            with_dispatch(dispatch, || {
                for width in [1, 2, 3, 8, 17] {
                    with_thread_count(width, || {
                        let mut data = vec![0u32; 1003];
                        for_each_chunk(&mut data, 1, |base, chunk| {
                            for (i, x) in chunk.iter_mut().enumerate() {
                                *x += (base + i) as u32 + 1;
                            }
                        });
                        for (i, &x) in data.iter().enumerate() {
                            assert_eq!(x, i as u32 + 1, "index {i} at width {width}");
                        }
                    });
                }
            });
        }
    }

    #[test]
    fn chunk2_keeps_slices_aligned() {
        for width in [1, 3, 8] {
            with_thread_count(width, || {
                let mut a: Vec<usize> = (0..517).collect();
                let mut b = vec![0usize; 517];
                for_each_chunk2(&mut a, &mut b, 1, |base, ca, cb| {
                    for (i, (x, y)) in ca.iter_mut().zip(cb.iter_mut()).enumerate() {
                        assert_eq!(*x, base + i, "misaligned chunk at width {width}");
                        *y = *x * 2;
                    }
                });
                for (i, &y) in b.iter().enumerate() {
                    assert_eq!(y, i * 2);
                }
            });
        }
    }

    #[test]
    fn fork_runs_every_chunk_once_on_both_dispatchers() {
        for dispatch in [Dispatch::Pooled, Dispatch::Spawn] {
            with_dispatch(dispatch, || {
                // Many consecutive forks: steady-state pool reuse, not
                // just the warm-up broadcast.
                for _ in 0..50 {
                    let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
                    fork(5, |i| {
                        hits[i].fetch_add(1, Ordering::SeqCst);
                    });
                    for (i, h) in hits.iter().enumerate() {
                        assert_eq!(
                            h.load(Ordering::SeqCst),
                            1,
                            "{dispatch:?}: chunk {i} run count"
                        );
                    }
                }
            });
        }
    }

    #[test]
    fn nested_pooled_fork_runs_in_place_without_deadlock() {
        with_dispatch(Dispatch::Pooled, || {
            let hits: Vec<AtomicUsize> = (0..12).map(|_| AtomicUsize::new(0)).collect();
            fork(3, |outer| {
                // A nested fork inside a pooled chunk body (submitter
                // chunk 0 and pool workers alike) must not re-enter the
                // pool; it covers its indices serially in place.
                fork(4, |inner| {
                    hits[outer * 4 + inner].fetch_add(1, Ordering::SeqCst);
                });
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "slot {i}");
            }
        });
    }

    #[test]
    fn pool_workers_report_stable_ids() {
        // Chunk i runs on the thread whose worker_id() is i (0 = the
        // submitting thread), which is what gives WorkerScratch its
        // per-worker slot affinity.
        with_dispatch(Dispatch::Pooled, || {
            let ids: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(usize::MAX)).collect();
            fork(4, |i| {
                ids[i].store(worker_id(), Ordering::SeqCst);
            });
            for (i, id) in ids.iter().enumerate() {
                assert_eq!(id.load(Ordering::SeqCst), i, "chunk {i} worker id");
            }
        });
    }

    #[test]
    #[should_panic(expected = "boom in chunk 2")]
    fn worker_panic_propagates_with_its_original_payload() {
        with_dispatch(Dispatch::Pooled, || {
            fork(3, |i| {
                if i == 2 {
                    panic!("boom in chunk {i}");
                }
            });
        });
    }

    #[test]
    fn pool_survives_a_panicked_job() {
        with_dispatch(Dispatch::Pooled, || {
            let _ = catch_unwind(AssertUnwindSafe(|| {
                fork(3, |i| {
                    if i > 0 {
                        panic!("boom");
                    }
                });
            }));
            // The pool must still dispatch correctly afterwards.
            let hits = AtomicUsize::new(0);
            fork(3, |_| {
                hits.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(hits.load(Ordering::SeqCst), 3);
        });
    }

    #[test]
    fn grain_forces_serial_on_small_inputs() {
        with_thread_count(8, || {
            let calls = AtomicUsize::new(0);
            let mut data = vec![0u8; 63];
            for_each_chunk(&mut data, 32, |_, _| {
                calls.fetch_add(1, Ordering::SeqCst);
            });
            // 63 / 32 = 1 worker's worth of work -> one serial call.
            assert_eq!(calls.load(Ordering::SeqCst), 1);
        });
    }

    #[test]
    fn empty_slice_is_a_single_serial_call() {
        let calls = AtomicUsize::new(0);
        let mut data: Vec<u8> = Vec::new();
        for_each_chunk(&mut data, 1, |base, chunk| {
            assert_eq!(base, 0);
            assert!(chunk.is_empty());
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn override_nests_and_restores() {
        with_thread_count(3, || {
            assert_eq!(num_threads(), 3);
            with_thread_count(7, || assert_eq!(num_threads(), 7));
            assert_eq!(num_threads(), 3);
        });
    }

    #[test]
    fn dispatch_override_nests_and_restores() {
        let outer = dispatch_mode();
        with_dispatch(Dispatch::Spawn, || {
            assert_eq!(dispatch_mode(), Dispatch::Spawn);
            with_dispatch(Dispatch::Pooled, || {
                assert_eq!(dispatch_mode(), Dispatch::Pooled);
            });
            assert_eq!(dispatch_mode(), Dispatch::Spawn);
        });
        assert_eq!(dispatch_mode(), outer);
    }

    #[test]
    fn splitter_covers_len_without_empty_chunks() {
        for len in [1usize, 2, 5, 6, 7, 64, 1003] {
            for width in [1usize, 2, 3, 4, 8, 17] {
                let s = Splitter::new(len, width);
                assert!(s.width >= 1 && s.width <= width);
                let mut covered = 0;
                for i in 0..s.width {
                    let (a, b) = s.bounds(i);
                    assert!(a < b, "empty chunk {i} for len {len} width {width}");
                    assert_eq!(a, covered, "gap before chunk {i}");
                    covered = b;
                }
                assert_eq!(covered, len, "len {len} width {width} not covered");
            }
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn chunk2_rejects_mismatched_lengths() {
        let mut a = vec![0u8; 4];
        let mut b = vec![0u8; 5];
        for_each_chunk2(&mut a, &mut b, 1, |_, _, _| {});
    }
}
