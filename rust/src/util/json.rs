//! Minimal JSON value model, parser and writer.
//!
//! The offline environment has no `serde`/`serde_json`, so we implement a
//! small JSON codec from scratch. It is used for:
//!
//! * reading `artifacts/manifest.json` (shapes/dtypes emitted by the AOT
//!   pipeline) in `runtime::manifest`;
//! * writing experiment results (`results/*.json`) from the metrics layer.
//!
//! Scope: full JSON per RFC 8259 minus `\u` surrogate-pair edge cases
//! (escapes are decoded; astral-plane pairs are combined). Numbers are
//! held as f64, which is sufficient for manifests and metric dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; emit null (documented lossy behaviour).
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Error with byte offset for diagnostics.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // High surrogate: expect \uXXXX low surrogate.
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(
                                    char::from_u32(c)
                                        .ok_or_else(|| self.err("invalid codepoint"))?,
                                );
                            } else {
                                s.push(
                                    char::from_u32(cp)
                                        .ok_or_else(|| self.err("invalid codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 character.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Convenience: build a Json object from key/value pairs.
#[macro_export]
macro_rules! json_obj {
    ($($k:expr => $v:expr),* $(,)?) => {{
        let mut o = $crate::util::json::Json::obj();
        $( o.set($k, $v); )*
        o
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn pretty_output_parses_back() {
        let mut o = Json::obj();
        o.set("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)]));
        o.set("name", Json::Str("safa".into()));
        let pretty = o.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), o);
    }

    #[test]
    fn integers_serialized_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn exponent_numbers() {
        let v = Json::parse("1e3").unwrap();
        assert_eq!(v.as_f64(), Some(1000.0));
        let v = Json::parse("-2.5E-2").unwrap();
        assert!((v.as_f64().unwrap() + 0.025).abs() < 1e-12);
    }

    #[test]
    fn macro_builds_objects() {
        let o = json_obj! {"a" => Json::Num(1.0), "b" => Json::Bool(true)};
        assert_eq!(o.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(o.get("b").unwrap().as_bool(), Some(true));
    }
}
