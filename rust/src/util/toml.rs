//! Minimal TOML-subset parser for experiment config files.
//!
//! Supports the subset actually used by SAFA configs:
//! `[section]` headers, `key = value` pairs with string / integer / float /
//! boolean / homogeneous-array values, `#` comments, and bare or quoted
//! keys. Nested tables are flattened to dotted keys
//! (`[protocol]` + `tau = 5` → `protocol.tau`).

use std::collections::BTreeMap;
use std::fmt;

/// A TOML scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: flattened dotted-key → value map.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(TomlValue::as_str)
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(TomlValue::as_i64)
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(TomlValue::as_f64)
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(TomlValue::as_bool)
    }
}

#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TOML error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or(TomlError {
                line: lineno + 1,
                msg: "unterminated section header".into(),
            })?;
            let name = name.trim();
            if name.is_empty() || name.contains('[') {
                return Err(TomlError {
                    line: lineno + 1,
                    msg: "bad section name".into(),
                });
            }
            section = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or(TomlError {
            line: lineno + 1,
            msg: "expected 'key = value'".into(),
        })?;
        let key_raw = line[..eq].trim();
        let key = unquote_key(key_raw).ok_or(TomlError {
            line: lineno + 1,
            msg: format!("bad key '{key_raw}'"),
        })?;
        let val_text = line[eq + 1..].trim();
        let val = parse_value(val_text).map_err(|msg| TomlError {
            line: lineno + 1,
            msg,
        })?;
        let full_key = if section.is_empty() {
            key
        } else {
            format!("{section}.{key}")
        };
        doc.entries.insert(full_key, val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote_key(key: &str) -> Option<String> {
    if let Some(inner) = key.strip_prefix('"').and_then(|k| k.strip_suffix('"')) {
        return Some(inner.to_string());
    }
    if !key.is_empty()
        && key
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.')
    {
        Some(key.to_string())
    } else {
        None
    }
}

fn parse_value(text: &str) -> Result<TomlValue, String> {
    let text = text.trim();
    if text.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    _ => return Err("bad escape in string".into()),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    if text == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if text == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(TomlValue::Array(items));
    }
    // Number: integer if it parses as i64 and has no '.', 'e', 'E'.
    let clean = text.replace('_', "");
    if !clean.contains('.') && !clean.contains('e') && !clean.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    clean
        .parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| format!("cannot parse value '{text}'"))
}

/// Split on commas that are not inside nested brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_document() {
        let doc = parse(
            r#"
            # experiment config
            name = "task1"
            seed = 42

            [protocol]
            kind = "safa"
            tau = 5
            c_fraction = 0.3
            verbose = false
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("task1"));
        assert_eq!(doc.get_i64("seed"), Some(42));
        assert_eq!(doc.get_str("protocol.kind"), Some("safa"));
        assert_eq!(doc.get_i64("protocol.tau"), Some(5));
        assert_eq!(doc.get_f64("protocol.c_fraction"), Some(0.3));
        assert_eq!(doc.get_bool("protocol.verbose"), Some(false));
    }

    #[test]
    fn parses_arrays() {
        let doc = parse("xs = [0.1, 0.3, 0.5]\nnames = [\"a\", \"b\"]").unwrap();
        let xs = doc.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[1].as_f64(), Some(0.3));
        let names = doc.get("names").unwrap().as_array().unwrap();
        assert_eq!(names[0].as_str(), Some("a"));
    }

    #[test]
    fn comments_and_hash_in_strings() {
        let doc = parse("s = \"a#b\" # trailing").unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b"));
    }

    #[test]
    fn int_vs_float() {
        let doc = parse("a = 3\nb = 3.0\nc = 1e-4\nd = 1_000").unwrap();
        assert_eq!(doc.get("a"), Some(&TomlValue::Int(3)));
        assert_eq!(doc.get("b"), Some(&TomlValue::Float(3.0)));
        assert!((doc.get_f64("c").unwrap() - 1e-4).abs() < 1e-12);
        assert_eq!(doc.get_i64("d"), Some(1000));
        // Int coerces to f64 on demand.
        assert_eq!(doc.get_f64("a"), Some(3.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse("[unterminated").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn string_escapes() {
        let doc = parse(r#"s = "line1\nline2\t\"q\"""#).unwrap();
        assert_eq!(doc.get_str("s"), Some("line1\nline2\t\"q\""));
    }
}
