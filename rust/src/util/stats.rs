//! Small statistics toolkit used by the metrics layer and bench harness.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (divides by n); 0.0 for an empty slice.
///
/// The paper's Version Variance (Eq. 10) is a population variance over the
/// per-client version distribution, so this is the variant we expose.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Arithmetic mean of an iterator without collecting it; 0.0 when empty.
/// Accumulates a plain running sum — the same FP order as [`mean`] — so
/// summary methods that switch to this from a collect-then-`mean` pattern
/// keep bit-identical results.
pub fn mean_iter<I: IntoIterator<Item = f64>>(xs: I) -> f64 {
    let mut sum = 0.0;
    let mut n: u64 = 0;
    for x in xs {
        sum += x;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Population variance of an iterator without collecting it (single
/// Welford pass); 0.0 when empty. FP rounding differs from the two-pass
/// [`variance`] at the ~1e-12 level.
pub fn variance_iter<I: IntoIterator<Item = f64>>(xs: I) -> f64 {
    let mut r = Running::new();
    for x in xs {
        r.push(x);
    }
    r.variance()
}

/// Sample standard deviation (divides by n-1); 0.0 when n < 2.
pub fn stddev_sample(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Minimum; NaN-free inputs assumed. None for empty.
pub fn min(xs: &[f64]) -> Option<f64> {
    xs.iter().copied().reduce(f64::min)
}

/// Maximum. None for empty.
pub fn max(xs: &[f64]) -> Option<f64> {
    xs.iter().copied().reduce(f64::max)
}

/// Percentile via linear interpolation on the sorted copy. `p` in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        return Some(sorted[lo]);
    }
    let frac = rank - lo as f64;
    Some(sorted[lo] * (1.0 - frac) + sorted[hi] * frac)
}

/// Median.
pub fn median(xs: &[f64]) -> Option<f64> {
    percentile(xs, 50.0)
}

/// Simple OLS fit y = a + b*x. Returns (a, b). None if degenerate.
pub fn linreg(xs: &[f64], ys: &[f64]) -> Option<(f64, f64)> {
    if xs.len() != ys.len() || xs.len() < 2 {
        return None;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if sxx == 0.0 {
        return None;
    }
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let b = sxy / sxx;
    Some((my - b * mx, b))
}

/// Running (Welford) accumulator for streaming mean/variance.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Running {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance of the values pushed so far.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(variance(&xs), 1.25);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(variance(&[7.0]), 0.0);
    }

    #[test]
    fn iter_variants_match_slice_versions() {
        let xs = [0.5, 1.5, -2.0, 4.0, 3.25];
        // mean_iter sums in the same order as mean: bit-identical.
        assert_eq!(mean_iter(xs.iter().copied()), mean(&xs));
        assert!((variance_iter(xs.iter().copied()) - variance(&xs)).abs() < 1e-12);
        assert_eq!(mean_iter(std::iter::empty()), 0.0);
        assert_eq!(variance_iter(std::iter::empty()), 0.0);
        assert_eq!(variance_iter([7.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), Some(1.0));
        assert_eq!(percentile(&xs, 100.0), Some(4.0));
        assert_eq!(median(&xs), Some(2.5));
        assert_eq!(percentile(&[], 50.0), None);
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 + 3.0 * x).collect();
        let (a, b) = linreg(&xs, &ys).unwrap();
        assert!((a - 2.0).abs() < 1e-9);
        assert!((b - 3.0).abs() < 1e-9);
        assert!(linreg(&[1.0, 1.0], &[2.0, 3.0]).is_none());
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [0.5, 1.5, -2.0, 4.0, 3.25];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(r.count(), 5);
    }

    #[test]
    fn sample_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        // Known example: population sigma = 2, sample s ~ 2.138.
        assert!((stddev_sample(&xs) - 2.13808993).abs() < 1e-6);
        assert_eq!(stddev_sample(&[1.0]), 0.0);
    }
}
