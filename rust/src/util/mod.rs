//! Substrate utilities built from scratch for the offline environment:
//! RNG + distributions, JSON/TOML codecs, stats, logging, CLI parsing,
//! a property-testing mini-framework, a persistent parked worker pool
//! and per-worker scratch slots.

pub mod cli;
pub mod json;
pub mod logging;
pub mod parallel;
pub mod proptest;
pub mod rng;
pub mod scratch;
pub mod stats;
pub mod toml;
