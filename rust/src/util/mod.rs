//! Substrate utilities built from scratch for the offline environment:
//! RNG + distributions, JSON/TOML codecs, stats, logging, CLI parsing and
//! a property-testing mini-framework.

pub mod cli;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod toml;
