//! Rounds/sec profiling runner: the single harness every perf PR quotes
//! before/after numbers from.
//!
//! Sweeps protocol × churn × m over the timing-only Null backend (Task-3
//! environment shape) and reports, per cell: rounds/sec, events/sec,
//! per-phase wall-time shares from the telemetry spans, and bytes moved
//! per round from the comm-cost accounting. Shared by the `safa profile`
//! CLI subcommand and `benches/profile_runner.rs`; JSON output follows
//! the established `BENCH_*.json` schema (`{name, mean_ns, stddev_ns,
//! min_ns, max_ns, iters}` plus profiling extras).

use std::fmt::Write as _;
use std::time::Instant;

use super::hist::HistMetric;
use super::{set_enabled, snapshot, Counter, Phase, NUM_PHASES};
use crate::bench_harness::write_results_file;
use crate::config::{presets, Backend, ChurnModel, ProtocolKind};
use crate::error::Result;
use crate::protocol::{make_protocol, FedEnv};
use crate::util::json::Json;
use crate::util::stats;

/// Churn axis of the profiling grid. `Markov` uses the preset helper's
/// dwell times (0.6/0.25 × T_lim) so cells match the `*-churn` presets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProfileChurn {
    Bernoulli,
    Markov,
}

impl ProfileChurn {
    pub const ALL: [ProfileChurn; 2] = [ProfileChurn::Bernoulli, ProfileChurn::Markov];

    pub fn name(self) -> &'static str {
        match self {
            ProfileChurn::Bernoulli => "bernoulli",
            ProfileChurn::Markov => "markov",
        }
    }

    pub fn parse(s: &str) -> Option<ProfileChurn> {
        match s.to_ascii_lowercase().as_str() {
            "bernoulli" => Some(ProfileChurn::Bernoulli),
            "markov" => Some(ProfileChurn::Markov),
            _ => None,
        }
    }
}

/// Network-fabric axis of the profiling grid. `Off` is the closed-form
/// Eq. 19 network (the historical cells, names unchanged); `Contended`
/// applies the `contended` preset's fabric (FIFO server link, lognormal
/// client links, latency/jitter/loss) to measure the event-fabric tax.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProfileFabric {
    Off,
    Contended,
}

impl ProfileFabric {
    pub const ALL: [ProfileFabric; 2] = [ProfileFabric::Off, ProfileFabric::Contended];

    pub fn name(self) -> &'static str {
        match self {
            ProfileFabric::Off => "off",
            ProfileFabric::Contended => "contended",
        }
    }

    pub fn parse(s: &str) -> Option<ProfileFabric> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Some(ProfileFabric::Off),
            "contended" => Some(ProfileFabric::Contended),
            _ => None,
        }
    }
}

/// One profiling sweep: the grid plus per-cell round counts.
#[derive(Debug, Clone)]
pub struct ProfileSpec {
    pub protocols: Vec<ProtocolKind>,
    pub churns: Vec<ProfileChurn>,
    pub fabrics: Vec<ProfileFabric>,
    pub m_values: Vec<usize>,
    /// Timed rounds per cell.
    pub rounds: usize,
    /// Untimed warm-up rounds per cell (pool spawn, buffer growth).
    pub warmup: usize,
}

impl Default for ProfileSpec {
    fn default() -> Self {
        ProfileSpec {
            protocols: ProtocolKind::ALL.to_vec(),
            churns: ProfileChurn::ALL.to_vec(),
            // Fabric off by default: the historical grid (and its cell
            // names) stays comparable across bench revisions.
            fabrics: vec![ProfileFabric::Off],
            m_values: vec![100],
            rounds: 30,
            warmup: 5,
        }
    }
}

/// Measured numbers for one (protocol, churn, m) cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// `profile_<protocol>_<churn>_m<m>` — the BENCH-schema name.
    pub name: String,
    pub protocol: ProtocolKind,
    pub churn: ProfileChurn,
    pub fabric: ProfileFabric,
    pub m: usize,
    /// Timed rounds (BENCH-schema `iters`).
    pub rounds: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub rounds_per_sec: f64,
    /// Fleet-engine events popped per wall second.
    pub events_per_sec: f64,
    /// Mean bytes distributed (downlink) per round.
    pub bytes_down_per_round: f64,
    /// Mean bytes uploaded per round.
    pub bytes_up_per_round: f64,
    /// Per-phase span time over wall time, [`Phase::ALL`] order. The
    /// `fork_dispatch` share measures wall time spent inside parallel
    /// dispatches (its workers run concurrently), so shares are CPU-style
    /// and need not sum to 1.
    pub share: [f64; NUM_PHASES],
    /// Simulated round-duration percentiles (ms, log2-bucket midpoint)
    /// over the timed rounds.
    pub round_ms_p50: u64,
    pub round_ms_p90: u64,
    pub round_ms_p99: u64,
}

impl CellResult {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name.clone()));
        o.set("mean_ns", Json::Num(self.mean_ns));
        o.set("stddev_ns", Json::Num(self.stddev_ns));
        o.set("min_ns", Json::Num(self.min_ns));
        o.set("max_ns", Json::Num(self.max_ns));
        o.set("iters", Json::Num(self.rounds as f64));
        o.set("protocol", Json::Str(self.protocol.name().to_string()));
        o.set("churn", Json::Str(self.churn.name().to_string()));
        o.set("fabric", Json::Str(self.fabric.name().to_string()));
        o.set("m", Json::Num(self.m as f64));
        o.set("rounds_per_sec", Json::Num(self.rounds_per_sec));
        o.set("events_per_sec", Json::Num(self.events_per_sec));
        o.set("bytes_down_per_round", Json::Num(self.bytes_down_per_round));
        o.set("bytes_up_per_round", Json::Num(self.bytes_up_per_round));
        for p in Phase::ALL {
            o.set(
                &format!("share_{}", p.name()),
                Json::Num(self.share[p.idx()]),
            );
        }
        o.set("round_ms_p50", Json::Num(self.round_ms_p50 as f64));
        o.set("round_ms_p90", Json::Num(self.round_ms_p90 as f64));
        o.set("round_ms_p99", Json::Num(self.round_ms_p99 as f64));
        o
    }
}

/// Cell config: Task-3 environment shape on the timing-only Null backend
/// (the profiling grid measures simulator throughput, not numerics), with
/// `n` scaled to the fleet so the Gaussian partitioner stays meaningful.
fn cell_config(
    protocol: ProtocolKind,
    churn: ProfileChurn,
    fabric: ProfileFabric,
    m: usize,
) -> Result<crate::config::ExperimentConfig> {
    let mut cfg = presets::preset("task3")?;
    // Fabric-off cells keep their historical names so bench series stay
    // comparable; contended cells get an explicit suffix.
    let fabric_suffix = match fabric {
        ProfileFabric::Off => String::new(),
        ProfileFabric::Contended => format!("_{}", fabric.name()),
    };
    cfg.name = format!(
        "profile_{}_{}{fabric_suffix}_m{m}",
        protocol.name().to_ascii_lowercase(),
        churn.name()
    );
    cfg.protocol.kind = protocol;
    cfg.env.m = m;
    cfg.task.n = (10 * m).max(1000);
    cfg.task.n_test = 100;
    cfg.backend = Backend::Null;
    cfg.eval_every = 1_000_000; // throughput study: never evaluate
    cfg.seed = 1;
    if churn == ProfileChurn::Markov {
        cfg.env.churn = ChurnModel::Markov {
            mean_uptime_s: cfg.train.t_lim * 0.6,
            mean_downtime_s: cfg.train.t_lim * 0.25,
        };
    }
    if fabric == ProfileFabric::Contended {
        // Same fabric shape as the `contended` preset, so the profile
        // cell and the preset stay one definition.
        cfg.env.fabric = presets::preset("contended")?.env.fabric;
    }
    Ok(cfg)
}

/// Run one cell: `warmup` untimed rounds, then `rounds` timed rounds with
/// telemetry force-enabled (prior enable state restored on exit).
/// Telemetry never perturbs results — the determinism suite holds the
/// simulation bit-identical with it on or off — so forcing it here only
/// costs the clock reads it is measuring.
pub fn run_cell(
    protocol: ProtocolKind,
    churn: ProfileChurn,
    fabric: ProfileFabric,
    m: usize,
    rounds: usize,
    warmup: usize,
) -> Result<CellResult> {
    assert!(rounds > 0, "profile cell needs at least one timed round");
    let cfg = cell_config(protocol, churn, fabric, m)?;
    let mut env = FedEnv::new(&cfg)?;
    let mut proto = make_protocol(&env);

    let prior = super::enabled();
    set_enabled(true);
    for t in 1..=warmup {
        proto.run_round(t, &mut env);
    }

    let before = snapshot();
    let mut sample_ns: Vec<f64> = Vec::with_capacity(rounds);
    let mut bytes_down = 0.0;
    let mut bytes_up = 0.0;
    for t in warmup + 1..=warmup + rounds {
        let start = Instant::now();
        let rec = proto.run_round(t, &mut env);
        sample_ns.push(start.elapsed().as_nanos() as f64);
        bytes_down += rec.bytes_down;
        bytes_up += rec.bytes_up;
    }
    let delta = snapshot().since(&before);
    set_enabled(prior);

    let wall_ns: f64 = sample_ns.iter().sum();
    let wall_s = wall_ns / 1e9;
    let mut share = [0.0; NUM_PHASES];
    for p in Phase::ALL {
        share[p.idx()] = if wall_ns > 0.0 {
            delta.phase_ns(p) as f64 / wall_ns
        } else {
            0.0
        };
    }
    Ok(CellResult {
        name: cfg.name.clone(),
        protocol,
        churn,
        fabric,
        m,
        rounds,
        mean_ns: stats::mean(&sample_ns),
        stddev_ns: stats::stddev_sample(&sample_ns),
        min_ns: stats::min(&sample_ns).unwrap_or(0.0),
        max_ns: stats::max(&sample_ns).unwrap_or(0.0),
        rounds_per_sec: if wall_s > 0.0 {
            rounds as f64 / wall_s
        } else {
            0.0
        },
        events_per_sec: if wall_s > 0.0 {
            delta.counter(Counter::EventsPopped) as f64 / wall_s
        } else {
            0.0
        },
        bytes_down_per_round: bytes_down / rounds as f64,
        bytes_up_per_round: bytes_up / rounds as f64,
        share,
        round_ms_p50: delta.hists.percentile(HistMetric::RoundDurationMs, 0.50),
        round_ms_p90: delta.hists.percentile(HistMetric::RoundDurationMs, 0.90),
        round_ms_p99: delta.hists.percentile(HistMetric::RoundDurationMs, 0.99),
    })
}

/// Run the full grid, one cell at a time (cells share the process-global
/// worker pool, so they must not overlap).
pub fn run_spec(spec: &ProfileSpec) -> Result<Vec<CellResult>> {
    let mut cells = Vec::new();
    for &m in &spec.m_values {
        for &fabric in &spec.fabrics {
            for &churn in &spec.churns {
                for &protocol in &spec.protocols {
                    cells.push(run_cell(protocol, churn, fabric, m, spec.rounds, spec.warmup)?);
                }
            }
        }
    }
    Ok(cells)
}

/// Fixed-width table over the grid: throughput, comm cost, and the
/// dominant phase shares.
pub fn render_table(cells: &[CellResult]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<34} {:>10} {:>11} {:>9} {:>9} {:>8} {:>8} {:>8} {:>6} {:>6} {:>6} {:>6} {:>6}",
        "cell",
        "rounds/s",
        "events/s",
        "KB down",
        "KB up",
        "simp50ms",
        "simp90ms",
        "simp99ms",
        "dist%",
        "sel%",
        "loc%",
        "agg%",
        "pop%"
    );
    for c in cells {
        let pct = |p: Phase| 100.0 * c.share[p.idx()];
        let _ = writeln!(
            out,
            "{:<34} {:>10.1} {:>11.0} {:>9.1} {:>9.1} {:>8} {:>8} {:>8} {:>6.1} {:>6.1} {:>6.1} {:>6.1} {:>6.1}",
            c.name,
            c.rounds_per_sec,
            c.events_per_sec,
            c.bytes_down_per_round / 1e3,
            c.bytes_up_per_round / 1e3,
            c.round_ms_p50,
            c.round_ms_p90,
            c.round_ms_p99,
            pct(Phase::Distribute),
            pct(Phase::Select),
            pct(Phase::LocalUpdate),
            pct(Phase::Aggregate),
            pct(Phase::EventPop),
        );
    }
    out
}

/// Persist the grid as a BENCH-schema JSON array.
pub fn write_json(cells: &[CellResult], path: &str) -> std::io::Result<()> {
    let arr: Vec<Json> = cells.iter().map(CellResult::to_json).collect();
    write_results_file(path, &Json::Arr(arr).to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_config_shapes_the_grid() {
        let cfg =
            cell_config(ProtocolKind::FedAvg, ProfileChurn::Markov, ProfileFabric::Off, 40)
                .unwrap();
        assert_eq!(cfg.protocol.kind, ProtocolKind::FedAvg);
        assert_eq!(cfg.env.m, 40);
        assert_eq!(cfg.task.n, 1000); // floor dominates 10*m
        assert_eq!(cfg.backend, Backend::Null);
        assert!(matches!(cfg.env.churn, ChurnModel::Markov { .. }));
        assert!(!cfg.env.fabric.enabled);
        assert_eq!(cfg.name, "profile_fedavg_markov_m40");
        cfg.validate().unwrap();
        let big =
            cell_config(ProtocolKind::Safa, ProfileChurn::Bernoulli, ProfileFabric::Off, 500)
                .unwrap();
        assert_eq!(big.task.n, 5000);
        assert_eq!(big.env.churn, ChurnModel::Bernoulli);
        let contended = cell_config(
            ProtocolKind::Safa,
            ProfileChurn::Bernoulli,
            ProfileFabric::Contended,
            20,
        )
        .unwrap();
        assert!(contended.env.fabric.enabled);
        assert_eq!(contended.name, "profile_safa_bernoulli_contended_m20");
        contended.validate().unwrap();
    }

    #[test]
    fn one_tiny_cell_produces_sane_numbers() {
        // Serialize against the other telemetry tests: run_cell toggles
        // the process-global enable flag.
        let _g = super::super::TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let was = super::super::enabled();
        let c = run_cell(
            ProtocolKind::FedAvg,
            ProfileChurn::Bernoulli,
            ProfileFabric::Off,
            10,
            3,
            1,
        )
        .unwrap();
        assert_eq!(super::super::enabled(), was, "enable state restored");
        assert_eq!(c.rounds, 3);
        assert!(c.mean_ns > 0.0);
        assert!(c.rounds_per_sec > 0.0);
        // FedAvg distributes to every picked client each round.
        assert!(c.bytes_down_per_round > 0.0);
        let j = c.to_json();
        assert!(j.get("rounds_per_sec").is_some());
        assert!(j.get("share_distribute").is_some());
        assert!(j.get("mean_ns").is_some());
        assert!(j.get("round_ms_p99").is_some());
        // Every round records one sim-duration sample while enabled.
        assert!(c.round_ms_p50 > 0, "round-duration histogram populated");
        let table = render_table(std::slice::from_ref(&c));
        assert!(table.contains("profile_"));
        // Contended smoke cell: the fabric-on grid runs end to end and
        // labels itself in the JSON.
        let f = run_cell(
            ProtocolKind::Safa,
            ProfileChurn::Bernoulli,
            ProfileFabric::Contended,
            8,
            2,
            1,
        )
        .unwrap();
        assert!(f.name.contains("_contended_"));
        assert!(f.rounds_per_sec > 0.0);
        assert_eq!(
            f.to_json().get("fabric").and_then(Json::as_str),
            Some("contended")
        );
    }
}
