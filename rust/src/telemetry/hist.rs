//! Zero-dependency log2-bucket histograms, per-worker sharded like the
//! span shards in the parent module.
//!
//! Every recorded value lands in bucket `floor(log2(v))` (values ≤ 1 in
//! bucket 0), so 48 buckets cover the full `u64` range a nanosecond span
//! or millisecond sim-time quantity can take. Recording is one gated
//! relaxed-atomic add — no locks, no allocation — and merging happens
//! only in serial snapshot code, so histograms inherit the telemetry
//! layer's contract: alloc-free at steady state and bit-for-bit neutral
//! to simulation results at any thread width.
//!
//! Percentiles are read from the merged buckets using the bucket
//! midpoint (`1.5 · 2^i`) as the representative value: a p99 is exact to
//! within its power-of-two bucket, which is the right fidelity for
//! latency tails and costs nothing to maintain.

use super::Phase;
use crate::util::json::Json;
use crate::util::parallel::{self, MAX_THREADS};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// log2 buckets per histogram: bucket `i` holds values in
/// `[2^i, 2^(i+1))`, bucket 0 additionally holds 0 and 1.
pub const NUM_BUCKETS: usize = 48;

/// Number of [`HistMetric`] variants (shard slot count).
pub const NUM_HISTS: usize = 12;

/// Quantities tracked as distributions. The first eight mirror
/// [`Phase::ALL`] (span durations in wall-clock ns, fed automatically by
/// the span recorder); the rest are sim-time quantities recorded at
/// their serial emission points.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HistMetric {
    /// Span durations (ns) for [`Phase::Distribute`].
    DistributeNs,
    /// Span durations (ns) for [`Phase::Select`].
    SelectNs,
    /// Span durations (ns) for [`Phase::LocalUpdate`].
    LocalUpdateNs,
    /// Span durations (ns) for [`Phase::Aggregate`].
    AggregateNs,
    /// Span durations (ns) for [`Phase::CacheRefresh`].
    CacheRefreshNs,
    /// Span durations (ns) for [`Phase::EventPop`].
    EventPopNs,
    /// Span durations (ns) for [`Phase::ForkDispatch`].
    ForkDispatchNs,
    /// Span durations (ns) for [`Phase::TransferWait`].
    TransferWaitNs,
    /// Simulated round length (ms) — one sample per completed round.
    RoundDurationMs,
    /// Applied staleness (rounds) — one sample per merged update.
    StalenessRounds,
    /// Per-client online dwell inside a round window (sim ms).
    ClientDwellMs,
    /// Per-transfer network-fabric distribution wait (sim ms).
    TransferWaitMs,
}

impl HistMetric {
    /// Every metric, in shard-slot order (first eight = [`Phase::ALL`]).
    pub const ALL: [HistMetric; NUM_HISTS] = [
        HistMetric::DistributeNs,
        HistMetric::SelectNs,
        HistMetric::LocalUpdateNs,
        HistMetric::AggregateNs,
        HistMetric::CacheRefreshNs,
        HistMetric::EventPopNs,
        HistMetric::ForkDispatchNs,
        HistMetric::TransferWaitNs,
        HistMetric::RoundDurationMs,
        HistMetric::StalenessRounds,
        HistMetric::ClientDwellMs,
        HistMetric::TransferWaitMs,
    ];

    /// Shard slot of this metric.
    pub fn idx(self) -> usize {
        self as usize
    }

    /// The span-duration metric for `phase`.
    pub fn from_phase(phase: Phase) -> HistMetric {
        HistMetric::ALL[phase.idx()]
    }

    /// Stable snake_case name (JSON keys, table headers).
    pub fn name(self) -> &'static str {
        match self {
            HistMetric::DistributeNs => "distribute_ns",
            HistMetric::SelectNs => "select_ns",
            HistMetric::LocalUpdateNs => "local_update_ns",
            HistMetric::AggregateNs => "aggregate_ns",
            HistMetric::CacheRefreshNs => "cache_refresh_ns",
            HistMetric::EventPopNs => "event_pop_ns",
            HistMetric::ForkDispatchNs => "fork_dispatch_ns",
            HistMetric::TransferWaitNs => "transfer_wait_ns",
            HistMetric::RoundDurationMs => "round_duration_ms",
            HistMetric::StalenessRounds => "staleness_rounds",
            HistMetric::ClientDwellMs => "client_dwell_ms",
            HistMetric::TransferWaitMs => "transfer_wait_ms",
        }
    }
}

/// Bucket index for `v`: 0 for `v ≤ 1`, else `floor(log2(v))` clamped to
/// the last bucket.
pub fn bucket_of(v: u64) -> usize {
    if v <= 1 {
        0
    } else {
        ((63 - v.leading_zeros()) as usize).min(NUM_BUCKETS - 1)
    }
}

/// Representative value of bucket `i` (its midpoint, `1.5 · 2^i`;
/// bucket 0 reports 1).
pub fn bucket_mid(i: usize) -> u64 {
    if i == 0 {
        1
    } else {
        (1u64 << i) + (1u64 << (i - 1))
    }
}

// ---------------------------------------------------------------------------
// Per-worker shards.
// ---------------------------------------------------------------------------

/// One worker's histogram buckets, cache-line aligned like the span
/// shards so concurrent recorders never share a line boundary.
#[repr(align(64))]
struct HistShard {
    buckets: [[AtomicU64; NUM_BUCKETS]; NUM_HISTS],
}

impl HistShard {
    const fn new() -> HistShard {
        HistShard {
            buckets: [const { [const { AtomicU64::new(0) }; NUM_BUCKETS] }; NUM_HISTS],
        }
    }
}

static HIST_SHARDS: [HistShard; MAX_THREADS] = [const { HistShard::new() }; MAX_THREADS];

fn shard() -> &'static HistShard {
    &HIST_SHARDS[parallel::worker_id() % MAX_THREADS]
}

/// Record one sample (no-op while recording is off).
pub fn record(metric: HistMetric, value: u64) {
    if super::enabled() {
        bump(metric, value);
    }
}

/// Record a sim-time quantity given in seconds, bucketed in integer
/// milliseconds. Non-finite and negative values land in bucket 0.
pub fn record_secs_as_ms(metric: HistMetric, secs: f64) {
    if super::enabled() {
        let ms = if secs.is_finite() && secs > 0.0 {
            (secs * 1e3) as u64
        } else {
            0
        };
        bump(metric, ms);
    }
}

/// Unconditional sample add (the gated entry points are [`record`] and
/// [`record_secs_as_ms`]).
pub(crate) fn bump(metric: HistMetric, value: u64) {
    shard().buckets[metric.idx()][bucket_of(value)].fetch_add(1, Relaxed);
}

// ---------------------------------------------------------------------------
// Merged histograms (carried inside `telemetry::Snapshot`).
// ---------------------------------------------------------------------------

/// A merged, point-in-time copy of every histogram shard. Fixed-size and
/// `Copy`, so snapshot deltas stay safe inside alloc-free windows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hists {
    pub buckets: [[u64; NUM_BUCKETS]; NUM_HISTS],
}

impl Default for Hists {
    fn default() -> Self {
        Hists {
            buckets: [[0; NUM_BUCKETS]; NUM_HISTS],
        }
    }
}

impl Hists {
    /// Field-wise `self - earlier` (wrapping, matching `Snapshot::since`).
    pub fn since(&self, earlier: &Hists) -> Hists {
        let mut d = Hists::default();
        for h in 0..NUM_HISTS {
            for b in 0..NUM_BUCKETS {
                d.buckets[h][b] = self.buckets[h][b].wrapping_sub(earlier.buckets[h][b]);
            }
        }
        d
    }

    /// Total samples recorded for `metric`.
    pub fn count(&self, metric: HistMetric) -> u64 {
        self.buckets[metric.idx()].iter().sum()
    }

    /// Bucket-midpoint percentile for `metric` at quantile `q` in
    /// `[0, 1]`; 0 when the histogram is empty.
    pub fn percentile(&self, metric: HistMetric, q: f64) -> u64 {
        let row = &self.buckets[metric.idx()];
        let total: u64 = row.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in row.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_mid(i);
            }
        }
        bucket_mid(NUM_BUCKETS - 1)
    }

    /// `{metric: {count, p50, p90, p99}}` for every metric — the
    /// `hists` object of the JSONL trace.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        for m in HistMetric::ALL {
            let mut e = Json::obj();
            e.set("count", Json::Num(self.count(m) as f64));
            e.set("p50", Json::Num(self.percentile(m, 0.50) as f64));
            e.set("p90", Json::Num(self.percentile(m, 0.90) as f64));
            e.set("p99", Json::Num(self.percentile(m, 0.99) as f64));
            o.set(m.name(), e);
        }
        o
    }
}

/// Merge every shard (serial, fixed order).
pub(crate) fn merged() -> Hists {
    let mut out = Hists::default();
    for shard in HIST_SHARDS.iter() {
        for h in 0..NUM_HISTS {
            for b in 0..NUM_BUCKETS {
                out.buckets[h][b] =
                    out.buckets[h][b].wrapping_add(shard.buckets[h][b].load(Relaxed));
            }
        }
    }
    out
}

/// Zero every histogram shard (called from `telemetry::reset`).
pub(crate) fn reset() {
    for shard in HIST_SHARDS.iter() {
        for row in shard.buckets.iter() {
            for a in row.iter() {
                a.store(0, Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_mapping_is_log2() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), NUM_BUCKETS - 1);
        assert_eq!(bucket_mid(0), 1);
        assert_eq!(bucket_mid(1), 3);
        assert_eq!(bucket_mid(10), 1536);
    }

    #[test]
    fn metric_table_is_consistent_and_mirrors_phases() {
        for (i, m) in HistMetric::ALL.iter().enumerate() {
            assert_eq!(m.idx(), i, "{}", m.name());
        }
        for p in Phase::ALL {
            let m = HistMetric::from_phase(p);
            assert!(
                m.name().starts_with(p.name()),
                "{} !~ {}",
                m.name(),
                p.name()
            );
        }
    }

    #[test]
    fn percentiles_walk_the_buckets() {
        let mut h = Hists::default();
        // 90 samples at value 1, 9 at ~2^10, 1 at ~2^20.
        h.buckets[HistMetric::RoundDurationMs.idx()][0] = 90;
        h.buckets[HistMetric::RoundDurationMs.idx()][10] = 9;
        h.buckets[HistMetric::RoundDurationMs.idx()][20] = 1;
        assert_eq!(h.count(HistMetric::RoundDurationMs), 100);
        assert_eq!(h.percentile(HistMetric::RoundDurationMs, 0.50), 1);
        assert_eq!(h.percentile(HistMetric::RoundDurationMs, 0.95), bucket_mid(10));
        assert_eq!(h.percentile(HistMetric::RoundDurationMs, 0.999), bucket_mid(20));
        // Empty metric reports 0 everywhere.
        assert_eq!(h.percentile(HistMetric::ClientDwellMs, 0.99), 0);
        assert_eq!(h.count(HistMetric::ClientDwellMs), 0);
    }

    #[test]
    fn since_subtracts_bucketwise() {
        let mut a = Hists::default();
        let mut b = Hists::default();
        a.buckets[0][0] = 3;
        b.buckets[0][0] = 10;
        b.buckets[2][5] = 4;
        let d = b.since(&a);
        assert_eq!(d.buckets[0][0], 7);
        assert_eq!(d.buckets[2][5], 4);
    }

    #[test]
    fn json_names_every_metric() {
        let mut h = Hists::default();
        h.buckets[HistMetric::StalenessRounds.idx()][2] = 5;
        let j = h.to_json();
        for m in HistMetric::ALL {
            let e = j.get(m.name()).unwrap();
            assert!(e.get("count").is_some());
            assert!(e.get("p50").is_some());
            assert!(e.get("p90").is_some());
            assert!(e.get("p99").is_some());
        }
        assert_eq!(
            j.get("staleness_rounds")
                .unwrap()
                .get("p99")
                .unwrap()
                .as_f64(),
            Some(bucket_mid(2) as f64)
        );
    }
}
