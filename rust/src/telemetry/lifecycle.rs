//! Per-client lifecycle event stream: typed `{"type":"client",...}`
//! lines in the SAFA_TRACE v2 JSONL schema.
//!
//! Each event tags one client with the round, the event kind, the
//! simulated time it happened at, and (where meaningful) the model
//! version it acted on, the applied staleness, or a failure reason.
//! Events are emitted **only from serial sections** of the engine and
//! the protocol servers — never from parallel workers — so line order
//! is deterministic and emission can never perturb reductions or RNG.
//!
//! The stream shares the trace destination and failure accounting with
//! [`super::trace_line`], but formats directly into the locked
//! `BufWriter` with `core::fmt` (stack buffers only): with a trace
//! active, per-client events still allocate nothing, which keeps
//! `tests/alloc_free.rs` green with lifecycle recording ON.
//!
//! `SAFA_TRACE_SAMPLE=k` keeps m = 10k+ traces bounded: only clients
//! with `id % k == 0` emit lifecycle events (round lines are never
//! sampled away). Strict-env convention: garbage values warn once and
//! fall back to 1 (every client).

use std::io::Write;
use std::sync::OnceLock;

/// Lifecycle event kinds, in protocol order of a client's round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// Selected by the server (CFCFM pick, random draw, estimate sort).
    Picked,
    /// Received the global model (sync push under the lag-tolerant Eq. 3).
    Distributed,
    /// Began local training (fresh-job engine paths).
    TrainStart,
    /// Finished local training.
    TrainEnd,
    /// Update arrived at the server.
    Upload,
    /// Update merged into the global model (with its applied staleness).
    Merged,
    /// Update parked in the bypass set (SAFA three-step aggregation).
    Bypassed,
    /// Crashed / went offline before completing the round.
    Crashed,
    /// Arrived but not drafted this round (SAFA CFCFM overflow).
    Undrafted,
    /// Server retried a cancelled transfer leg after backoff (faults).
    Retry,
    /// Joined the fleet this round (scenario flash crowds).
    Join,
    /// Departed the fleet this round (scenario flash leaves).
    Leave,
}

impl Event {
    /// Stable snake_case name (the `event` key of a client line).
    pub fn name(self) -> &'static str {
        match self {
            Event::Picked => "picked",
            Event::Distributed => "distributed",
            Event::TrainStart => "train_start",
            Event::TrainEnd => "train_end",
            Event::Upload => "upload",
            Event::Merged => "merged",
            Event::Bypassed => "bypassed",
            Event::Crashed => "crashed",
            Event::Undrafted => "undrafted",
            Event::Retry => "retry",
            Event::Join => "join",
            Event::Leave => "leave",
        }
    }
}

/// One lifecycle event, builder-style so call sites only name the
/// fields that apply.
#[derive(Debug, Clone, Copy)]
pub struct ClientEvent {
    pub round: usize,
    pub client: usize,
    pub event: Event,
    /// Simulated time (seconds within the round window).
    pub t: f64,
    pub version: Option<usize>,
    pub staleness: Option<u32>,
    pub reason: Option<&'static str>,
    /// Round phase the event hit (`download` / `train` / `upload`) —
    /// set on fault-path `crashed` / `retry` lines.
    pub phase: Option<&'static str>,
}

impl ClientEvent {
    pub fn new(round: usize, client: usize, event: Event, t: f64) -> ClientEvent {
        ClientEvent {
            round,
            client,
            event,
            t,
            version: None,
            staleness: None,
            reason: None,
            phase: None,
        }
    }

    pub fn version(mut self, v: usize) -> ClientEvent {
        self.version = Some(v);
        self
    }

    pub fn staleness(mut self, s: u32) -> ClientEvent {
        self.staleness = Some(s);
        self
    }

    pub fn reason(mut self, r: &'static str) -> ClientEvent {
        self.reason = Some(r);
        self
    }

    pub fn phase(mut self, p: &'static str) -> ClientEvent {
        self.phase = Some(p);
        self
    }
}

/// Is lifecycle emission live? Call sites check this once per serial
/// section and skip event construction entirely when no trace is
/// configured.
pub fn active() -> bool {
    super::trace_active()
}

// ---------------------------------------------------------------------------
// Sampling (SAFA_TRACE_SAMPLE=k).
// ---------------------------------------------------------------------------

static SAMPLE: OnceLock<u64> = OnceLock::new();

/// The sampling stride: only clients with `id % k == 0` emit. First
/// read consumes `SAFA_TRACE_SAMPLE`; afterwards it is pinned.
pub fn sample_stride() -> u64 {
    *SAMPLE.get_or_init(|| match std::env::var("SAFA_TRACE_SAMPLE") {
        Err(_) => 1,
        Ok(v) => match parse_stride(&v) {
            Some(k) => k,
            None => {
                crate::log_warn!(
                    "SAFA_TRACE_SAMPLE={v:?}: expected a positive integer stride; \
                     sampling every client"
                );
                1
            }
        },
    })
}

/// Pin the sampling stride from code, consuming the one-shot
/// environment read (first call wins, like [`super::set_enabled`]).
pub fn set_sample_stride(k: u64) {
    SAMPLE.get_or_init(|| k.max(1));
}

fn parse_stride(v: &str) -> Option<u64> {
    match v.trim().parse::<u64>() {
        Ok(k) if k >= 1 => Some(k),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Emission.
// ---------------------------------------------------------------------------

/// Emit one client line to the trace (no-op without an active trace or
/// for clients filtered out by the sampling stride). Allocation-free:
/// formats with `core::fmt` straight into the locked buffered writer.
/// Failed writes are counted in [`super::trace_dropped`].
pub fn emit(ev: ClientEvent) {
    let Some(w) = super::trace_writer() else {
        return;
    };
    if ev.client as u64 % sample_stride() != 0 {
        return;
    }
    let mut g = w.lock().unwrap_or_else(|e| e.into_inner());
    let ok = write_event(&mut *g, &ev).is_ok() && g.flush().is_ok();
    if !ok {
        super::note_trace_dropped();
    }
}

/// Serialize one client line. Split from [`emit`] so tests can format
/// into a buffer without owning the process-global trace destination.
pub(crate) fn write_event<W: Write>(out: &mut W, ev: &ClientEvent) -> std::io::Result<()> {
    write!(
        out,
        "{{\"type\":\"client\",\"v\":2,\"round\":{},\"client\":{},\"event\":\"{}\",\"t\":",
        ev.round,
        ev.client,
        ev.event.name()
    )?;
    // JSON has no NaN/Inf; mirror Json::write_num's null fallback.
    if ev.t.is_finite() {
        write!(out, "{}", ev.t)?;
    } else {
        write!(out, "null")?;
    }
    if let Some(v) = ev.version {
        write!(out, ",\"version\":{v}")?;
    }
    if let Some(s) = ev.staleness {
        write!(out, ",\"staleness\":{s}")?;
    }
    if let Some(r) = ev.reason {
        write!(out, ",\"reason\":\"{r}\"")?;
    }
    if let Some(p) = ev.phase {
        write!(out, ",\"phase\":\"{p}\"")?;
    }
    writeln!(out, "}}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn render(ev: ClientEvent) -> Json {
        let mut buf = Vec::new();
        write_event(&mut buf, &ev).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.ends_with('\n'));
        Json::parse(text.trim_end()).unwrap()
    }

    #[test]
    fn minimal_event_is_valid_v2_json() {
        let j = render(ClientEvent::new(3, 17, Event::Upload, 41.25));
        assert_eq!(j.get("type").and_then(Json::as_str), Some("client"));
        assert_eq!(j.get("v").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("round").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("client").and_then(Json::as_f64), Some(17.0));
        assert_eq!(j.get("event").and_then(Json::as_str), Some("upload"));
        assert_eq!(j.get("t").and_then(Json::as_f64), Some(41.25));
        assert!(j.get("version").is_none());
        assert!(j.get("staleness").is_none());
        assert!(j.get("reason").is_none());
        assert!(j.get("phase").is_none());
    }

    #[test]
    fn optional_fields_round_trip() {
        let j = render(
            ClientEvent::new(9, 4, Event::Merged, 12.0)
                .version(7)
                .staleness(2)
                .reason("crash"),
        );
        assert_eq!(j.get("version").and_then(Json::as_f64), Some(7.0));
        assert_eq!(j.get("staleness").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("reason").and_then(Json::as_str), Some("crash"));
        assert_eq!(j.get("event").and_then(Json::as_str), Some("merged"));
    }

    #[test]
    fn phase_round_trips_on_crash_and_retry() {
        let j = render(
            ClientEvent::new(2, 8, Event::Crashed, 10.5)
                .reason("crash")
                .phase("download"),
        );
        assert_eq!(j.get("phase").and_then(Json::as_str), Some("download"));
        let j = render(ClientEvent::new(2, 8, Event::Retry, 30.0).phase("upload"));
        assert_eq!(j.get("event").and_then(Json::as_str), Some("retry"));
        assert_eq!(j.get("phase").and_then(Json::as_str), Some("upload"));
    }

    #[test]
    fn non_finite_time_becomes_null() {
        let j = render(ClientEvent::new(1, 0, Event::Crashed, f64::NAN));
        assert_eq!(j.get("t"), Some(&Json::Null));
    }

    #[test]
    fn stride_parse_is_strict() {
        assert_eq!(parse_stride("1"), Some(1));
        assert_eq!(parse_stride(" 25 "), Some(25));
        assert_eq!(parse_stride("0"), None);
        assert_eq!(parse_stride("-3"), None);
        assert_eq!(parse_stride("yes"), None);
        assert_eq!(parse_stride(""), None);
    }

    #[test]
    fn event_names_are_stable() {
        let all = [
            (Event::Picked, "picked"),
            (Event::Distributed, "distributed"),
            (Event::TrainStart, "train_start"),
            (Event::TrainEnd, "train_end"),
            (Event::Upload, "upload"),
            (Event::Merged, "merged"),
            (Event::Bypassed, "bypassed"),
            (Event::Crashed, "crashed"),
            (Event::Undrafted, "undrafted"),
            (Event::Retry, "retry"),
            (Event::Join, "join"),
            (Event::Leave, "leave"),
        ];
        for (e, name) in all {
            assert_eq!(e.name(), name);
        }
    }
}
