//! Zero-dependency observability: phase-scoped span timers, fleet
//! counters, a counting allocator hook and an opt-in JSONL round trace.
//!
//! Always compiled, **default-off**. The hot path pays one relaxed
//! atomic load per instrumentation point while disabled; while enabled
//! it pays a monotonic-clock read per span plus relaxed atomic adds into
//! **per-worker shards** (indexed by [`parallel::worker_id`], the same
//! identity that gives `util::scratch` its slot affinity) — no locks, no
//! allocation, so steady-state rounds stay alloc-free with telemetry on
//! (`tests/alloc_free.rs` asserts this). Telemetry never consumes RNG
//! and never reorders reductions, so results are bit-identical with it
//! on or off at any width (`tests/determinism.rs` asserts this).
//!
//! # Enabling
//!
//! * `SAFA_TELEMETRY=1` (or `true`/`on`) turns recording on at startup.
//! * `SAFA_TRACE=<path>` implies recording and additionally streams one
//!   JSON object per round (round record + span/counter deltas) to
//!   `<path>` as JSONL — see the coordinator's round loop.
//! * [`set_enabled`] overrides both from code (the profile runner and
//!   tests use it); like `logging::set_max_level` it consumes the
//!   one-shot environment read so a later [`enabled`] cannot clobber it.
//!
//! # What the numbers mean
//!
//! Spans are wall-clock nanoseconds between guard creation and drop,
//! summed per [`Phase`] across all workers. Spans **nest and overlap**
//! (a `local_update` span contains `fork_dispatch` spans; parallel
//! workers time concurrently), so phase sums are CPU-style shares that
//! can exceed the enclosing wall time — compare phases against each
//! other, not against 100%.

pub mod hist;
pub mod lifecycle;
pub mod profile;

use crate::util::json::Json;
use crate::util::parallel::{self, MAX_THREADS};
use std::alloc::{GlobalAlloc, Layout, System};
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Simulator phases a span can be attributed to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Server-side model distribution (sync pushes, Eq. 3 bookkeeping).
    Distribute,
    /// Client selection (CFCFM / random / estimate-sorted).
    Select,
    /// Local-update computation over arrivals ([`crate::protocol`]'s
    /// `collect_updates`, all protocols).
    LocalUpdate,
    /// Global aggregation (weighted sums, Eq. 6–8 passes, FedAsync
    /// mixing).
    Aggregate,
    /// SAFA cache refresh (Eq. 6 pre-aggregation cache pass).
    CacheRefresh,
    /// Discrete-event loop of the fleet engine (queue pops + handlers).
    EventPop,
    /// Parallel regions: whole fork-join dispatches of the worker pool.
    ForkDispatch,
    /// Network-fabric queueing: applying contention waits to synced
    /// clients' transfers ([`crate::net::fabric`]).
    TransferWait,
}

/// Number of [`Phase`] variants (shard slot count).
pub const NUM_PHASES: usize = 8;

impl Phase {
    /// Every phase, in shard-slot order.
    pub const ALL: [Phase; NUM_PHASES] = [
        Phase::Distribute,
        Phase::Select,
        Phase::LocalUpdate,
        Phase::Aggregate,
        Phase::CacheRefresh,
        Phase::EventPop,
        Phase::ForkDispatch,
        Phase::TransferWait,
    ];

    /// Shard slot of this phase.
    pub fn idx(self) -> usize {
        self as usize
    }

    /// Stable snake_case name (JSON keys, table headers).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Distribute => "distribute",
            Phase::Select => "select",
            Phase::LocalUpdate => "local_update",
            Phase::Aggregate => "aggregate",
            Phase::CacheRefresh => "cache_refresh",
            Phase::EventPop => "event_pop",
            Phase::ForkDispatch => "fork_dispatch",
            Phase::TransferWait => "transfer_wait",
        }
    }
}

/// Monotonic fleet counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Events pushed onto the discrete-event queue.
    EventsScheduled,
    /// Events popped off the queue (clock advances).
    EventsPopped,
    /// Parallel fork-join dispatches (width > 1).
    Forks,
    /// Chunks handed to workers across all forks.
    Chunks,
    /// Network-fabric transfers priced (one per download/upload leg).
    Transfers,
    /// Fabric retransmissions (lost attempts that were retried).
    Retransmits,
    /// Fault interruptions injected (crash / flap / regional outage).
    FaultsInjected,
    /// Server retry attempts after a cancelled transfer leg.
    Retries,
}

/// Number of [`Counter`] variants.
pub const NUM_COUNTERS: usize = 8;

impl Counter {
    /// Every counter, in shard-slot order.
    pub const ALL: [Counter; NUM_COUNTERS] = [
        Counter::EventsScheduled,
        Counter::EventsPopped,
        Counter::Forks,
        Counter::Chunks,
        Counter::Transfers,
        Counter::Retransmits,
        Counter::FaultsInjected,
        Counter::Retries,
    ];

    /// Shard slot of this counter.
    pub fn idx(self) -> usize {
        self as usize
    }

    /// Stable snake_case name.
    pub fn name(self) -> &'static str {
        match self {
            Counter::EventsScheduled => "events_scheduled",
            Counter::EventsPopped => "events_popped",
            Counter::Forks => "forks",
            Counter::Chunks => "chunks",
            Counter::Transfers => "transfers",
            Counter::Retransmits => "retransmits",
            Counter::FaultsInjected => "faults_injected",
            Counter::Retries => "retries",
        }
    }
}

// ---------------------------------------------------------------------------
// Per-worker shards.
// ---------------------------------------------------------------------------

/// One worker's slice of the recording state. Cache-line aligned so two
/// workers' hot adds never share a line.
#[repr(align(64))]
struct Shard {
    span_ns: [AtomicU64; NUM_PHASES],
    span_count: [AtomicU64; NUM_PHASES],
    counts: [AtomicU64; NUM_COUNTERS],
}

impl Shard {
    const fn new() -> Shard {
        Shard {
            span_ns: [const { AtomicU64::new(0) }; NUM_PHASES],
            span_count: [const { AtomicU64::new(0) }; NUM_PHASES],
            counts: [const { AtomicU64::new(0) }; NUM_COUNTERS],
        }
    }
}

/// One shard per pool identity: slot 0 for ordinary threads (the
/// submitter and anything `Dispatch::Spawn` creates), slot `i + 1` for
/// pool worker `i` — [`parallel::worker_id`] never exceeds
/// `MAX_THREADS - 1`, the modulo is a panic-proofing guard only.
static SHARDS: [Shard; MAX_THREADS] = [const { Shard::new() }; MAX_THREADS];

fn shard() -> &'static Shard {
    &SHARDS[parallel::worker_id() % MAX_THREADS]
}

// ---------------------------------------------------------------------------
// Enable flag (mirrors util::logging's one-shot env pattern).
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static ENV_ENABLE: OnceLock<()> = OnceLock::new();

fn enabled_from_env() -> bool {
    // Strict-env convention (matches SAFA_THREADS): an unrecognized
    // value warns once instead of silently disabling recording.
    let flag = match std::env::var("SAFA_TELEMETRY").as_deref() {
        Ok("1") | Ok("true") | Ok("on") => true,
        Ok("") | Ok("0") | Ok("false") | Ok("off") | Err(_) => false,
        Ok(other) => {
            crate::log_warn!(
                "SAFA_TELEMETRY={other:?}: expected 1|true|on or 0|false|off; \
                 recording stays off"
            );
            false
        }
    };
    flag || std::env::var_os("SAFA_TRACE").is_some()
}

/// Is recording currently on? First call reads the environment
/// (`SAFA_TELEMETRY`, `SAFA_TRACE`); afterwards one relaxed load.
pub fn enabled() -> bool {
    ENV_ENABLE.get_or_init(|| ENABLED.store(enabled_from_env(), Relaxed));
    ENABLED.load(Relaxed)
}

/// Turn recording on/off from code. Consumes the one-time environment
/// read so a later [`enabled`] cannot clobber the override.
pub fn set_enabled(on: bool) {
    ENV_ENABLE.get_or_init(|| ());
    ENABLED.store(on, Relaxed);
}

// ---------------------------------------------------------------------------
// Spans and counters.
// ---------------------------------------------------------------------------

/// RAII span guard: records elapsed wall-clock ns into the dropping
/// worker's shard. Inert (no clock read) while recording is off.
#[must_use = "a span records on drop; binding it to _ drops immediately"]
pub struct Span {
    active: Option<(Phase, Instant)>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((phase, start)) = self.active.take() {
            record_span(phase, start.elapsed().as_nanos() as u64);
        }
    }
}

/// Open a span for `phase`; it records when dropped.
pub fn span(phase: Phase) -> Span {
    Span {
        active: if enabled() {
            Some((phase, Instant::now()))
        } else {
            None
        },
    }
}

/// Unconditionally credit `ns` to `phase` on this worker's shard
/// (the gated entry point is [`span`]). Every span also feeds the
/// matching duration histogram, so tail latency comes for free.
fn record_span(phase: Phase, ns: u64) {
    let s = shard();
    s.span_ns[phase.idx()].fetch_add(ns, Relaxed);
    s.span_count[phase.idx()].fetch_add(1, Relaxed);
    hist::bump(hist::HistMetric::from_phase(phase), ns);
}

/// Add `n` to counter `c` (no-op while recording is off).
pub fn count(c: Counter, n: u64) {
    if enabled() {
        bump(c, n);
    }
}

/// Unconditional counter add (the gated entry point is [`count`]).
fn bump(c: Counter, n: u64) {
    shard().counts[c.idx()].fetch_add(n, Relaxed);
}

// ---------------------------------------------------------------------------
// Allocator accounting.
// ---------------------------------------------------------------------------

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Counting wrapper over the system allocator. Install it per binary —
/// `#[global_allocator] static A: safa::telemetry::CountingAlloc =
/// safa::telemetry::CountingAlloc;` — and [`alloc_count`] /
/// [`Snapshot::allocs`] report heap traffic (`tests/alloc_free.rs` is
/// the reference user). Deliberately not installed by the library: the
/// counters read 0 unless a binary opts in.
///
/// The counting path touches only two plain atomics — never the
/// environment, locks or `OnceLock` — so it cannot recurse or allocate.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Heap allocations observed so far (0 unless [`CountingAlloc`] is the
/// binary's global allocator).
pub fn alloc_count() -> u64 {
    ALLOCS.load(Relaxed)
}

/// Heap bytes requested so far (same caveat as [`alloc_count`]).
pub fn alloc_bytes() -> u64 {
    ALLOC_BYTES.load(Relaxed)
}

// ---------------------------------------------------------------------------
// Snapshots.
// ---------------------------------------------------------------------------

/// A merged, point-in-time copy of every shard plus the allocator
/// counters. Fixed-size — taking one allocates nothing, so snapshot
/// deltas are safe inside alloc-free measurement windows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Snapshot {
    pub span_ns: [u64; NUM_PHASES],
    pub span_count: [u64; NUM_PHASES],
    pub counters: [u64; NUM_COUNTERS],
    pub allocs: u64,
    pub alloc_bytes: u64,
    pub hists: hist::Hists,
}

impl Snapshot {
    /// Field-wise `self - earlier` (wrapping, so a concurrent reset
    /// cannot panic the reader).
    pub fn since(&self, earlier: &Snapshot) -> Snapshot {
        let mut d = Snapshot::default();
        for i in 0..NUM_PHASES {
            d.span_ns[i] = self.span_ns[i].wrapping_sub(earlier.span_ns[i]);
            d.span_count[i] = self.span_count[i].wrapping_sub(earlier.span_count[i]);
        }
        for i in 0..NUM_COUNTERS {
            d.counters[i] = self.counters[i].wrapping_sub(earlier.counters[i]);
        }
        d.allocs = self.allocs.wrapping_sub(earlier.allocs);
        d.alloc_bytes = self.alloc_bytes.wrapping_sub(earlier.alloc_bytes);
        d.hists = self.hists.since(&earlier.hists);
        d
    }

    /// Nanoseconds attributed to `phase`.
    pub fn phase_ns(&self, phase: Phase) -> u64 {
        self.span_ns[phase.idx()]
    }

    /// Current value of counter `c`.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c.idx()]
    }

    /// `{spans: {name: {ns, count}}, counters: {name: n}, allocs,
    /// alloc_bytes, hists: {name: {count, p50, p90, p99}}}` — the
    /// `telemetry` object of the JSONL trace.
    pub fn to_json(&self) -> Json {
        let mut spans = Json::obj();
        for p in Phase::ALL {
            let mut s = Json::obj();
            s.set("ns", Json::Num(self.span_ns[p.idx()] as f64));
            s.set("count", Json::Num(self.span_count[p.idx()] as f64));
            spans.set(p.name(), s);
        }
        let mut counters = Json::obj();
        for c in Counter::ALL {
            counters.set(c.name(), Json::Num(self.counters[c.idx()] as f64));
        }
        let mut o = Json::obj();
        o.set("spans", spans);
        o.set("counters", counters);
        o.set("allocs", Json::Num(self.allocs as f64));
        o.set("alloc_bytes", Json::Num(self.alloc_bytes as f64));
        o.set("hists", self.hists.to_json());
        o
    }
}

/// Merge every shard (serial, fixed order) plus the allocator counters.
pub fn snapshot() -> Snapshot {
    let mut s = Snapshot::default();
    for shard in SHARDS.iter() {
        for i in 0..NUM_PHASES {
            s.span_ns[i] = s.span_ns[i].wrapping_add(shard.span_ns[i].load(Relaxed));
            s.span_count[i] = s.span_count[i].wrapping_add(shard.span_count[i].load(Relaxed));
        }
        for i in 0..NUM_COUNTERS {
            s.counters[i] = s.counters[i].wrapping_add(shard.counts[i].load(Relaxed));
        }
    }
    s.allocs = ALLOCS.load(Relaxed);
    s.alloc_bytes = ALLOC_BYTES.load(Relaxed);
    s.hists = hist::merged();
    s
}

/// Zero every span/counter shard (allocator counters are monotonic and
/// stay — diff them via [`Snapshot::since`]). Only call between runs:
/// a reset concurrent with active workers loses their in-flight adds.
pub fn reset() {
    for shard in SHARDS.iter() {
        for a in shard.span_ns.iter().chain(&shard.span_count) {
            a.store(0, Relaxed);
        }
        for a in shard.counts.iter() {
            a.store(0, Relaxed);
        }
    }
    hist::reset();
}

// ---------------------------------------------------------------------------
// JSONL trace (SAFA_TRACE=<path>).
// ---------------------------------------------------------------------------

static TRACE: OnceLock<Option<Mutex<BufWriter<File>>>> = OnceLock::new();

/// Trace lines lost to write/flush errors (full disk, revoked fd): a
/// truncated trace no longer silently passes for a complete one — the
/// coordinator reports this count at end of run.
static TRACE_DROPPED: AtomicU64 = AtomicU64::new(0);

pub(crate) fn trace_writer() -> &'static Option<Mutex<BufWriter<File>>> {
    TRACE.get_or_init(|| {
        let path = std::env::var_os("SAFA_TRACE")?;
        match File::create(&path) {
            Ok(f) => Some(Mutex::new(BufWriter::new(f))),
            Err(e) => {
                crate::log_warn!("SAFA_TRACE: cannot create {path:?}: {e}");
                None
            }
        }
    })
}

/// Point the JSONL trace at `path` from code, consuming the one-shot
/// `SAFA_TRACE` environment read (first call wins, like [`set_enabled`]).
/// Returns whether a trace is active afterwards. Test binaries use this;
/// a process that already opened a trace keeps the original destination.
pub fn set_trace(path: &str) -> bool {
    TRACE.get_or_init(|| match File::create(path) {
        Ok(f) => Some(Mutex::new(BufWriter::new(f))),
        Err(e) => {
            crate::log_warn!("set_trace: cannot create {path:?}: {e}");
            None
        }
    });
    trace_active()
}

/// Is a JSONL trace destination configured and writable?
pub fn trace_active() -> bool {
    trace_writer().is_some()
}

/// Trace lines dropped so far because a write or flush failed.
pub fn trace_dropped() -> u64 {
    TRACE_DROPPED.load(Relaxed)
}

pub(crate) fn note_trace_dropped() {
    TRACE_DROPPED.fetch_add(1, Relaxed);
}

/// Append one compact JSON object + newline to the trace file, flushed
/// per line so a killed run keeps every completed round. No-op without
/// an active trace; failed writes are counted in [`trace_dropped`].
pub fn trace_line(line: &Json) {
    if let Some(w) = trace_writer() {
        let mut g = w.lock().unwrap_or_else(|e| e.into_inner());
        let ok = writeln!(g, "{}", line.to_string_compact()).is_ok() && g.flush().is_ok();
        if !ok {
            note_trace_dropped();
        }
    }
}

/// Serializes every test that toggles [`set_enabled`] or asserts exact
/// shard deltas (shards and the enable flag are process-global; lib
/// tests run concurrently). Shared with `profile`'s tests.
#[cfg(test)]
pub(crate) static TEST_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// Take the process-global telemetry test lock and pin recording
    /// OFF for the window, so concurrently running lib tests (whose
    /// gated spans/counts are then no-ops) cannot pollute exact-delta
    /// assertions. These tests drive the private unconditional
    /// recorders directly.
    fn locked() -> std::sync::MutexGuard<'static, ()> {
        let g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        g
    }

    /// A span that records regardless of the process-global flag.
    fn forced_span(phase: Phase) -> Span {
        Span {
            active: Some((phase, Instant::now())),
        }
    }

    #[test]
    fn disabled_spans_and_counts_record_nothing() {
        let _g = locked();
        let before = snapshot();
        {
            let _s = span(Phase::Distribute);
            count(Counter::Forks, 3);
        }
        let d = snapshot().since(&before);
        assert_eq!(d.phase_ns(Phase::Distribute), 0);
        assert_eq!(d.span_count[Phase::Distribute.idx()], 0);
        assert_eq!(d.counter(Counter::Forks), 0);
    }

    #[test]
    fn nested_spans_credit_outer_at_least_inner() {
        let _g = locked();
        let before = snapshot();
        {
            let _outer = forced_span(Phase::Distribute);
            {
                let _inner = forced_span(Phase::Aggregate);
                std::thread::sleep(Duration::from_millis(2));
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let d = snapshot().since(&before);
        assert_eq!(d.span_count[Phase::Distribute.idx()], 1);
        assert_eq!(d.span_count[Phase::Aggregate.idx()], 1);
        assert!(
            d.phase_ns(Phase::Distribute) >= d.phase_ns(Phase::Aggregate),
            "outer {} < inner {}",
            d.phase_ns(Phase::Distribute),
            d.phase_ns(Phase::Aggregate)
        );
        assert!(d.phase_ns(Phase::Aggregate) >= 2_000_000);
    }

    #[test]
    fn per_worker_shards_merge_exact_sums() {
        let _g = locked();
        let before = snapshot();
        // Distinct per-chunk values from distinct workers; the fork
        // width pins chunk i to worker_id i (pooled dispatch), so this
        // exercises merging across real shards.
        parallel::with_dispatch(parallel::Dispatch::Pooled, || {
            parallel::fork(4, |i| {
                bump(Counter::Chunks, (i as u64 + 1) * 10);
                record_span(Phase::EventPop, (i as u64 + 1) * 100);
            });
        });
        let d = snapshot().since(&before);
        assert_eq!(d.counter(Counter::Chunks), 10 + 20 + 30 + 40);
        assert_eq!(d.phase_ns(Phase::EventPop), 100 + 200 + 300 + 400);
        assert_eq!(d.span_count[Phase::EventPop.idx()], 4);
    }

    #[test]
    fn snapshot_delta_isolates_a_window() {
        let _g = locked();
        bump(Counter::EventsScheduled, 7);
        let t0 = snapshot();
        bump(Counter::EventsScheduled, 5);
        bump(Counter::EventsPopped, 2);
        let d = snapshot().since(&t0);
        assert_eq!(d.counter(Counter::EventsScheduled), 5);
        assert_eq!(d.counter(Counter::EventsPopped), 2);
    }

    #[test]
    fn json_shape_names_every_phase_and_counter() {
        let mut s = Snapshot::default();
        s.span_ns[Phase::Select.idx()] = 42;
        s.counters[Counter::Forks.idx()] = 9;
        let j = s.to_json();
        let spans = j.get("spans").unwrap();
        for p in Phase::ALL {
            let e = spans.get(p.name()).unwrap();
            assert!(e.get("ns").is_some() && e.get("count").is_some());
        }
        let counters = j.get("counters").unwrap();
        for c in Counter::ALL {
            assert!(counters.get(c.name()).is_some());
        }
        assert_eq!(
            spans.get("select").unwrap().get("ns").unwrap().as_f64(),
            Some(42.0)
        );
        assert_eq!(counters.get("forks").unwrap().as_f64(), Some(9.0));
        assert!(j.get("allocs").is_some());
    }

    #[test]
    fn phase_and_counter_tables_are_consistent() {
        for (i, p) in Phase::ALL.iter().enumerate() {
            assert_eq!(p.idx(), i, "{}", p.name());
        }
        for (i, c) in Counter::ALL.iter().enumerate() {
            assert_eq!(c.idx(), i, "{}", c.name());
        }
    }
}
