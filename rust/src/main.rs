//! `safa` — launcher CLI for the SAFA federated-learning reproduction.
//!
//! ```text
//! safa run     [--preset task1] [--protocol safa|fedavg|fedcs|fedasync|local]
//!              [--c 0.3] [--cr 0.1] [--tau 5] [--rounds N] [--seed S]
//!              [--alpha 0.6] [--staleness-exp 0.5]
//!              [--churn bernoulli|markov|trace] [--churn-uptime 2000]
//!              [--churn-downtime 500] [--churn-trace file.txt]
//!              [--bw 10] [--server-bw 100] [--model-size 10]
//!              [--fabric off|none|fifo|fair] [--fabric-streams 4]
//!              [--fabric-link fixed|uniform|lognormal]
//!              [--fabric-link-spread 0.5] [--fabric-latency 0.05]
//!              [--fabric-jitter 0.02] [--fabric-loss 0.02]
//!              [--fabric-retries 3]
//!              [--fabric-compression none|topk|quantize]
//!              [--fabric-topk 0.1] [--fabric-bits 8]
//!              [--faults off|on] [--faults-crash-hazard 0.15]
//!              [--faults-flap 0.5] [--faults-flap-downtime 60]
//!              [--faults-regions 2] [--faults-outage 0.1]
//!              [--faults-outage-len 120] [--faults-degrade 0.2]
//!              [--faults-degrade-factor 2.0] [--faults-retries 2]
//!              [--faults-backoff 5] [--faults-backoff-cap 60]
//!              [--faults-partial-credit true|false]
//!              [--scenario off|continuous|bernoulli|markov]
//!              [--scenario-crash-prob 0.1] [--scenario-uptime 2000]
//!              [--scenario-downtime 500] [--scenario-diurnal-amp 0.6]
//!              [--scenario-diurnal-period 3320]
//!              [--scenario-regions 4] [--scenario-flash-at 5000]
//!              [--scenario-flash-joins 10] [--scenario-flash-leaves 0]
//!              [--scenario-outage-at 8000] [--scenario-outage-region 2]
//!              [--scenario-outage-len 600]
//!              [--backend native|xla|null] [--config file.toml]
//!              [--out results/run.json]
//! safa sweep   [--preset task1] [--protocols safa,fedavg]
//!              [--c 0.1,0.3] [--cr 0.1,0.3,0.5,0.7] [--metric round_len]
//! safa bias    [--cr 0.3] [--rounds 20]         # Fig. 5 closed form
//! safa profile [--protocols safa,fedavg] [--churn bernoulli,markov]
//!              [--fabric off,contended] [--m 100,500] [--rounds 30]
//!              [--warmup 5] [--json BENCH_profile.json] # rounds/sec grid
//! safa report  <trace.jsonl> [--client K] [--json report.json]
//!                                                # analyze a SAFA_TRACE v2 file
//! safa presets                                   # list presets
//! ```

use safa::bench_harness::{write_results_file, Series, Table};
use safa::config::{presets, Backend, ChurnModel, ExperimentConfig, ProtocolKind};
use safa::coordinator::run_experiment;
use safa::util::cli::{Args, CliError};
use safa::util::logging;

type CliResult<T> = Result<T, Box<dyn std::error::Error>>;

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(argv, &["help", "quiet"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "bias" => cmd_bias(&args),
        "profile" => cmd_profile(&args),
        "report" => cmd_report(&args),
        "presets" => {
            for name in presets::preset_names() {
                println!("{name}");
            }
            Ok(())
        }
        _ => {
            print_help();
            Ok(())
        }
    }
    .map_or_else(
        |e: Box<dyn std::error::Error>| {
            eprintln!("error: {e}");
            1
        },
        |_| 0,
    );
    std::process::exit(code);
}

fn print_help() {
    println!(
        "safa — SAFA semi-asynchronous federated learning (paper reproduction)\n\
         \n\
         Commands:\n\
         \x20 run      run one experiment (see --preset/--protocol/--c/--cr/--tau)\n\
         \x20 sweep    run a protocol × C × cr grid and print a paper-style table\n\
         \x20 bias     print the Fig. 5 closed-form bias series\n\
         \x20 profile  rounds/sec profiling grid (--protocols/--churn/--m/\n\
         \x20          --rounds/--warmup/--json; telemetry phase shares)\n\
         \x20 report   analyze a SAFA_TRACE v2 JSONL file: round-duration\n\
         \x20          percentiles, staleness CDF, EUR/wasted-work per\n\
         \x20          protocol (--client K timeline, --json out.json)\n\
         \x20 presets  list available presets\n\
         \n\
         Protocols: safa, fedavg, fedcs, fedasync (--alpha/--staleness-exp), local\n\
         Churn:     --churn bernoulli|markov|trace, with --churn-uptime /\n\
         \x20          --churn-downtime (seconds, markov) or --churn-trace <file>\n\
         Network:   --bw <Mbps> per-client link, --server-bw <Mbps> server link,\n\
         \x20          --model-size <MB> model payload (all must be positive)\n\
         Fabric:    --fabric off|none|fifo|fair enables the event-driven network\n\
         \x20          fabric; refine with --fabric-streams (fair), --fabric-link\n\
         \x20          fixed|uniform|lognormal + --fabric-link-spread,\n\
         \x20          --fabric-latency/--fabric-jitter (seconds), --fabric-loss\n\
         \x20          (probability), --fabric-retries, and update compression via\n\
         \x20          --fabric-compression topk|quantize with --fabric-topk\n\
         \x20          (fraction) or --fabric-bits (1..=32)\n\
         Faults:    --faults off|on arms the deterministic fault injectors;\n\
         \x20          refine with --faults-crash-hazard/--faults-flap\n\
         \x20          (probabilities), --faults-flap-downtime (seconds),\n\
         \x20          --faults-regions + --faults-outage/--faults-outage-len\n\
         \x20          (correlated outages), --faults-degrade/\n\
         \x20          --faults-degrade-factor (link slowdown), and policy via\n\
         \x20          --faults-retries (0..=64), --faults-backoff/\n\
         \x20          --faults-backoff-cap (seconds), --faults-partial-credit;\n\
         \x20          the `chaos` preset arms everything at once\n\
         Scenario:  --scenario off|continuous|bernoulli|markov scripts client\n\
         \x20          availability on the continuous wall clock; refine with\n\
         \x20          --scenario-uptime/--scenario-downtime (mean dwell seconds),\n\
         \x20          --scenario-diurnal-amp [0,1) + --scenario-diurnal-period\n\
         \x20          (sine-modulated churn), --scenario-regions, flash crowds via\n\
         \x20          --scenario-flash-at + --scenario-flash-joins/-leaves, and\n\
         \x20          correlated outages via --scenario-outage-at +\n\
         \x20          --scenario-outage-region/--scenario-outage-len; the\n\
         \x20          reductions take --scenario-crash-prob (bernoulli) or the\n\
         \x20          dwell flags (markov); the `diurnal` and `flashcrowd`\n\
         \x20          presets are ready-made scenarios\n"
    );
}

/// Build a config from --config/--preset plus CLI overrides.
fn build_config(args: &Args) -> CliResult<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)?;
        let doc = safa::util::toml::parse(&text)?;
        ExperimentConfig::from_toml(&doc)?
    } else {
        presets::preset(args.get("preset").unwrap_or("task1"))?
    };
    if let Some(p) = args.get("protocol") {
        cfg.protocol.kind = ProtocolKind::parse(p)?;
    }
    if let Some(c) = args.get_parsed::<f64>("c")? {
        cfg.protocol.c_fraction = c;
    }
    if let Some(cr) = args.get_parsed::<f64>("cr")? {
        cfg.env.crash_prob = cr;
    }
    if let Some(tau) = args.get_parsed::<usize>("tau")? {
        cfg.protocol.tau = tau;
    }
    if let Some(a) = args.get_parsed::<f64>("alpha")? {
        cfg.protocol.alpha = a;
    }
    if let Some(a) = args.get_parsed::<f64>("staleness-exp")? {
        cfg.protocol.staleness_exp = a;
    }
    if let Some(choice) = args.get_choice("churn", &["bernoulli", "markov", "trace"])? {
        cfg.env.churn = ChurnModel::from_parts(
            &choice,
            args.get_parsed::<f64>("churn-uptime")?,
            args.get_parsed::<f64>("churn-downtime")?,
            args.get("churn-trace"),
        )?;
    } else if args.get("churn-uptime").is_some()
        || args.get("churn-downtime").is_some()
        || args.get("churn-trace").is_some()
    {
        return Err(CliError(
            "--churn-uptime/--churn-downtime/--churn-trace require --churn <model>".into(),
        )
        .into());
    }
    // Network constants: CLI units are human-scale (Mbps / MB); the
    // config stores bits and bits/sec. Rejected here (not just by
    // cfg.validate) so the error names the flag and its unit.
    if let Some(bw) = args.get_parsed::<f64>("bw")? {
        if !bw.is_finite() || bw <= 0.0 {
            return Err(
                CliError(format!("--bw {bw}: client bandwidth in Mbps must be > 0")).into(),
            );
        }
        cfg.env.client_bw_bps = bw * 1e6;
    }
    if let Some(bw) = args.get_parsed::<f64>("server-bw")? {
        if !bw.is_finite() || bw <= 0.0 {
            return Err(
                CliError(format!("--server-bw {bw}: server bandwidth in Mbps must be > 0"))
                    .into(),
            );
        }
        cfg.env.server_bw_bps = bw * 1e6;
    }
    if let Some(mb) = args.get_parsed::<f64>("model-size")? {
        if !mb.is_finite() || mb <= 0.0 {
            return Err(
                CliError(format!("--model-size {mb}: model size in MB must be > 0")).into(),
            );
        }
        cfg.env.model_size_bits = mb * 8e6;
    }
    // Event-driven network fabric (mirrors the churn flags: a mode
    // selects the model, satellite flags refine it and are rejected
    // without it).
    if let Some(mode) = args.get_choice("fabric", &["off", "none", "fifo", "fair"])? {
        cfg.env.fabric = safa::net::fabric::FabricConfig::from_parts(
            &mode,
            args.get_parsed::<i64>("fabric-streams")?,
            args.get("fabric-link"),
            args.get_parsed::<f64>("fabric-link-spread")?,
            args.get_parsed::<f64>("fabric-latency")?,
            args.get_parsed::<f64>("fabric-jitter")?,
            args.get_parsed::<f64>("fabric-loss")?,
            args.get_parsed::<i64>("fabric-retries")?,
            args.get("fabric-compression"),
            args.get_parsed::<f64>("fabric-topk")?,
            args.get_parsed::<i64>("fabric-bits")?,
        )?;
    } else if [
        "fabric-streams",
        "fabric-link",
        "fabric-link-spread",
        "fabric-latency",
        "fabric-jitter",
        "fabric-loss",
        "fabric-retries",
        "fabric-compression",
        "fabric-topk",
        "fabric-bits",
    ]
    .iter()
    .any(|f| args.get(f).is_some())
    {
        return Err(CliError(
            "--fabric-* flags require --fabric none|fifo|fair".into(),
        )
        .into());
    }
    // Fault-injection plan (same shape again: --faults selects the mode,
    // satellite flags refine it and are rejected without it).
    if let Some(mode) = args.get_choice("faults", &["off", "on"])? {
        cfg.env.faults = safa::faults::FaultPlan::from_parts(
            &mode,
            args.get_parsed::<f64>("faults-crash-hazard")?,
            args.get_parsed::<f64>("faults-flap")?,
            args.get_parsed::<f64>("faults-flap-downtime")?,
            args.get_parsed::<i64>("faults-regions")?,
            args.get_parsed::<f64>("faults-outage")?,
            args.get_parsed::<f64>("faults-outage-len")?,
            args.get_parsed::<f64>("faults-degrade")?,
            args.get_parsed::<f64>("faults-degrade-factor")?,
            args.get_parsed::<i64>("faults-retries")?,
            args.get_parsed::<f64>("faults-backoff")?,
            args.get_parsed::<f64>("faults-backoff-cap")?,
            args.get_parsed::<bool>("faults-partial-credit")?,
        )?;
    } else if [
        "faults-crash-hazard",
        "faults-flap",
        "faults-flap-downtime",
        "faults-regions",
        "faults-outage",
        "faults-outage-len",
        "faults-degrade",
        "faults-degrade-factor",
        "faults-retries",
        "faults-backoff",
        "faults-backoff-cap",
        "faults-partial-credit",
    ]
    .iter()
    .any(|f| args.get(f).is_some())
    {
        return Err(CliError(
            "--faults-* flags require --faults off|on".into(),
        )
        .into());
    }
    // Continuous wall-clock scenario (same shape: --scenario selects the
    // process, satellite flags refine it and are rejected without it).
    if let Some(mode) =
        args.get_choice("scenario", &["off", "continuous", "bernoulli", "markov"])?
    {
        cfg.env.scenario = safa::scenario::ScenarioSpec::from_parts(
            &mode,
            args.get_parsed::<f64>("scenario-crash-prob")?,
            args.get_parsed::<f64>("scenario-uptime")?,
            args.get_parsed::<f64>("scenario-downtime")?,
            args.get_parsed::<f64>("scenario-diurnal-amp")?,
            args.get_parsed::<f64>("scenario-diurnal-period")?,
            args.get_parsed::<i64>("scenario-regions")?,
            args.get_parsed::<f64>("scenario-flash-at")?,
            args.get_parsed::<i64>("scenario-flash-joins")?,
            args.get_parsed::<i64>("scenario-flash-leaves")?,
            args.get_parsed::<f64>("scenario-outage-at")?,
            args.get_parsed::<i64>("scenario-outage-region")?,
            args.get_parsed::<f64>("scenario-outage-len")?,
        )?;
    } else if [
        "scenario-crash-prob",
        "scenario-uptime",
        "scenario-downtime",
        "scenario-diurnal-amp",
        "scenario-diurnal-period",
        "scenario-regions",
        "scenario-flash-at",
        "scenario-flash-joins",
        "scenario-flash-leaves",
        "scenario-outage-at",
        "scenario-outage-region",
        "scenario-outage-len",
    ]
    .iter()
    .any(|f| args.get(f).is_some())
    {
        return Err(CliError(
            "--scenario-* flags require --scenario off|continuous|bernoulli|markov".into(),
        )
        .into());
    }
    if let Some(r) = args.get_parsed::<usize>("rounds")? {
        cfg.train.rounds = r;
    }
    if let Some(s) = args.get_parsed::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(m) = args.get_parsed::<usize>("m")? {
        cfg.env.m = m;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = Backend::parse(b)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> CliResult<()> {
    let cfg = build_config(args)?;
    safa::log_info!(
        "running {} on {} (m={}, C={}, cr={}, tau={}, rounds={})",
        cfg.protocol.kind.name(),
        cfg.task.kind.name(),
        cfg.env.m,
        cfg.protocol.c_fraction,
        cfg.env.crash_prob,
        cfg.protocol.tau,
        cfg.train.rounds
    );
    let result = if cfg.backend == Backend::Xla {
        run_with_xla(&cfg)?
    } else {
        run_experiment(&cfg)?
    };
    println!(
        "protocol={} rounds={} avg_round_len={:.2}s avg_t_dist={:.2}s SR={:.3} EUR={:.3} VV={:.3} futility={:.3} online={:.3} down_MB/round={:.2} up_MB/round={:.2}",
        result.protocol,
        result.rounds.len(),
        result.avg_round_len(),
        result.avg_t_dist(),
        result.sync_ratio(),
        result.eur(),
        result.version_variance(),
        result.futility(),
        result.avg_online_fraction(),
        result.avg_bytes_down() / 1e6,
        result.avg_bytes_up() / 1e6,
    );
    if result.avg_bytes_saved() > 0.0 {
        println!(
            "compression_saved_MB/round={:.2}",
            result.avg_bytes_saved() / 1e6
        );
    }
    let hist = result.staleness_histogram();
    if hist.iter().skip(1).any(|&c| c > 0) {
        println!("staleness_histogram={hist:?}");
    }
    if let Some(loss) = result.best_loss() {
        println!("best_loss={loss:.6}");
    }
    if let Some(acc) = result.best_accuracy() {
        println!("best_accuracy={acc:.4}");
    }
    if let Some(e) = result.final_eval {
        println!("final_loss={:.6} final_accuracy={:.4}", e.loss, e.accuracy);
    }
    let out = args
        .get("out")
        .map(str::to_string)
        .unwrap_or_else(|| format!("results/run_{}_{}.json", result.task, result.protocol));
    write_results_file(&out, &result.to_json().to_string_pretty())?;
    println!("wrote {out}");
    Ok(())
}

/// Run with the XLA (PJRT artifact) backend.
fn run_with_xla(cfg: &ExperimentConfig) -> CliResult<safa::metrics::RunResult> {
    use safa::coordinator::Coordinator;
    use safa::data::{partition_gaussian, synth, FedData};
    use safa::runtime::XlaTrainer;
    use safa::util::rng::Pcg64;
    use std::sync::Arc;
    let (train, test) = synth::generate(cfg.task.kind, cfg.task.n, cfg.task.n_test, cfg.seed);
    let mut rng = Pcg64::with_stream(cfg.seed, 0x9a57);
    let partitions = partition_gaussian(train.n, cfg.env.m, cfg.env.partition_rel_std, &mut rng);
    let data = Arc::new(FedData {
        train,
        test,
        partitions,
    });
    let trainer = XlaTrainer::new(cfg, Arc::clone(&data))?;
    Ok(Coordinator::with_trainer(cfg, data, Box::new(trainer))?.run())
}

fn cmd_sweep(args: &Args) -> CliResult<()> {
    let base = build_config(args)?;
    let protocols: Vec<ProtocolKind> = match args.get("protocols") {
        Some(spec) => spec
            .split(',')
            .map(|s| ProtocolKind::parse(s.trim()))
            .collect::<Result<_, _>>()?,
        None => vec![ProtocolKind::FedAvg, ProtocolKind::FedCs, ProtocolKind::Safa],
    };
    let cs: Vec<f64> = args
        .get_list("c")?
        .unwrap_or_else(|| vec![0.1, 0.3, 0.5, 0.7, 1.0]);
    let crs: Vec<f64> = args
        .get_list("cr")?
        .unwrap_or_else(|| vec![0.1, 0.3, 0.5, 0.7]);
    let metric = args.get("metric").unwrap_or("round_len").to_string();

    let mut table = Table::new(
        &format!("{} — {}", base.name, metric),
        &crs,
        &cs,
    );
    for proto in &protocols {
        let mut rows = Vec::new();
        for &cr in &crs {
            let mut row = Vec::new();
            for &c in &cs {
                let mut cfg = base.clone();
                cfg.protocol.kind = *proto;
                cfg.protocol.c_fraction = c;
                cfg.env.crash_prob = cr;
                let r = run_experiment(&cfg)?;
                let v = match metric.as_str() {
                    "round_len" => r.avg_round_len(),
                    "t_dist" => r.avg_t_dist(),
                    "sr" => r.sync_ratio(),
                    "eur" => r.eur(),
                    "vv" => r.version_variance(),
                    "futility" => r.futility(),
                    "online" => r.avg_online_fraction(),
                    "best_loss" => r.best_loss().unwrap_or(f64::NAN),
                    "best_accuracy" => r.best_accuracy().unwrap_or(f64::NAN),
                    other => {
                        return Err(
                            CliError(format!("unknown metric '{other}'")).into()
                        )
                    }
                };
                row.push(v);
            }
            rows.push(row);
        }
        table.add_block(proto.name(), rows);
    }
    table.emit(&format!("sweep_{}_{metric}", base.task.kind.name()));
    Ok(())
}

fn cmd_profile(args: &Args) -> CliResult<()> {
    use safa::telemetry::profile::{
        render_table, run_spec, write_json, ProfileChurn, ProfileFabric, ProfileSpec,
    };
    let mut spec = ProfileSpec::default();
    if let Some(list) = args.get("protocols") {
        spec.protocols = list
            .split(',')
            .map(|s| ProtocolKind::parse(s.trim()))
            .collect::<Result<_, _>>()?;
    }
    if let Some(list) = args.get("churn") {
        spec.churns = list
            .split(',')
            .map(|s| {
                ProfileChurn::parse(s.trim()).ok_or_else(|| {
                    CliError(format!("--churn: expected bernoulli|markov, got '{s}'"))
                })
            })
            .collect::<Result<_, _>>()?;
    }
    if let Some(list) = args.get("fabric") {
        spec.fabrics = list
            .split(',')
            .map(|s| {
                ProfileFabric::parse(s.trim()).ok_or_else(|| {
                    CliError(format!("--fabric: expected off|contended, got '{s}'"))
                })
            })
            .collect::<Result<_, _>>()?;
    }
    if let Some(ms) = args.get_list::<usize>("m")? {
        spec.m_values = ms;
    }
    spec.rounds = args.get_or("rounds", spec.rounds)?;
    spec.warmup = args.get_or("warmup", spec.warmup)?;
    let cells = run_spec(&spec)?;
    print!("{}", render_table(&cells));
    if let Some(path) = args.get("json") {
        write_json(&cells, path)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_report(args: &Args) -> CliResult<()> {
    use safa::report::{parse_trace, render_report, render_timeline, report_json};
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| {
            CliError("usage: safa report <trace.jsonl> [--client K] [--json out.json]".into())
        })?;
    let text = std::fs::read_to_string(path)?;
    let trace = parse_trace(&text)?;
    print!("{}", render_report(&trace));
    if let Some(client) = args.get_parsed::<usize>("client")? {
        println!();
        print!("{}", render_timeline(&trace, client));
    }
    if let Some(out) = args.get("json") {
        write_results_file(out, &report_json(&trace).to_string_pretty())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_bias(args: &Args) -> CliResult<()> {
    let cr = args.get_or("cr", 0.3)?;
    let rounds = args.get_or("rounds", 20u32)?;
    let (fedavg, [c1, c2, c3]) = safa::analysis::fig5_series(cr, rounds);
    let x: Vec<f64> = (1..=rounds).map(|r| r as f64).collect();
    let mut s = Series::new(&format!("Fig. 5 bias (cr={cr})"), "round", x);
    s.add_line("FedAvg", fedavg);
    s.add_line("SAFA case 1", c1);
    s.add_line("SAFA case 2", c2);
    s.add_line("SAFA case 3", c3);
    s.emit("fig5_bias_cli");
    Ok(())
}
