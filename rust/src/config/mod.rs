//! Experiment configuration: the paper's Table II presets, the environment
//! model constants (§IV-A), protocol hyper-parameters, and loading from
//! TOML files / CLI overrides.

pub mod presets;

pub use presets::{preset, preset_names, scaled_preset};

use crate::error::{Result, SafaError};
use crate::util::toml::TomlDoc;

/// Which ML task (paper §IV-A, Table II).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Task 1: regression on a Boston-housing-like dataset.
    Regression,
    /// Task 2: CNN classification on an MNIST-like dataset.
    Cnn,
    /// Task 3: linear SVM on a KDD-Cup'99-like intrusion dataset.
    Svm,
}

impl TaskKind {
    pub fn parse(s: &str) -> Result<TaskKind> {
        match s.to_ascii_lowercase().as_str() {
            "task1" | "regression" | "boston" => Ok(TaskKind::Regression),
            "task2" | "cnn" | "mnist" => Ok(TaskKind::Cnn),
            "task3" | "svm" | "kdd" => Ok(TaskKind::Svm),
            other => Err(SafaError::Config(format!("unknown task '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Regression => "regression",
            TaskKind::Cnn => "cnn",
            TaskKind::Svm => "svm",
        }
    }
}

/// Which protocol drives the federation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProtocolKind {
    Safa,
    FedAvg,
    FedCs,
    FullyLocal,
}

impl ProtocolKind {
    pub fn parse(s: &str) -> Result<ProtocolKind> {
        match s.to_ascii_lowercase().as_str() {
            "safa" => Ok(ProtocolKind::Safa),
            "fedavg" => Ok(ProtocolKind::FedAvg),
            "fedcs" => Ok(ProtocolKind::FedCs),
            "local" | "fullylocal" | "fully_local" => Ok(ProtocolKind::FullyLocal),
            other => Err(SafaError::Config(format!("unknown protocol '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ProtocolKind::Safa => "SAFA",
            ProtocolKind::FedAvg => "FedAvg",
            ProtocolKind::FedCs => "FedCS",
            ProtocolKind::FullyLocal => "FullyLocal",
        }
    }

    pub const ALL: [ProtocolKind; 4] = [
        ProtocolKind::FullyLocal,
        ProtocolKind::FedAvg,
        ProtocolKind::FedCs,
        ProtocolKind::Safa,
    ];
}

/// Which trainer backend performs local updates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust SGD (fast, used by the benchmark grids).
    Native,
    /// PJRT execution of the JAX/Pallas AOT artifacts (the paper stack).
    Xla,
    /// No training (timing/protocol metrics only).
    Null,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            "null" | "none" => Ok(Backend::Null),
            other => Err(SafaError::Config(format!("unknown backend '{other}'"))),
        }
    }
}

/// CNN layer widths (Task 2). The paper's model is conv5x5(c1) → pool →
/// conv5x5(c2) → pool → fc(hidden, ReLU) → softmax(10); Table II implies
/// (20, 50) conv channels. Scaled presets shrink these for 1-core grids.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CnnArch {
    pub c1: usize,
    pub c2: usize,
    pub hidden: usize,
}

impl CnnArch {
    /// The paper's architecture.
    pub fn paper() -> CnnArch {
        CnnArch {
            c1: 20,
            c2: 50,
            hidden: 500,
        }
    }

    /// Scaled-down architecture for single-core benchmark grids.
    pub fn scaled() -> CnnArch {
        CnnArch {
            c1: 8,
            c2: 16,
            hidden: 64,
        }
    }
}

/// Task/dataset parameters (paper Table II).
#[derive(Debug, Clone)]
pub struct TaskConfig {
    pub kind: TaskKind,
    /// Total training-set size n.
    pub n: usize,
    /// Feature dimensionality d (28*28 for the CNN).
    pub d: usize,
    /// Number of classes (1 for regression, 2 for SVM).
    pub num_classes: usize,
    /// Held-out test-set size used for global evaluation.
    pub n_test: usize,
    /// CNN layer widths (Task 2 only; ignored elsewhere).
    pub cnn: CnnArch,
}

/// Edge-environment parameters (paper §IV-A).
#[derive(Debug, Clone)]
pub struct EnvConfig {
    /// Number of clients m.
    pub m: usize,
    /// Per-round crash probability cr (i.i.d. across clients and rounds).
    pub crash_prob: f64,
    /// Rate of the exponential client-performance distribution
    /// (batches per second); the paper uses lambda = 1.0.
    pub perf_lambda: f64,
    /// Relative std of the Gaussian partition-size distribution
    /// N(mu, rel_std * mu); the paper uses 0.3.
    pub partition_rel_std: f64,
    /// Client uplink/downlink bandwidth in bits/s (paper: 1.40 Mbps).
    pub client_bw_bps: f64,
    /// Effective per-model server distribution bandwidth in bits/s.
    ///
    /// The paper states 10 Gbps, but its T_dist tables correspond to
    /// ~0.404 s per 10 MB model (Tasks 1/3) — an effective ~198 Mbps per
    /// sequentialized copy. We calibrate to the tables and document the
    /// discrepancy in EXPERIMENTS.md.
    pub server_bw_bps: f64,
    /// Compressed model size in bits (paper: 10 MB after compression).
    pub model_size_bits: f64,
}

/// Federated-optimization parameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Maximum number of global rounds r.
    pub rounds: usize,
    /// Local epochs E per round.
    pub epochs: usize,
    /// Mini-batch size B.
    pub batch_size: usize,
    /// Learning rate eta.
    pub lr: f64,
    /// Round time limit T_lim in seconds.
    pub t_lim: f64,
}

/// Protocol hyper-parameters.
#[derive(Debug, Clone)]
pub struct ProtocolConfig {
    pub kind: ProtocolKind,
    /// Selection fraction C.
    pub c_fraction: f64,
    /// Lag tolerance tau (SAFA only).
    pub tau: usize,
}

/// A complete experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub task: TaskConfig,
    pub env: EnvConfig,
    pub train: TrainConfig,
    pub protocol: ProtocolConfig,
    pub backend: Backend,
    pub seed: u64,
    /// Evaluate the global model every `eval_every` rounds (1 = every
    /// round; loss-trace figures need 1, grid tables can skip).
    pub eval_every: usize,
    /// Directory holding AOT artifacts (Backend::Xla only).
    pub artifacts_dir: String,
}

impl ExperimentConfig {
    /// Selection quota = ceil(C * m), at least 1 (the paper selects "a
    /// C-fraction"; with m=5, C=0.1 this must round up to one client).
    pub fn quota(&self) -> usize {
        ((self.protocol.c_fraction * self.env.m as f64).ceil() as usize).max(1)
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        let e = |msg: String| Err(SafaError::Config(msg));
        if self.env.m == 0 {
            return e("m must be positive".into());
        }
        if !(0.0..=1.0).contains(&self.env.crash_prob) {
            return e(format!("crash_prob {} outside [0,1]", self.env.crash_prob));
        }
        if !(0.0..=1.0).contains(&self.protocol.c_fraction) || self.protocol.c_fraction == 0.0 {
            return e(format!(
                "c_fraction {} outside (0,1]",
                self.protocol.c_fraction
            ));
        }
        if self.protocol.kind == ProtocolKind::Safa && self.protocol.tau == 0 {
            return e("tau must be >= 1 for SAFA".into());
        }
        if self.train.rounds == 0 || self.train.epochs == 0 || self.train.batch_size == 0 {
            return e("rounds, epochs and batch_size must be positive".into());
        }
        if self.task.n < self.env.m {
            return e(format!(
                "dataset size n={} smaller than client count m={}",
                self.task.n, self.env.m
            ));
        }
        if self.train.t_lim <= 0.0 {
            return e("t_lim must be positive".into());
        }
        if self.eval_every == 0 {
            return e("eval_every must be >= 1".into());
        }
        Ok(())
    }

    /// Load from a TOML document, starting from the named preset (key
    /// `preset`, default "task1") and applying any overrides present.
    pub fn from_toml(doc: &TomlDoc) -> Result<ExperimentConfig> {
        let preset_name = doc.get_str("preset").unwrap_or("task1");
        let mut cfg = preset(preset_name)?;
        if let Some(v) = doc.get_str("name") {
            cfg.name = v.to_string();
        }
        if let Some(v) = doc.get_i64("seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_str("backend") {
            cfg.backend = Backend::parse(v)?;
        }
        if let Some(v) = doc.get_str("protocol.kind") {
            cfg.protocol.kind = ProtocolKind::parse(v)?;
        }
        if let Some(v) = doc.get_f64("protocol.c_fraction") {
            cfg.protocol.c_fraction = v;
        }
        if let Some(v) = doc.get_i64("protocol.tau") {
            cfg.protocol.tau = v as usize;
        }
        if let Some(v) = doc.get_i64("env.m") {
            cfg.env.m = v as usize;
        }
        if let Some(v) = doc.get_f64("env.crash_prob") {
            cfg.env.crash_prob = v;
        }
        if let Some(v) = doc.get_f64("env.client_bw_mbps") {
            cfg.env.client_bw_bps = v * 1e6;
        }
        if let Some(v) = doc.get_f64("env.server_bw_mbps") {
            cfg.env.server_bw_bps = v * 1e6;
        }
        if let Some(v) = doc.get_f64("env.model_size_mb") {
            cfg.env.model_size_bits = v * 8e6;
        }
        if let Some(v) = doc.get_i64("train.rounds") {
            cfg.train.rounds = v as usize;
        }
        if let Some(v) = doc.get_i64("train.epochs") {
            cfg.train.epochs = v as usize;
        }
        if let Some(v) = doc.get_i64("train.batch_size") {
            cfg.train.batch_size = v as usize;
        }
        if let Some(v) = doc.get_f64("train.lr") {
            cfg.train.lr = v;
        }
        if let Some(v) = doc.get_f64("train.t_lim") {
            cfg.train.t_lim = v;
        }
        if let Some(v) = doc.get_i64("task.n") {
            cfg.task.n = v as usize;
        }
        if let Some(v) = doc.get_i64("task.n_test") {
            cfg.task.n_test = v as usize;
        }
        if let Some(v) = doc.get_str("artifacts_dir") {
            cfg.artifacts_dir = v.to_string();
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quota_rounds_up_and_floors_at_one() {
        let mut cfg = preset("task1").unwrap();
        cfg.env.m = 5;
        cfg.protocol.c_fraction = 0.1;
        assert_eq!(cfg.quota(), 1);
        cfg.protocol.c_fraction = 0.3;
        assert_eq!(cfg.quota(), 2);
        cfg.protocol.c_fraction = 1.0;
        assert_eq!(cfg.quota(), 5);
        cfg.env.m = 100;
        cfg.protocol.c_fraction = 0.1;
        assert_eq!(cfg.quota(), 10);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut cfg = preset("task1").unwrap();
        assert!(cfg.validate().is_ok());
        cfg.env.crash_prob = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = preset("task1").unwrap();
        cfg.protocol.c_fraction = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = preset("task1").unwrap();
        cfg.protocol.tau = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = preset("task1").unwrap();
        cfg.env.m = cfg.task.n + 1;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn from_toml_applies_overrides() {
        let doc = crate::util::toml::parse(
            r#"
            preset = "task1"
            seed = 99
            [protocol]
            kind = "fedavg"
            c_fraction = 0.5
            [env]
            crash_prob = 0.3
            [train]
            rounds = 10
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.protocol.kind, ProtocolKind::FedAvg);
        assert_eq!(cfg.protocol.c_fraction, 0.5);
        assert_eq!(cfg.env.crash_prob, 0.3);
        assert_eq!(cfg.train.rounds, 10);
        // Untouched fields keep preset values.
        assert_eq!(cfg.env.m, 5);
    }

    #[test]
    fn parse_enums() {
        assert_eq!(TaskKind::parse("TASK2").unwrap(), TaskKind::Cnn);
        assert!(TaskKind::parse("task9").is_err());
        assert_eq!(ProtocolKind::parse("FedCS").unwrap(), ProtocolKind::FedCs);
        assert!(ProtocolKind::parse("x").is_err());
        assert_eq!(Backend::parse("XLA").unwrap(), Backend::Xla);
    }
}
