//! Experiment configuration: the paper's Table II presets, the environment
//! model constants (§IV-A), protocol hyper-parameters, and loading from
//! TOML files / CLI overrides.

pub mod presets;

pub use presets::{preset, preset_names, scaled_preset};

use crate::error::{Result, SafaError};
use crate::faults::FaultPlan;
use crate::net::fabric::FabricConfig;
use crate::scenario::ScenarioSpec;
use crate::util::toml::TomlDoc;

/// Which ML task (paper §IV-A, Table II).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Task 1: regression on a Boston-housing-like dataset.
    Regression,
    /// Task 2: CNN classification on an MNIST-like dataset.
    Cnn,
    /// Task 3: linear SVM on a KDD-Cup'99-like intrusion dataset.
    Svm,
}

impl TaskKind {
    pub fn parse(s: &str) -> Result<TaskKind> {
        match s.to_ascii_lowercase().as_str() {
            "task1" | "regression" | "boston" => Ok(TaskKind::Regression),
            "task2" | "cnn" | "mnist" => Ok(TaskKind::Cnn),
            "task3" | "svm" | "kdd" => Ok(TaskKind::Svm),
            other => Err(SafaError::Config(format!("unknown task '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Regression => "regression",
            TaskKind::Cnn => "cnn",
            TaskKind::Svm => "svm",
        }
    }
}

/// Which protocol drives the federation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProtocolKind {
    Safa,
    FedAvg,
    FedCs,
    /// Fully-asynchronous baseline with staleness-discounted server
    /// updates (Xie et al. 2019), for comparison against SAFA's
    /// semi-asynchronous middle ground.
    FedAsync,
    FullyLocal,
}

impl ProtocolKind {
    pub fn parse(s: &str) -> Result<ProtocolKind> {
        match s.to_ascii_lowercase().as_str() {
            "safa" => Ok(ProtocolKind::Safa),
            "fedavg" => Ok(ProtocolKind::FedAvg),
            "fedcs" => Ok(ProtocolKind::FedCs),
            "fedasync" | "fed_async" | "async" => Ok(ProtocolKind::FedAsync),
            "local" | "fullylocal" | "fully_local" => Ok(ProtocolKind::FullyLocal),
            other => Err(SafaError::Config(format!("unknown protocol '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ProtocolKind::Safa => "SAFA",
            ProtocolKind::FedAvg => "FedAvg",
            ProtocolKind::FedCs => "FedCS",
            ProtocolKind::FedAsync => "FedAsync",
            ProtocolKind::FullyLocal => "FullyLocal",
        }
    }

    pub const ALL: [ProtocolKind; 5] = [
        ProtocolKind::FullyLocal,
        ProtocolKind::FedAvg,
        ProtocolKind::FedCs,
        ProtocolKind::FedAsync,
        ProtocolKind::Safa,
    ];
}

/// Which trainer backend performs local updates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust SGD (fast, used by the benchmark grids).
    Native,
    /// PJRT execution of the JAX/Pallas AOT artifacts (the paper stack).
    Xla,
    /// No training (timing/protocol metrics only).
    Null,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            "null" | "none" => Ok(Backend::Null),
            other => Err(SafaError::Config(format!("unknown backend '{other}'"))),
        }
    }
}

/// CNN layer widths (Task 2). The paper's model is conv5x5(c1) → pool →
/// conv5x5(c2) → pool → fc(hidden, ReLU) → softmax(10); Table II implies
/// (20, 50) conv channels. Scaled presets shrink these for 1-core grids.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CnnArch {
    pub c1: usize,
    pub c2: usize,
    pub hidden: usize,
}

impl CnnArch {
    /// The paper's architecture.
    pub fn paper() -> CnnArch {
        CnnArch {
            c1: 20,
            c2: 50,
            hidden: 500,
        }
    }

    /// Scaled-down architecture for single-core benchmark grids.
    pub fn scaled() -> CnnArch {
        CnnArch {
            c1: 8,
            c2: 16,
            hidden: 64,
        }
    }
}

/// Task/dataset parameters (paper Table II).
#[derive(Debug, Clone)]
pub struct TaskConfig {
    pub kind: TaskKind,
    /// Total training-set size n.
    pub n: usize,
    /// Feature dimensionality d (28*28 for the CNN).
    pub d: usize,
    /// Number of classes (1 for regression, 2 for SVM).
    pub num_classes: usize,
    /// Held-out test-set size used for global evaluation.
    pub n_test: usize,
    /// CNN layer widths (Task 2 only; ignored elsewhere).
    pub cnn: CnnArch,
}

/// Client availability / churn process (consumed by the fleet engine,
/// [`crate::engine`]).
#[derive(Debug, Clone, PartialEq)]
pub enum ChurnModel {
    /// Paper parity (§IV-A): one i.i.d. Bernoulli(`crash_prob`) draw per
    /// (round, client); an offline client is offline all round.
    Bernoulli,
    /// Two-state on/off churn with exponential dwell times (seconds);
    /// clients drop and recover mid-round, and their state persists
    /// across rounds. Ignores `crash_prob`.
    Markov {
        mean_uptime_s: f64,
        mean_downtime_s: f64,
    },
    /// Deterministic replay of an online/offline matrix loaded from a
    /// file: one line per round, one `0`/`1` char per client; the trace
    /// cycles when the run is longer.
    Trace { path: String },
}

impl ChurnModel {
    /// Default Markov mean uptime (seconds), shared by the TOML and CLI
    /// parsers so both spell the same default model.
    pub const DEFAULT_UPTIME_S: f64 = 2000.0;
    /// Default Markov mean downtime (seconds).
    pub const DEFAULT_DOWNTIME_S: f64 = 500.0;

    /// Build a model from parsed front-end parts (shared by the TOML and
    /// CLI parsers so they cannot drift): `kind` is one of
    /// bernoulli|markov|trace (case-insensitive), missing dwell times
    /// fall back to the defaults above, and trace requires a file path.
    /// Parameters that do not apply to the chosen kind are rejected —
    /// silently ignoring them would hide a misconfigured run.
    pub fn from_parts(
        kind: &str,
        uptime_s: Option<f64>,
        downtime_s: Option<f64>,
        trace_path: Option<&str>,
    ) -> Result<ChurnModel> {
        let has_dwell = uptime_s.is_some() || downtime_s.is_some();
        match kind.to_ascii_lowercase().as_str() {
            "bernoulli" => {
                if has_dwell || trace_path.is_some() {
                    return Err(SafaError::Config(
                        "bernoulli churn takes no dwell times or trace file \
                         (did you mean churn = \"markov\" or \"trace\"?)"
                            .into(),
                    ));
                }
                Ok(ChurnModel::Bernoulli)
            }
            "markov" => {
                if trace_path.is_some() {
                    return Err(SafaError::Config(
                        "markov churn takes dwell times, not a trace file \
                         (did you mean churn = \"trace\"?)"
                            .into(),
                    ));
                }
                Ok(ChurnModel::Markov {
                    mean_uptime_s: uptime_s.unwrap_or(Self::DEFAULT_UPTIME_S),
                    mean_downtime_s: downtime_s.unwrap_or(Self::DEFAULT_DOWNTIME_S),
                })
            }
            "trace" => {
                if has_dwell {
                    return Err(SafaError::Config(
                        "trace churn takes a trace file, not dwell times \
                         (did you mean churn = \"markov\"?)"
                            .into(),
                    ));
                }
                Ok(ChurnModel::Trace {
                    path: trace_path
                        .ok_or_else(|| {
                            SafaError::Config(
                                "trace churn requires a trace file path \
                                 (env.churn_trace in TOML, --churn-trace on the CLI)"
                                    .into(),
                            )
                        })?
                        .to_string(),
                })
            }
            other => Err(SafaError::Config(format!("unknown churn model '{other}'"))),
        }
    }
}

/// Edge-environment parameters (paper §IV-A).
#[derive(Debug, Clone)]
pub struct EnvConfig {
    /// Number of clients m.
    pub m: usize,
    /// Per-round crash probability cr (i.i.d. across clients and rounds).
    pub crash_prob: f64,
    /// Rate of the exponential client-performance distribution
    /// (batches per second); the paper uses lambda = 1.0.
    pub perf_lambda: f64,
    /// Relative std of the Gaussian partition-size distribution
    /// N(mu, rel_std * mu); the paper uses 0.3.
    pub partition_rel_std: f64,
    /// Client uplink/downlink bandwidth in bits/s (paper: 1.40 Mbps).
    pub client_bw_bps: f64,
    /// Effective per-model server distribution bandwidth in bits/s.
    ///
    /// The paper states 10 Gbps, but its T_dist tables correspond to
    /// ~0.404 s per 10 MB model (Tasks 1/3) — an effective ~198 Mbps per
    /// sequentialized copy. We calibrate to the tables and document the
    /// discrepancy in EXPERIMENTS.md.
    pub server_bw_bps: f64,
    /// Compressed model size in bits (paper: 10 MB after compression).
    pub model_size_bits: f64,
    /// Client availability process (default: the paper's Bernoulli).
    pub churn: ChurnModel,
    /// Network fabric (contention, heterogeneous links, lossy transfers,
    /// update compression). Default: disabled — the closed-form Eq. 17–19
    /// arithmetic, untouched.
    pub fabric: FabricConfig,
    /// Fault-injection plan (crash hazards, flapping, regional outages,
    /// link degradation, retry/partial-credit policies). Default:
    /// disabled — the engine's legacy paths, bit-for-bit.
    pub faults: FaultPlan,
    /// Continuous wall-clock availability scenario (diurnal churn, flash
    /// crowds, regional outages) or a per-round reduction. Default:
    /// disabled — `env.churn` drives availability, bit-for-bit as before.
    /// When enabled it replaces `env.churn` entirely.
    pub scenario: ScenarioSpec,
}

/// Federated-optimization parameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Maximum number of global rounds r.
    pub rounds: usize,
    /// Local epochs E per round.
    pub epochs: usize,
    /// Mini-batch size B.
    pub batch_size: usize,
    /// Learning rate eta.
    pub lr: f64,
    /// Round time limit T_lim in seconds.
    pub t_lim: f64,
}

/// Protocol hyper-parameters.
#[derive(Debug, Clone)]
pub struct ProtocolConfig {
    pub kind: ProtocolKind,
    /// Selection fraction C.
    pub c_fraction: f64,
    /// Lag tolerance tau (SAFA only).
    pub tau: usize,
    /// Base server mixing rate alpha (FedAsync only): each applied update
    /// moves the global model by `alpha / (1 + staleness)^staleness_exp`.
    pub alpha: f64,
    /// Polynomial staleness-discount exponent `a` (FedAsync only).
    pub staleness_exp: f64,
}

/// A complete experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub task: TaskConfig,
    pub env: EnvConfig,
    pub train: TrainConfig,
    pub protocol: ProtocolConfig,
    pub backend: Backend,
    pub seed: u64,
    /// Evaluate the global model every `eval_every` rounds (1 = every
    /// round; loss-trace figures need 1, grid tables can skip).
    pub eval_every: usize,
    /// Directory holding AOT artifacts (Backend::Xla only).
    pub artifacts_dir: String,
}

impl ExperimentConfig {
    /// Selection quota = ceil(C * m), at least 1 (the paper selects "a
    /// C-fraction"; with m=5, C=0.1 this must round up to one client).
    pub fn quota(&self) -> usize {
        ((self.protocol.c_fraction * self.env.m as f64).ceil() as usize).max(1)
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        let e = |msg: String| Err(SafaError::Config(msg));
        if self.env.m == 0 {
            return e("m must be positive".into());
        }
        if !(0.0..=1.0).contains(&self.env.crash_prob) {
            return e(format!("crash_prob {} outside [0,1]", self.env.crash_prob));
        }
        if !(0.0..=1.0).contains(&self.protocol.c_fraction) || self.protocol.c_fraction == 0.0 {
            return e(format!(
                "c_fraction {} outside (0,1]",
                self.protocol.c_fraction
            ));
        }
        if self.protocol.kind == ProtocolKind::Safa && self.protocol.tau == 0 {
            return e("tau must be >= 1 for SAFA".into());
        }
        if self.protocol.kind == ProtocolKind::FedAsync {
            if !(0.0..=1.0).contains(&self.protocol.alpha) || self.protocol.alpha == 0.0 {
                return e(format!("alpha {} outside (0,1]", self.protocol.alpha));
            }
            // Finiteness first so NaN (which every comparison rejects)
            // cannot slip through and poison the discount weights.
            if !self.protocol.staleness_exp.is_finite() || self.protocol.staleness_exp < 0.0 {
                return e(format!(
                    "staleness_exp {} must be >= 0 and finite",
                    self.protocol.staleness_exp
                ));
            }
        }
        match &self.env.churn {
            ChurnModel::Markov {
                mean_uptime_s,
                mean_downtime_s,
            } => {
                // Finiteness first so NaN/inf fail too (an infinite dwell
                // would panic inside Exponential::new).
                if !mean_uptime_s.is_finite()
                    || !mean_downtime_s.is_finite()
                    || *mean_uptime_s <= 0.0
                    || *mean_downtime_s <= 0.0
                {
                    return e(format!(
                        "Markov churn dwell times must be positive and finite (up={mean_uptime_s}, down={mean_downtime_s})"
                    ));
                }
            }
            ChurnModel::Trace { path } => {
                if path.is_empty() {
                    return e("trace churn requires a trace file path".into());
                }
            }
            ChurnModel::Bernoulli => {}
        }
        if self.train.rounds == 0 || self.train.epochs == 0 || self.train.batch_size == 0 {
            return e("rounds, epochs and batch_size must be positive".into());
        }
        if self.task.n < self.env.m {
            return e(format!(
                "dataset size n={} smaller than client count m={}",
                self.task.n, self.env.m
            ));
        }
        if self.train.t_lim <= 0.0 {
            return e("t_lim must be positive".into());
        }
        if self.eval_every == 0 {
            return e("eval_every must be >= 1".into());
        }
        // Network constants divide into every transfer time: a zero,
        // negative, NaN or infinite value poisons all downstream timings,
        // so reject it at load time (finiteness first — NaN fails every
        // comparison) instead of clamping later.
        if !self.env.client_bw_bps.is_finite() || self.env.client_bw_bps <= 0.0 {
            return e(format!(
                "client_bw_bps {} must be positive and finite",
                self.env.client_bw_bps
            ));
        }
        if !self.env.server_bw_bps.is_finite() || self.env.server_bw_bps <= 0.0 {
            return e(format!(
                "server_bw_bps {} must be positive and finite",
                self.env.server_bw_bps
            ));
        }
        if !self.env.model_size_bits.is_finite() || self.env.model_size_bits <= 0.0 {
            return e(format!(
                "model_size_bits {} must be positive and finite",
                self.env.model_size_bits
            ));
        }
        // Positive perf_lambda (plus build_clients' floor on each draw)
        // guarantees every client's perf is positive, which is what lets
        // net::t_train divide without a silent clamp.
        if !self.env.perf_lambda.is_finite() || self.env.perf_lambda <= 0.0 {
            return e(format!(
                "perf_lambda {} must be positive and finite",
                self.env.perf_lambda
            ));
        }
        self.env.fabric.validate()?;
        self.env.faults.validate()?;
        self.env.scenario.validate()?;
        Ok(())
    }

    /// Load from a TOML document, starting from the named preset (key
    /// `preset`, default "task1") and applying any overrides present.
    pub fn from_toml(doc: &TomlDoc) -> Result<ExperimentConfig> {
        let preset_name = doc.get_str("preset").unwrap_or("task1");
        let mut cfg = preset(preset_name)?;
        if let Some(v) = doc.get_str("name") {
            cfg.name = v.to_string();
        }
        if let Some(v) = doc.get_i64("seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_str("backend") {
            cfg.backend = Backend::parse(v)?;
        }
        if let Some(v) = doc.get_str("protocol.kind") {
            cfg.protocol.kind = ProtocolKind::parse(v)?;
        }
        if let Some(v) = doc.get_f64("protocol.c_fraction") {
            cfg.protocol.c_fraction = v;
        }
        if let Some(v) = doc.get_i64("protocol.tau") {
            cfg.protocol.tau = v as usize;
        }
        if let Some(v) = doc.get_f64("protocol.alpha") {
            cfg.protocol.alpha = v;
        }
        if let Some(v) = doc.get_f64("protocol.staleness_exp") {
            cfg.protocol.staleness_exp = v;
        }
        if let Some(v) = doc.get_i64("env.m") {
            cfg.env.m = v as usize;
        }
        if let Some(v) = doc.get_f64("env.crash_prob") {
            cfg.env.crash_prob = v;
        }
        // Unit conversions (also documented in `safa --help`): the TOML
        // keys carry megabits/s and megabytes; EnvConfig stores bits/s
        // and bits. Positivity is enforced by validate() below.
        if let Some(v) = doc.get_f64("env.client_bw_mbps") {
            cfg.env.client_bw_bps = v * 1e6;
        }
        if let Some(v) = doc.get_f64("env.server_bw_mbps") {
            cfg.env.server_bw_bps = v * 1e6;
        }
        if let Some(v) = doc.get_f64("env.model_size_mb") {
            cfg.env.model_size_bits = v * 8e6;
        }
        if let Some(v) = doc.get_str("env.fabric") {
            cfg.env.fabric = FabricConfig::from_parts(
                v,
                doc.get_i64("env.fabric_streams"),
                doc.get_str("env.fabric_link"),
                doc.get_f64("env.fabric_link_spread"),
                doc.get_f64("env.fabric_latency_s"),
                doc.get_f64("env.fabric_jitter_s"),
                doc.get_f64("env.fabric_loss_prob"),
                doc.get_i64("env.fabric_max_retries"),
                doc.get_str("env.fabric_compression"),
                doc.get_f64("env.fabric_topk_fraction"),
                doc.get_i64("env.fabric_quantize_bits"),
            )?;
        } else if doc.get_i64("env.fabric_streams").is_some()
            || doc.get_str("env.fabric_link").is_some()
            || doc.get_f64("env.fabric_link_spread").is_some()
            || doc.get_f64("env.fabric_latency_s").is_some()
            || doc.get_f64("env.fabric_jitter_s").is_some()
            || doc.get_f64("env.fabric_loss_prob").is_some()
            || doc.get_i64("env.fabric_max_retries").is_some()
            || doc.get_str("env.fabric_compression").is_some()
            || doc.get_f64("env.fabric_topk_fraction").is_some()
            || doc.get_i64("env.fabric_quantize_bits").is_some()
        {
            return Err(SafaError::Config(
                "env.fabric_* keys require env.fabric = \"none\", \"fifo\" or \"fair\"".into(),
            ));
        }
        if let Some(v) = doc.get_str("env.faults") {
            cfg.env.faults = FaultPlan::from_parts(
                v,
                doc.get_f64("env.faults_crash_hazard"),
                doc.get_f64("env.faults_flap_prob"),
                doc.get_f64("env.faults_flap_downtime_s"),
                doc.get_i64("env.faults_regions"),
                doc.get_f64("env.faults_outage_prob"),
                doc.get_f64("env.faults_outage_len_s"),
                doc.get_f64("env.faults_degrade_prob"),
                doc.get_f64("env.faults_degrade_factor"),
                doc.get_i64("env.faults_retry_max"),
                doc.get_f64("env.faults_retry_backoff_s"),
                doc.get_f64("env.faults_retry_backoff_cap_s"),
                doc.get_bool("env.faults_partial_credit"),
            )?;
        } else if doc.get_f64("env.faults_crash_hazard").is_some()
            || doc.get_f64("env.faults_flap_prob").is_some()
            || doc.get_f64("env.faults_flap_downtime_s").is_some()
            || doc.get_i64("env.faults_regions").is_some()
            || doc.get_f64("env.faults_outage_prob").is_some()
            || doc.get_f64("env.faults_outage_len_s").is_some()
            || doc.get_f64("env.faults_degrade_prob").is_some()
            || doc.get_f64("env.faults_degrade_factor").is_some()
            || doc.get_i64("env.faults_retry_max").is_some()
            || doc.get_f64("env.faults_retry_backoff_s").is_some()
            || doc.get_f64("env.faults_retry_backoff_cap_s").is_some()
            || doc.get_bool("env.faults_partial_credit").is_some()
        {
            return Err(SafaError::Config(
                "env.faults_* keys require env.faults = \"off\" or \"on\"".into(),
            ));
        }
        if let Some(v) = doc.get_str("env.scenario") {
            cfg.env.scenario = ScenarioSpec::from_parts(
                v,
                doc.get_f64("env.scenario_crash_prob"),
                doc.get_f64("env.scenario_uptime_s"),
                doc.get_f64("env.scenario_downtime_s"),
                doc.get_f64("env.scenario_diurnal_amp"),
                doc.get_f64("env.scenario_diurnal_period_s"),
                doc.get_i64("env.scenario_regions"),
                doc.get_f64("env.scenario_flash_at_s"),
                doc.get_i64("env.scenario_flash_joins"),
                doc.get_i64("env.scenario_flash_leaves"),
                doc.get_f64("env.scenario_outage_at_s"),
                doc.get_i64("env.scenario_outage_region"),
                doc.get_f64("env.scenario_outage_len_s"),
            )?;
        } else if doc.get_f64("env.scenario_crash_prob").is_some()
            || doc.get_f64("env.scenario_uptime_s").is_some()
            || doc.get_f64("env.scenario_downtime_s").is_some()
            || doc.get_f64("env.scenario_diurnal_amp").is_some()
            || doc.get_f64("env.scenario_diurnal_period_s").is_some()
            || doc.get_i64("env.scenario_regions").is_some()
            || doc.get_f64("env.scenario_flash_at_s").is_some()
            || doc.get_i64("env.scenario_flash_joins").is_some()
            || doc.get_i64("env.scenario_flash_leaves").is_some()
            || doc.get_f64("env.scenario_outage_at_s").is_some()
            || doc.get_i64("env.scenario_outage_region").is_some()
            || doc.get_f64("env.scenario_outage_len_s").is_some()
        {
            return Err(SafaError::Config(
                "env.scenario_* keys require env.scenario = \"off\", \"continuous\", \
                 \"bernoulli\" or \"markov\""
                    .into(),
            ));
        }
        if let Some(v) = doc.get_str("env.churn") {
            cfg.env.churn = ChurnModel::from_parts(
                v,
                doc.get_f64("env.churn_uptime_s"),
                doc.get_f64("env.churn_downtime_s"),
                doc.get_str("env.churn_trace"),
            )?;
        } else if doc.get_f64("env.churn_uptime_s").is_some()
            || doc.get_f64("env.churn_downtime_s").is_some()
            || doc.get_str("env.churn_trace").is_some()
        {
            return Err(SafaError::Config(
                "env.churn_uptime_s / env.churn_downtime_s / env.churn_trace \
                 require env.churn = \"markov\" or \"trace\""
                    .into(),
            ));
        }
        if let Some(v) = doc.get_i64("train.rounds") {
            cfg.train.rounds = v as usize;
        }
        if let Some(v) = doc.get_i64("train.epochs") {
            cfg.train.epochs = v as usize;
        }
        if let Some(v) = doc.get_i64("train.batch_size") {
            cfg.train.batch_size = v as usize;
        }
        if let Some(v) = doc.get_f64("train.lr") {
            cfg.train.lr = v;
        }
        if let Some(v) = doc.get_f64("train.t_lim") {
            cfg.train.t_lim = v;
        }
        if let Some(v) = doc.get_i64("task.n") {
            cfg.task.n = v as usize;
        }
        if let Some(v) = doc.get_i64("task.n_test") {
            cfg.task.n_test = v as usize;
        }
        if let Some(v) = doc.get_str("artifacts_dir") {
            cfg.artifacts_dir = v.to_string();
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quota_rounds_up_and_floors_at_one() {
        let mut cfg = preset("task1").unwrap();
        cfg.env.m = 5;
        cfg.protocol.c_fraction = 0.1;
        assert_eq!(cfg.quota(), 1);
        cfg.protocol.c_fraction = 0.3;
        assert_eq!(cfg.quota(), 2);
        cfg.protocol.c_fraction = 1.0;
        assert_eq!(cfg.quota(), 5);
        cfg.env.m = 100;
        cfg.protocol.c_fraction = 0.1;
        assert_eq!(cfg.quota(), 10);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut cfg = preset("task1").unwrap();
        assert!(cfg.validate().is_ok());
        cfg.env.crash_prob = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = preset("task1").unwrap();
        cfg.protocol.c_fraction = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = preset("task1").unwrap();
        cfg.protocol.tau = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = preset("task1").unwrap();
        cfg.env.m = cfg.task.n + 1;
        assert!(cfg.validate().is_err());
    }

    /// Satellite: network constants are rejected at load time instead of
    /// silently producing NaN/inf timings (or clamped divisions)
    /// downstream.
    #[test]
    fn validation_catches_bad_network_constants() {
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let mut cfg = preset("task1").unwrap();
            cfg.env.client_bw_bps = bad;
            assert!(cfg.validate().is_err(), "client_bw_bps {bad} accepted");
            let mut cfg = preset("task1").unwrap();
            cfg.env.server_bw_bps = bad;
            assert!(cfg.validate().is_err(), "server_bw_bps {bad} accepted");
            let mut cfg = preset("task1").unwrap();
            cfg.env.model_size_bits = bad;
            assert!(cfg.validate().is_err(), "model_size_bits {bad} accepted");
            let mut cfg = preset("task1").unwrap();
            cfg.env.perf_lambda = bad;
            assert!(cfg.validate().is_err(), "perf_lambda {bad} accepted");
        }
        // Validation delegates to the fabric's own checks.
        let mut cfg = preset("task1").unwrap();
        cfg.env.fabric.enabled = true;
        cfg.env.fabric.loss_prob = 2.0;
        assert!(cfg.validate().is_err(), "bad fabric accepted");
    }

    #[test]
    fn from_toml_applies_overrides() {
        let doc = crate::util::toml::parse(
            r#"
            preset = "task1"
            seed = 99
            [protocol]
            kind = "fedavg"
            c_fraction = 0.5
            [env]
            crash_prob = 0.3
            [train]
            rounds = 10
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.protocol.kind, ProtocolKind::FedAvg);
        assert_eq!(cfg.protocol.c_fraction, 0.5);
        assert_eq!(cfg.env.crash_prob, 0.3);
        assert_eq!(cfg.train.rounds, 10);
        // Untouched fields keep preset values.
        assert_eq!(cfg.env.m, 5);
    }

    #[test]
    fn parse_enums() {
        assert_eq!(TaskKind::parse("TASK2").unwrap(), TaskKind::Cnn);
        assert!(TaskKind::parse("task9").is_err());
        assert_eq!(ProtocolKind::parse("FedCS").unwrap(), ProtocolKind::FedCs);
        assert_eq!(
            ProtocolKind::parse("FedAsync").unwrap(),
            ProtocolKind::FedAsync
        );
        assert!(ProtocolKind::parse("x").is_err());
        assert_eq!(Backend::parse("XLA").unwrap(), Backend::Xla);
    }

    #[test]
    fn from_toml_configures_churn_and_fedasync() {
        let doc = crate::util::toml::parse(
            r#"
            preset = "tiny"
            [protocol]
            kind = "fedasync"
            alpha = 0.4
            staleness_exp = 1.0
            [env]
            churn = "markov"
            churn_uptime_s = 300.0
            churn_downtime_s = 100.0
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.protocol.kind, ProtocolKind::FedAsync);
        assert_eq!(cfg.protocol.alpha, 0.4);
        assert_eq!(cfg.protocol.staleness_exp, 1.0);
        assert_eq!(
            cfg.env.churn,
            ChurnModel::Markov {
                mean_uptime_s: 300.0,
                mean_downtime_s: 100.0
            }
        );
    }

    #[test]
    fn from_parts_rejects_inapplicable_churn_params() {
        assert!(ChurnModel::from_parts("bernoulli", None, None, None).is_ok());
        assert!(ChurnModel::from_parts("bernoulli", Some(50.0), None, None).is_err());
        assert!(ChurnModel::from_parts("bernoulli", None, None, Some("f.txt")).is_err());
        assert!(ChurnModel::from_parts("markov", Some(300.0), Some(100.0), None).is_ok());
        assert!(ChurnModel::from_parts("markov", None, None, Some("f.txt")).is_err());
        assert!(ChurnModel::from_parts("trace", None, None, Some("f.txt")).is_ok());
        assert!(ChurnModel::from_parts("trace", Some(300.0), None, Some("f.txt")).is_err());
        assert!(ChurnModel::from_parts("trace", None, None, None).is_err());
        assert!(ChurnModel::from_parts("weibull", None, None, None).is_err());
        // Defaults fill in missing Markov dwell times.
        match ChurnModel::from_parts("markov", None, None, None).unwrap() {
            ChurnModel::Markov {
                mean_uptime_s,
                mean_downtime_s,
            } => {
                assert_eq!(mean_uptime_s, ChurnModel::DEFAULT_UPTIME_S);
                assert_eq!(mean_downtime_s, ChurnModel::DEFAULT_DOWNTIME_S);
            }
            other => panic!("expected Markov, got {other:?}"),
        }
    }

    #[test]
    fn from_toml_configures_fabric() {
        use crate::net::fabric::{Compression, Contention, LinkDist};
        let doc = crate::util::toml::parse(
            r#"
            preset = "tiny"
            [env]
            fabric = "fifo"
            fabric_link = "lognormal"
            fabric_link_spread = 0.6
            fabric_latency_s = 0.05
            fabric_compression = "topk"
            fabric_topk_fraction = 0.2
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert!(cfg.env.fabric.enabled);
        assert_eq!(cfg.env.fabric.contention, Contention::Fifo);
        assert_eq!(cfg.env.fabric.link_dist, LinkDist::LogNormal { sigma: 0.6 });
        assert_eq!(cfg.env.fabric.latency_s, 0.05);
        assert_eq!(
            cfg.env.fabric.compression,
            Compression::TopK { fraction: 0.2 }
        );
        // Orphan fabric parameters without env.fabric are rejected.
        let doc = crate::util::toml::parse(
            r#"
            preset = "tiny"
            [env]
            fabric_latency_s = 0.05
            "#,
        )
        .unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn from_toml_configures_faults() {
        let doc = crate::util::toml::parse(
            r#"
            preset = "tiny"
            [env]
            faults = "on"
            faults_crash_hazard = 0.1
            faults_regions = 3
            faults_outage_prob = 0.05
            faults_retry_max = 4
            faults_partial_credit = false
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        let f = &cfg.env.faults;
        assert!(f.enabled && f.any_injector());
        assert_eq!(f.crash_hazard, 0.1);
        assert_eq!(f.regions, 3);
        assert_eq!(f.outage_prob, 0.05);
        assert_eq!(f.retry_max, 4);
        assert!(!f.partial_credit);
        // Unset parameters keep the enabled-plan defaults.
        assert_eq!(f.retry_backoff_s, FaultPlan::default().retry_backoff_s);
        // Orphan fault parameters without env.faults are rejected.
        let doc = crate::util::toml::parse(
            r#"
            preset = "tiny"
            [env]
            faults_crash_hazard = 0.1
            "#,
        )
        .unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
        // As are parameters under an explicit "off".
        let doc = crate::util::toml::parse(
            r#"
            preset = "tiny"
            [env]
            faults = "off"
            faults_retry_max = 4
            "#,
        )
        .unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn from_toml_configures_scenario() {
        use crate::scenario::{ScenarioEventKind, ScenarioProcess};
        let doc = crate::util::toml::parse(
            r#"
            preset = "tiny"
            [env]
            scenario = "continuous"
            scenario_uptime_s = 900.0
            scenario_downtime_s = 300.0
            scenario_diurnal_amp = 0.4
            scenario_diurnal_period_s = 4000.0
            scenario_regions = 3
            scenario_flash_at_s = 1500.0
            scenario_flash_joins = 2
            scenario_outage_at_s = 2500.0
            scenario_outage_region = 1
            scenario_outage_len_s = 400.0
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        let s = &cfg.env.scenario;
        assert!(s.enabled);
        assert_eq!(s.process, ScenarioProcess::Continuous);
        assert_eq!(s.base_uptime_s, 900.0);
        assert_eq!(s.diurnal_amp, 0.4);
        assert_eq!(s.regions, 3);
        assert_eq!(s.events.len(), 2);
        assert_eq!(
            s.events[0].kind,
            ScenarioEventKind::FlashCrowd { joins: 2, leaves: 0 }
        );
        // Reductions pass through their parameters.
        let doc = crate::util::toml::parse(
            r#"
            preset = "tiny"
            [env]
            scenario = "bernoulli"
            scenario_crash_prob = 0.25
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(
            cfg.env.scenario.process,
            ScenarioProcess::Bernoulli { crash_prob: 0.25 }
        );
        // Orphan scenario parameters without env.scenario are rejected.
        let doc = crate::util::toml::parse(
            r#"
            preset = "tiny"
            [env]
            scenario_diurnal_amp = 0.4
            "#,
        )
        .unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
        // As are parameters under an explicit "off".
        let doc = crate::util::toml::parse(
            r#"
            preset = "tiny"
            [env]
            scenario = "off"
            scenario_uptime_s = 900.0
            "#,
        )
        .unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn validation_catches_bad_churn_and_alpha() {
        let mut cfg = preset("tiny").unwrap();
        cfg.env.churn = ChurnModel::Markov {
            mean_uptime_s: 0.0,
            mean_downtime_s: 100.0,
        };
        assert!(cfg.validate().is_err());
        let mut cfg = preset("tiny").unwrap();
        cfg.env.churn = ChurnModel::Trace { path: String::new() };
        assert!(cfg.validate().is_err());
        let mut cfg = preset("tiny").unwrap();
        cfg.protocol.kind = ProtocolKind::FedAsync;
        cfg.protocol.alpha = 0.0;
        assert!(cfg.validate().is_err());
        cfg.protocol.alpha = 1.5;
        assert!(cfg.validate().is_err());
        cfg.protocol.alpha = 0.6;
        cfg.protocol.staleness_exp = -1.0;
        assert!(cfg.validate().is_err());
        cfg.protocol.staleness_exp = 0.5;
        assert!(cfg.validate().is_ok());
    }
}
