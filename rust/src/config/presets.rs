//! Experiment presets: the paper's Table II settings plus scaled-down
//! variants sized for a single-core box.
//!
//! Calibration notes:
//! * `server_bw_bps` is calibrated so T_dist matches the paper's tables:
//!   Tables V/IX correspond to ~0.404 s per 10 MB model; Table VII to
//!   ~0.204 s per model for the CNN. The paper *states* 10 Gbps but its
//!   own numbers imply an effective ~198 Mbps serialized stream — we
//!   reproduce the tables, not the prose (see EXPERIMENTS.md §Notes).
//! * T_lim values (830 s / 5600 s / 1620 s) are the paper's.

use super::{
    Backend, ChurnModel, CnnArch, EnvConfig, ExperimentConfig, ProtocolConfig, ProtocolKind,
    TaskConfig, TaskKind, TrainConfig,
};
use crate::error::{Result, SafaError};
use crate::faults::FaultPlan;
use crate::net::fabric::{Compression, Contention, FabricConfig, LinkDist};
use crate::scenario::{Scenario, ScenarioSpec};

const MB_BITS: f64 = 8e6;

fn base_env(m: usize) -> EnvConfig {
    EnvConfig {
        m,
        crash_prob: 0.1,
        perf_lambda: 1.0,
        partition_rel_std: 0.3,
        client_bw_bps: 1.40e6,
        // 10 MB / 0.404 s ≈ 198 Mbps effective per-model stream.
        server_bw_bps: 198.02e6,
        model_size_bits: 10.0 * MB_BITS,
        churn: ChurnModel::Bernoulli,
        // Disabled fabric = closed-form Eq. 17–19 arithmetic. The
        // default FabricConfig is also *neutral*: force-enabling it
        // without touching any knob reproduces the closed form
        // bit-for-bit (asserted by tests/net_fabric.rs).
        fabric: FabricConfig::default(),
        // Disabled faults = the engine's legacy paths, bit-for-bit.
        faults: FaultPlan::default(),
        // Disabled scenario = `churn` drives availability, bit-for-bit.
        scenario: ScenarioSpec::default(),
    }
}

fn base_protocol() -> ProtocolConfig {
    ProtocolConfig {
        kind: ProtocolKind::Safa,
        c_fraction: 0.3,
        tau: 5,
        // FedAsync defaults (Xie et al. 2019): alpha = 0.6 with a
        // polynomial staleness discount of exponent 0.5.
        alpha: 0.6,
        staleness_exp: 0.5,
    }
}

/// Task 1 (paper): Boston-like regression, n=506, d=13, m=5, 100 rounds,
/// E=3, B=5, T_lim=830 s.
///
/// Learning-rate deviation: the paper lists lr=1e-4, which presumes
/// unnormalized Boston features (raw scales up to ~400 make effective
/// gradients ~100x larger). Our synthetic generator standardizes
/// features, so we scale lr to 2e-3 to land in the same convergence
/// regime — the paper's ~0.64 accuracy ceiling is reached by round ~100
/// under reliable settings, and protocol differentiation appears at
/// small C / high cr exactly as in Table X. See EXPERIMENTS.md §Notes.
pub fn task1() -> ExperimentConfig {
    ExperimentConfig {
        name: "task1-regression".into(),
        task: TaskConfig {
            kind: TaskKind::Regression,
            n: 506,
            d: 13,
            num_classes: 1,
            n_test: 100,
            cnn: CnnArch::paper(),
        },
        env: base_env(5),
        train: TrainConfig {
            rounds: 100,
            epochs: 3,
            batch_size: 5,
            lr: 2e-3,
            t_lim: 830.0,
        },
        protocol: base_protocol(),
        backend: Backend::Native,
        seed: 1,
        eval_every: 1,
        artifacts_dir: "artifacts".into(),
    }
}

/// Task 2 (paper): MNIST-like CNN, n=70000, d=784, m=100, 50 rounds, E=5,
/// B=40, lr=1e-3, T_lim=5600 s.
pub fn task2() -> ExperimentConfig {
    let mut env = base_env(100);
    // Table VII implies ~0.204 s per model for the CNN task.
    env.server_bw_bps = 392.16e6;
    ExperimentConfig {
        name: "task2-cnn".into(),
        task: TaskConfig {
            kind: TaskKind::Cnn,
            n: 70_000,
            d: 28 * 28,
            num_classes: 10,
            n_test: 10_000,
            cnn: CnnArch::paper(),
        },
        env,
        train: TrainConfig {
            rounds: 50,
            epochs: 5,
            batch_size: 40,
            lr: 1e-3,
            t_lim: 5600.0,
        },
        protocol: base_protocol(),
        backend: Backend::Native,
        seed: 1,
        eval_every: 1,
        artifacts_dir: "artifacts".into(),
    }
}

/// Task 3 (paper): KDD-like SVM, n=186480, d=35, m=500, 100 rounds, E=5,
/// B=100, lr=1e-2, T_lim=1620 s.
pub fn task3() -> ExperimentConfig {
    ExperimentConfig {
        name: "task3-svm".into(),
        task: TaskConfig {
            kind: TaskKind::Svm,
            n: 186_480,
            d: 35,
            num_classes: 2,
            n_test: 20_000,
            cnn: CnnArch::paper(),
        },
        env: base_env(500),
        train: TrainConfig {
            rounds: 100,
            epochs: 5,
            batch_size: 100,
            lr: 1e-2,
            t_lim: 1620.0,
        },
        protocol: base_protocol(),
        backend: Backend::Native,
        seed: 1,
        eval_every: 1,
        artifacts_dir: "artifacts".into(),
    }
}

/// Scaled variants: identical environment *shape* (same m, same timing
/// constants, same E/B/lr) but smaller datasets and fewer rounds so full
/// protocol × cr × C grids finish on one core. The timing metrics
/// (round length, T_dist, SR, EUR, VV, futility) are invariant to the
/// dataset scaling because they depend only on batch *counts* per client,
/// which we preserve proportionally.
pub fn task1_scaled() -> ExperimentConfig {
    let mut cfg = task1();
    cfg.name = "task1-regression-scaled".into();
    // Task 1 is already tiny; only trim rounds slightly.
    cfg.train.rounds = 100;
    cfg
}

pub fn task2_scaled() -> ExperimentConfig {
    let mut cfg = task2();
    cfg.name = "task2-cnn-scaled".into();
    cfg.task.n = 4_000;
    cfg.task.n_test = 800;
    cfg.task.cnn = CnnArch::scaled();
    cfg.train.rounds = 25;
    cfg
}

pub fn task3_scaled() -> ExperimentConfig {
    let mut cfg = task3();
    cfg.name = "task3-svm-scaled".into();
    cfg.task.n = 30_000;
    cfg.task.n_test = 4_000;
    cfg.env.m = 500;
    cfg.train.rounds = 40;
    cfg
}

/// Scale-axis preset: a 10 000-client fleet on the timing-only Null
/// backend, for the parallel-runtime benches (`benches/fleet_scale.rs`)
/// and large-m churn sweeps. The environment shape (timing constants,
/// E/B, T_lim, cr) is Task 3's; the dataset is token-sized because the
/// Null trainer never touches numerics, but n >= 10·m keeps the
/// Gaussian partitioner meaningful (shards average 10 samples).
pub fn fleet10k() -> ExperimentConfig {
    let mut cfg = task3();
    cfg.name = "fleet10k".into();
    cfg.env.m = 10_000;
    cfg.task.n = 100_000;
    cfg.task.n_test = 100;
    cfg.backend = Backend::Null;
    cfg.train.rounds = 10;
    cfg.eval_every = 1_000_000; // timing study: never evaluate
    cfg
}

/// Tiny preset for unit/integration tests and the quickstart example.
pub fn tiny() -> ExperimentConfig {
    let mut cfg = task1();
    cfg.name = "tiny".into();
    cfg.task.n = 120;
    cfg.task.n_test = 30;
    cfg.env.m = 4;
    cfg.train.rounds = 8;
    cfg.train.epochs = 2;
    cfg.train.lr = 1e-3;
    cfg
}

/// Markov-churn variant of a preset: clients flap on/off with
/// exponential dwell times sized relative to the task's T_lim, so drops
/// and recoveries land mid-round (the regime SAFA targets; `crash_prob`
/// is ignored under Markov churn).
fn with_markov_churn(mut cfg: ExperimentConfig, suffix: &str) -> ExperimentConfig {
    cfg.name = format!("{}-{suffix}", cfg.name);
    cfg.env.churn = ChurnModel::Markov {
        mean_uptime_s: cfg.train.t_lim * 0.6,
        mean_downtime_s: cfg.train.t_lim * 0.25,
    };
    cfg
}

/// Tiny Markov-churn preset for tests and the churn examples.
pub fn tiny_churn() -> ExperimentConfig {
    with_markov_churn(tiny(), "churn")
}

/// Contended-fabric variant of Task 1: the server downlink serializes
/// distribution FIFO, client links are lognormally heterogeneous
/// (sigma 0.5: ~2/3 of clients within 0.6–1.6× the nominal 1.40 Mbps)
/// with WAN-ish latency/jitter and mild loss. Everything else — dataset,
/// T_lim, bandwidth constants — is Task 1's, so fabric-off vs `contended`
/// isolates the transport's effect on round shape.
pub fn contended() -> ExperimentConfig {
    let mut cfg = task1();
    cfg.name = "contended".into();
    cfg.env.fabric = FabricConfig {
        enabled: true,
        contention: Contention::Fifo,
        link_dist: LinkDist::LogNormal { sigma: 0.5 },
        latency_s: 0.05,
        jitter_s: 0.02,
        loss_prob: 0.02,
        max_retries: FabricConfig::DEFAULT_MAX_RETRIES,
        compression: Compression::None,
    };
    cfg
}

/// Chaos preset: the contended fabric plus every fault injector live —
/// crash hazard, flapping, correlated regional outages and link
/// degradation — under the default retry/partial-credit policies. The
/// CI robustness smoke and the `chaos_sweep` bench drive this profile;
/// A/B against `contended` isolates the injectors' effect.
pub fn chaos() -> ExperimentConfig {
    let mut cfg = contended();
    cfg.name = "chaos".into();
    cfg.env.faults = FaultPlan {
        enabled: true,
        crash_hazard: 0.15,
        flap_prob: 0.5,
        flap_downtime_s: 60.0,
        regions: 2,
        outage_prob: 0.1,
        outage_len_s: 120.0,
        degrade_prob: 0.2,
        degrade_factor: 2.0,
        ..FaultPlan::default()
    };
    cfg
}

/// Diurnal-scenario preset: Task-1 environment, 50 clients on the
/// continuous wall-clock timeline with dwell means sized to T_lim and a
/// strong day/night sine modulation over four rounds — availability
/// swings from near-full to sparse and back, the Papaya-style regime
/// the round-indexed models cannot express.
pub fn diurnal() -> ExperimentConfig {
    let mut cfg = task1();
    cfg.name = "diurnal".into();
    cfg.env.m = 50;
    cfg.env.scenario = Scenario::new()
        .uptime(cfg.train.t_lim * 0.6, cfg.train.t_lim * 0.25)
        .diurnal(0.7, cfg.train.t_lim * 4.0)
        .build()
        .expect("diurnal preset spec");
    cfg
}

/// Flash-crowd preset: the contended fabric (FIFO server link) plus a
/// scripted mass join — 10 latecomers enter as round 3 opens and queue
/// on the serialized downlink — followed by 5 departures and a regional
/// outage. The CI scenario smoke and `scenario_sweep` bench drive this
/// profile.
pub fn flashcrowd() -> ExperimentConfig {
    let mut cfg = contended();
    cfg.name = "flashcrowd".into();
    cfg.env.m = 50;
    cfg.env.scenario = Scenario::new()
        .uptime(cfg.train.t_lim * 0.8, cfg.train.t_lim * 0.2)
        .regions(4)
        .at_round(3)
        .flash_crowd(10, 0)
        .at_round(5)
        .flash_crowd(0, 5)
        .at_round(6)
        .regional_outage(1, cfg.train.t_lim * 0.5)
        .build()
        .expect("flashcrowd preset spec");
    cfg
}

/// Task-1 profile under Markov churn (the `churn_sweep` bench's base).
pub fn task1_churn() -> ExperimentConfig {
    with_markov_churn(task1(), "churn")
}

/// Task-2 profile under Markov churn.
pub fn task2_churn() -> ExperimentConfig {
    with_markov_churn(task2(), "churn")
}

/// Look up a preset by name.
pub fn preset(name: &str) -> Result<ExperimentConfig> {
    match name.to_ascii_lowercase().as_str() {
        "task1" => Ok(task1()),
        "task2" => Ok(task2()),
        "task3" => Ok(task3()),
        "task1-scaled" | "task1_scaled" => Ok(task1_scaled()),
        "task2-scaled" | "task2_scaled" => Ok(task2_scaled()),
        "task3-scaled" | "task3_scaled" => Ok(task3_scaled()),
        "task1-churn" | "task1_churn" => Ok(task1_churn()),
        "task2-churn" | "task2_churn" => Ok(task2_churn()),
        "fleet10k" => Ok(fleet10k()),
        "tiny" => Ok(tiny()),
        "tiny-churn" | "tiny_churn" => Ok(tiny_churn()),
        "contended" => Ok(contended()),
        "chaos" => Ok(chaos()),
        "diurnal" => Ok(diurnal()),
        "flashcrowd" | "flash-crowd" | "flash_crowd" => Ok(flashcrowd()),
        other => Err(SafaError::Config(format!("unknown preset '{other}'"))),
    }
}

pub fn preset_names() -> &'static [&'static str] {
    &[
        "task1",
        "task2",
        "task3",
        "task1-scaled",
        "task2-scaled",
        "task3-scaled",
        "task1-churn",
        "task2-churn",
        "fleet10k",
        "tiny",
        "tiny-churn",
        "contended",
        "chaos",
        "diurnal",
        "flashcrowd",
    ]
}

/// Paper-or-scaled preset for a task index (1..=3), honouring the
/// `SAFA_PRESET=paper` environment switch used by the bench suite.
pub fn scaled_preset(task: usize) -> ExperimentConfig {
    let paper = std::env::var("SAFA_PRESET").as_deref() == Ok("paper");
    match (task, paper) {
        (1, true) => task1(),
        (1, false) => task1_scaled(),
        (2, true) => task2(),
        (2, false) => task2_scaled(),
        (3, true) => task3(),
        (3, false) => task3_scaled(),
        _ => panic!("scaled_preset: task must be 1..=3"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_table2() {
        let t1 = task1();
        assert_eq!(t1.task.n, 506);
        assert_eq!(t1.task.d, 13);
        assert_eq!(t1.env.m, 5);
        assert_eq!(t1.train.rounds, 100);
        assert_eq!(t1.train.epochs, 3);
        assert_eq!(t1.train.batch_size, 5);
        assert!((t1.train.lr - 2e-3).abs() < 1e-12); // documented deviation
        assert_eq!(t1.train.t_lim, 830.0);

        let t2 = task2();
        assert_eq!(t2.task.n, 70_000);
        assert_eq!(t2.task.d, 784);
        assert_eq!(t2.env.m, 100);
        assert_eq!(t2.train.rounds, 50);
        assert_eq!(t2.train.epochs, 5);
        assert_eq!(t2.train.batch_size, 40);
        assert_eq!(t2.train.t_lim, 5600.0);

        let t3 = task3();
        assert_eq!(t3.task.n, 186_480);
        assert_eq!(t3.task.d, 35);
        assert_eq!(t3.env.m, 500);
        assert_eq!(t3.train.batch_size, 100);
        assert_eq!(t3.train.t_lim, 1620.0);
    }

    #[test]
    fn tdist_calibration() {
        // One 10 MB model over the calibrated server stream ≈ 0.404 s.
        let t1 = task1();
        let per_model = t1.env.model_size_bits / t1.env.server_bw_bps;
        assert!((per_model - 0.404).abs() < 1e-3, "per_model={per_model}");
        // CNN task ≈ 0.204 s.
        let t2 = task2();
        let per_model = t2.env.model_size_bits / t2.env.server_bw_bps;
        assert!((per_model - 0.204).abs() < 1e-3, "per_model={per_model}");
    }

    #[test]
    fn churn_presets_use_markov_dwell_times() {
        for name in ["tiny-churn", "task1-churn", "task2-churn"] {
            let cfg = preset(name).unwrap();
            match cfg.env.churn {
                ChurnModel::Markov {
                    mean_uptime_s,
                    mean_downtime_s,
                } => {
                    assert!(mean_uptime_s > 0.0 && mean_uptime_s < cfg.train.t_lim);
                    assert!(mean_downtime_s > 0.0 && mean_downtime_s < mean_uptime_s);
                }
                ref other => panic!("{name}: expected Markov churn, got {other:?}"),
            }
        }
        assert_eq!(preset("tiny").unwrap().env.churn, ChurnModel::Bernoulli);
    }

    #[test]
    fn fleet10k_is_null_backend_at_scale() {
        let cfg = preset("fleet10k").unwrap();
        assert_eq!(cfg.env.m, 10_000);
        assert_eq!(cfg.backend, Backend::Null);
        assert!(cfg.task.n >= cfg.env.m);
        // Same environment timing shape as Task 3.
        assert_eq!(cfg.train.t_lim, task3().train.t_lim);
    }

    #[test]
    fn contended_preset_enables_the_fabric() {
        let cfg = preset("contended").unwrap();
        assert!(cfg.env.fabric.enabled);
        assert_eq!(cfg.env.fabric.contention, Contention::Fifo);
        assert!(matches!(
            cfg.env.fabric.link_dist,
            LinkDist::LogNormal { .. }
        ));
        // Same base environment as Task 1 so A/B runs isolate the fabric.
        assert_eq!(cfg.env.client_bw_bps, task1().env.client_bw_bps);
        assert_eq!(cfg.train.t_lim, task1().train.t_lim);
        // The non-fabric presets all stay off (fabric-off is the default
        // the bit-for-bit regression suite pins). `chaos` and
        // `flashcrowd` ride on the contended fabric, so they are the
        // other exceptions.
        for name in preset_names() {
            if !matches!(*name, "contended" | "chaos" | "flashcrowd") {
                assert!(!preset(name).unwrap().env.fabric.enabled, "{name}");
            }
        }
    }

    #[test]
    fn chaos_preset_arms_every_injector() {
        let cfg = preset("chaos").unwrap();
        assert!(cfg.env.fabric.enabled, "chaos builds on the contended fabric");
        let f = &cfg.env.faults;
        assert!(f.enabled && f.any_injector());
        assert!(f.crash_hazard > 0.0);
        assert!(f.flap_prob > 0.0);
        assert!(f.regions >= 2 && f.outage_prob > 0.0);
        assert!(f.degrade_prob > 0.0 && f.degrade_factor > 1.0);
        f.validate().unwrap();
        // Every other preset keeps faults off — the injectors-off
        // bit-for-bit guarantee rests on this default.
        for name in preset_names() {
            if *name != "chaos" {
                assert!(!preset(name).unwrap().env.faults.enabled, "{name}");
            }
        }
    }

    #[test]
    fn scenario_presets_compile_the_continuous_process() {
        use crate::scenario::{ScenarioEventKind, ScenarioProcess};
        let d = preset("diurnal").unwrap();
        assert!(d.env.scenario.enabled);
        assert_eq!(d.env.scenario.process, ScenarioProcess::Continuous);
        assert!(d.env.scenario.diurnal_amp > 0.0);
        assert!(!d.env.fabric.enabled && !d.env.faults.enabled);

        let f = preset("flashcrowd").unwrap();
        assert!(f.env.scenario.enabled);
        assert!(f.env.fabric.enabled, "join bursts must hit the contended link");
        assert_eq!(f.env.scenario.total_joins(), 10);
        assert!(f
            .env
            .scenario
            .events
            .iter()
            .any(|e| matches!(e.kind, ScenarioEventKind::RegionalOutage { .. })));

        // Every other preset keeps the scenario off — the scenario-off
        // bit-for-bit guarantee rests on this default.
        for name in preset_names() {
            if !matches!(*name, "diurnal" | "flashcrowd") {
                assert!(!preset(name).unwrap().env.scenario.enabled, "{name}");
            }
        }
    }

    #[test]
    fn all_presets_validate() {
        for name in preset_names() {
            let cfg = preset(name).unwrap();
            cfg.validate()
                .unwrap_or_else(|e| panic!("preset {name} invalid: {e}"));
        }
        assert!(preset("nope").is_err());
    }
}
