//! Micro-benchmark + experiment-table harness (criterion replacement).
//!
//! Two roles:
//!
//! 1. [`Bencher`] — wall-clock micro-benchmarks with warmup, repeated
//!    timed iterations, and mean/stddev/min reporting. Used by
//!    `rust/benches/microbench_hotpath.rs` for the L3 perf pass.
//! 2. [`Table`] — a formatter that prints the paper's cr × C grids in the
//!    same layout as Tables IV–XV and writes machine-readable CSV/JSON
//!    next to them under `results/`.

use crate::util::json::Json;
use crate::util::stats;
use std::fmt::Write as _;
use std::hint::black_box;
use std::path::Path;
use std::time::{Duration, Instant};

/// Result of one micro-benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            format!("±{}", fmt_ns(self.stddev_ns)),
            format!("min {}", fmt_ns(self.min_ns)),
            self.iters
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Micro-benchmark runner.
pub struct Bencher {
    /// Target total measurement time per benchmark.
    pub measure_time: Duration,
    /// Warmup time before measurement.
    pub warmup_time: Duration,
    /// Number of timed samples to collect.
    pub samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure_time: Duration::from_millis(900),
            warmup_time: Duration::from_millis(200),
            samples: 20,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        let mut b = Bencher::default();
        // SAFA_BENCH_FAST=1 trims times for CI-style smoke runs.
        if std::env::var("SAFA_BENCH_FAST").as_deref() == Ok("1") {
            b.measure_time = Duration::from_millis(120);
            b.warmup_time = Duration::from_millis(30);
            b.samples = 8;
        }
        b
    }

    /// Time `f`, which should return a value that depends on the work
    /// (it is passed through `black_box` to defeat DCE).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration: how many iters fit in one sample?
        let warmup_end = Instant::now() + self.warmup_time;
        let mut calib_iters: u64 = 0;
        let calib_start = Instant::now();
        while Instant::now() < warmup_end {
            black_box(f());
            calib_iters += 1;
        }
        let per_iter = calib_start.elapsed().as_nanos() as f64 / calib_iters.max(1) as f64;
        let sample_ns = self.measure_time.as_nanos() as f64 / self.samples as f64;
        let iters_per_sample = ((sample_ns / per_iter.max(1.0)).ceil() as u64).max(1);

        let mut sample_means = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let start = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            let elapsed = start.elapsed().as_nanos() as f64;
            sample_means.push(elapsed / iters_per_sample as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            iters: iters_per_sample * self.samples as u64,
            mean_ns: stats::mean(&sample_means),
            stddev_ns: stats::stddev_sample(&sample_means),
            min_ns: stats::min(&sample_means).unwrap_or(0.0),
            max_ns: stats::max(&sample_means).unwrap_or(0.0),
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Dump all results as JSON: an array of
    /// `{name, mean_ns, stddev_ns, min_ns, max_ns, iters}` objects (the
    /// `BENCH_*.json` format documented in EXPERIMENTS.md).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        let mut arr = Vec::new();
        for r in &self.results {
            let mut o = Json::obj();
            o.set("name", Json::Str(r.name.clone()));
            o.set("mean_ns", Json::Num(r.mean_ns));
            o.set("stddev_ns", Json::Num(r.stddev_ns));
            o.set("min_ns", Json::Num(r.min_ns));
            o.set("max_ns", Json::Num(r.max_ns));
            o.set("iters", Json::Num(r.iters as f64));
            arr.push(o);
        }
        write_results_file(path, &Json::Arr(arr).to_string_pretty())
    }
}

/// Resolve a bench's machine-readable output path: honour a
/// `--json <path>` (or `--json=<path>`) argument — `cargo bench --bench
/// foo -- --json out.json` forwards it — falling back to
/// `default_path`. With `harness = false` the bench binary owns its
/// argv, so this is the whole CLI.
pub fn json_path_from_args(default_path: &str) -> String {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--json" {
            match args.next() {
                Some(p) => return p,
                None => panic!("--json requires a path argument"),
            }
        } else if let Some(p) = a.strip_prefix("--json=") {
            return p.to_string();
        }
    }
    default_path.to_string()
}

/// Ensure `results/` exists and write a file inside it.
pub fn write_results_file(path: &str, contents: &str) -> std::io::Result<()> {
    let p = Path::new(path);
    if let Some(dir) = p.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(p, contents)
}

/// A cr × C grid table in the paper's layout (one block per protocol).
pub struct Table {
    pub title: String,
    pub col_header: Vec<String>,
    pub row_header: Vec<String>,
    /// blocks: (protocol name, rows×cols values)
    pub blocks: Vec<(String, Vec<Vec<f64>>)>,
    pub precision: usize,
}

impl Table {
    pub fn new(title: &str, crs: &[f64], cs: &[f64]) -> Table {
        Table {
            title: title.to_string(),
            col_header: cs.iter().map(|c| format!("C = {c}")).collect(),
            row_header: crs.iter().map(|cr| format!("{cr}")).collect(),
            blocks: Vec::new(),
            precision: 2,
        }
    }

    pub fn add_block(&mut self, protocol: &str, values: Vec<Vec<f64>>) {
        assert_eq!(values.len(), self.row_header.len(), "row count mismatch");
        for row in &values {
            assert_eq!(row.len(), self.col_header.len(), "col count mismatch");
        }
        self.blocks.push((protocol.to_string(), values));
    }

    /// Render in the paper's visual layout.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let width = 12.max(self.precision + 6);
        let _ = writeln!(out, "=== {} ===", self.title);
        for (proto, rows) in &self.blocks {
            let _ = writeln!(out, "--- {proto} ---");
            let _ = write!(out, "{:>6}", "cr");
            for h in &self.col_header {
                let _ = write!(out, "{h:>width$}");
            }
            let _ = writeln!(out);
            for (ri, row) in rows.iter().enumerate() {
                let _ = write!(out, "{:>6}", self.row_header[ri]);
                for v in row {
                    let _ = write!(out, "{:>width$.prec$}", v, prec = self.precision);
                }
                let _ = writeln!(out);
            }
        }
        out
    }

    /// CSV with one line per (protocol, cr, C) cell.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("protocol,cr,C,value\n");
        for (proto, rows) in &self.blocks {
            for (ri, row) in rows.iter().enumerate() {
                for (ci, v) in row.iter().enumerate() {
                    let c = self.col_header[ci].trim_start_matches("C = ");
                    let _ = writeln!(out, "{proto},{},{c},{v}", self.row_header[ri]);
                }
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("title", Json::Str(self.title.clone()));
        let mut blocks = Vec::new();
        for (proto, rows) in &self.blocks {
            let mut b = Json::obj();
            b.set("protocol", Json::Str(proto.clone()));
            b.set(
                "rows",
                Json::Arr(
                    rows.iter()
                        .map(|r| Json::Arr(r.iter().map(|&v| Json::Num(v)).collect()))
                        .collect(),
                ),
            );
            blocks.push(b);
        }
        o.set("blocks", Json::Arr(blocks));
        o.set(
            "cr",
            Json::Arr(self.row_header.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        o.set(
            "C",
            Json::Arr(self.col_header.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        o
    }

    /// Print to stdout and persist CSV + JSON under `results/<stem>.*`.
    pub fn emit(&self, stem: &str) {
        print!("{}", self.render());
        let _ = write_results_file(&format!("results/{stem}.csv"), &self.to_csv());
        let _ = write_results_file(
            &format!("results/{stem}.json"),
            &self.to_json().to_string_pretty(),
        );
    }
}

/// A named (x, series...) line-plot dump for the paper's figures.
pub struct Series {
    pub title: String,
    pub x_label: String,
    pub x: Vec<f64>,
    pub lines: Vec<(String, Vec<f64>)>,
}

impl Series {
    pub fn new(title: &str, x_label: &str, x: Vec<f64>) -> Series {
        Series {
            title: title.to_string(),
            x_label: x_label.to_string(),
            x,
            lines: Vec::new(),
        }
    }

    pub fn add_line(&mut self, name: &str, ys: Vec<f64>) {
        assert_eq!(ys.len(), self.x.len(), "series length mismatch");
        self.lines.push((name.to_string(), ys));
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "{}", self.x_label);
        for (name, _) in &self.lines {
            let _ = write!(out, ",{name}");
        }
        let _ = writeln!(out);
        for (i, x) in self.x.iter().enumerate() {
            let _ = write!(out, "{x}");
            for (_, ys) in &self.lines {
                let _ = write!(out, ",{}", ys[i]);
            }
            let _ = writeln!(out);
        }
        out
    }

    /// Render a coarse ASCII sparkline per series (terminal-friendly view
    /// of the figure) plus first/last values.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} (x = {}) ===", self.title, self.x_label);
        for (name, ys) in &self.lines {
            let lo = stats::min(ys).unwrap_or(0.0);
            let hi = stats::max(ys).unwrap_or(1.0);
            let glyphs = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
            let spark: String = ys
                .iter()
                .map(|&y| {
                    let t = if hi > lo { (y - lo) / (hi - lo) } else { 0.0 };
                    glyphs[((t * 7.0).round() as usize).min(7)]
                })
                .collect();
            let _ = writeln!(
                out,
                "{name:<28} {spark}  [{:.4} → {:.4}, min {:.4}]",
                ys.first().copied().unwrap_or(0.0),
                ys.last().copied().unwrap_or(0.0),
                lo
            );
        }
        out
    }

    pub fn emit(&self, stem: &str) {
        print!("{}", self.render());
        let _ = write_results_file(&format!("results/{stem}.csv"), &self.to_csv());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(5),
            samples: 4,
            results: Vec::new(),
        };
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 4);
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new("demo", &[0.1, 0.3], &[0.1, 0.5]);
        t.add_block("SAFA", vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let text = t.render();
        assert!(text.contains("SAFA"));
        assert!(text.contains("C = 0.5"));
        let csv = t.to_csv();
        assert!(csv.contains("SAFA,0.3,0.5,4"));
    }

    #[test]
    #[should_panic(expected = "row count mismatch")]
    fn table_shape_checked() {
        let mut t = Table::new("demo", &[0.1, 0.3], &[0.1]);
        t.add_block("X", vec![vec![1.0]]);
    }

    #[test]
    fn series_csv() {
        let mut s = Series::new("loss", "round", vec![1.0, 2.0, 3.0]);
        s.add_line("safa", vec![0.9, 0.5, 0.3]);
        let csv = s.to_csv();
        assert!(csv.starts_with("round,safa\n"));
        assert!(csv.contains("3,0.3"));
        assert!(s.render().contains("safa"));
    }
}
