//! Micro-benchmark + experiment-table harness (criterion replacement).
//!
//! Two roles:
//!
//! 1. [`Bencher`] — wall-clock micro-benchmarks with warmup, repeated
//!    timed iterations, and mean/stddev/min reporting. Used by
//!    `rust/benches/microbench_hotpath.rs` for the L3 perf pass.
//! 2. [`Table`] — a formatter that prints the paper's cr × C grids in the
//!    same layout as Tables IV–XV and writes machine-readable CSV/JSON
//!    next to them under `results/`.

use crate::util::json::Json;
use crate::util::stats;
use std::fmt::Write as _;
use std::hint::black_box;
use std::path::Path;
use std::time::{Duration, Instant};

/// Result of one micro-benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            format!("±{}", fmt_ns(self.stddev_ns)),
            format!("min {}", fmt_ns(self.min_ns)),
            self.iters
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Micro-benchmark runner.
pub struct Bencher {
    /// Target total measurement time per benchmark.
    pub measure_time: Duration,
    /// Warmup time before measurement.
    pub warmup_time: Duration,
    /// Number of timed samples to collect.
    pub samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure_time: Duration::from_millis(900),
            warmup_time: Duration::from_millis(200),
            samples: 20,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        let mut b = Bencher::default();
        // SAFA_BENCH_FAST=1 trims times for CI-style smoke runs.
        if std::env::var("SAFA_BENCH_FAST").as_deref() == Ok("1") {
            b.measure_time = Duration::from_millis(120);
            b.warmup_time = Duration::from_millis(30);
            b.samples = 8;
        }
        b
    }

    /// Time `f`, which should return a value that depends on the work
    /// (it is passed through `black_box` to defeat DCE).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration: how many iters fit in one sample?
        let warmup_end = Instant::now() + self.warmup_time;
        let mut calib_iters: u64 = 0;
        let calib_start = Instant::now();
        while Instant::now() < warmup_end {
            black_box(f());
            calib_iters += 1;
        }
        let per_iter = calib_start.elapsed().as_nanos() as f64 / calib_iters.max(1) as f64;
        let sample_ns = self.measure_time.as_nanos() as f64 / self.samples as f64;
        let iters_per_sample = ((sample_ns / per_iter.max(1.0)).ceil() as u64).max(1);

        let mut sample_means = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let start = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            let elapsed = start.elapsed().as_nanos() as f64;
            sample_means.push(elapsed / iters_per_sample as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            iters: iters_per_sample * self.samples as u64,
            mean_ns: stats::mean(&sample_means),
            stddev_ns: stats::stddev_sample(&sample_means),
            min_ns: stats::min(&sample_means).unwrap_or(0.0),
            max_ns: stats::max(&sample_means).unwrap_or(0.0),
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Dump all results as JSON: an array of
    /// `{name, mean_ns, stddev_ns, min_ns, max_ns, iters}` objects (the
    /// `BENCH_*.json` format documented in EXPERIMENTS.md).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        let mut arr = Vec::new();
        for r in &self.results {
            let mut o = Json::obj();
            o.set("name", Json::Str(r.name.clone()));
            o.set("mean_ns", Json::Num(r.mean_ns));
            o.set("stddev_ns", Json::Num(r.stddev_ns));
            o.set("min_ns", Json::Num(r.min_ns));
            o.set("max_ns", Json::Num(r.max_ns));
            o.set("iters", Json::Num(r.iters as f64));
            arr.push(o);
        }
        write_results_file(path, &Json::Arr(arr).to_string_pretty())
    }
}

/// Resolve a bench's machine-readable output path: honour a
/// `--json <path>` (or `--json=<path>`) argument — `cargo bench --bench
/// foo -- --json out.json` forwards it — falling back to
/// `default_path`. With `harness = false` the bench binary owns its
/// argv, so this is the whole CLI.
pub fn json_path_from_args(default_path: &str) -> String {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--json" {
            match args.next() {
                Some(p) => return p,
                None => panic!("--json requires a path argument"),
            }
        } else if let Some(p) = a.strip_prefix("--json=") {
            return p.to_string();
        }
    }
    default_path.to_string()
}

/// Ensure `results/` exists and write a file inside it.
pub fn write_results_file(path: &str, contents: &str) -> std::io::Result<()> {
    let p = Path::new(path);
    if let Some(dir) = p.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(p, contents)
}

/// A cr × C grid table in the paper's layout (one block per protocol).
pub struct Table {
    pub title: String,
    pub col_header: Vec<String>,
    pub row_header: Vec<String>,
    /// blocks: (protocol name, rows×cols values)
    pub blocks: Vec<(String, Vec<Vec<f64>>)>,
    pub precision: usize,
}

impl Table {
    pub fn new(title: &str, crs: &[f64], cs: &[f64]) -> Table {
        Table {
            title: title.to_string(),
            col_header: cs.iter().map(|c| format!("C = {c}")).collect(),
            row_header: crs.iter().map(|cr| format!("{cr}")).collect(),
            blocks: Vec::new(),
            precision: 2,
        }
    }

    pub fn add_block(&mut self, protocol: &str, values: Vec<Vec<f64>>) {
        assert_eq!(values.len(), self.row_header.len(), "row count mismatch");
        for row in &values {
            assert_eq!(row.len(), self.col_header.len(), "col count mismatch");
        }
        self.blocks.push((protocol.to_string(), values));
    }

    /// Render in the paper's visual layout.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let width = 12.max(self.precision + 6);
        let _ = writeln!(out, "=== {} ===", self.title);
        for (proto, rows) in &self.blocks {
            let _ = writeln!(out, "--- {proto} ---");
            let _ = write!(out, "{:>6}", "cr");
            for h in &self.col_header {
                let _ = write!(out, "{h:>width$}");
            }
            let _ = writeln!(out);
            for (ri, row) in rows.iter().enumerate() {
                let _ = write!(out, "{:>6}", self.row_header[ri]);
                for v in row {
                    let _ = write!(out, "{:>width$.prec$}", v, prec = self.precision);
                }
                let _ = writeln!(out);
            }
        }
        out
    }

    /// CSV with one line per (protocol, cr, C) cell.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("protocol,cr,C,value\n");
        for (proto, rows) in &self.blocks {
            for (ri, row) in rows.iter().enumerate() {
                for (ci, v) in row.iter().enumerate() {
                    let c = self.col_header[ci].trim_start_matches("C = ");
                    let _ = writeln!(out, "{proto},{},{c},{v}", self.row_header[ri]);
                }
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("title", Json::Str(self.title.clone()));
        let mut blocks = Vec::new();
        for (proto, rows) in &self.blocks {
            let mut b = Json::obj();
            b.set("protocol", Json::Str(proto.clone()));
            b.set(
                "rows",
                Json::Arr(
                    rows.iter()
                        .map(|r| Json::Arr(r.iter().map(|&v| Json::Num(v)).collect()))
                        .collect(),
                ),
            );
            blocks.push(b);
        }
        o.set("blocks", Json::Arr(blocks));
        o.set(
            "cr",
            Json::Arr(self.row_header.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        o.set(
            "C",
            Json::Arr(self.col_header.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        o
    }

    /// Print to stdout and persist CSV + JSON under `results/<stem>.*`.
    pub fn emit(&self, stem: &str) {
        print!("{}", self.render());
        let _ = write_results_file(&format!("results/{stem}.csv"), &self.to_csv());
        let _ = write_results_file(
            &format!("results/{stem}.json"),
            &self.to_json().to_string_pretty(),
        );
    }
}

/// A named (x, series...) line-plot dump for the paper's figures.
pub struct Series {
    pub title: String,
    pub x_label: String,
    pub x: Vec<f64>,
    pub lines: Vec<(String, Vec<f64>)>,
}

impl Series {
    pub fn new(title: &str, x_label: &str, x: Vec<f64>) -> Series {
        Series {
            title: title.to_string(),
            x_label: x_label.to_string(),
            x,
            lines: Vec::new(),
        }
    }

    pub fn add_line(&mut self, name: &str, ys: Vec<f64>) {
        assert_eq!(ys.len(), self.x.len(), "series length mismatch");
        self.lines.push((name.to_string(), ys));
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "{}", self.x_label);
        for (name, _) in &self.lines {
            let _ = write!(out, ",{name}");
        }
        let _ = writeln!(out);
        for (i, x) in self.x.iter().enumerate() {
            let _ = write!(out, "{x}");
            for (_, ys) in &self.lines {
                let _ = write!(out, ",{}", ys[i]);
            }
            let _ = writeln!(out);
        }
        out
    }

    /// Render a coarse ASCII sparkline per series (terminal-friendly view
    /// of the figure) plus first/last values.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} (x = {}) ===", self.title, self.x_label);
        for (name, ys) in &self.lines {
            let lo = stats::min(ys).unwrap_or(0.0);
            let hi = stats::max(ys).unwrap_or(1.0);
            let glyphs = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
            let spark: String = ys
                .iter()
                .map(|&y| {
                    let t = if hi > lo { (y - lo) / (hi - lo) } else { 0.0 };
                    glyphs[((t * 7.0).round() as usize).min(7)]
                })
                .collect();
            let _ = writeln!(
                out,
                "{name:<28} {spark}  [{:.4} → {:.4}, min {:.4}]",
                ys.first().copied().unwrap_or(0.0),
                ys.last().copied().unwrap_or(0.0),
                lo
            );
        }
        out
    }

    pub fn emit(&self, stem: &str) {
        print!("{}", self.render());
        let _ = write_results_file(&format!("results/{stem}.csv"), &self.to_csv());
    }
}

// ---------------------------------------------------------------------------
// Bench regression diff (benches/bench_diff.rs).
// ---------------------------------------------------------------------------

/// Outcome of comparing one cell between a baseline and a fresh run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiffStatus {
    /// Within tolerance (or improved).
    Ok,
    /// Slower than baseline beyond the relative tolerance.
    Regressed,
    /// Present in only one of the two files.
    Unmatched,
}

impl DiffStatus {
    pub fn name(self) -> &'static str {
        match self {
            DiffStatus::Ok => "ok",
            DiffStatus::Regressed => "REGRESSED",
            DiffStatus::Unmatched => "unmatched",
        }
    }
}

/// One cell's comparison: relative deltas are `(fresh - base) / base`.
#[derive(Debug, Clone)]
pub struct CellDiff {
    pub name: String,
    pub base_mean_ns: Option<f64>,
    pub fresh_mean_ns: Option<f64>,
    /// Relative mean_ns change (positive = slower).
    pub mean_delta: Option<f64>,
    /// Relative rounds_per_sec change (negative = slower), when both
    /// sides carry the profiling extra.
    pub rps_delta: Option<f64>,
    pub status: DiffStatus,
}

/// Extract the BENCH cell array from either supported file shape: a bare
/// array of cells, or the placeholder object form `{"results": [...]}`.
pub fn bench_cells(doc: &Json) -> &[Json] {
    match doc {
        Json::Arr(a) => a,
        _ => doc
            .get("results")
            .and_then(Json::as_arr)
            .unwrap_or_default(),
    }
}

fn cell_num(cell: &Json, key: &str) -> Option<f64> {
    cell.get(key).and_then(Json::as_f64).filter(|v| *v > 0.0)
}

/// Compare fresh BENCH cells against a committed baseline by `name`,
/// flagging cells whose `mean_ns` grew (or `rounds_per_sec` shrank) by
/// more than `tolerance` (relative, e.g. 0.25 = 25%). Cells present in
/// only one file are reported as unmatched, never as regressions — an
/// empty placeholder baseline diffs clean by construction.
pub fn diff_bench_cells(baseline: &Json, fresh: &Json, tolerance: f64) -> Vec<CellDiff> {
    let base_cells = bench_cells(baseline);
    let fresh_cells = bench_cells(fresh);
    let name_of = |c: &Json| {
        c.get("name")
            .and_then(Json::as_str)
            .unwrap_or("?")
            .to_string()
    };
    let mut out: Vec<CellDiff> = Vec::new();
    for f in fresh_cells {
        let name = name_of(f);
        let base = base_cells.iter().find(|b| name_of(b) == name);
        let fresh_mean = cell_num(f, "mean_ns");
        match base {
            None => out.push(CellDiff {
                name,
                base_mean_ns: None,
                fresh_mean_ns: fresh_mean,
                mean_delta: None,
                rps_delta: None,
                status: DiffStatus::Unmatched,
            }),
            Some(b) => {
                let base_mean = cell_num(b, "mean_ns");
                let mean_delta = match (base_mean, fresh_mean) {
                    (Some(bm), Some(fm)) => Some((fm - bm) / bm),
                    _ => None,
                };
                let rps_delta = match (cell_num(b, "rounds_per_sec"), cell_num(f, "rounds_per_sec"))
                {
                    (Some(br), Some(fr)) => Some((fr - br) / br),
                    _ => None,
                };
                let regressed = mean_delta.is_some_and(|d| d > tolerance)
                    || rps_delta.is_some_and(|d| d < -tolerance);
                out.push(CellDiff {
                    name,
                    base_mean_ns: base_mean,
                    fresh_mean_ns: fresh_mean,
                    mean_delta,
                    rps_delta,
                    status: if regressed {
                        DiffStatus::Regressed
                    } else {
                        DiffStatus::Ok
                    },
                });
            }
        }
    }
    for b in base_cells {
        let name = name_of(b);
        if !fresh_cells.iter().any(|f| name_of(f) == name) {
            out.push(CellDiff {
                name,
                base_mean_ns: cell_num(b, "mean_ns"),
                fresh_mean_ns: None,
                mean_delta: None,
                rps_delta: None,
                status: DiffStatus::Unmatched,
            });
        }
    }
    out
}

/// Fixed-width regression table over a diff.
pub fn render_diff(diffs: &[CellDiff], tolerance: f64) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== bench diff (relative tolerance {:.0}%) ==",
        tolerance * 100.0
    );
    let _ = writeln!(
        out,
        "{:<40} {:>12} {:>12} {:>9} {:>9} {:<10}",
        "cell", "base ms", "fresh ms", "mean Δ%", "r/s Δ%", "status"
    );
    let fmt_ms = |v: Option<f64>| match v {
        Some(ns) => format!("{:.2}", ns / 1e6),
        None => "-".to_string(),
    };
    let fmt_pct = |v: Option<f64>| match v {
        Some(d) => format!("{:+.1}", d * 100.0),
        None => "-".to_string(),
    };
    for d in diffs {
        let _ = writeln!(
            out,
            "{:<40} {:>12} {:>12} {:>9} {:>9} {:<10}",
            d.name,
            fmt_ms(d.base_mean_ns),
            fmt_ms(d.fresh_mean_ns),
            fmt_pct(d.mean_delta),
            fmt_pct(d.rps_delta),
            d.status.name(),
        );
    }
    let regressions = diffs
        .iter()
        .filter(|d| d.status == DiffStatus::Regressed)
        .count();
    let _ = writeln!(
        out,
        "{} cell(s) compared, {} regression(s)",
        diffs.len(),
        regressions
    );
    out
}

/// Serialize a diff for the CI artifact.
pub fn diff_to_json(diffs: &[CellDiff], tolerance: f64) -> Json {
    let mut o = Json::obj();
    o.set("tolerance", Json::Num(tolerance));
    o.set(
        "regressions",
        Json::Num(
            diffs
                .iter()
                .filter(|d| d.status == DiffStatus::Regressed)
                .count() as f64,
        ),
    );
    let mut arr = Vec::new();
    for d in diffs {
        let mut c = Json::obj();
        c.set("name", Json::Str(d.name.clone()));
        c.set("status", Json::Str(d.status.name().to_string()));
        if let Some(v) = d.base_mean_ns {
            c.set("base_mean_ns", Json::Num(v));
        }
        if let Some(v) = d.fresh_mean_ns {
            c.set("fresh_mean_ns", Json::Num(v));
        }
        if let Some(v) = d.mean_delta {
            c.set("mean_delta", Json::Num(v));
        }
        if let Some(v) = d.rps_delta {
            c.set("rps_delta", Json::Num(v));
        }
        arr.push(c);
    }
    o.set("cells", Json::Arr(arr));
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(5),
            samples: 4,
            results: Vec::new(),
        };
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 4);
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new("demo", &[0.1, 0.3], &[0.1, 0.5]);
        t.add_block("SAFA", vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let text = t.render();
        assert!(text.contains("SAFA"));
        assert!(text.contains("C = 0.5"));
        let csv = t.to_csv();
        assert!(csv.contains("SAFA,0.3,0.5,4"));
    }

    #[test]
    #[should_panic(expected = "row count mismatch")]
    fn table_shape_checked() {
        let mut t = Table::new("demo", &[0.1, 0.3], &[0.1]);
        t.add_block("X", vec![vec![1.0]]);
    }

    fn cell(name: &str, mean_ns: f64, rps: Option<f64>) -> Json {
        let mut c = Json::obj();
        c.set("name", Json::Str(name.to_string()));
        c.set("mean_ns", Json::Num(mean_ns));
        if let Some(r) = rps {
            c.set("rounds_per_sec", Json::Num(r));
        }
        c
    }

    #[test]
    fn diff_flags_only_out_of_tolerance_cells() {
        let baseline = Json::Arr(vec![
            cell("a", 100.0, Some(50.0)),
            cell("b", 100.0, Some(50.0)),
            cell("gone", 100.0, None),
        ]);
        let fresh = Json::Arr(vec![
            cell("a", 110.0, Some(48.0)), // within 25%
            cell("b", 200.0, Some(20.0)), // 2x slower
            cell("new", 100.0, None),
        ]);
        let diffs = diff_bench_cells(&baseline, &fresh, 0.25);
        let by_name = |n: &str| diffs.iter().find(|d| d.name == n).unwrap();
        assert_eq!(by_name("a").status, DiffStatus::Ok);
        assert_eq!(by_name("b").status, DiffStatus::Regressed);
        assert_eq!(by_name("new").status, DiffStatus::Unmatched);
        assert_eq!(by_name("gone").status, DiffStatus::Unmatched);
        assert!((by_name("b").mean_delta.unwrap() - 1.0).abs() < 1e-12);
        let table = render_diff(&diffs, 0.25);
        assert!(table.contains("REGRESSED"), "{table}");
        assert!(table.contains("1 regression(s)"), "{table}");
        let j = diff_to_json(&diffs, 0.25);
        assert_eq!(j.get("regressions").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("cells").and_then(Json::as_arr).unwrap().len(), 4);
    }

    #[test]
    fn diff_accepts_placeholder_object_baseline() {
        // The committed BENCH_profile.json placeholder is an object with
        // an empty `results` array — it must diff clean, not crash.
        let mut placeholder = Json::obj();
        placeholder.set("status", Json::Str("unmeasured placeholder".into()));
        placeholder.set("results", Json::Arr(Vec::new()));
        let fresh = Json::Arr(vec![cell("a", 100.0, Some(50.0))]);
        let diffs = diff_bench_cells(&placeholder, &fresh, 0.25);
        assert_eq!(diffs.len(), 1);
        assert_eq!(diffs[0].status, DiffStatus::Unmatched);
        assert!(diffs.iter().all(|d| d.status != DiffStatus::Regressed));
        // Object form on the fresh side too.
        let mut fresh_obj = Json::obj();
        fresh_obj.set("results", Json::Arr(vec![cell("a", 100.0, None)]));
        let d2 = diff_bench_cells(&fresh, &fresh_obj, 0.25);
        assert_eq!(d2[0].status, DiffStatus::Ok);
    }

    #[test]
    fn series_csv() {
        let mut s = Series::new("loss", "round", vec![1.0, 2.0, 3.0]);
        s.add_line("safa", vec![0.9, 0.5, 0.3]);
        let csv = s.to_csv();
        assert!(csv.starts_with("round,safa\n"));
        assert!(csv.contains("3,0.3"));
        assert!(s.render().contains("safa"));
    }
}
