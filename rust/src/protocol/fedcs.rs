//! FedCS baseline (Nishio & Yonetani 2019), as characterized in the
//! paper: FedAvg with *estimation-based client filtering* at the
//! selection stage.
//!
//! The server requests resource information from a candidate pool (twice
//! the quota, capped at m), estimates each candidate's round time from
//! its known speed and link bandwidth, and greedily keeps the fastest
//! `quota` candidates whose estimate fits the deadline. Estimates are
//! perfect up to crashes — the paper's criticism that FedCS "relies on
//! accurate estimation and does not take client unreliability into
//! account" is preserved: crashes still waste the slots.

use super::{aggregate_updates_into, collect_updates, FedEnv, Protocol};
use crate::config::ProtocolKind;
use crate::metrics::RoundRecord;
use crate::model::ParamVec;
use crate::net;
use crate::sim::RoundSim;
use crate::telemetry::lifecycle::{self, ClientEvent, Event as LcEvent};

/// Candidate pool size factor (resource requests per selection slot).
const POOL_FACTOR: usize = 2;

pub struct FedCs {
    global: ParamVec,
    /// Reused per-round buffers (see [`super::FedAvg`]).
    agg: ParamVec,
    sel_pool: Vec<usize>,
    pool: Vec<usize>,
    selected: Vec<usize>,
    synced: Vec<bool>,
    sim: RoundSim,
    updates: Vec<(usize, ParamVec, f64)>,
    picked_mask: Vec<bool>,
    /// Per-client round-time estimates for the current pool (cached so
    /// each candidate is probed exactly once per round — under the
    /// fabric an estimate is a per-(round, client) transfer probe).
    estimates: Vec<f64>,
    /// Current fleet members (scenario flash crowds); the resource-
    /// request pool draws from this when membership is dynamic.
    members: Vec<usize>,
}

impl FedCs {
    pub fn new(global: ParamVec) -> FedCs {
        let dim = global.dim();
        FedCs {
            global,
            agg: ParamVec::zeros(dim),
            sel_pool: Vec::new(),
            pool: Vec::new(),
            selected: Vec::new(),
            synced: Vec::new(),
            sim: RoundSim::default(),
            updates: Vec::new(),
            picked_mask: Vec::new(),
            estimates: Vec::new(),
            members: Vec::new(),
        }
    }
}

impl Protocol for FedCs {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::FedCs
    }

    fn global(&self) -> &ParamVec {
        &self.global
    }

    fn run_round(&mut self, t: usize, env: &mut FedEnv) -> RoundRecord {
        let m = env.m();
        let quota = env.cfg.quota();
        if self.picked_mask.len() != m {
            self.picked_mask = vec![false; m];
        }

        // Resource-request pool, then keep the fastest-estimated quota
        // clients that fit the deadline.
        let select_span = crate::telemetry::span(crate::telemetry::Phase::Select);
        let mut sel_rng = env.round_rng(t, 0xfeda);
        if env.dynamic_membership() {
            // Scenario flash crowds: resource requests go to current
            // members only; sampled pool indices map back to client ids.
            self.members.clear();
            self.members.extend((0..m).filter(|&k| env.is_member(t, k)));
            let n = self.members.len();
            let pool_size = (quota * POOL_FACTOR).min(n);
            sel_rng.sample_indices_into(n, pool_size, &mut self.sel_pool, &mut self.pool);
            for s in self.pool.iter_mut() {
                *s = self.members[*s];
            }
        } else {
            let pool_size = (quota * POOL_FACTOR).min(m);
            sel_rng.sample_indices_into(m, pool_size, &mut self.sel_pool, &mut self.pool);
        }
        // Estimated round time per candidate (perfect information
        // model). Under the fabric the estimate is the client's actual
        // per-(round, client) transfer times plus training; with the
        // fabric off it is the closed-form constant, bit-identical to
        // the seed expression.
        if self.estimates.len() != m {
            self.estimates = vec![0.0; m];
        }
        for &k in &self.pool {
            self.estimates[k] = env.t_down_k(t, k)
                + env.clients[k].t_train(env.cfg.train.epochs)
                + env.t_up_k(t, k);
        }
        // Estimates are continuous draws, so ties are measure-zero; the
        // id tie-break just makes the in-place (allocation-free) unstable
        // sort fully deterministic anyway.
        let estimates = &self.estimates;
        self.pool.sort_unstable_by(|&a, &b| {
            estimates[a]
                .partial_cmp(&estimates[b])
                .unwrap()
                .then(a.cmp(&b))
        });
        self.selected.clear();
        self.selected.extend(
            self.pool
                .iter()
                .copied()
                .filter(|&k| estimates[k] <= env.cfg.train.t_lim)
                .take(quota),
        );
        drop(select_span);

        let m_sync = self.selected.len();
        let t_dist = env.t_dist(m_sync);

        let dist_span = crate::telemetry::span(crate::telemetry::Phase::Distribute);
        let lc = lifecycle::active();
        let mut futility_wasted = 0.0;
        for &k in &self.selected {
            if lc {
                // Estimate-sorted pick and sync push both happen at
                // round start (selection ahead of training).
                lifecycle::emit(ClientEvent::new(t, k, LcEvent::Picked, 0.0));
                lifecycle::emit(
                    ClientEvent::new(t, k, LcEvent::Distributed, 0.0).version(t.saturating_sub(1)),
                );
            }
            futility_wasted += env.clients[k].pending_partial;
            env.clients[k].pending_partial = 0.0;
            env.clients[k].local_model.copy_from(&self.global);
            env.clients[k].version = t as i64 - 1;
            env.clients[k].base_version = t as i64 - 1;
        }
        drop(dist_span);

        self.synced.clear();
        self.synced.resize(self.selected.len(), true);
        let round_rng = env.round_rng(t, 0xc4a5);
        env.simulate_round_into(t, &self.selected, &self.synced, &round_rng, &mut self.sim);
        let futility_total = self.selected.len() as f64;

        // Estimation is accurate, so overtime cannot occur among the
        // selected (they were filtered); the wait ends at the last
        // non-crashed arrival — or the last detected mid-round drop
        // under churn (the shared synchronous close rule).
        let client_term = super::sync_close_term(&self.sim, env.cfg.train.t_lim);
        let round_len = net::round_length(t_dist, client_term, env.cfg.train.t_lim);

        collect_updates(env, t, &self.sim.arrivals, &mut self.updates);
        let train_loss_sum: f64 = self.updates.iter().map(|(_, _, loss)| loss).sum();
        let n_committed = self.updates.len();
        let agg_span = crate::telemetry::span(crate::telemetry::Phase::Aggregate);
        if aggregate_updates_into(env, &self.updates, &mut self.agg) {
            self.global.copy_from(&self.agg);
        }
        drop(agg_span);

        self.picked_mask.fill(false);
        for (k, params, _) in &self.updates {
            let c = &mut env.clients[*k];
            if lc {
                lifecycle::emit(
                    ClientEvent::new(t, *k, LcEvent::Merged, round_len)
                        .version(c.base_version.max(0) as usize)
                        .staleness(0),
                );
            }
            c.local_model.copy_from(params);
            c.version = c.base_version + 1;
            c.committed_last = true;
            c.pending_partial = 0.0;
            self.picked_mask[*k] = true;
        }
        for &(k, _, partial) in &self.sim.failures {
            env.clients[k].pending_partial += partial;
            env.clients[k].committed_last = false;
        }
        for k in 0..m {
            env.clients[k].picked_last = self.picked_mask[k];
        }

        let eval = if t % env.cfg.eval_every == 0 {
            Some(env.trainer.evaluate(&self.global))
        } else {
            None
        };

        let rec = RoundRecord {
            round: t,
            round_len,
            t_dist,
            m_sync,
            n_picked: n_committed,
            // As in FedAvg: n_picked already excludes crashed selections.
            n_picked_crashed: 0,
            n_crashed: self.sim.failures.len(),
            n_committed,
            n_undrafted: 0,
            version_variance: env.version_variance(),
            futility_wasted,
            futility_total,
            online_time: self.sim.online_time,
            offline_time: self.sim.offline_time,
            staleness: vec![0; n_committed],
            bytes_down: env.bytes_down(m_sync) + self.sim.retx_bytes_down,
            bytes_up: env.bytes_up(n_committed) + self.sim.retx_bytes_up,
            bytes_saved: env.bytes_saved(m_sync, n_committed),
            train_loss: if n_committed == 0 {
                0.0
            } else {
                train_loss_sum / n_committed as f64
            },
            eval,
        };
        super::observe_round(&rec);
        rec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn tiny_env(crash: f64, c_fraction: f64) -> FedEnv {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.env.crash_prob = crash;
        cfg.protocol.c_fraction = c_fraction;
        FedEnv::new(&cfg).unwrap()
    }

    #[test]
    fn prefers_faster_clients() {
        let mut env = tiny_env(0.0, 0.5); // quota 2 of 4
        // Give clients strictly ordered speeds.
        for (i, c) in env.clients.iter_mut().enumerate() {
            c.perf = (i + 1) as f64;
            c.batches_per_epoch = 10;
        }
        let mut p = FedCs::new(env.init_global());
        let rec = p.run_round(1, &mut env);
        assert_eq!(rec.n_committed, 2);
        // With a pool of 4 (2*quota = 4 = m), the two fastest clients
        // (ids 2, 3) must be the selected ones.
        let trained: Vec<usize> = env
            .clients
            .iter()
            .filter(|c| c.version == 1)
            .map(|c| c.id)
            .collect();
        assert_eq!(trained, vec![2, 3]);
    }

    #[test]
    fn filters_clients_that_cannot_meet_deadline() {
        let mut env = tiny_env(0.0, 1.0);
        // Make one client impossibly slow.
        env.clients[0].perf = 1e-9;
        let mut p = FedCs::new(env.init_global());
        let rec = p.run_round(1, &mut env);
        assert_eq!(rec.m_sync, env.m() - 1, "slow client filtered");
        // And the round never hits the deadline.
        assert!(rec.round_len < env.cfg.train.t_lim);
    }

    #[test]
    fn round_shorter_or_equal_than_fedavg_with_same_seed() {
        // Statistical smoke: across a few seeds FedCS should never be
        // slower than FedAvg when both select from the same fleet.
        for seed in 0..5u64 {
            let mut cfg = presets::preset("tiny").unwrap();
            cfg.env.crash_prob = 0.0;
            cfg.protocol.c_fraction = 0.5;
            cfg.seed = seed;
            let mut env_a = FedEnv::new(&cfg).unwrap();
            let mut env_c = FedEnv::new(&cfg).unwrap();
            let mut fa = FedAvg::new(env_a.init_global());
            let mut fc = FedCs::new(env_c.init_global());
            let ra = fa.run_round(1, &mut env_a);
            let rc = fc.run_round(1, &mut env_c);
            assert!(
                rc.round_len <= ra.round_len + 1e-9,
                "seed {seed}: FedCS {} > FedAvg {}",
                rc.round_len,
                ra.round_len
            );
        }
    }

    use super::super::FedAvg;
}
