//! FedAsync baseline (Xie et al. 2019, "Asynchronous Federated
//! Optimization"), the fully-asynchronous comparison point for SAFA's
//! semi-asynchronous middle ground.
//!
//! Server model: there is no selection and no waiting. Every idle client
//! immediately pulls the *current* global model and starts a new job
//! (download + E local epochs + upload); jobs continue across rounds
//! under the engine's continuation semantics (a crash pauses, a long job
//! spans rounds). Each upload is applied to the global model the moment
//! it arrives, in arrival order, with a staleness-discounted mixing rate
//!
//! ```text
//! w ← (1 − α_s)·w + α_s·w_k,   α_s = alpha / (1 + s)^a
//! ```
//!
//! where `s` is the update's staleness in rounds (how many global rounds
//! passed since the client pulled its base model), `alpha` is
//! `protocol.alpha` and `a` is `protocol.staleness_exp` — the polynomial
//! discount from the FedAsync paper.
//!
//! Within this round-driven harness a "round" is one reporting window:
//! the server applies every arrival inside the window and the round
//! closes at the last arrival (it never blocks on stragglers, mirroring
//! SAFA's close rule without the quota). Staleness is therefore measured
//! in rounds, which keeps it comparable with SAFA's version lag.

use super::{collect_updates, FedEnv, Protocol};
use crate::config::ProtocolKind;
use crate::metrics::RoundRecord;
use crate::model::ParamVec;
use crate::sim::ContinuationSim;
use crate::telemetry::lifecycle::{self, ClientEvent, Event as LcEvent};

pub struct FedAsync {
    /// Current global model.
    global: ParamVec,
    /// Round index of the last completed reporting window.
    global_version: i64,
    /// Reused per-round buffers (allocation-free steady state).
    participants: Vec<usize>,
    jobs: Vec<f64>,
    sim: ContinuationSim,
    updates: Vec<(usize, ParamVec, f64)>,
    /// Clients that pulled a fresh global this round, in client order
    /// (the download queue order under a contended fabric).
    fresh: Vec<usize>,
    /// Fleet membership for the running round (scenario flash crowds);
    /// only filled when membership is dynamic.
    member_mask: Vec<bool>,
}

impl FedAsync {
    pub fn new(global: ParamVec) -> FedAsync {
        FedAsync {
            global,
            global_version: 0,
            participants: Vec::new(),
            jobs: Vec::new(),
            sim: ContinuationSim::default(),
            updates: Vec::new(),
            fresh: Vec::new(),
            member_mask: Vec::new(),
        }
    }
}

impl Protocol for FedAsync {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::FedAsync
    }

    fn global(&self) -> &ParamVec {
        &self.global
    }

    fn run_round(&mut self, t: usize, env: &mut FedEnv) -> RoundRecord {
        let m = env.m();
        let t_i = t as i64;
        debug_assert_eq!(self.global_version, t_i - 1, "round driven out of order");

        // --- 1. Every idle client pulls the current global and starts a
        // fresh job. Paused and in-flight jobs continue untouched — the
        // fully-async server never forces a sync, so no work is ever
        // destroyed (futility stays zero by construction).
        let epochs = env.cfg.train.epochs;
        let (t_down, t_up) = (env.net.t_down(), env.net.t_up());
        let fabric = env.fabric.as_ref();
        let dist_span = crate::telemetry::span(crate::telemetry::Phase::Distribute);
        let lc = lifecycle::active();
        // Scenario flash crowds: non-members take no part — a latecomer
        // never pulls before joining, and a departed device's in-flight
        // job is abandoned (the device is gone; that destroyed progress
        // is the protocol's only futility source).
        let dynamic = env.dynamic_membership();
        if dynamic {
            self.member_mask.clear();
            self.member_mask.extend((0..m).map(|k| env.is_member(t, k)));
        }
        let mut futility_wasted = 0.0;
        self.fresh.clear();
        for c in env.clients.iter_mut() {
            if dynamic && !self.member_mask[c.id] {
                if let Some(job) = c.job.take() {
                    futility_wasted += job.progress();
                }
                continue;
            }
            if c.job.is_none() {
                if lc {
                    // No selection stage: an idle client's pull IS its
                    // entry into the round.
                    lifecycle::emit(
                        ClientEvent::new(t, c.id, LcEvent::Distributed, 0.0)
                            .version((t_i - 1).max(0) as usize),
                    );
                }
                c.local_model.copy_from(&self.global);
                c.version = t_i - 1;
                c.base_version = t_i - 1;
                let (td, tu) = match fabric {
                    Some(f) => (f.t_down(t, c.id), f.t_up(t, c.id)),
                    None => (t_down, t_up),
                };
                let total = td + c.t_train(epochs) + tu;
                c.start_job(total, t_i - 1);
                if let Some(j) = c.job.as_mut() {
                    j.tail_up = tu;
                }
                self.fresh.push(c.id);
            }
        }
        let m_sync = self.fresh.len();
        // Contended fabric: fresh pulls queue on the shared server link
        // in client order; the scheduled wait stretches each new job.
        if let Some(f) = fabric.filter(|f| f.has_dist_wait()) {
            let _span = crate::telemetry::span(crate::telemetry::Phase::TransferWait);
            for (i, &k) in self.fresh.iter().enumerate() {
                let wait = f.dist_wait(i, m_sync);
                if wait > 0.0 {
                    if let Some(job) = env.clients[k].job.as_mut() {
                        job.remaining += wait;
                        job.total += wait;
                    }
                }
            }
        }
        drop(dist_span);
        let t_dist = env.t_dist(m_sync);

        // --- 2. Advance the whole fleet on the event engine.
        if self.participants.len() != m {
            self.participants = (0..m).collect();
        }
        self.jobs.clear();
        self.jobs.extend(
            env.clients
                .iter()
                .map(|c| c.job.map(|j| j.remaining).unwrap_or(f64::INFINITY)),
        );
        let round_rng = env.round_rng(t, 0xc4a5);
        env.simulate_continuation_into(
            t,
            &self.participants,
            &self.jobs,
            &round_rng,
            &mut self.sim,
        );

        // --- 3. Apply arrivals immediately, in arrival order, each
        // discounted by its staleness. The update *computation* fans out
        // across the pool for stateless backends (it only reads client
        // state); the mixing below stays serial because each merge reads
        // the global the previous one produced.
        let alpha = env.cfg.protocol.alpha;
        let a_exp = env.cfg.protocol.staleness_exp;
        collect_updates(env, t, &self.sim.arrivals, &mut self.updates);
        let agg_span = crate::telemetry::span(crate::telemetry::Phase::Aggregate);
        let mut staleness: Vec<u32> = Vec::with_capacity(self.updates.len());
        let mut train_loss_sum = 0.0;
        for c in env.clients.iter_mut() {
            c.picked_last = false;
        }
        for (i, (k, params, loss)) in self.updates.iter().enumerate() {
            let k = *k;
            let base_version = env.clients[k].job_base_version();
            let s = (t_i - 1 - base_version).max(0) as u32;
            if lc {
                // Applied the moment it arrives: merge time == arrival
                // time (collect_updates preserves arrival order).
                lifecycle::emit(
                    ClientEvent::new(t, k, LcEvent::Merged, self.sim.arrivals[i].time)
                        .version(base_version.max(0) as usize)
                        .staleness(s),
                );
            }
            let alpha_s = (alpha / (1.0 + s as f64).powf(a_exp)) as f32;
            self.global.scale(1.0 - alpha_s);
            self.global.axpy(alpha_s, params);
            staleness.push(s);
            train_loss_sum += loss;
            let c = &mut env.clients[k];
            c.local_model.copy_from(params);
            c.version = base_version + 1;
            c.committed_last = true;
            c.picked_last = true;
            c.job = None;
        }
        self.global_version = t_i;
        drop(agg_span);

        // --- 4. Round close: never wait (no quota) — the shared
        // continuation rule closes at the last arrival, advances
        // straggler jobs and clears crashed/straggler up-to-date flags.
        let round_len = super::close_continuation_round(env, &self.sim, None, t_dist);

        let eval = if t % env.cfg.eval_every == 0 {
            Some(env.trainer.evaluate(&self.global))
        } else {
            None
        };

        let n_applied = self.sim.arrivals.len();
        // Non-members ride the engine pass with always-off windows and
        // land in the crashed set; charge crashes and futility to actual
        // members only.
        let n_absent = if dynamic {
            self.member_mask.iter().filter(|&&b| !b).count()
        } else {
            0
        };
        let rec = RoundRecord {
            round: t,
            round_len,
            t_dist,
            m_sync,
            n_picked: n_applied,
            // No selection at all: every applied update counts; the only
            // "picked crash" is a fault injector cutting an upload leg.
            n_picked_crashed: self.sim.upload_crashed,
            n_crashed: (self.sim.crashed.len() + self.sim.stragglers.len())
                .saturating_sub(n_absent),
            n_committed: n_applied,
            n_undrafted: 0,
            version_variance: env.version_variance(),
            futility_wasted,
            futility_total: (m - n_absent) as f64,
            online_time: self.sim.online_time,
            offline_time: self.sim.offline_time,
            staleness,
            bytes_down: env.bytes_down(m_sync),
            bytes_up: env.bytes_up(n_applied) + self.sim.retx_bytes_up,
            bytes_saved: env.bytes_saved(m_sync, n_applied),
            train_loss: if n_applied == 0 {
                0.0
            } else {
                train_loss_sum / n_applied as f64
            },
            eval,
        };
        super::observe_round(&rec);
        rec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn tiny_env(crash: f64) -> FedEnv {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.protocol.kind = crate::config::ProtocolKind::FedAsync;
        cfg.env.crash_prob = crash;
        FedEnv::new(&cfg).unwrap()
    }

    #[test]
    fn round_one_syncs_everyone_and_applies_fresh_updates() {
        let mut env = tiny_env(0.0);
        let mut p = FedAsync::new(env.init_global());
        let rec = p.run_round(1, &mut env);
        assert_eq!(rec.m_sync, env.m());
        assert!(rec.t_dist > 0.0);
        assert_eq!(rec.n_picked, rec.n_committed);
        assert_eq!(rec.n_committed + rec.n_crashed, env.m());
        // Everything applied in round 1 trained on w(0): zero staleness.
        assert!(rec.staleness.iter().all(|&s| s == 0));
        assert_eq!(rec.staleness.len(), rec.n_committed);
        // FedAsync never destroys client work.
        assert_eq!(rec.futility_wasted, 0.0);
    }

    #[test]
    fn all_crashed_pauses_jobs_and_keeps_global() {
        let mut env = tiny_env(1.0);
        let g0 = env.init_global();
        let mut p = FedAsync::new(g0.clone());
        let r1 = p.run_round(1, &mut env);
        assert_eq!(r1.n_committed, 0);
        assert_eq!(p.global(), &g0);
        // Jobs survive the crash round (paused, not destroyed) …
        assert!(env.clients.iter().all(|c| c.job.is_some()));
        // … so no fresh syncs happen in round 2.
        let r2 = p.run_round(2, &mut env);
        assert_eq!(r2.m_sync, 0);
    }

    #[test]
    fn updates_move_the_global_model() {
        let mut env = tiny_env(0.0);
        let g0 = env.init_global();
        let mut p = FedAsync::new(g0.clone());
        let rec = p.run_round(1, &mut env);
        if rec.n_committed > 0 {
            assert!(p.global().dist(&g0) > 0.0, "applied updates must move w");
        }
    }

    #[test]
    fn stale_updates_are_logged_and_discounted() {
        // Round 1 under full crashes parks every client on a w(0)-based
        // job; once crashes stop, those jobs commit one or more rounds
        // late and must be recorded with staleness >= 1.
        let mut env = tiny_env(1.0);
        let mut p = FedAsync::new(env.init_global());
        let _ = p.run_round(1, &mut env);
        env.cfg.env.crash_prob = 0.0;
        let mut saw_stale = false;
        for t in 2..=4 {
            let rec = p.run_round(t, &mut env);
            if rec.staleness.iter().any(|&s| s >= 1) {
                saw_stale = true;
            }
        }
        assert!(saw_stale, "paused jobs should commit with staleness >= 1");
    }

    #[test]
    fn discount_weight_shrinks_with_staleness() {
        // The mixing-rate formula itself (unit sanity, no fleet needed).
        let alpha = 0.6;
        let a = 0.5;
        let w = |s: f64| alpha / (1.0 + s).powf(a);
        assert!(w(0.0) > w(1.0));
        assert!(w(1.0) > w(4.0));
        assert!((w(0.0) - alpha).abs() < 1e-12);
    }
}
