//! Fully-local baseline: clients train on their own shards every round
//! with no intermediate aggregation; one weighted average over a random
//! C-fraction of local models is taken after the final round (§IV-A:
//! "the fully local protocol never performs the global aggregation until
//! the end of the final round").

use super::{collect_updates, FedEnv, Protocol};
use crate::config::ProtocolKind;
use crate::metrics::RoundRecord;
use crate::model::ParamVec;
use crate::net;

pub struct FullyLocal {
    /// Holds w(0) during training; replaced by the final aggregate in
    /// `finalize`.
    global: ParamVec,
    finalized: bool,
}

impl FullyLocal {
    pub fn new(global: ParamVec) -> FullyLocal {
        FullyLocal {
            global,
            finalized: false,
        }
    }
}

impl Protocol for FullyLocal {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::FullyLocal
    }

    fn global(&self) -> &ParamVec {
        &self.global
    }

    fn run_round(&mut self, t: usize, env: &mut FedEnv) -> RoundRecord {
        let m = env.m();
        // Every client trains from its own model; no distribution, no
        // uploads (m_sync = 0, T_dist = 0, commits are local-only).
        // Scenario flash crowds: only current members train.
        let participants: Vec<usize> = if env.dynamic_membership() {
            (0..m).filter(|&k| env.is_member(t, k)).collect()
        } else {
            (0..m).collect()
        };
        let synced = vec![false; participants.len()];
        let round_rng = env.round_rng(t, 0xc4a5);
        let sim = env.simulate_round(t, &participants, &synced, &round_rng);

        let mut train_loss_sum = 0.0;
        let mut updates = Vec::new();
        collect_updates(env, t, &sim.arrivals, &mut updates);
        let n_finished = updates.len();
        for (k, params, loss) in &updates {
            train_loss_sum += loss;
            let c = &mut env.clients[*k];
            c.local_model.copy_from(params);
            c.version += 1; // local lineage only
        }

        // Round pacing: last finisher (no uploads, so subtract t_up is
        // debatable; we keep the simulated arrival to stay comparable).
        let round_len = net::round_length(0.0, sim.last_arrival(), env.cfg.train.t_lim);

        let eval = if t % env.cfg.eval_every == 0 {
            // During training the "global model" is meaningless for the
            // fully-local baseline; the paper evaluates it only after the
            // final aggregation. We report the mean of local-model
            // accuracies (over a fixed-size client sample to bound eval
            // cost at m=500) as the per-round trace.
            let sample = m.min(8);
            let mut srng = env.round_rng(t, 0xe7a1);
            let ids = srng.sample_indices(m, sample);
            let mut loss = 0.0;
            let mut acc = 0.0;
            for k in ids {
                let e = env.trainer.evaluate(&env.clients[k].local_model);
                loss += e.loss;
                acc += e.accuracy;
            }
            Some(crate::model::EvalResult {
                loss: loss / sample as f64,
                accuracy: acc / sample as f64,
            })
        } else {
            None
        };

        let rec = RoundRecord {
            round: t,
            round_len,
            t_dist: 0.0,
            m_sync: 0,
            n_picked: 0,
            n_picked_crashed: 0,
            n_crashed: sim.failures.len(),
            n_committed: n_finished,
            n_undrafted: 0,
            version_variance: env.version_variance(),
            futility_wasted: 0.0,
            futility_total: participants.len() as f64,
            online_time: sim.online_time,
            offline_time: sim.offline_time,
            staleness: Vec::new(),
            // No server traffic until the single end-of-run aggregation.
            bytes_down: 0.0,
            bytes_up: 0.0,
            bytes_saved: 0.0,
            train_loss: if n_finished == 0 {
                0.0
            } else {
                train_loss_sum / n_finished as f64
            },
            eval,
        };
        super::observe_round(&rec);
        rec
    }

    fn finalize(&mut self, env: &mut FedEnv) {
        if self.finalized {
            return;
        }
        self.finalized = true;
        // Single end-of-run aggregation over a random C-fraction. With
        // dynamic membership (scenario flash crowds) the sample is drawn
        // from the final round's members; otherwise from the whole fleet,
        // bit-for-bit as before (the identity index map below is free).
        let _span = crate::telemetry::span(crate::telemetry::Phase::Aggregate);
        let final_round = env.cfg.train.rounds;
        let pool: Vec<usize> = if env.dynamic_membership() {
            (0..env.m())
                .filter(|&k| env.is_member(final_round.max(1), k))
                .collect()
        } else {
            (0..env.m()).collect()
        };
        let quota = env.cfg.quota().min(pool.len());
        let mut rng = env.round_rng(env.cfg.train.rounds + 1, 0xf17a);
        let subset: Vec<usize> = rng
            .sample_indices(pool.len(), quota)
            .into_iter()
            .map(|i| pool[i])
            .collect();
        let total: f64 = subset.iter().map(|&k| env.clients[k].n_k as f64).sum();
        if subset.is_empty() {
            // Degenerate scenario: nobody left to aggregate — keep w(0).
            return;
        }
        let mut agg = ParamVec::zeros(self.global.dim());
        for &k in &subset {
            let w = (env.clients[k].n_k as f64 / total) as f32;
            agg.axpy(w, &env.clients[k].local_model);
        }
        self.global = agg;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn no_distribution_overhead() {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.env.crash_prob = 0.0;
        let mut env = FedEnv::new(&cfg).unwrap();
        let mut p = FullyLocal::new(env.init_global());
        let rec = p.run_round(1, &mut env);
        assert_eq!(rec.t_dist, 0.0);
        assert_eq!(rec.m_sync, 0);
        assert_eq!(rec.n_picked, 0);
        assert_eq!(rec.n_committed, env.m());
    }

    #[test]
    fn models_diverge_without_aggregation() {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.env.crash_prob = 0.0;
        let mut env = FedEnv::new(&cfg).unwrap();
        let mut p = FullyLocal::new(env.init_global());
        for t in 1..=3 {
            let _ = p.run_round(t, &mut env);
        }
        // Different shards -> different local models.
        let d01 = env.clients[0].local_model.dist(&env.clients[1].local_model);
        assert!(d01 > 1e-9, "local models should diverge, dist {d01}");
    }

    #[test]
    fn finalize_aggregates_once() {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.env.crash_prob = 0.0;
        cfg.protocol.c_fraction = 1.0;
        let mut env = FedEnv::new(&cfg).unwrap();
        let g0 = env.init_global();
        let mut p = FullyLocal::new(g0.clone());
        for t in 1..=2 {
            let _ = p.run_round(t, &mut env);
        }
        assert_eq!(p.global(), &g0, "global untouched before finalize");
        p.finalize(&mut env);
        assert_ne!(p.global(), &g0, "finalize installs the aggregate");
        let snapshot = p.global().clone();
        p.finalize(&mut env);
        assert_eq!(p.global(), &snapshot, "finalize is idempotent");
    }
}
