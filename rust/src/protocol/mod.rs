//! Federated protocols: SAFA (the paper's contribution) and the four
//! baselines it is evaluated against (FedAvg, FedCS, FedAsync,
//! fully-local).
//!
//! A [`Protocol`] drives one federated round at a time against a shared
//! [`FedEnv`] (clients, data, trainer, network model, fleet engine, RNG).
//! The coordinator owns the round loop and metric collection; round
//! execution happens on the discrete-event fleet engine held by the
//! environment, which honours the configured availability model
//! (`env.churn`).

mod fedasync;
mod fedavg;
mod fedcs;
mod local;
mod safa;

pub use fedasync::FedAsync;
pub use fedavg::FedAvg;
pub use fedcs::FedCs;
pub use local::FullyLocal;
pub use safa::{Safa, SafaOptions};

use crate::client::{build_clients, ClientState};
use crate::config::{ExperimentConfig, ProtocolKind};
use crate::data::{partition_gaussian, synth, FedData};
use crate::engine::{FleetEngine, RoundCtx};
use crate::error::Result;
use crate::faults::FaultRuntime;
use crate::metrics::RoundRecord;
use crate::sim::{Arrival, ContinuationSim, FailReason, RoundSim};
use crate::model::{make_trainer, ParamVec, Trainer};
use crate::net::fabric::FabricRuntime;
use crate::net::NetworkModel;
use crate::util::parallel;
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// Minimum client updates per worker before [`collect_updates`] fans
/// out (stateless backends only, toy-dim models). An update is at
/// least an RNG split + a model clone, so even small shares pay once
/// fleets reach hundreds.
const UPDATE_GRAIN: usize = 16;

/// [`UPDATE_GRAIN`] scaled to the model, mirroring [`fleet_grain`]: one
/// client update costs O(dim) SGD work per batch, so the per-worker
/// share shrinks as the model grows — 16 updates/worker at toy dims
/// down to 1 for CNN-scale models, where a single update dwarfs a
/// pooled dispatch.
fn update_grain(dim: usize) -> usize {
    (UPDATE_GRAIN / (1 + dim / 256)).max(1)
}

/// Per-client grain for fleet-sized parallel passes (sync pushes, cache
/// refreshes, state transitions): the per-client work is a fixed
/// bookkeeping cost plus a dim-sized model copy, so the grain shrinks as
/// the model grows. At dim 1 (Null backend) a worker takes 512 clients;
/// at CNN scale (431k) every client is already a worker's worth.
pub(crate) fn fleet_grain(dim: usize) -> usize {
    (512 / (1 + dim / 128)).max(1)
}

/// Shared experiment state every protocol operates on.
pub struct FedEnv {
    pub cfg: ExperimentConfig,
    pub data: Arc<FedData>,
    pub clients: Vec<ClientState>,
    pub trainer: Box<dyn Trainer>,
    pub net: NetworkModel,
    /// Network fabric runtime, when `cfg.env.fabric.enabled`: transfer
    /// pricing, contention waits and update compression. `None` keeps
    /// the closed-form `net` arithmetic bit-for-bit (the `t_dist` /
    /// `bytes_*` / `t_down_k` helpers below dispatch on this).
    pub fabric: Option<FabricRuntime>,
    /// Fault-injection runtime, when `cfg.env.faults.enabled`: crash
    /// hazards, flapping, regional outages, link degradation and the
    /// server's retry policy. `None` (or an enabled plan with no
    /// injector) keeps the legacy engine paths bit-for-bit.
    pub faults: Option<FaultRuntime>,
    /// Discrete-event round executor (availability model from
    /// `cfg.env.churn`; Markov churn state persists across rounds here).
    pub engine: FleetEngine,
    /// Aggregation weights n_k / n (Eq. 7).
    pub weights: Vec<f32>,
    root_rng: Pcg64,
    /// Reused slot buffer for the parallel update fan-out
    /// ([`collect_updates`]).
    upd_slots: Vec<Option<(usize, ParamVec, f64)>>,
    /// Reused per-participant upload-tail buffer for the faults
    /// continuation path ([`FedEnv::simulate_continuation_into`]).
    cont_tails: Vec<f64>,
}

impl FedEnv {
    /// Build the environment: synthesize data, partition it, draw the
    /// client fleet, and initialize the trainer and global model. All
    /// randomness descends from `cfg.seed`.
    pub fn new(cfg: &ExperimentConfig) -> Result<FedEnv> {
        cfg.validate()?;
        let (train, test) = synth::generate(cfg.task.kind, cfg.task.n, cfg.task.n_test, cfg.seed);
        let mut part_rng = Pcg64::with_stream(cfg.seed, 0x9a57);
        let partitions =
            partition_gaussian(train.n, cfg.env.m, cfg.env.partition_rel_std, &mut part_rng);
        let data = Arc::new(FedData {
            train,
            test,
            partitions,
        });
        Self::with_data(cfg, data)
    }

    /// Build from pre-made data (lets benches reuse one dataset across a
    /// protocol grid, and tests inject tiny fixtures).
    pub fn with_data(cfg: &ExperimentConfig, data: Arc<FedData>) -> Result<FedEnv> {
        let trainer = make_trainer(cfg, Arc::clone(&data));
        Self::with_trainer(cfg, data, trainer)
    }

    /// Full injection point (the XLA runtime backend enters here).
    pub fn with_trainer(
        cfg: &ExperimentConfig,
        data: Arc<FedData>,
        trainer: Box<dyn Trainer>,
    ) -> Result<FedEnv> {
        let root_rng = Pcg64::with_stream(cfg.seed, 0x5afa);
        let mut init_rng = root_rng.split(0x1817);
        let init = trainer.init_params(&mut init_rng);
        let mut fleet_rng = root_rng.split(0xf1ee);
        let clients = build_clients(cfg, &data, &init, &mut fleet_rng);
        let total: f64 = clients.iter().map(|c| c.n_k as f64).sum();
        let weights = clients.iter().map(|c| (c.n_k as f64 / total) as f32).collect();
        let net = NetworkModel::new(&cfg.env);
        let fabric = cfg
            .env
            .fabric
            .enabled
            .then(|| FabricRuntime::new(&cfg.env, cfg.seed));
        let faults = cfg.env.faults.enabled.then(|| FaultRuntime::new(cfg));
        let engine = FleetEngine::from_config(cfg)?;
        Ok(FedEnv {
            cfg: cfg.clone(),
            data,
            clients,
            trainer,
            net,
            fabric,
            faults,
            engine,
            weights,
            root_rng,
            upd_slots: Vec::new(),
            cont_tails: Vec::new(),
        })
    }

    /// Fresh global-model initialization (same across protocols for a
    /// given seed).
    pub fn init_global(&self) -> ParamVec {
        let mut rng = self.root_rng.split(0x1817);
        self.trainer.init_params(&mut rng)
    }

    /// Run round `t`'s fresh-job training phase on the fleet engine.
    /// Bundles the disjoint field borrows (`RoundCtx`) so protocols
    /// don't repeat the plumbing.
    pub fn simulate_round(
        &mut self,
        t: usize,
        participants: &[usize],
        synced: &[bool],
        round_rng: &Pcg64,
    ) -> RoundSim {
        let ctx = RoundCtx {
            cfg: &self.cfg,
            net: &self.net,
            clients: &self.clients,
            fabric: self.fabric.as_ref(),
            faults: self.faults.as_ref(),
        };
        self.engine.run_round(t, ctx, participants, synced, round_rng)
    }

    /// [`FedEnv::simulate_round`] into a caller-owned, buffer-reusing
    /// record (steady-state rounds stay allocation-free).
    pub fn simulate_round_into(
        &mut self,
        t: usize,
        participants: &[usize],
        synced: &[bool],
        round_rng: &Pcg64,
        out: &mut RoundSim,
    ) {
        let ctx = RoundCtx {
            cfg: &self.cfg,
            net: &self.net,
            clients: &self.clients,
            fabric: self.fabric.as_ref(),
            faults: self.faults.as_ref(),
        };
        self.engine
            .run_round_into(t, ctx, participants, synced, round_rng, out)
    }

    /// Run round `t` over in-flight jobs (continuation semantics) on the
    /// fleet engine.
    pub fn simulate_continuation(
        &mut self,
        t: usize,
        participants: &[usize],
        jobs: &[f64],
        round_rng: &Pcg64,
    ) -> ContinuationSim {
        let mut out = ContinuationSim::default();
        self.simulate_continuation_into(t, participants, jobs, round_rng, &mut out);
        out
    }

    /// [`FedEnv::simulate_continuation`] into a caller-owned,
    /// buffer-reusing record. With a fault runtime live, dispatches to
    /// the engine's faults continuation path, handing it each in-flight
    /// job's trailing-upload seconds (`Job::tail_up`) so mid-transfer
    /// cuts are classified as upload-leg crashes.
    pub fn simulate_continuation_into(
        &mut self,
        t: usize,
        participants: &[usize],
        jobs: &[f64],
        round_rng: &Pcg64,
        out: &mut ContinuationSim,
    ) {
        if let Some(f) = self.faults.as_ref() {
            let clients = &self.clients;
            self.cont_tails.clear();
            self.cont_tails.extend(
                participants
                    .iter()
                    .map(|&k| clients[k].job.map_or(0.0, |j| j.tail_up)),
            );
            self.engine.run_continuation_faults_into(
                t,
                &self.cfg,
                participants,
                jobs,
                &self.cont_tails,
                self.fabric.as_ref(),
                f,
                round_rng,
                out,
            );
        } else {
            self.engine
                .run_continuation_into(t, &self.cfg, participants, jobs, round_rng, out)
        }
    }

    /// Download seconds for client `k` in round `t` (fabric-aware; falls
    /// back to the closed-form link time, bit-for-bit, with no fabric).
    pub fn t_down_k(&self, t: usize, k: usize) -> f64 {
        match &self.fabric {
            Some(f) => f.t_down(t, k),
            None => self.net.t_down(),
        }
    }

    /// Upload seconds for client `k` in round `t` (see [`FedEnv::t_down_k`]).
    pub fn t_up_k(&self, t: usize, k: usize) -> f64 {
        match &self.fabric {
            Some(f) => f.t_up(t, k),
            None => self.net.t_up(),
        }
    }

    /// Contention queueing delay before sync copy `sync_idx` of `m_sync`
    /// starts downloading (0.0 without a fabric or under an uncontended
    /// policy).
    pub fn dist_wait(&self, sync_idx: usize, m_sync: usize) -> f64 {
        match &self.fabric {
            Some(f) => f.dist_wait(sync_idx, m_sync),
            None => 0.0,
        }
    }

    /// Server-side distribution overhead (Eq. 19; compression-scaled
    /// under a fabric).
    pub fn t_dist(&self, m_sync: usize) -> f64 {
        match &self.fabric {
            Some(f) => f.t_dist(m_sync),
            None => self.net.t_dist(m_sync),
        }
    }

    /// Downlink bytes actually sent for `m_sync` distributed copies.
    pub fn bytes_down(&self, m_sync: usize) -> f64 {
        match &self.fabric {
            Some(f) => f.bytes_down(m_sync),
            None => self.net.bytes_down(m_sync),
        }
    }

    /// Uplink bytes actually sent for `n_uploads` arrived updates.
    pub fn bytes_up(&self, n_uploads: usize) -> f64 {
        match &self.fabric {
            Some(f) => f.bytes_up(n_uploads),
            None => self.net.bytes_up(n_uploads),
        }
    }

    /// Bytes compression saved this round versus uncompressed transfers.
    pub fn bytes_saved(&self, m_sync: usize, n_uploads: usize) -> f64 {
        match &self.fabric {
            Some(f) => f.bytes_saved(m_sync, n_uploads),
            None => 0.0,
        }
    }

    /// RNG stream for round-level events (crashes, selection shuffles).
    pub fn round_rng(&self, t: usize, salt: u64) -> Pcg64 {
        self.root_rng.split(t as u64).split(salt)
    }

    /// RNG stream for client `k`'s local training in round `t`
    /// (batch shuffling) — identical across protocols.
    pub fn client_train_rng(&self, t: usize, k: usize) -> Pcg64 {
        self.root_rng
            .split(t as u64)
            .split(0x7a11 + k as u64)
    }

    /// Variance of the fleet's local-model versions (Eq. 10's per-round
    /// term). Same two-pass formula as `stats::variance`, streamed over
    /// the clients so no m-sized vector is collected every round.
    pub fn version_variance(&self) -> f64 {
        let n = self.clients.len();
        if n == 0 {
            return 0.0;
        }
        let mean = self.clients.iter().map(|c| c.version as f64).sum::<f64>() / n as f64;
        self.clients
            .iter()
            .map(|c| {
                let x = c.version as f64;
                (x - mean) * (x - mean)
            })
            .sum::<f64>()
            / n as f64
    }

    pub fn m(&self) -> usize {
        self.cfg.env.m
    }

    /// Does fleet membership change over the run (scenario flash crowds)?
    /// False for every legacy configuration — the protocols gate all
    /// membership filtering on this so scenario-off runs keep their RNG
    /// consumption and selection order bit-for-bit.
    pub fn dynamic_membership(&self) -> bool {
        self.engine.scenario().is_some()
    }

    /// Is client `k` a fleet member during round `t`? Always true without
    /// a scenario timeline; with one, flash-crowd latecomers are
    /// non-members before their join and leavers after their departure.
    pub fn is_member(&self, t: usize, k: usize) -> bool {
        match self.engine.scenario() {
            Some(tl) => tl.member_in_round(k, t),
            None => true,
        }
    }
}

/// Run the local updates for every arrival, in arrival order, into a
/// reused output buffer. When the backend is stateless
/// ([`crate::model::StatelessTrainer`]) the per-client updates fan out
/// across the worker pool — each slot is an independent function of its
/// per-(round, client) RNG stream, so the result is bit-identical to
/// the serial path at any width. All native backends are stateless (the
/// CNN trains in per-worker scratch slots); only backends with
/// exclusive device state (the XLA trainer) take the serial loop.
pub(crate) fn collect_updates(
    env: &mut FedEnv,
    t: usize,
    arrivals: &[Arrival],
    out: &mut Vec<(usize, ParamVec, f64)>,
) {
    let _span = crate::telemetry::span(crate::telemetry::Phase::LocalUpdate);
    out.clear();
    out.reserve(arrivals.len());
    // Hoist the round-level split (loop-invariant): `base.split(0x7a11 +
    // k)` below reproduces `client_train_rng(t, k)` stream-for-stream.
    let base_rng = env.root_rng.split(t as u64);
    let FedEnv {
        clients,
        trainer,
        upd_slots,
        fabric,
        ..
    } = env;
    let clients: &[ClientState] = clients;
    // Update compression (fabric codecs) applies to every protocol's
    // uploads in one place: the delta against the model the client
    // trained from (`local_model`, which the server knows) is compressed
    // and its reconstruction stored, so aggregation, caches and bypass
    // all see exactly what crossed the wire. Pure in (t, k) — safe in
    // the parallel fan-out below.
    let fabric: Option<&FabricRuntime> = fabric.as_ref().filter(|f| f.compresses_updates());
    // Heavier models amortize a dispatch over fewer updates.
    let grain = update_grain(trainer.dim());
    // Two `stateless()` calls instead of one `if let`: binding the
    // returned borrow in an `if let` would extend it into the else
    // branch (NLL limitation), where `trainer` must be mutable.
    if trainer.stateless().is_some() {
        let shared = trainer.stateless().expect("checked stateless");
        upd_slots.clear();
        upd_slots.resize(arrivals.len(), None);
        parallel::for_each_chunk(upd_slots, grain, |off, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                let k = arrivals[off + i].client;
                let mut rng = base_rng.split(0x7a11 + k as u64);
                let mut u = shared.local_update_shared(&clients[k].local_model, k, &mut rng);
                if let Some(f) = fabric {
                    f.compress_update(t, k, &clients[k].local_model, &mut u.params);
                }
                *slot = Some((k, u.params, u.train_loss));
            }
        });
        out.extend(
            upd_slots
                .iter_mut()
                .map(|s| s.take().expect("update slot filled")),
        );
    } else {
        for a in arrivals {
            let k = a.client;
            let mut rng = base_rng.split(0x7a11 + k as u64);
            let mut u = trainer.local_update(&clients[k].local_model, k, &mut rng);
            if let Some(f) = fabric {
                f.compress_update(t, k, &clients[k].local_model, &mut u.params);
            }
            out.push((k, u.params, u.train_loss));
        }
    }
}

/// A federated protocol.
pub trait Protocol {
    fn kind(&self) -> ProtocolKind;

    /// Current global model parameters.
    fn global(&self) -> &ParamVec;

    /// Execute one federated round (1-based `t`).
    fn run_round(&mut self, t: usize, env: &mut FedEnv) -> RoundRecord;

    /// Called once after the final round; the fully-local baseline
    /// performs its only aggregation here. Default: no-op.
    fn finalize(&mut self, _env: &mut FedEnv) {}
}

/// Build a protocol instance for the configured kind.
pub fn make_protocol(env: &FedEnv) -> Box<dyn Protocol> {
    let global = env.init_global();
    match env.cfg.protocol.kind {
        ProtocolKind::Safa => Box::new(Safa::new(env, global)),
        ProtocolKind::FedAvg => Box::new(FedAvg::new(global)),
        ProtocolKind::FedCs => Box::new(FedCs::new(global)),
        ProtocolKind::FedAsync => Box::new(FedAsync::new(global)),
        ProtocolKind::FullyLocal => Box::new(FullyLocal::new(global)),
    }
}

/// Round-close term for synchronous servers (FedAvg / FedCS): anyone
/// going overtime holds the round open to the deadline; otherwise the
/// server waits for the last arrival — or the last *detected* mid-round
/// disconnect under churn (opt-out crashes at round start add no wait).
pub(crate) fn sync_close_term(sim: &RoundSim, t_lim: f64) -> f64 {
    if sim
        .failures
        .iter()
        .any(|&(_, reason, _)| reason == FailReason::Overtime)
    {
        t_lim
    } else {
        sim.last_arrival().max(sim.last_drop)
    }
}

/// Close a continuation-semantics round (SAFA / FedAsync): resolve the
/// client-side term (quota-close time when given, else the last arrival;
/// with only stragglers left the window spans T_lim; an empty round
/// closes immediately), advance straggler jobs by the round's duration,
/// and mark crashed + straggling clients as not up-to-date. Returns the
/// round length.
pub(crate) fn close_continuation_round(
    env: &mut FedEnv,
    sim: &crate::sim::ContinuationSim,
    quota_close: Option<f64>,
    t_dist: f64,
) -> f64 {
    let t_lim = env.cfg.train.t_lim;
    let client_term = quota_close.unwrap_or_else(|| {
        if !sim.arrivals.is_empty() {
            sim.last_arrival()
        } else if !sim.stragglers.is_empty() {
            t_lim
        } else {
            0.0
        }
    });
    let duration = client_term.min(t_lim);
    for &k in &sim.stragglers {
        if let Some(job) = env.clients[k].job.as_mut() {
            job.remaining -= duration;
        }
    }
    // Graceful degradation: clients the fault injectors cut mid-job keep
    // the work they finished before the cut (their job resumes from
    // there next round) when the plan grants partial credit. Off the
    // faults path `crash_info` is empty, so legacy rounds are untouched.
    if env
        .faults
        .as_ref()
        .is_some_and(|f| f.plan().partial_credit)
    {
        for &(k, done) in &sim.crash_info {
            if let Some(job) = env.clients[k].job.as_mut() {
                job.remaining = (job.remaining - done).max(0.0);
            }
        }
    }
    for &k in sim.crashed.iter().chain(&sim.stragglers) {
        env.clients[k].committed_last = false;
    }
    crate::net::round_length(t_dist, client_term, t_lim)
}

/// Record a finished round's sim-time distributions — round duration and
/// every applied staleness — into the telemetry histograms. Called by
/// each protocol server from its serial tail, just before the
/// `RoundRecord` is returned, so recording order is deterministic.
pub(crate) fn observe_round(rec: &RoundRecord) {
    use crate::telemetry::hist::{self, HistMetric};
    if !crate::telemetry::enabled() {
        return;
    }
    hist::record_secs_as_ms(HistMetric::RoundDurationMs, rec.round_len);
    for &s in &rec.staleness {
        hist::record(HistMetric::StalenessRounds, s as u64);
    }
}

/// FedAvg-style weighted aggregation over committed updates (client ids
/// taken from the update tuples, which the callers build in committed
/// order): out = Σ_{k∈S} n_k·w_k / Σ_{k∈S} n_k, written into a reused
/// buffer. Returns false (out untouched) for an empty set.
pub(crate) fn aggregate_updates_into(
    env: &FedEnv,
    updates: &[(usize, ParamVec, f64)],
    out: &mut ParamVec,
) -> bool {
    if updates.is_empty() {
        return false;
    }
    let total: f64 = updates
        .iter()
        .map(|&(k, _, _)| env.clients[k].n_k as f64)
        .sum();
    out.clear();
    for (k, p, _) in updates {
        let w = (env.clients[*k].n_k as f64 / total) as f32;
        out.axpy(w, p);
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn env_construction_is_deterministic() {
        let cfg = presets::preset("tiny").unwrap();
        let a = FedEnv::new(&cfg).unwrap();
        let b = FedEnv::new(&cfg).unwrap();
        assert_eq!(a.init_global(), b.init_global());
        for (x, y) in a.clients.iter().zip(&b.clients) {
            assert_eq!(x.perf, y.perf);
            assert_eq!(x.n_k, y.n_k);
        }
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn weights_sum_to_one() {
        let cfg = presets::preset("tiny").unwrap();
        let env = FedEnv::new(&cfg).unwrap();
        let sum: f64 = env.weights.iter().map(|&w| w as f64).sum();
        assert!((sum - 1.0).abs() < 1e-5);
    }

    #[test]
    fn client_rng_streams_differ_by_round_and_client() {
        let cfg = presets::preset("tiny").unwrap();
        let env = FedEnv::new(&cfg).unwrap();
        let mut a = env.client_train_rng(1, 0);
        let mut b = env.client_train_rng(1, 1);
        let mut c = env.client_train_rng(2, 0);
        let mut a2 = env.client_train_rng(1, 0);
        assert_eq!(a.next_u64(), a2.next_u64());
        assert_ne!(a.next_u64(), b.next_u64());
        assert_ne!(b.next_u64(), c.next_u64());
    }

    #[test]
    fn aggregate_updates_weighted_mean() {
        let cfg = presets::preset("tiny").unwrap();
        let mut env = FedEnv::new(&cfg).unwrap();
        // Two clients with known sizes.
        env.clients[0].n_k = 10;
        env.clients[1].n_k = 30;
        let dim = env.trainer.dim();
        let updates = vec![
            (0usize, ParamVec(vec![1.0; dim]), 0.0),
            (1usize, ParamVec(vec![2.0; dim]), 0.0),
        ];
        let mut agg = ParamVec::zeros(dim);
        assert!(aggregate_updates_into(&env, &updates, &mut agg));
        assert!((agg.0[0] - 1.75).abs() < 1e-6);
        assert!(!aggregate_updates_into(&env, &[], &mut agg));
        // An empty set leaves the buffer untouched.
        assert!((agg.0[0] - 1.75).abs() < 1e-6);
    }

    #[test]
    fn collect_updates_matches_serial_rng_streams() {
        // The fan-out path must reproduce client_train_rng(t, k) exactly
        // and keep arrival order.
        let cfg = presets::preset("tiny").unwrap();
        let mut env = FedEnv::new(&cfg).unwrap();
        let arrivals: Vec<Arrival> = (0..env.m())
            .map(|k| Arrival {
                client: k,
                time: k as f64,
            })
            .collect();
        let t = 3;
        // Serial reference built with the public per-client streams.
        let mut expect = Vec::new();
        for a in &arrivals {
            let k = a.client;
            let mut rng = env.client_train_rng(t, k);
            let base = env.clients[k].local_model.clone();
            let u = env.trainer.local_update(&base, k, &mut rng);
            expect.push((k, u.params, u.train_loss));
        }
        for width in [1, 3, 8] {
            let mut got = Vec::new();
            parallel::with_thread_count(width, || {
                collect_updates(&mut env, t, &arrivals, &mut got);
            });
            assert_eq!(got.len(), expect.len());
            for ((ka, pa, la), (kb, pb, lb)) in got.iter().zip(&expect) {
                assert_eq!(ka, kb, "width {width}: client order");
                assert_eq!(pa, pb, "width {width}: params");
                assert_eq!(la.to_bits(), lb.to_bits(), "width {width}: loss");
            }
        }
    }

    #[test]
    fn sync_close_term_waits_for_overtime_and_drops() {
        use crate::sim::Arrival;
        let base = RoundSim {
            arrivals: vec![Arrival {
                client: 0,
                time: 300.0,
            }],
            ..RoundSim::default()
        };
        assert_eq!(sync_close_term(&base, 830.0), 300.0);
        // A mid-round disconnect after the last arrival holds the round
        // open until the server detects it.
        let mut dropped = base.clone();
        dropped.failures = vec![(1, FailReason::Crash, 0.5)];
        dropped.last_drop = 700.0;
        assert_eq!(sync_close_term(&dropped, 830.0), 700.0);
        // Overtime dominates: the server waits out the full deadline.
        let mut over = dropped.clone();
        over.failures.push((2, FailReason::Overtime, 0.9));
        assert_eq!(sync_close_term(&over, 830.0), 830.0);
        // Opt-out crashes at round start (Bernoulli) add no wait.
        let mut optout = base.clone();
        optout.failures = vec![(1, FailReason::Crash, 0.2)];
        assert_eq!(sync_close_term(&optout, 830.0), 300.0);
    }

    #[test]
    fn make_protocol_matches_kind() {
        for kind in ProtocolKind::ALL {
            let mut cfg = presets::preset("tiny").unwrap();
            cfg.protocol.kind = kind;
            let env = FedEnv::new(&cfg).unwrap();
            let p = make_protocol(&env);
            assert_eq!(p.kind(), kind);
            assert_eq!(p.global().dim(), env.trainer.dim());
        }
    }
}
