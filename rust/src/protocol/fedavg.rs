//! FedAvg baseline (McMahan et al. 2017), as evaluated in the paper:
//! selection-ahead-of-training with synchronous aggregation.
//!
//! * Round start: the server picks a random C·m subset and pushes w(t−1)
//!   to every selected client (they overwrite their local models —
//!   the progress-waste the paper's futility metric charges to FedAvg).
//! * The server waits for the selected clients. Crashed clients are
//!   detected (devices opt out / drop), so the server does not block on
//!   them; clients that would exceed T_lim hold the round open until the
//!   deadline fires (the paper's low-round-efficiency failure mode).
//! * Aggregation: w(t) = Σ n_k·w'_k / Σ n_k over committed selected
//!   clients only.

use super::{aggregate_updates_into, collect_updates, FedEnv, Protocol};
use crate::config::ProtocolKind;
use crate::metrics::RoundRecord;
use crate::model::ParamVec;
use crate::net;
use crate::sim::RoundSim;
use crate::telemetry::lifecycle::{self, ClientEvent, Event as LcEvent};

pub struct FedAvg {
    global: ParamVec,
    /// Reused per-round buffers (allocation-free steady state): the
    /// aggregation output, the selection pool/result, the engine record
    /// and the update set.
    agg: ParamVec,
    sel_pool: Vec<usize>,
    selected: Vec<usize>,
    synced: Vec<bool>,
    sim: RoundSim,
    updates: Vec<(usize, ParamVec, f64)>,
    picked_mask: Vec<bool>,
    /// Current fleet members (scenario flash crowds); selection samples
    /// from this pool when membership is dynamic. Unused otherwise.
    members: Vec<usize>,
}

impl FedAvg {
    pub fn new(global: ParamVec) -> FedAvg {
        let dim = global.dim();
        FedAvg {
            global,
            agg: ParamVec::zeros(dim),
            sel_pool: Vec::new(),
            selected: Vec::new(),
            synced: Vec::new(),
            sim: RoundSim::default(),
            updates: Vec::new(),
            picked_mask: Vec::new(),
            members: Vec::new(),
        }
    }
}

impl Protocol for FedAvg {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::FedAvg
    }

    fn global(&self) -> &ParamVec {
        &self.global
    }

    fn run_round(&mut self, t: usize, env: &mut FedEnv) -> RoundRecord {
        let m = env.m();
        let quota = env.cfg.quota();
        if self.picked_mask.len() != m {
            self.picked_mask = vec![false; m];
        }

        // Random selection ahead of training (allocation-free form of
        // `sample_indices` — identical draws).
        let select_span = crate::telemetry::span(crate::telemetry::Phase::Select);
        let mut sel_rng = env.round_rng(t, 0xfeda);
        if env.dynamic_membership() {
            // Scenario flash crowds: sample from the current members only
            // (quota capped by the live population), then map the sampled
            // pool indices back to client ids.
            self.members.clear();
            self.members.extend((0..m).filter(|&k| env.is_member(t, k)));
            let n = self.members.len();
            sel_rng.sample_indices_into(n, quota.min(n), &mut self.sel_pool, &mut self.selected);
            for s in self.selected.iter_mut() {
                *s = self.members[*s];
            }
        } else {
            sel_rng.sample_indices_into(m, quota, &mut self.sel_pool, &mut self.selected);
        }
        drop(select_span);
        let m_sync = self.selected.len();
        let t_dist = env.t_dist(m_sync);

        // Forced sync destroys any uncommitted partial work the selected
        // clients carried (futility accounting).
        let dist_span = crate::telemetry::span(crate::telemetry::Phase::Distribute);
        let lc = lifecycle::active();
        let mut futility_wasted = 0.0;
        for &k in &self.selected {
            if lc {
                // Selection-ahead-of-training: pick and push happen
                // together at round start.
                lifecycle::emit(ClientEvent::new(t, k, LcEvent::Picked, 0.0));
                lifecycle::emit(
                    ClientEvent::new(t, k, LcEvent::Distributed, 0.0).version(t.saturating_sub(1)),
                );
            }
            futility_wasted += env.clients[k].pending_partial;
            env.clients[k].pending_partial = 0.0;
            env.clients[k].local_model.copy_from(&self.global);
            env.clients[k].version = t as i64 - 1;
            env.clients[k].base_version = t as i64 - 1;
        }
        drop(dist_span);

        self.synced.clear();
        self.synced.resize(self.selected.len(), true);
        let round_rng = env.round_rng(t, 0xc4a5);
        env.simulate_round_into(t, &self.selected, &self.synced, &round_rng, &mut self.sim);
        let futility_total = self.selected.len() as f64;

        // The server waits for every selected client it believes alive:
        // overtime stragglers hold the round open until T_lim; opt-out
        // crashes are detected at round start and skipped, but a
        // mid-round disconnect (churn) is only detected when it happens.
        let client_term = super::sync_close_term(&self.sim, env.cfg.train.t_lim);
        let round_len = net::round_length(t_dist, client_term, env.cfg.train.t_lim);

        // Local training for committed clients (parallel across clients
        // for stateless backends).
        collect_updates(env, t, &self.sim.arrivals, &mut self.updates);
        let train_loss_sum: f64 = self.updates.iter().map(|(_, _, loss)| loss).sum();
        let n_committed = self.updates.len();

        // Synchronous aggregation over the committed subset.
        let agg_span = crate::telemetry::span(crate::telemetry::Phase::Aggregate);
        if aggregate_updates_into(env, &self.updates, &mut self.agg) {
            self.global.copy_from(&self.agg);
        }
        drop(agg_span);

        // Client state: committed clients hold their update; crashed
        // selected clients accumulate partial work that the next forced
        // sync will destroy.
        self.picked_mask.fill(false);
        for (k, params, _) in &self.updates {
            let c = &mut env.clients[*k];
            if lc {
                lifecycle::emit(
                    ClientEvent::new(t, *k, LcEvent::Merged, round_len)
                        .version(c.base_version.max(0) as usize)
                        .staleness(0),
                );
            }
            c.local_model.copy_from(params);
            c.version = c.base_version + 1;
            c.committed_last = true;
            c.pending_partial = 0.0;
            self.picked_mask[*k] = true;
        }
        for &(k, _, partial) in &self.sim.failures {
            env.clients[k].pending_partial += partial;
            env.clients[k].committed_last = false;
        }
        for k in 0..m {
            env.clients[k].picked_last = self.picked_mask[k];
        }

        let eval = if t % env.cfg.eval_every == 0 {
            Some(env.trainer.evaluate(&self.global))
        } else {
            None
        };

        let rec = RoundRecord {
            round: t,
            round_len,
            t_dist,
            m_sync,
            n_picked: n_committed,
            // EUR's picked set is the committed subset here (selected
            // clients that crashed are excluded from n_picked already).
            n_picked_crashed: 0,
            n_crashed: self.sim.failures.len(),
            n_committed,
            n_undrafted: 0,
            version_variance: env.version_variance(),
            futility_wasted,
            futility_total,
            online_time: self.sim.online_time,
            offline_time: self.sim.offline_time,
            staleness: vec![0; n_committed],
            bytes_down: env.bytes_down(m_sync) + self.sim.retx_bytes_down,
            bytes_up: env.bytes_up(n_committed) + self.sim.retx_bytes_up,
            bytes_saved: env.bytes_saved(m_sync, n_committed),
            train_loss: if n_committed == 0 {
                0.0
            } else {
                train_loss_sum / n_committed as f64
            },
            eval,
        };
        super::observe_round(&rec);
        rec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::proptest::property;

    fn tiny_env(crash: f64, c_fraction: f64) -> FedEnv {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.env.crash_prob = crash;
        cfg.protocol.c_fraction = c_fraction;
        FedEnv::new(&cfg).unwrap()
    }

    #[test]
    fn selects_exactly_quota_and_syncs_them() {
        let mut env = tiny_env(0.0, 0.5);
        let quota = env.cfg.quota();
        let mut p = FedAvg::new(env.init_global());
        let rec = p.run_round(1, &mut env);
        assert_eq!(rec.m_sync, quota);
        assert_eq!(rec.n_committed, quota);
        assert_eq!(rec.n_undrafted, 0);
        assert!((rec.sr(env.m()) - 0.5).abs() < 0.26); // ceil rounding
    }

    #[test]
    fn crashes_reduce_eur() {
        property("fedavg eur = committed fraction", 15, |g| {
            let crash = g.f64_range(0.0, 1.0);
            let mut cfg = presets::preset("tiny").unwrap();
            cfg.env.crash_prob = crash;
            cfg.protocol.c_fraction = 1.0;
            cfg.seed = g.u64();
            let mut env = FedEnv::new(&cfg).unwrap();
            let mut p = FedAvg::new(env.init_global());
            let rec = p.run_round(1, &mut env);
            assert_eq!(rec.n_committed + rec.n_crashed, env.m());
            assert!(rec.eur(env.m()) <= 1.0);
        });
    }

    #[test]
    fn all_crashed_keeps_global() {
        let mut env = tiny_env(1.0, 1.0);
        let g0 = env.init_global();
        let mut p = FedAvg::new(g0.clone());
        let _ = p.run_round(1, &mut env);
        assert_eq!(p.global(), &g0);
    }

    #[test]
    fn futility_accrues_from_crash_partials() {
        let mut env = tiny_env(1.0, 1.0);
        let mut p = FedAvg::new(env.init_global());
        let r1 = p.run_round(1, &mut env);
        // Round 1: everyone crashes; nothing destroyed yet.
        assert_eq!(r1.futility_wasted, 0.0);
        assert!(env.clients.iter().all(|c| c.pending_partial > 0.0));
        // Round 2: re-selected clients are force-synced; their partials
        // are destroyed.
        let r2 = p.run_round(2, &mut env);
        assert!(r2.futility_wasted > 0.0);
    }

    #[test]
    fn unselected_clients_do_not_train() {
        let mut env = tiny_env(0.0, 0.25); // quota 1 of 4
        let mut p = FedAvg::new(env.init_global());
        let rec = p.run_round(1, &mut env);
        assert_eq!(rec.n_committed, 1);
        let trained = env
            .clients
            .iter()
            .filter(|c| c.version == 1)
            .count();
        assert_eq!(trained, 1);
    }
}
