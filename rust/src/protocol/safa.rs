//! The SAFA protocol (paper §III).
//!
//! Round structure (Alg. 2):
//! 1. **Lag-tolerant distribution** (Eq. 3): classify clients as
//!    *up-to-date* (committed last round, Def. 1), *deprecated*
//!    (version lag beyond τ, Def. 2) or *tolerable* (Def. 3). Only the
//!    first two groups download w(t−1); tolerable clients stay
//!    asynchronous and keep training on their stale base.
//! 2. **Local training**: *all* clients train (SAFA removes FedAvg's
//!    selection-ahead-of-training restriction, §III-B); crashes and
//!    deadline overruns produce the failed set K(t).
//! 3. **CFCFM post-training selection** (Alg. 1): updates are accepted in
//!    arrival order; clients not picked last round have priority; the
//!    round closes when C·m new picks accumulated, all survivors arrived,
//!    or T_lim fires. Remaining committers are *undrafted* (Q(t)).
//! 4. **Three-step discriminative aggregation** (Eqs. 6–8): picked
//!    entries overwrite the cache; deprecated entries are reset to
//!    w(t−1); the weighted average over all m cache entries becomes
//!    w(t); undrafted updates enter the cache *after* aggregation (the
//!    bypass), taking effect next round.

use super::{FedEnv, Protocol};
use crate::config::ProtocolKind;
use crate::metrics::RoundRecord;
use crate::model::ParamVec;

/// Ablation switches for the design-choice study (bench
/// `ablation_safa`): disable the bypass (Eq. 8) or CFCFM's compensatory
/// priority to quantify each mechanism's contribution.
#[derive(Debug, Clone, Copy)]
pub struct SafaOptions {
    /// Carry undrafted updates into the cache (Eq. 8). Off = undrafted
    /// work is discarded like FedAvg does.
    pub bypass: bool,
    /// Prioritize clients missed last round (Alg. 1). Off = pure
    /// first-come-first-merge.
    pub compensatory: bool,
}

impl Default for SafaOptions {
    fn default() -> Self {
        SafaOptions {
            bypass: true,
            compensatory: true,
        }
    }
}

pub struct Safa {
    /// Current global model w(t−1).
    global: ParamVec,
    /// Ablation switches (all on = the paper's SAFA).
    opts: SafaOptions,
    /// Global version (round index of the last aggregation; starts 0).
    global_version: i64,
    /// Per-client cache entries w*_k (Eq. 6); one per client, initialized
    /// to w(0).
    cache: Vec<ParamVec>,
    /// Staleness-at-commit of a bypassed (Eq. 8) cache entry that has not
    /// yet reached an aggregation. Counted into the round record only
    /// when the entry actually merges (next round's Eq. 7), and dropped
    /// if a pick or deprecated reset overwrites it first.
    pending_bypass: Vec<Option<u32>>,
    /// Scratch for the aggregation output (reused every round — avoids a
    /// d-sized allocation on the hot path).
    agg_scratch: ParamVec,
}

impl Safa {
    pub fn new(env: &FedEnv, global: ParamVec) -> Safa {
        Self::with_options(env, global, SafaOptions::default())
    }

    /// Construct with ablation switches (see [`SafaOptions`]).
    pub fn with_options(env: &FedEnv, global: ParamVec, opts: SafaOptions) -> Safa {
        let cache = vec![global.clone(); env.m()];
        let dim = global.dim();
        Safa {
            global,
            opts,
            global_version: 0,
            cache,
            pending_bypass: vec![None; env.m()],
            agg_scratch: ParamVec::zeros(dim),
        }
    }

    /// Expose the cache for invariant tests.
    #[cfg(test)]
    pub(crate) fn cache(&self) -> &[ParamVec] {
        &self.cache
    }
}

impl Protocol for Safa {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::Safa
    }

    fn global(&self) -> &ParamVec {
        &self.global
    }

    fn run_round(&mut self, t: usize, env: &mut FedEnv) -> RoundRecord {
        let m = env.m();
        let tau = env.cfg.protocol.tau as i64;
        let t_i = t as i64;
        debug_assert_eq!(self.global_version, t_i - 1, "round driven out of order");

        // --- Step 1: lag-tolerant distribution (Eq. 3). ---
        let mut synced = vec![false; m];
        let mut deprecated = vec![false; m];
        let mut futility_wasted = 0.0f64;
        for k in 0..m {
            let c = &env.clients[k];
            let is_deprecated = c.version < t_i - tau;
            let is_up_to_date = c.committed_last;
            if is_deprecated || is_up_to_date {
                synced[k] = true;
                deprecated[k] = is_deprecated && !is_up_to_date;
            }
        }
        // Apply the downloads and (re)start training jobs. Synced clients
        // adopt w(t-1); a forced sync of a deprecated client abandons its
        // in-flight job — that destroyed progress is the futility cost.
        // Tolerable clients continue their in-flight jobs (SAFA's
        // continuation semantics: crashes pause, stragglers span rounds).
        let epochs = env.cfg.train.epochs;
        for k in 0..m {
            if synced[k] {
                if let Some(job) = env.clients[k].job.take() {
                    futility_wasted += job.progress();
                }
                env.clients[k].local_model.copy_from(&self.global);
                env.clients[k].version = t_i - 1;
                env.clients[k].base_version = t_i - 1;
                let total =
                    env.net.t_down() + env.clients[k].t_train(epochs) + env.net.t_up();
                env.clients[k].start_job(total, t_i - 1);
            } else if env.clients[k].job.is_none() {
                // Tolerable without a job (committed long ago but never
                // re-synced — possible only via exotic configs): train on
                // the stale local model without a download.
                let total = env.clients[k].t_train(epochs) + env.net.t_up();
                let base = env.clients[k].version;
                env.clients[k].start_job(total, base);
            }
        }
        let m_sync = synced.iter().filter(|&&s| s).count();
        let t_dist = env.net.t_dist(m_sync);

        // --- Step 2: everyone's job advances. ---
        let participants: Vec<usize> = (0..m).collect();
        let jobs: Vec<f64> = env
            .clients
            .iter()
            .map(|c| c.job.map(|j| j.remaining).unwrap_or(f64::INFINITY))
            .collect();
        let round_rng = env.round_rng(t, 0xc4a5);
        let sim = env.simulate_continuation(t, &participants, &jobs, &round_rng);
        let futility_total = m as f64;

        // Run actual local updates only for committed clients (failed
        // clients' numerics never reach the server this round).
        let mut updates: Vec<(usize, ParamVec, f64)> = Vec::with_capacity(sim.arrivals.len());
        for a in &sim.arrivals {
            let k = a.client;
            let base = env.clients[k].local_model.clone();
            let mut rng = env.client_train_rng(t, k);
            let u = env.trainer.local_update(&base, k, &mut rng);
            updates.push((k, u.params, u.train_loss));
        }

        // --- Step 3: CFCFM selection (Alg. 1). ---
        let quota = env.cfg.quota();
        let mut picked: Vec<usize> = Vec::with_capacity(quota);
        let mut undrafted: Vec<usize> = Vec::new();
        let mut close_time: Option<f64> = None;
        for a in &sim.arrivals {
            let k = a.client;
            if close_time.is_none() {
                if !self.opts.compensatory || !env.clients[k].picked_last {
                    picked.push(k);
                    if picked.len() >= quota {
                        close_time = Some(a.time);
                    }
                } else {
                    undrafted.push(k);
                }
            } else {
                // Round already closed; late arrivals (within T_lim)
                // still commit to the bypass (Fig. 1's undrafted
                // clients).
                undrafted.push(k);
            }
        }
        // Quota unmet by new arrivals: fill from undrafted in arrival
        // order (Alg. 1's post-deadline block).
        while picked.len() < quota && !undrafted.is_empty() {
            picked.push(undrafted.remove(0));
        }
        // Round close: quota time, else the shared continuation rule
        // (the semi-async server never blocks on in-flight stragglers —
        // their commits simply arrive in a later round). Also advances
        // straggler jobs and clears crashed/straggler up-to-date flags.
        let round_len = super::close_continuation_round(env, &sim, close_time, t_dist);

        // --- Step 4: three-step discriminative aggregation. ---
        // (6) Pre-aggregation cache update. Picked updates carry the lag
        // of the base model their job trained on (staleness metric).
        let mut staleness: Vec<u32> = Vec::with_capacity(picked.len());
        for &k in &picked {
            let update = updates
                .iter()
                .find(|(id, _, _)| *id == k)
                .map(|(_, p, _)| p)
                .expect("picked client without update");
            self.cache[k].copy_from(update);
            self.pending_bypass[k] = None; // bypassed entry overwritten
            let base = env.clients[k].job_base_version();
            staleness.push((t_i - 1 - base).max(0) as u32);
        }
        for k in 0..m {
            if deprecated[k] && !picked.contains(&k) {
                // Deprecated entries are replaced by w(t-1) to purge
                // heavy staleness (Eq. 6 middle case).
                self.cache[k].copy_from(&self.global);
                self.pending_bypass[k] = None;
            }
        }
        // Bypassed entries that survived to this aggregation merge now,
        // one round later (and one round staler) than they committed.
        for k in 0..m {
            if let Some(s) = self.pending_bypass[k].take() {
                staleness.push(s + 1);
            }
        }
        // (7) SAFA aggregation over ALL m cache entries.
        self.agg_scratch.clear();
        for k in 0..m {
            self.agg_scratch.axpy(env.weights[k], &self.cache[k]);
        }
        self.global.copy_from(&self.agg_scratch);
        self.global_version = t_i;
        // (8) Post-aggregation cache update: bypass carries undrafted
        // updates into the cache for round t+1 (skipped under the
        // no-bypass ablation — undrafted work is then discarded).
        // A bypassed update only reaches the global model at a *later*
        // aggregation (if not overwritten first), so its staleness is
        // parked here and counted when it actually merges.
        for &k in undrafted.iter().filter(|_| self.opts.bypass) {
            let update = updates
                .iter()
                .find(|(id, _, _)| *id == k)
                .map(|(_, p, _)| p)
                .expect("undrafted client without update");
            self.cache[k].copy_from(update);
            let base = env.clients[k].job_base_version();
            self.pending_bypass[k] = Some((t_i - 1 - base).max(0) as u32);
        }

        // --- Client state transitions (crashed/straggler flags were
        // cleared by close_continuation_round). ---
        let committed: Vec<usize> = sim.arrivals.iter().map(|a| a.client).collect();
        let n_failed = sim.crashed.len() + sim.stragglers.len();
        let mut train_loss_sum = 0.0;
        for (k, params, loss) in &updates {
            let c = &mut env.clients[*k];
            c.local_model.copy_from(params);
            c.version = c.job_base_version() + 1;
            c.committed_last = true;
            c.job = None; // job complete
            train_loss_sum += loss;
        }
        for k in 0..m {
            env.clients[k].picked_last = picked.contains(&k);
        }

        let eval = if t % env.cfg.eval_every == 0 {
            Some(env.trainer.evaluate(&self.global))
        } else {
            None
        };

        RoundRecord {
            round: t,
            round_len,
            t_dist,
            m_sync,
            n_picked: picked.len(),
            n_crashed: n_failed,
            n_committed: committed.len(),
            n_undrafted: undrafted.len(),
            version_variance: env.version_variance(),
            futility_wasted,
            futility_total,
            online_time: sim.online_time,
            offline_time: sim.offline_time,
            staleness,
            train_loss: if updates.is_empty() {
                0.0
            } else {
                train_loss_sum / updates.len() as f64
            },
            eval,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::proptest::property;

    fn tiny_env(crash: f64, c_fraction: f64, tau: usize) -> FedEnv {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.env.crash_prob = crash;
        cfg.protocol.c_fraction = c_fraction;
        cfg.protocol.tau = tau;
        FedEnv::new(&cfg).unwrap()
    }

    #[test]
    fn round_one_syncs_everyone() {
        let mut env = tiny_env(0.0, 0.5, 2);
        let mut safa = Safa::new(&env, env.init_global());
        let rec = safa.run_round(1, &mut env);
        // All clients start up-to-date -> all sync in round 1.
        assert_eq!(rec.m_sync, env.m());
        assert!(rec.t_dist > 0.0);
    }

    #[test]
    fn no_crash_picks_exactly_quota() {
        let mut env = tiny_env(0.0, 0.5, 2);
        let quota = env.cfg.quota();
        let mut safa = Safa::new(&env, env.init_global());
        let rec = safa.run_round(1, &mut env);
        assert_eq!(rec.n_picked, quota);
        assert_eq!(rec.n_committed, env.m());
        assert_eq!(rec.n_undrafted, env.m() - quota);
        assert_eq!(rec.n_crashed, 0);
    }

    #[test]
    fn all_crashed_leaves_global_unchanged_in_round_one() {
        let mut env = tiny_env(1.0, 0.5, 2);
        let g0 = env.init_global();
        let mut safa = Safa::new(&env, g0.clone());
        let rec = safa.run_round(1, &mut env);
        assert_eq!(rec.n_committed, 0);
        assert_eq!(rec.n_picked, 0);
        // Cache entries all equal w(0) -> aggregation reproduces w(0).
        assert!(safa.global().dist(&g0) < 1e-6);
    }

    #[test]
    fn cfcfm_prioritizes_clients_missed_last_round() {
        let mut env = tiny_env(0.0, 0.25, 3); // quota = 1 of 4
        let mut safa = Safa::new(&env, env.init_global());
        let r1 = safa.run_round(1, &mut env);
        assert_eq!(r1.n_picked, 1);
        let picked_first: Vec<usize> = env
            .clients
            .iter()
            .filter(|c| c.picked_last)
            .map(|c| c.id)
            .collect();
        assert_eq!(picked_first.len(), 1);
        // Round 2: the round-1 pick must NOT be picked again while
        // unpicked clients' updates are available.
        let _r2 = safa.run_round(2, &mut env);
        let picked_second: Vec<usize> = env
            .clients
            .iter()
            .filter(|c| c.picked_last)
            .map(|c| c.id)
            .collect();
        assert_eq!(picked_second.len(), 1);
        assert_ne!(picked_first[0], picked_second[0]);
    }

    #[test]
    fn deprecated_clients_forced_to_sync() {
        let mut env = tiny_env(1.0, 0.5, 2); // everyone crashes forever
        let mut safa = Safa::new(&env, env.init_global());
        // Rounds 1, 2: clients' version stays 0; deprecated when
        // version < t - tau, i.e. 0 < t - 2 -> from t = 3 onward.
        let r1 = safa.run_round(1, &mut env);
        assert_eq!(r1.m_sync, env.m()); // initial up-to-date sync
        let r2 = safa.run_round(2, &mut env);
        assert_eq!(r2.m_sync, 0); // tolerable now
        let r3 = safa.run_round(3, &mut env);
        assert_eq!(r3.m_sync, env.m()); // all deprecated -> forced sync
        // After forced sync their version advances to t-1 = 2.
        assert!(env.clients.iter().all(|c| c.version == 2));
    }

    #[test]
    fn version_lag_never_exceeds_tau_after_distribution() {
        property("safa version lag bounded", 20, |g| {
            let crash = g.f64_range(0.0, 0.9);
            let tau = g.usize_range(1, 4);
            let mut cfg = presets::preset("tiny").unwrap();
            cfg.env.crash_prob = crash;
            cfg.protocol.tau = tau;
            cfg.protocol.c_fraction = *g.choose(&[0.25, 0.5, 1.0]);
            cfg.seed = g.u64();
            let mut env = FedEnv::new(&cfg).unwrap();
            let mut safa = Safa::new(&env, env.init_global());
            for t in 1..=6 {
                let _ = safa.run_round(t, &mut env);
                // Post-round invariant: every client's version lag w.r.t.
                // the new global version is at most tau + 1 (a client can
                // add one round of lag by crashing right after the check).
                for c in &env.clients {
                    let lag = safa.global_version - c.version;
                    assert!(
                        lag <= tau as i64 + 1,
                        "client {} lag {lag} > tau+1 (tau={tau}, t={t})",
                        c.id
                    );
                }
            }
        });
    }

    #[test]
    fn aggregation_is_convex_in_cache_entries() {
        property("safa aggregate convex", 15, |g| {
            let mut cfg = presets::preset("tiny").unwrap();
            cfg.env.crash_prob = g.f64_range(0.0, 0.8);
            cfg.seed = g.u64();
            let mut env = FedEnv::new(&cfg).unwrap();
            let mut safa = Safa::new(&env, env.init_global());
            for t in 1..=3 {
                let _ = safa.run_round(t, &mut env);
                // Global must lie inside the coordinate-wise hull of the
                // cache entries (weights sum to 1).
                let g_vec = safa.global().as_slice();
                for i in (0..g_vec.len()).step_by(7) {
                    let lo = safa
                        .cache()
                        .iter()
                        .map(|e| e.0[i])
                        .fold(f32::MAX, f32::min);
                    let hi = safa
                        .cache()
                        .iter()
                        .map(|e| e.0[i])
                        .fold(f32::MIN, f32::max);
                    assert!(
                        g_vec[i] >= lo - 1e-4 && g_vec[i] <= hi + 1e-4,
                        "coord {i} out of hull at t={t}"
                    );
                }
            }
        });
    }

    #[test]
    fn undrafted_updates_take_effect_next_round() {
        // With quota 1 and no crashes, round 1 leaves m-1 undrafted
        // updates in the bypass; their content must be in the cache
        // before round 2's aggregation.
        let mut env = tiny_env(0.0, 0.25, 3);
        let mut safa = Safa::new(&env, env.init_global());
        let _ = safa.run_round(1, &mut env);
        // Each committed client's cache entry equals its local model
        // (picked via Eq. 6, undrafted via Eq. 8).
        for c in &env.clients {
            assert!(
                safa.cache()[c.id].dist(&c.local_model) < 1e-6,
                "client {} cache entry diverges",
                c.id
            );
        }
    }

    #[test]
    fn eur_at_most_commit_fraction() {
        property("safa eur bounds", 15, |g| {
            let mut cfg = presets::preset("tiny").unwrap();
            cfg.env.crash_prob = g.f64_range(0.0, 1.0);
            cfg.protocol.c_fraction = *g.choose(&[0.25, 0.5, 0.75, 1.0]);
            cfg.seed = g.u64();
            let mut env = FedEnv::new(&cfg).unwrap();
            let quota = env.cfg.quota();
            let mut safa = Safa::new(&env, env.init_global());
            for t in 1..=4 {
                let rec = safa.run_round(t, &mut env);
                assert!(rec.n_picked <= quota);
                assert!(rec.n_picked <= rec.n_committed);
                assert_eq!(
                    rec.n_committed,
                    rec.n_picked + rec.n_undrafted,
                    "commit split"
                );
                assert_eq!(rec.n_committed + rec.n_crashed, env.m());
            }
        });
    }
}
