//! The SAFA protocol (paper §III).
//!
//! Round structure (Alg. 2):
//! 1. **Lag-tolerant distribution** (Eq. 3): classify clients as
//!    *up-to-date* (committed last round, Def. 1), *deprecated*
//!    (version lag beyond τ, Def. 2) or *tolerable* (Def. 3). Only the
//!    first two groups download w(t−1); tolerable clients stay
//!    asynchronous and keep training on their stale base.
//! 2. **Local training**: *all* clients train (SAFA removes FedAvg's
//!    selection-ahead-of-training restriction, §III-B); crashes and
//!    deadline overruns produce the failed set K(t).
//! 3. **CFCFM post-training selection** (Alg. 1): updates are accepted in
//!    arrival order; clients not picked last round have priority; the
//!    round closes when C·m new picks accumulated, all survivors arrived,
//!    or T_lim fires. Remaining committers are *undrafted* (Q(t)).
//! 4. **Three-step discriminative aggregation** (Eqs. 6–8): picked
//!    entries overwrite the cache; deprecated entries are reset to
//!    w(t−1); the weighted average over all m cache entries becomes
//!    w(t); undrafted updates enter the cache *after* aggregation (the
//!    bypass), taking effect next round.

use super::{collect_updates, fleet_grain, FedEnv, Protocol};
use crate::config::ProtocolKind;
use crate::metrics::RoundRecord;
use crate::model::{weighted_sum_slices_into, ParamVec};
use crate::sim::ContinuationSim;
use crate::telemetry::lifecycle::{self, ClientEvent, Event as LcEvent};
use crate::util::parallel;

/// Ablation switches for the design-choice study (bench
/// `ablation_safa`): disable the bypass (Eq. 8) or CFCFM's compensatory
/// priority to quantify each mechanism's contribution.
#[derive(Debug, Clone, Copy)]
pub struct SafaOptions {
    /// Carry undrafted updates into the cache (Eq. 8). Off = undrafted
    /// work is discarded like FedAvg does.
    pub bypass: bool,
    /// Prioritize clients missed last round (Alg. 1). Off = pure
    /// first-come-first-merge.
    pub compensatory: bool,
}

impl Default for SafaOptions {
    fn default() -> Self {
        SafaOptions {
            bypass: true,
            compensatory: true,
        }
    }
}

/// Per-client outcome of the lag-tolerant distribution pass (Eq. 3),
/// computed in parallel and consolidated serially.
#[derive(Debug, Clone, Copy, Default)]
struct SyncOut {
    synced: bool,
    deprecated: bool,
    /// Remaining seconds of the client's (possibly freshly started) job.
    remaining: f64,
    /// Progress destroyed by a forced sync (futility accounting).
    wasted: f64,
}

/// Reusable per-round buffers (m- or commit-sized) so steady-state SAFA
/// rounds do not reallocate in the fleet size.
struct SafaScratch {
    sync_out: Vec<SyncOut>,
    participants: Vec<usize>,
    jobs: Vec<f64>,
    sim: ContinuationSim,
    /// (client, update, train_loss) per arrival, in arrival order.
    updates: Vec<(usize, ParamVec, f64)>,
    /// client -> index into `updates` (commit lookup without the old
    /// O(commits) scan per pick).
    update_of: Vec<Option<usize>>,
    picked: Vec<usize>,
    undrafted: Vec<usize>,
    picked_mask: Vec<bool>,
    undrafted_mask: Vec<bool>,
    /// Fleet membership for the running round (scenario flash crowds).
    /// All-true without a scenario timeline, in which case none of the
    /// membership branches below fire and rounds are bit-identical to
    /// the legacy path.
    member_mask: Vec<bool>,
    /// Clients whose membership begins this round: they force-sync
    /// (a device entering the federation downloads w(t-1)), so a join
    /// burst hits the distribution link — and queues under a contended
    /// fabric.
    joined_now: Vec<bool>,
    /// Eq. 7 weights renormalized over the current members (non-members'
    /// cache entries carry weight 0 so departed devices stop pulling on
    /// the global model). Only used with dynamic membership.
    member_weights: Vec<f32>,
}

pub struct Safa {
    /// Current global model w(t−1).
    global: ParamVec,
    /// Ablation switches (all on = the paper's SAFA).
    opts: SafaOptions,
    /// Global version (round index of the last aggregation; starts 0).
    global_version: i64,
    /// Per-client cache entries w*_k (Eq. 6); one per client, initialized
    /// to w(0).
    cache: Vec<ParamVec>,
    /// Staleness-at-commit of a bypassed (Eq. 8) cache entry that has not
    /// yet reached an aggregation. Counted into the round record only
    /// when the entry actually merges (next round's Eq. 7), and dropped
    /// if a pick or deprecated reset overwrites it first.
    pending_bypass: Vec<Option<u32>>,
    /// Scratch for the aggregation output (reused every round — avoids a
    /// d-sized allocation on the hot path).
    agg_scratch: ParamVec,
    /// Pooled per-round buffers.
    scratch: SafaScratch,
}

impl Safa {
    pub fn new(env: &FedEnv, global: ParamVec) -> Safa {
        Self::with_options(env, global, SafaOptions::default())
    }

    /// Construct with ablation switches (see [`SafaOptions`]).
    pub fn with_options(env: &FedEnv, global: ParamVec, opts: SafaOptions) -> Safa {
        let m = env.m();
        let cache = vec![global.clone(); m];
        let dim = global.dim();
        Safa {
            global,
            opts,
            global_version: 0,
            cache,
            pending_bypass: vec![None; m],
            agg_scratch: ParamVec::zeros(dim),
            scratch: SafaScratch {
                sync_out: vec![SyncOut::default(); m],
                participants: (0..m).collect(),
                jobs: Vec::with_capacity(m),
                sim: ContinuationSim::default(),
                updates: Vec::new(),
                update_of: vec![None; m],
                picked: Vec::new(),
                undrafted: Vec::new(),
                picked_mask: vec![false; m],
                undrafted_mask: vec![false; m],
                member_mask: vec![true; m],
                joined_now: vec![false; m],
                member_weights: Vec::with_capacity(m),
            },
        }
    }

    /// Expose the cache for invariant tests.
    #[cfg(test)]
    pub(crate) fn cache(&self) -> &[ParamVec] {
        &self.cache
    }
}

impl Protocol for Safa {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::Safa
    }

    fn global(&self) -> &ParamVec {
        &self.global
    }

    fn run_round(&mut self, t: usize, env: &mut FedEnv) -> RoundRecord {
        let m = env.m();
        let tau = env.cfg.protocol.tau as i64;
        let t_i = t as i64;
        debug_assert_eq!(self.global_version, t_i - 1, "round driven out of order");
        debug_assert_eq!(self.scratch.sync_out.len(), m, "fleet size changed mid-run");
        let dim = self.global.dim();
        let grain = fleet_grain(dim);
        let scratch = &mut self.scratch;

        // Fleet membership (scenario flash crowds). `dynamic` is false
        // for every legacy configuration, so the masks stay all-true /
        // all-false and no membership branch below changes behaviour.
        let dynamic = env.dynamic_membership();
        if dynamic {
            for k in 0..m {
                let is_member = env.is_member(t, k);
                scratch.member_mask[k] = is_member;
                // Round-1 members are founding members, not joiners.
                scratch.joined_now[k] = is_member && t > 1 && !env.is_member(t - 1, k);
            }
        }

        // --- Step 1: lag-tolerant distribution (Eq. 3). ---
        // Classify, apply the downloads and (re)start training jobs, one
        // independent client at a time — fanned out across the pool.
        // Synced clients adopt w(t-1); a forced sync of a deprecated
        // client abandons its in-flight job — that destroyed progress is
        // the futility cost. Tolerable clients continue their in-flight
        // jobs (SAFA's continuation semantics: crashes pause, stragglers
        // span rounds).
        let epochs = env.cfg.train.epochs;
        let (t_down, t_up) = (env.net.t_down(), env.net.t_up());
        // Per-(round, client) fabric times are pure functions of (t, k),
        // so they are safe to evaluate inside the parallel fan-out; with
        // the fabric off the closed-form constants reproduce the seed
        // expression bit-for-bit.
        let fabric = env.fabric.as_ref();
        let dist_span = crate::telemetry::span(crate::telemetry::Phase::Distribute);
        {
            let global = &self.global;
            let member_mask = &scratch.member_mask;
            let joined_now = &scratch.joined_now;
            parallel::for_each_chunk2(
                &mut env.clients,
                &mut scratch.sync_out,
                grain,
                |off, clients, outs| {
                    for (i, (c, out)) in clients.iter_mut().zip(outs.iter_mut()).enumerate() {
                        // Non-members (departed or not yet joined) take no
                        // part in distribution: no download, no job. A
                        // departure abandons any in-flight job — that
                        // destroyed progress is futility, charged once.
                        if dynamic && !member_mask[off + i] {
                            let wasted = c.job.take().map_or(0.0, |j| j.progress());
                            *out = SyncOut {
                                synced: false,
                                deprecated: false,
                                remaining: f64::INFINITY,
                                wasted,
                            };
                            continue;
                        }
                        let is_deprecated = c.version < t_i - tau;
                        let is_up_to_date = c.committed_last;
                        // A client joining this round always syncs: a
                        // device entering the federation downloads the
                        // current global model before training.
                        let synced = is_deprecated || is_up_to_date || joined_now[off + i];
                        let mut wasted = 0.0;
                        if synced {
                            if let Some(job) = c.job.take() {
                                wasted = job.progress();
                            }
                            c.local_model.copy_from(global);
                            c.version = t_i - 1;
                            c.base_version = t_i - 1;
                            let (td, tu) = match fabric {
                                Some(f) => (f.t_down(t, c.id), f.t_up(t, c.id)),
                                None => (t_down, t_up),
                            };
                            let total = td + c.t_train(epochs) + tu;
                            c.start_job(total, t_i - 1);
                            if let Some(j) = c.job.as_mut() {
                                j.tail_up = tu;
                            }
                        } else if c.job.is_none() {
                            // Tolerable without a job (committed long ago
                            // but never re-synced — possible only via
                            // exotic configs): train on the stale local
                            // model without a download.
                            let tu = match fabric {
                                Some(f) => f.t_up(t, c.id),
                                None => t_up,
                            };
                            let total = c.t_train(epochs) + tu;
                            let base = c.version;
                            c.start_job(total, base);
                            if let Some(j) = c.job.as_mut() {
                                j.tail_up = tu;
                            }
                        }
                        *out = SyncOut {
                            synced,
                            deprecated: is_deprecated && !is_up_to_date,
                            remaining: c.job.map(|j| j.remaining).unwrap_or(f64::INFINITY),
                            wasted,
                        };
                    }
                },
            );
        }
        // Serial consolidation in client order (fixed f64 sum order).
        let lc = lifecycle::active();
        let mut futility_wasted = 0.0f64;
        let mut m_sync = 0usize;
        scratch.jobs.clear();
        for (k, s) in scratch.sync_out.iter().enumerate() {
            futility_wasted += s.wasted;
            if s.synced {
                m_sync += 1;
                if lc {
                    lifecycle::emit(
                        ClientEvent::new(t, k, LcEvent::Distributed, 0.0)
                            .version((t_i - 1).max(0) as usize),
                    );
                }
            }
            scratch.jobs.push(s.remaining);
        }
        // Under a contended fabric, downloads queue on the shared server
        // link: the i-th synced client (client order) waits its scheduled
        // head-of-line delay before its copy starts. The wait stretches
        // the in-flight job on both sides of the books.
        if let Some(f) = fabric.filter(|f| f.has_dist_wait()) {
            let _span = crate::telemetry::span(crate::telemetry::Phase::TransferWait);
            let mut idx = 0usize;
            for (k, s) in scratch.sync_out.iter().enumerate() {
                if s.synced {
                    let wait = f.dist_wait(idx, m_sync);
                    idx += 1;
                    if wait > 0.0 {
                        if let Some(job) = env.clients[k].job.as_mut() {
                            job.remaining += wait;
                            job.total += wait;
                        }
                        scratch.jobs[k] += wait;
                    }
                }
            }
        }
        let t_dist = env.t_dist(m_sync);
        drop(dist_span);

        // --- Step 2: everyone's job advances. ---
        let round_rng = env.round_rng(t, 0xc4a5);
        env.simulate_continuation_into(
            t,
            &scratch.participants,
            &scratch.jobs,
            &round_rng,
            &mut scratch.sim,
        );
        // Non-members ride the engine pass with always-off windows (the
        // timeline masks them), landing in the crashed set; the books
        // below charge futility and crashes to actual members only.
        let n_absent = if dynamic {
            scratch.member_mask.iter().filter(|&&b| !b).count()
        } else {
            0
        };
        let futility_total = (m - n_absent) as f64;

        // Run actual local updates only for committed clients (failed
        // clients' numerics never reach the server this round); parallel
        // across clients for stateless backends.
        collect_updates(env, t, &scratch.sim.arrivals, &mut scratch.updates);
        scratch.update_of.fill(None);
        for (idx, (k, _, _)) in scratch.updates.iter().enumerate() {
            scratch.update_of[*k] = Some(idx);
        }

        // --- Step 3: CFCFM selection (Alg. 1). ---
        let select_span = crate::telemetry::span(crate::telemetry::Phase::Select);
        let quota = env.cfg.quota();
        scratch.picked.clear();
        scratch.undrafted.clear();
        let mut close_time: Option<f64> = None;
        for a in &scratch.sim.arrivals {
            let k = a.client;
            if close_time.is_none() {
                if !self.opts.compensatory || !env.clients[k].picked_last {
                    scratch.picked.push(k);
                    if lc {
                        lifecycle::emit(ClientEvent::new(t, k, LcEvent::Picked, a.time));
                    }
                    if scratch.picked.len() >= quota {
                        close_time = Some(a.time);
                    }
                } else {
                    scratch.undrafted.push(k);
                    if lc {
                        lifecycle::emit(ClientEvent::new(t, k, LcEvent::Undrafted, a.time));
                    }
                }
            } else {
                // Round already closed; late arrivals (within T_lim)
                // still commit to the bypass (Fig. 1's undrafted
                // clients).
                scratch.undrafted.push(k);
                if lc {
                    lifecycle::emit(ClientEvent::new(t, k, LcEvent::Undrafted, a.time));
                }
            }
        }
        // Quota unmet by new arrivals: fill from undrafted in arrival
        // order (Alg. 1's post-deadline block). A filled client was
        // traced undrafted first, then picked — exactly Alg. 1's order.
        let mut fill = 0;
        while scratch.picked.len() < quota && fill < scratch.undrafted.len() {
            let k = scratch.undrafted[fill];
            scratch.picked.push(k);
            if lc {
                lifecycle::emit(ClientEvent::new(t, k, LcEvent::Picked, env.cfg.train.t_lim));
            }
            fill += 1;
        }
        scratch.undrafted.drain(..fill);
        drop(select_span);
        // Round close: quota time, else the shared continuation rule
        // (the semi-async server never blocks on in-flight stragglers —
        // their commits simply arrive in a later round). Also advances
        // straggler jobs and clears crashed/straggler up-to-date flags.
        let round_len = super::close_continuation_round(env, &scratch.sim, close_time, t_dist);

        // --- Step 4: three-step discriminative aggregation. ---
        // (6) Pre-aggregation cache update. Picked updates carry the lag
        // of the base model their job trained on (staleness metric).
        scratch.picked_mask.fill(false);
        for &k in &scratch.picked {
            scratch.picked_mask[k] = true;
        }
        scratch.undrafted_mask.fill(false);
        for &k in &scratch.undrafted {
            scratch.undrafted_mask[k] = true;
        }
        let mut staleness: Vec<u32> = Vec::with_capacity(scratch.picked.len());
        for &k in &scratch.picked {
            self.pending_bypass[k] = None; // bypassed entry overwritten
            let base = env.clients[k].job_base_version();
            let s = (t_i - 1 - base).max(0) as u32;
            if lc {
                lifecycle::emit(
                    ClientEvent::new(t, k, LcEvent::Merged, round_len)
                        .version(base.max(0) as usize)
                        .staleness(s),
                );
            }
            staleness.push(s);
        }
        for k in 0..m {
            if scratch.sync_out[k].deprecated && !scratch.picked_mask[k] {
                self.pending_bypass[k] = None;
            }
        }
        // Bypassed entries that survived to this aggregation merge now,
        // one round later (and one round staler) than they committed.
        for k in 0..m {
            if let Some(s) = self.pending_bypass[k].take() {
                if lc {
                    lifecycle::emit(
                        ClientEvent::new(t, k, LcEvent::Merged, round_len).staleness(s + 1),
                    );
                }
                staleness.push(s + 1);
            }
        }
        // Cache content refresh (picked overwrite + deprecated reset to
        // w(t-1), Eq. 6), chunked across the pool — each entry is an
        // independent dim-sized copy.
        {
            let _span = crate::telemetry::span(crate::telemetry::Phase::CacheRefresh);
            let sync_out = &scratch.sync_out;
            let picked_mask = &scratch.picked_mask;
            let joined_now = &scratch.joined_now;
            let update_of = &scratch.update_of;
            let updates = &scratch.updates;
            let global = &self.global;
            parallel::for_each_chunk(&mut self.cache, grain, |off, chunk| {
                for (i, entry) in chunk.iter_mut().enumerate() {
                    let k = off + i;
                    if picked_mask[k] {
                        let idx = update_of[k].expect("picked client without update");
                        entry.copy_from(&updates[idx].1);
                    } else if sync_out[k].deprecated || joined_now[k] {
                        // Deprecated entries are replaced by w(t-1) to
                        // purge heavy staleness (Eq. 6 middle case). A
                        // joiner's entry — still w(0) from construction —
                        // resets the same way before it first gains
                        // aggregation weight.
                        entry.copy_from(global);
                    }
                }
            });
        }
        // (7) SAFA aggregation over ALL m cache entries (chunked over the
        // model dimension, fixed entry order — bit-identical to the
        // serial axpy loop at any width). With dynamic membership the
        // n_k/n weights are renormalized over the current members so a
        // departed device's frozen cache entry stops pulling on w(t) and
        // a joiner's entry starts counting the round it arrives.
        let agg_span = crate::telemetry::span(crate::telemetry::Phase::Aggregate);
        let agg_weights: &[f32] = if dynamic {
            let member_total: f64 = env
                .weights
                .iter()
                .zip(&scratch.member_mask)
                .filter(|&(_, &is_m)| is_m)
                .map(|(&w, _)| w as f64)
                .sum();
            if member_total > 0.0 {
                scratch.member_weights.clear();
                scratch.member_weights.extend(
                    env.weights
                        .iter()
                        .zip(&scratch.member_mask)
                        .map(|(&w, &is_m)| if is_m { (w as f64 / member_total) as f32 } else { 0.0 }),
                );
                &scratch.member_weights
            } else {
                // Degenerate: nobody is a member this round — keep the
                // static weights (the cache is untouched anyway).
                &env.weights
            }
        } else {
            &env.weights
        };
        weighted_sum_slices_into(&mut self.agg_scratch, agg_weights, &self.cache);
        self.global.copy_from(&self.agg_scratch);
        self.global_version = t_i;
        // (8) Post-aggregation cache update: bypass carries undrafted
        // updates into the cache for round t+1 (skipped under the
        // no-bypass ablation — undrafted work is then discarded).
        // A bypassed update only reaches the global model at a *later*
        // aggregation (if not overwritten first), so its staleness is
        // parked here and counted when it actually merges. The parking
        // must precede the transition pass below, which consumes jobs.
        for &k in scratch.undrafted.iter().filter(|_| self.opts.bypass) {
            let base = env.clients[k].job_base_version();
            let s = (t_i - 1 - base).max(0) as u32;
            if lc {
                lifecycle::emit(
                    ClientEvent::new(t, k, LcEvent::Bypassed, round_len)
                        .version(base.max(0) as usize)
                        .staleness(s),
                );
            }
            self.pending_bypass[k] = Some(s);
        }

        // --- Eq. 8 cache writes + client state transitions, fused into
        // one parallel pass over (cache, clients). Crashed/straggler
        // flags were already cleared by close_continuation_round; the
        // committed set (update_of Some) is disjoint from it. ---
        let n_committed = scratch.sim.arrivals.len();
        let n_failed =
            (scratch.sim.crashed.len() + scratch.sim.stragglers.len()).saturating_sub(n_absent);
        let train_loss_sum: f64 = scratch.updates.iter().map(|(_, _, loss)| loss).sum();
        {
            let bypass = self.opts.bypass;
            let update_of = &scratch.update_of;
            let updates = &scratch.updates;
            let picked_mask = &scratch.picked_mask;
            let undrafted_mask = &scratch.undrafted_mask;
            parallel::for_each_chunk2(
                &mut self.cache,
                &mut env.clients,
                grain,
                |off, entries, clients| {
                    for (i, (entry, c)) in entries.iter_mut().zip(clients.iter_mut()).enumerate() {
                        let k = off + i;
                        if let Some(idx) = update_of[k] {
                            let params = &updates[idx].1;
                            if bypass && undrafted_mask[k] {
                                entry.copy_from(params); // Eq. 8
                            }
                            c.local_model.copy_from(params);
                            c.version = c.job_base_version() + 1;
                            c.committed_last = true;
                            c.job = None; // job complete
                        }
                        c.picked_last = picked_mask[k];
                    }
                },
            );
        }
        drop(agg_span);

        let eval = if t % env.cfg.eval_every == 0 {
            Some(env.trainer.evaluate(&self.global))
        } else {
            None
        };

        let rec = RoundRecord {
            round: t,
            round_len,
            t_dist,
            m_sync,
            n_picked: scratch.picked.len(),
            // SAFA selects post-training, so a picked client can only
            // "crash" by having a fault injector cut its trailing upload
            // leg before the update landed (0 off the faults path).
            n_picked_crashed: scratch.sim.upload_crashed,
            n_crashed: n_failed,
            n_committed,
            n_undrafted: scratch.undrafted.len(),
            version_variance: env.version_variance(),
            futility_wasted,
            futility_total,
            online_time: scratch.sim.online_time,
            offline_time: scratch.sim.offline_time,
            staleness,
            bytes_down: env.bytes_down(m_sync),
            bytes_up: env.bytes_up(n_committed) + scratch.sim.retx_bytes_up,
            bytes_saved: env.bytes_saved(m_sync, n_committed),
            train_loss: if scratch.updates.is_empty() {
                0.0
            } else {
                train_loss_sum / scratch.updates.len() as f64
            },
            eval,
        };
        super::observe_round(&rec);
        rec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::proptest::property;

    fn tiny_env(crash: f64, c_fraction: f64, tau: usize) -> FedEnv {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.env.crash_prob = crash;
        cfg.protocol.c_fraction = c_fraction;
        cfg.protocol.tau = tau;
        FedEnv::new(&cfg).unwrap()
    }

    #[test]
    fn round_one_syncs_everyone() {
        let mut env = tiny_env(0.0, 0.5, 2);
        let mut safa = Safa::new(&env, env.init_global());
        let rec = safa.run_round(1, &mut env);
        // All clients start up-to-date -> all sync in round 1.
        assert_eq!(rec.m_sync, env.m());
        assert!(rec.t_dist > 0.0);
    }

    #[test]
    fn no_crash_picks_exactly_quota() {
        let mut env = tiny_env(0.0, 0.5, 2);
        let quota = env.cfg.quota();
        let mut safa = Safa::new(&env, env.init_global());
        let rec = safa.run_round(1, &mut env);
        assert_eq!(rec.n_picked, quota);
        assert_eq!(rec.n_committed, env.m());
        assert_eq!(rec.n_undrafted, env.m() - quota);
        assert_eq!(rec.n_crashed, 0);
    }

    #[test]
    fn all_crashed_leaves_global_unchanged_in_round_one() {
        let mut env = tiny_env(1.0, 0.5, 2);
        let g0 = env.init_global();
        let mut safa = Safa::new(&env, g0.clone());
        let rec = safa.run_round(1, &mut env);
        assert_eq!(rec.n_committed, 0);
        assert_eq!(rec.n_picked, 0);
        // Cache entries all equal w(0) -> aggregation reproduces w(0).
        assert!(safa.global().dist(&g0) < 1e-6);
    }

    #[test]
    fn cfcfm_prioritizes_clients_missed_last_round() {
        let mut env = tiny_env(0.0, 0.25, 3); // quota = 1 of 4
        let mut safa = Safa::new(&env, env.init_global());
        let r1 = safa.run_round(1, &mut env);
        assert_eq!(r1.n_picked, 1);
        let picked_first: Vec<usize> = env
            .clients
            .iter()
            .filter(|c| c.picked_last)
            .map(|c| c.id)
            .collect();
        assert_eq!(picked_first.len(), 1);
        // Round 2: the round-1 pick must NOT be picked again while
        // unpicked clients' updates are available.
        let _r2 = safa.run_round(2, &mut env);
        let picked_second: Vec<usize> = env
            .clients
            .iter()
            .filter(|c| c.picked_last)
            .map(|c| c.id)
            .collect();
        assert_eq!(picked_second.len(), 1);
        assert_ne!(picked_first[0], picked_second[0]);
    }

    #[test]
    fn deprecated_clients_forced_to_sync() {
        let mut env = tiny_env(1.0, 0.5, 2); // everyone crashes forever
        let mut safa = Safa::new(&env, env.init_global());
        // Rounds 1, 2: clients' version stays 0; deprecated when
        // version < t - tau, i.e. 0 < t - 2 -> from t = 3 onward.
        let r1 = safa.run_round(1, &mut env);
        assert_eq!(r1.m_sync, env.m()); // initial up-to-date sync
        let r2 = safa.run_round(2, &mut env);
        assert_eq!(r2.m_sync, 0); // tolerable now
        let r3 = safa.run_round(3, &mut env);
        assert_eq!(r3.m_sync, env.m()); // all deprecated -> forced sync
        // After forced sync their version advances to t-1 = 2.
        assert!(env.clients.iter().all(|c| c.version == 2));
    }

    #[test]
    fn version_lag_never_exceeds_tau_after_distribution() {
        property("safa version lag bounded", 20, |g| {
            let crash = g.f64_range(0.0, 0.9);
            let tau = g.usize_range(1, 4);
            let mut cfg = presets::preset("tiny").unwrap();
            cfg.env.crash_prob = crash;
            cfg.protocol.tau = tau;
            cfg.protocol.c_fraction = *g.choose(&[0.25, 0.5, 1.0]);
            cfg.seed = g.u64();
            let mut env = FedEnv::new(&cfg).unwrap();
            let mut safa = Safa::new(&env, env.init_global());
            for t in 1..=6 {
                let _ = safa.run_round(t, &mut env);
                // Post-round invariant: every client's version lag w.r.t.
                // the new global version is at most tau + 1 (a client can
                // add one round of lag by crashing right after the check).
                for c in &env.clients {
                    let lag = safa.global_version - c.version;
                    assert!(
                        lag <= tau as i64 + 1,
                        "client {} lag {lag} > tau+1 (tau={tau}, t={t})",
                        c.id
                    );
                }
            }
        });
    }

    #[test]
    fn aggregation_is_convex_in_cache_entries() {
        property("safa aggregate convex", 15, |g| {
            let mut cfg = presets::preset("tiny").unwrap();
            cfg.env.crash_prob = g.f64_range(0.0, 0.8);
            cfg.seed = g.u64();
            let mut env = FedEnv::new(&cfg).unwrap();
            let mut safa = Safa::new(&env, env.init_global());
            for t in 1..=3 {
                let _ = safa.run_round(t, &mut env);
                // Global must lie inside the coordinate-wise hull of the
                // cache entries (weights sum to 1).
                let g_vec = safa.global().as_slice();
                for i in (0..g_vec.len()).step_by(7) {
                    let lo = safa
                        .cache()
                        .iter()
                        .map(|e| e.0[i])
                        .fold(f32::MAX, f32::min);
                    let hi = safa
                        .cache()
                        .iter()
                        .map(|e| e.0[i])
                        .fold(f32::MIN, f32::max);
                    assert!(
                        g_vec[i] >= lo - 1e-4 && g_vec[i] <= hi + 1e-4,
                        "coord {i} out of hull at t={t}"
                    );
                }
            }
        });
    }

    #[test]
    fn undrafted_updates_take_effect_next_round() {
        // With quota 1 and no crashes, round 1 leaves m-1 undrafted
        // updates in the bypass; their content must be in the cache
        // before round 2's aggregation.
        let mut env = tiny_env(0.0, 0.25, 3);
        let mut safa = Safa::new(&env, env.init_global());
        let _ = safa.run_round(1, &mut env);
        // Each committed client's cache entry equals its local model
        // (picked via Eq. 6, undrafted via Eq. 8).
        for c in &env.clients {
            assert!(
                safa.cache()[c.id].dist(&c.local_model) < 1e-6,
                "client {} cache entry diverges",
                c.id
            );
        }
    }

    #[test]
    fn eur_at_most_commit_fraction() {
        property("safa eur bounds", 15, |g| {
            let mut cfg = presets::preset("tiny").unwrap();
            cfg.env.crash_prob = g.f64_range(0.0, 1.0);
            cfg.protocol.c_fraction = *g.choose(&[0.25, 0.5, 0.75, 1.0]);
            cfg.seed = g.u64();
            let mut env = FedEnv::new(&cfg).unwrap();
            let quota = env.cfg.quota();
            let mut safa = Safa::new(&env, env.init_global());
            for t in 1..=4 {
                let rec = safa.run_round(t, &mut env);
                assert!(rec.n_picked <= quota);
                assert!(rec.n_picked <= rec.n_committed);
                assert_eq!(
                    rec.n_committed,
                    rec.n_picked + rec.n_undrafted,
                    "commit split"
                );
                assert_eq!(rec.n_committed + rec.n_crashed, env.m());
            }
        });
    }
}
