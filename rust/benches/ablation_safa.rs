//! Ablation study (DESIGN.md §6 extension): what each SAFA mechanism
//! contributes. Compares full SAFA against (a) no bypass — undrafted
//! updates discarded, and (b) no compensatory priority — pure
//! first-come-first-merge — on Task 1 at C = 0.3 across crash rates.

use safa::bench_harness::Table;
use safa::config::presets;
use safa::experiments::{shared_data, CRS};
use safa::metrics::{RoundRecord, RunResult};
use safa::protocol::{FedEnv, Protocol, Safa, SafaOptions};

fn run(opts: SafaOptions, cr: f64) -> RunResult {
    let mut cfg = presets::task1();
    cfg.protocol.c_fraction = 0.3;
    cfg.env.crash_prob = cr;
    let data = shared_data(&cfg);
    let mut env = FedEnv::with_data(&cfg, data).unwrap();
    let mut proto = Safa::with_options(&env, env.init_global(), opts);
    let rounds: Vec<RoundRecord> = (1..=cfg.train.rounds)
        .map(|t| proto.run_round(t, &mut env))
        .collect();
    RunResult {
        protocol: "SAFA".into(),
        task: "regression".into(),
        c_fraction: 0.3,
        crash_prob: cr,
        tau: cfg.protocol.tau,
        seed: cfg.seed,
        m: cfg.env.m,
        rounds,
        final_eval: Some(env.trainer.evaluate(proto.global())),
    }
}

fn main() {
    safa::util::logging::init();
    let variants: [(&str, SafaOptions); 3] = [
        ("SAFA (full)", SafaOptions::default()),
        (
            "no bypass",
            SafaOptions {
                bypass: false,
                ..SafaOptions::default()
            },
        ),
        (
            "no compensation",
            SafaOptions {
                compensatory: false,
                ..SafaOptions::default()
            },
        ),
    ];
    // One column per metric; rows = crash rates.
    let cols = [0.0f64]; // placeholder to reuse Table; we build manually
    let _ = cols;
    let mut acc_table = Table::new("SAFA ablation — best accuracy (Task 1, C=0.3)", &CRS, &[0.3]);
    let mut len_table = Table::new("SAFA ablation — avg round length (s)", &CRS, &[0.3]);
    acc_table.precision = 4;
    for (name, opts) in variants {
        let mut acc_rows = Vec::new();
        let mut len_rows = Vec::new();
        for &cr in &CRS {
            let r = run(opts, cr);
            acc_rows.push(vec![r.best_accuracy().unwrap_or(f64::NAN)]);
            len_rows.push(vec![r.avg_round_len()]);
        }
        acc_table.add_block(name, acc_rows);
        len_table.add_block(name, len_rows);
    }
    acc_table.emit("ablation_safa_accuracy");
    len_table.emit("ablation_safa_round_length");
    println!(
        "\nReading: disabling the bypass discards undrafted work (lower\n\
         effective updates -> slower convergence under crashes); disabling\n\
         compensation removes CFCFM's fairness bias correction (fast\n\
         clients monopolize picks; round close times shrink slightly at\n\
         the cost of bias — cf. Fig. 5 case 3)."
    );
}
