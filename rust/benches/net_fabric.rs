//! Network-fabric bench: full Null-backend rounds across protocol ×
//! fabric regime, measuring what the event-driven transfer layer costs
//! relative to the closed-form Eq. 17–19 arithmetic and what update
//! compression buys back.
//!
//! Regimes per protocol (SAFA, FedAvg, FedAsync) at the fleet sizes in
//! the grid:
//!
//! * `off`        — fabric disabled, the legacy closed-form baseline;
//! * `contended`  — the `contended` preset's fabric (FIFO server link,
//!   lognormal heterogeneous client links, latency/jitter/loss);
//! * `contended_topk` / `contended_q8` — same network plus top-k (10%)
//!   or 8-bit stochastic-quantization update compression.
//!
//! Each cell prints the per-round comm volume (down/up/saved MB) next
//! to the timing line, so the codec's byte savings and its CPU tax land
//! in the same artifact. Emits `BENCH_net_fabric.json` (override with
//! `-- --json <path>`; BENCH schema documented in EXPERIMENTS.md).
//! `SAFA_BENCH_FAST=1` trims the grid for CI smoke runs.

use safa::bench_harness::{json_path_from_args, Bencher};
use safa::config::{presets, ProtocolKind};
use safa::coordinator::Coordinator;
use safa::net::fabric::FabricConfig;

fn regimes() -> Vec<(&'static str, FabricConfig)> {
    let contended = presets::preset("contended")
        .expect("contended preset")
        .env
        .fabric;
    let with_codec = |codec: &str, frac: Option<f64>, bits: Option<i64>| {
        FabricConfig::from_parts(
            "fifo",
            None,
            Some("lognormal"),
            Some(0.5),
            Some(0.05),
            Some(0.02),
            Some(0.02),
            None,
            Some(codec),
            frac,
            bits,
        )
        .expect("fabric config")
    };
    vec![
        ("off", FabricConfig::default()),
        ("contended", contended),
        ("contended_topk", with_codec("topk", Some(0.1), None)),
        ("contended_q8", with_codec("quantize", None, Some(8))),
    ]
}

fn main() {
    safa::util::logging::init();
    let fast = std::env::var("SAFA_BENCH_FAST").as_deref() == Ok("1");
    let mut b = Bencher::new();
    let fleets: &[usize] = if fast { &[200] } else { &[500, 2_000] };
    let protocols = [
        ProtocolKind::Safa,
        ProtocolKind::FedAvg,
        ProtocolKind::FedAsync,
    ];

    for &m in fleets {
        for proto in protocols {
            for (regime, fabric) in regimes() {
                let mut cfg = presets::preset("fleet10k").expect("fleet10k preset");
                cfg.env.m = m;
                cfg.protocol.kind = proto;
                cfg.env.fabric = fabric;
                // Fresh coordinator per cell: rounds must be driven in
                // order, and the scratch pools warm up during
                // calibration so the measured rounds are steady-state.
                let mut coord = Coordinator::new(&cfg).expect("coordinator");
                let mut t = 1usize;
                let mut last = None;
                let name = format!(
                    "{}_round_m{m}_fabric_{regime}",
                    proto.name().to_ascii_lowercase()
                );
                b.bench(&name, || {
                    let rec = coord.protocol.run_round(t, &mut coord.env);
                    t += 1;
                    let len = rec.round_len;
                    last = Some((rec.bytes_down, rec.bytes_up, rec.bytes_saved));
                    len
                });
                if let Some((down, up, saved)) = last {
                    const MB: f64 = 1024.0 * 1024.0;
                    println!(
                        "    comm/round: down {:.2} MB, up {:.2} MB, saved {:.2} MB",
                        down / MB,
                        up / MB,
                        saved / MB
                    );
                }
            }
        }
    }

    b.write_json("results/net_fabric.json").expect("write results");
    b.write_json(&json_path_from_args("BENCH_net_fabric.json"))
        .expect("write BENCH json");
}
