//! Bench regression differ: compare a fresh `BENCH_profile.json` (or any
//! BENCH-schema file) against a committed baseline and print a
//! regression table.
//!
//! ```text
//! cargo bench --bench bench_diff -- \
//!     --baseline ../BENCH_profile.json --fresh BENCH_profile.json \
//!     [--tolerance 0.25] [--json BENCH_profile_diff.json]
//! ```
//!
//! A cell regresses when its `mean_ns` grows (or `rounds_per_sec`
//! shrinks) by more than the relative tolerance. Cells present on only
//! one side are reported but never fail the diff, so the unmeasured
//! placeholder baseline (`{"results": []}`) diffs clean. Exit code 1 on
//! regressions — CI runs this step warn-only (`continue-on-error`) and
//! uploads the JSON diff in the `bench-json` artifact.

use safa::bench_harness::{diff_bench_cells, diff_to_json, render_diff, write_results_file};
use safa::util::json::Json;

fn arg_value(name: &str) -> Option<String> {
    let mut args = std::env::args().skip(1);
    let eq_prefix = format!("{name}=");
    while let Some(a) = args.next() {
        if a == name {
            return args.next();
        }
        if let Some(v) = a.strip_prefix(&eq_prefix) {
            return Some(v.to_string());
        }
    }
    None
}

fn load(path: &str) -> Json {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("bench_diff: cannot read {path}: {e}"));
    Json::parse(&text).unwrap_or_else(|e| panic!("bench_diff: {path} is not valid JSON: {e}"))
}

fn main() {
    safa::util::logging::init();
    let baseline_path =
        arg_value("--baseline").unwrap_or_else(|| "../BENCH_profile.json".to_string());
    let fresh_path = arg_value("--fresh").unwrap_or_else(|| "BENCH_profile.json".to_string());
    let tolerance: f64 = arg_value("--tolerance")
        .map(|t| t.parse().expect("--tolerance expects a number"))
        .unwrap_or(0.25);

    let baseline = load(&baseline_path);
    let fresh = load(&fresh_path);
    let diffs = diff_bench_cells(&baseline, &fresh, tolerance);
    println!("baseline: {baseline_path}");
    println!("fresh:    {fresh_path}");
    print!("{}", render_diff(&diffs, tolerance));

    if let Some(out) = arg_value("--json") {
        write_results_file(&out, &diff_to_json(&diffs, tolerance).to_string_pretty())
            .expect("write diff json");
        println!("wrote {out}");
    }

    let regressions = diffs
        .iter()
        .filter(|d| d.status == safa::bench_harness::DiffStatus::Regressed)
        .count();
    if regressions > 0 {
        eprintln!(
            "bench_diff: {regressions} cell(s) regressed beyond {:.0}% tolerance",
            tolerance * 100.0
        );
        std::process::exit(1);
    }
}
