//! Bench regression differ: compare fresh BENCH-schema files against
//! their committed baselines and print a regression table.
//!
//! ```text
//! cargo bench --bench bench_diff -- \
//!     [--baseline ../BENCH_profile.json --fresh BENCH_profile.json] \
//!     [--tolerance 0.25] [--json BENCH_profile_diff.json]
//! ```
//!
//! With no `--baseline`/`--fresh` flags the differ walks the default
//! registry — `BENCH_profile.json`, `BENCH_chaos.json` and
//! `BENCH_scenario.json`, each diffed against the committed repo-root
//! baseline of the same name — and skips (with a note) any pair whose
//! files are missing, so a partial bench run still diffs what it
//! produced. Explicit flags diff exactly one pair, as before.
//!
//! A cell regresses when its `mean_ns` grows (or `rounds_per_sec`
//! shrinks) by more than the relative tolerance. Cells present on only
//! one side are reported but never fail the diff, so the unmeasured
//! placeholder baseline (`{"results": []}`) diffs clean. Exit code 1 on
//! regressions — CI runs this step warn-only (`continue-on-error`) and
//! uploads the JSON diff in the `bench-json` artifact.

use safa::bench_harness::{diff_bench_cells, diff_to_json, render_diff, write_results_file};
use safa::util::json::Json;

fn arg_value(name: &str) -> Option<String> {
    let mut args = std::env::args().skip(1);
    let eq_prefix = format!("{name}=");
    while let Some(a) = args.next() {
        if a == name {
            return args.next();
        }
        if let Some(v) = a.strip_prefix(&eq_prefix) {
            return Some(v.to_string());
        }
    }
    None
}

fn load(path: &str) -> Json {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("bench_diff: cannot read {path}: {e}"));
    Json::parse(&text).unwrap_or_else(|e| panic!("bench_diff: {path} is not valid JSON: {e}"))
}

/// Baseline/fresh pairs walked when no explicit flags are given: every
/// BENCH artifact the CI bench job produces, against its committed
/// repo-root baseline.
const REGISTRY: &[(&str, &str)] = &[
    ("../BENCH_profile.json", "BENCH_profile.json"),
    ("../BENCH_chaos.json", "BENCH_chaos.json"),
    ("../BENCH_scenario.json", "BENCH_scenario.json"),
];

/// Diff one baseline/fresh pair; returns its regression count.
fn diff_pair(
    baseline_path: &str,
    fresh_path: &str,
    tolerance: f64,
    json_out: Option<&str>,
) -> usize {
    let baseline = load(baseline_path);
    let fresh = load(fresh_path);
    let diffs = diff_bench_cells(&baseline, &fresh, tolerance);
    println!("baseline: {baseline_path}");
    println!("fresh:    {fresh_path}");
    print!("{}", render_diff(&diffs, tolerance));

    if let Some(out) = json_out {
        write_results_file(out, &diff_to_json(&diffs, tolerance).to_string_pretty())
            .expect("write diff json");
        println!("wrote {out}");
    }

    diffs
        .iter()
        .filter(|d| d.status == safa::bench_harness::DiffStatus::Regressed)
        .count()
}

fn main() {
    safa::util::logging::init();
    let tolerance: f64 = arg_value("--tolerance")
        .map(|t| t.parse().expect("--tolerance expects a number"))
        .unwrap_or(0.25);
    let json_out = arg_value("--json");

    let explicit_baseline = arg_value("--baseline");
    let explicit_fresh = arg_value("--fresh");
    let regressions = if explicit_baseline.is_some() || explicit_fresh.is_some() {
        // Explicit mode: one pair, missing files are hard errors.
        let baseline_path =
            explicit_baseline.unwrap_or_else(|| "../BENCH_profile.json".to_string());
        let fresh_path = explicit_fresh.unwrap_or_else(|| "BENCH_profile.json".to_string());
        diff_pair(&baseline_path, &fresh_path, tolerance, json_out.as_deref())
    } else {
        // Registry mode: diff every artifact pair that exists. The
        // `--json` report (if any) covers the last diffed pair only;
        // per-pair reports need explicit-mode invocations.
        let mut total = 0;
        for (baseline_path, fresh_path) in REGISTRY {
            let missing = [baseline_path, fresh_path]
                .into_iter()
                .find(|p| !std::path::Path::new(*p).exists());
            if let Some(p) = missing {
                println!("skipping {fresh_path}: {p} not found");
                continue;
            }
            total += diff_pair(baseline_path, fresh_path, tolerance, json_out.as_deref());
        }
        total
    };

    if regressions > 0 {
        eprintln!(
            "bench_diff: {regressions} cell(s) regressed beyond {:.0}% tolerance",
            tolerance * 100.0
        );
        std::process::exit(1);
    }
}
