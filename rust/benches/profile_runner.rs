//! Profiling-runner bench: the telemetry grid (protocol × churn ×
//! fabric × m) as a repeatable artifact. Thin wrapper over
//! `safa::telemetry::profile::run_spec` — the same harness behind the
//! `safa profile` CLI subcommand — so CI and local runs quote identical
//! numbers.
//!
//! Emits `BENCH_profile.json` (override with `-- --json <path>`) in the
//! BENCH schema plus profiling extras (rounds_per_sec, events_per_sec,
//! bytes_{down,up}_per_round, share_<phase>; documented in
//! EXPERIMENTS.md). `SAFA_BENCH_FAST=1` trims the grid for CI smoke.

use safa::bench_harness::json_path_from_args;
use safa::telemetry::profile::{render_table, run_spec, write_json, ProfileFabric, ProfileSpec};

fn main() {
    safa::util::logging::init();
    let fast = std::env::var("SAFA_BENCH_FAST").as_deref() == Ok("1");
    let mut spec = ProfileSpec::default();
    // Both fabric regimes: the historical closed-form cells (names
    // unchanged) plus `_contended` cells measuring the event-fabric tax.
    spec.fabrics = ProfileFabric::ALL.to_vec();
    if fast {
        spec.m_values = vec![50];
        spec.rounds = 8;
        spec.warmup = 2;
    } else {
        spec.m_values = vec![100, 500];
    }
    let cells = run_spec(&spec).expect("profile grid");
    print!("{}", render_table(&cells));
    let path = json_path_from_args("BENCH_profile.json");
    write_json(&cells, &path).expect("write BENCH json");
    println!("wrote {path}");
}
