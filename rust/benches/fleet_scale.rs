//! Scale-axis bench: full SAFA Null-backend rounds across fleet size ×
//! fork width (the tentpole measurement for the zero-dep parallel
//! runtime). Sweeps m ∈ {500, 2k, 10k} × SAFA_THREADS-equivalent widths
//! {1, 2, 4, 8} on one coordinator per fleet size, so the per-round
//! scratch pools are warm and steady-state rounds are allocation-free.
//!
//! Emits `BENCH_fleet_scale.json` (override with `-- --json <path>`;
//! format documented in EXPERIMENTS.md) plus the usual
//! `results/fleet_scale.json`. `SAFA_BENCH_FAST=1` trims the grid and
//! the measurement time for CI smoke runs.
//!
//! Each width gets a fresh coordinator and drives the run from round 1,
//! and round outcomes are bit-identical across widths
//! (`tests/determinism.rs`) — so every width replays the *same* round
//! sequence from the same state (widths only differ in how many of
//! those rounds the calibrated sample count covers).

use safa::bench_harness::{json_path_from_args, Bencher};
use safa::config::presets;
use safa::coordinator::Coordinator;
use safa::util::parallel;

fn main() {
    safa::util::logging::init();
    let fast = std::env::var("SAFA_BENCH_FAST").as_deref() == Ok("1");
    let mut b = Bencher::new();
    let fleets: &[usize] = if fast {
        &[500, 2_000]
    } else {
        &[500, 2_000, 10_000]
    };
    let widths: &[usize] = &[1, 2, 4, 8];

    for &m in fleets {
        let mut cfg = presets::preset("fleet10k").expect("fleet10k preset");
        cfg.env.m = m;
        for &width in widths {
            // Fresh coordinator per width so every width replays the
            // identical round sequence from round 1 (SAFA rounds must be
            // driven in order; scratch pools warm up during calibration).
            let mut coord = Coordinator::new(&cfg).expect("coordinator");
            let mut t = 1usize;
            b.bench(&format!("safa_null_round_m{m}_t{width}"), || {
                parallel::with_thread_count(width, || {
                    let rec = coord.protocol.run_round(t, &mut coord.env);
                    t += 1;
                    rec.round_len
                })
            });
        }
    }

    b.write_json("results/fleet_scale.json")
        .expect("write results");
    b.write_json(&json_path_from_args("BENCH_fleet_scale.json"))
        .expect("write BENCH json");
}
