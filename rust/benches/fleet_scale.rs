//! Scale-axis bench: full SAFA Null-backend rounds across fleet size ×
//! fork width (the tentpole measurement for the zero-dep parallel
//! runtime). Sweeps m ∈ {500, 2k, 10k} × SAFA_THREADS-equivalent widths
//! {1, 2, 4, 8} on one coordinator per fleet size, so the per-round
//! scratch pools are warm and steady-state rounds are allocation-free.
//!
//! Emits `BENCH_fleet_scale.json` (override with `-- --json <path>`;
//! format documented in EXPERIMENTS.md) plus the usual
//! `results/fleet_scale.json`. `SAFA_BENCH_FAST=1` trims the grid and
//! the measurement time for CI smoke runs.
//!
//! Rounds dispatch through the persistent worker pool by default;
//! `SAFA_DISPATCH=spawn` replays the identical grid on the legacy
//! spawn-per-fork dispatcher. Naming convention (matched by the
//! default output path, the committed repo-root trajectory file and
//! CI): `BENCH_fleet_scale.json` always holds the **spawn baseline**,
//! `BENCH_fleet_scale_pooled.json` the pooled post-change grid:
//!
//! ```bash
//! SAFA_DISPATCH=spawn cargo bench --bench fleet_scale   # -> BENCH_fleet_scale.json
//! cargo bench --bench fleet_scale                       # -> BENCH_fleet_scale_pooled.json
//! ```
//!
//! Bench names inside the JSONs are dispatch-independent, so the two
//! files compare point-for-point (rounds are bit-identical either way;
//! only the dispatch overhead differs).
//!
//! Each width gets a fresh coordinator and drives the run from round 1,
//! and round outcomes are bit-identical across widths
//! (`tests/determinism.rs`) — so every width replays the *same* round
//! sequence from the same state (widths only differ in how many of
//! those rounds the calibrated sample count covers).

use safa::bench_harness::{json_path_from_args, Bencher};
use safa::config::presets;
use safa::coordinator::Coordinator;
use safa::util::parallel;

fn main() {
    safa::util::logging::init();
    let fast = std::env::var("SAFA_BENCH_FAST").as_deref() == Ok("1");
    println!("fleet_scale dispatch mode: {:?}", parallel::dispatch_mode());
    let mut b = Bencher::new();
    let fleets: &[usize] = if fast {
        &[500, 2_000]
    } else {
        &[500, 2_000, 10_000]
    };
    let widths: &[usize] = &[1, 2, 4, 8];

    for &m in fleets {
        let mut cfg = presets::preset("fleet10k").expect("fleet10k preset");
        cfg.env.m = m;
        for &width in widths {
            // Fresh coordinator per width so every width replays the
            // identical round sequence from round 1 (SAFA rounds must be
            // driven in order; scratch pools warm up during calibration).
            let mut coord = Coordinator::new(&cfg).expect("coordinator");
            let mut t = 1usize;
            b.bench(&format!("safa_null_round_m{m}_t{width}"), || {
                parallel::with_thread_count(width, || {
                    let rec = coord.protocol.run_round(t, &mut coord.env);
                    t += 1;
                    rec.round_len
                })
            });
        }
    }

    b.write_json("results/fleet_scale.json")
        .expect("write results");
    // Default output name encodes the dispatcher (see module docs):
    // BENCH_fleet_scale.json is reserved for the spawn baseline.
    let default_json = match parallel::dispatch_mode() {
        parallel::Dispatch::Spawn => "BENCH_fleet_scale.json",
        parallel::Dispatch::Pooled => "BENCH_fleet_scale_pooled.json",
    };
    b.write_json(&json_path_from_args(default_json))
        .expect("write BENCH json");
}
