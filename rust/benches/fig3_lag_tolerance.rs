//! Figs. 3 & 4: the lag-tolerance analysis (§III-D).
//!
//! Sweeps tau from 1 to 10 on Task 1 with C in {0.1, 0.5, 1.0} and cr in
//! {0.3, 0.7}, reporting best loss (Fig. 3a), synchronization ratio
//! (Fig. 3b), EUR (Fig. 4a) and version variance (Fig. 4b).

use safa::bench_harness::Series;
use safa::experiments::tau_sweep;

fn main() {
    safa::util::logging::init();
    let sweep = tau_sweep();
    let x: Vec<f64> = sweep.taus.iter().map(|&t| t as f64).collect();

    let mut fig3a = Series::new("Fig. 3(a) — best loss vs lag tolerance", "tau", x.clone());
    let mut fig3b = Series::new("Fig. 3(b) — SR vs lag tolerance", "tau", x.clone());
    let mut fig4a = Series::new("Fig. 4(a) — EUR vs lag tolerance", "tau", x.clone());
    let mut fig4b = Series::new("Fig. 4(b) — VV vs lag tolerance", "tau", x);
    for (label, loss, sr, eur, vv) in &sweep.lines {
        fig3a.add_line(label, loss.clone());
        fig3b.add_line(label, sr.clone());
        fig4a.add_line(label, eur.clone());
        fig4b.add_line(label, vv.clone());
    }
    fig3a.emit("fig3a_loss_vs_tau");
    fig3b.emit("fig3b_sr_vs_tau");
    fig4a.emit("fig4a_eur_vs_tau");
    fig4b.emit("fig4b_vv_vs_tau");
}
