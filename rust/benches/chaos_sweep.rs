//! Chaos sweep bench: what the fault-injection engine costs and what it
//! does to round outcomes, A/B'd against the identical contended fabric
//! with the injectors off.
//!
//! Regimes per protocol (SAFA, FedAvg, FedAsync) at the fleet sizes in
//! the grid — both run the `contended` transport (FIFO server link,
//! lognormal client links, latency/jitter/loss):
//!
//! * `baseline` — faults disabled: the legacy event/fabric paths;
//! * `chaos`    — the `chaos` preset's full injector battery (crash
//!   hazard, flapping, correlated regional outages, link degradation)
//!   under the default retry/partial-credit policies.
//!
//! Each cell prints the survival outcome (crashed vs committed client
//! counts over the measured rounds) next to the timing line, so the
//! injectors' scheduling tax and their behavioral footprint land in the
//! same artifact. Emits `BENCH_chaos.json` (override with `-- --json
//! <path>`; BENCH schema documented in EXPERIMENTS.md).
//! `SAFA_BENCH_FAST=1` trims the grid for CI smoke runs.

use safa::bench_harness::{json_path_from_args, Bencher};
use safa::config::{presets, ProtocolKind};
use safa::coordinator::Coordinator;

fn main() {
    safa::util::logging::init();
    let fast = std::env::var("SAFA_BENCH_FAST").as_deref() == Ok("1");
    let mut b = Bencher::new();
    let fleets: &[usize] = if fast { &[200] } else { &[500, 2_000] };
    let protocols = [
        ProtocolKind::Safa,
        ProtocolKind::FedAvg,
        ProtocolKind::FedAsync,
    ];
    let chaos = presets::preset("chaos").expect("chaos preset");

    for &m in fleets {
        for proto in protocols {
            for regime in ["baseline", "chaos"] {
                let mut cfg = presets::preset("fleet10k").expect("fleet10k preset");
                cfg.env.m = m;
                cfg.protocol.kind = proto;
                // Same transport in both regimes: the A/B isolates the
                // injectors, not the fabric.
                cfg.env.fabric = chaos.env.fabric.clone();
                if regime == "chaos" {
                    cfg.env.faults = chaos.env.faults.clone();
                }
                // Fresh coordinator per cell: rounds must be driven in
                // order, and the scratch pools warm up during
                // calibration so the measured rounds are steady-state.
                let mut coord = Coordinator::new(&cfg).expect("coordinator");
                let mut t = 1usize;
                let mut crashed = 0usize;
                let mut committed = 0usize;
                let name = format!(
                    "{}_round_m{m}_{regime}",
                    proto.name().to_ascii_lowercase()
                );
                b.bench(&name, || {
                    let rec = coord.protocol.run_round(t, &mut coord.env);
                    t += 1;
                    crashed += rec.n_crashed;
                    committed += rec.n_committed;
                    rec.round_len
                });
                println!(
                    "    outcome: {crashed} crashed / {committed} committed \
                     client-rounds over {} rounds",
                    t - 1
                );
            }
        }
    }

    b.write_json("results/chaos_sweep.json").expect("write results");
    b.write_json(&json_path_from_args("BENCH_chaos.json"))
        .expect("write BENCH json");
}
