//! Table XV: synchronization ratio and futility percentage on Task 3.
//!
//! Paper-exact profile, Null trainer (SR and futility are timing-side
//! metrics). Emits two tables: SR and futility percentage.
use safa::config::ProtocolKind;
use safa::experiments::{grid_table, timing_cfg, Metric};

fn main() {
    safa::util::logging::init();
    let base = timing_cfg(3);
    let protos = [ProtocolKind::FedAvg, ProtocolKind::FedCs, ProtocolKind::Safa];
    grid_table("Table XV — Task 3 — synchronization ratio", &base, &protos, Metric::SyncRatio)
        .emit("table15_task3_sr");
    grid_table("Table XV — Task 3 — futility percentage", &base, &protos, Metric::Futility)
        .emit("table15_task3_futility");
}
