//! Fig. 5: client-selection bias vs federated round (§III-E).
//!
//! Emits the paper-verbatim series (Eqs. 13–16, which reproduce the
//! published figure) and the corrected recurrence-based series — see the
//! erratum note in `analysis/mod.rs`.

use safa::analysis::{fig5_series, fig5_series_corrected};
use safa::bench_harness::Series;

fn main() {
    safa::util::logging::init();
    let rounds = 20u32;
    let x: Vec<f64> = (1..=rounds).map(|r| r as f64).collect();
    for (name, stem, f) in [
        (
            "Fig. 5 — bias vs round (paper-verbatim, cr=0.3)",
            "fig5_bias_paper",
            fig5_series as fn(f64, u32) -> (Vec<f64>, [Vec<f64>; 3]),
        ),
        (
            "Fig. 5 — bias vs round (corrected recurrence, cr=0.3)",
            "fig5_bias_corrected",
            fig5_series_corrected as fn(f64, u32) -> (Vec<f64>, [Vec<f64>; 3]),
        ),
    ] {
        let (fedavg, [c1, c2, c3]) = f(0.3, rounds);
        let mut s = Series::new(name, "round", x.clone());
        s.add_line("FedAvg", fedavg);
        s.add_line("SAFA case 1", c1);
        s.add_line("SAFA case 2", c2);
        s.add_line("SAFA case 3", c3);
        s.emit(stem);
    }
}
