//! L3 hot-path micro-benchmarks (the §Perf instrumented loop):
//! dispatch latency (pooled park/wake vs legacy spawn-per-fork),
//! aggregation (Eq. 7), cache updates, round simulation at m=500, run
//! setup and the native matmul kernel.

use safa::bench_harness::{json_path_from_args, Bencher};
use safa::config::presets;
use safa::coordinator::Coordinator;
use safa::model::tensor::matmul;
use safa::model::{weighted_sum_slices_into, ParamVec};
use safa::protocol::FedEnv;
use safa::util::parallel;
use safa::util::rng::Pcg64;

fn main() {
    safa::util::logging::init();
    let mut b = Bencher::new();

    // Dispatch latency: an empty-body fork at widths {2, 4, 8} — the
    // persistent pool's park/wake broadcast vs the legacy
    // spawn-per-fork scope. The gap is the per-region overhead the
    // pool removes from every sub-millisecond round (~a thread spawn
    // per worker per fork, 15–25 µs each, vs one condvar wake).
    for &width in &[2usize, 4, 8] {
        b.bench(&format!("dispatch_pooled_fork_w{width}"), || {
            parallel::with_dispatch(parallel::Dispatch::Pooled, || {
                parallel::fork(width, |i| {
                    std::hint::black_box(i);
                });
            });
            width
        });
        b.bench(&format!("dispatch_spawn_fork_w{width}"), || {
            parallel::with_dispatch(parallel::Dispatch::Spawn, || {
                parallel::fork(width, |i| {
                    std::hint::black_box(i);
                });
            });
            width
        });
    }

    // Eq. 7 aggregation at Task-2 paper scale: 100 clients x 431k params
    // — the serial baseline (one axpy at a time, the pre-pool shape)...
    let dim = 431_080;
    let m = 100;
    let cache: Vec<ParamVec> = (0..m)
        .map(|i| ParamVec(vec![i as f32 * 0.01; dim]))
        .collect();
    let weights: Vec<f32> = vec![1.0 / m as f32; m];
    let mut out = ParamVec::zeros(dim);
    b.bench("aggregate_eq7_m100_d431k", || {
        parallel::with_thread_count(1, || {
            out.clear();
            for (w, entry) in weights.iter().zip(&cache) {
                out.axpy(*w, entry);
            }
            out.0[0]
        })
    });

    // ... and the chunked weighted-sum kernel at 1 / 2 / 4 widths
    // (bit-identical output; see tests/determinism.rs).
    for threads in [1usize, 2, 4] {
        b.bench(&format!("weighted_sum_eq7_m100_d431k_t{threads}"), || {
            parallel::with_thread_count(threads, || {
                weighted_sum_slices_into(&mut out, &weights, &cache);
                out.0[0]
            })
        });
    }

    // Cache entry refresh (Eq. 6 / Eq. 8 path).
    let update = ParamVec(vec![1.5; dim]);
    let mut entry = ParamVec::zeros(dim);
    b.bench("cache_copy_d431k", || {
        entry.copy_from(&update);
        entry.0[0]
    });

    // Full Null-backend SAFA round at Task-3 scale (m = 500).
    let mut cfg = presets::task3();
    cfg.backend = safa::config::Backend::Null;
    cfg.eval_every = 1_000_000;
    cfg.train.rounds = 1;
    let mut coord = Coordinator::new(&cfg).expect("coordinator");
    let mut t = 1usize;
    b.bench("safa_null_round_m500", || {
        let rec = coord.protocol.run_round(t, &mut coord.env);
        t += 1;
        rec.round_len
    });

    // FedEnv construction (data synthesis + partition + fleet) at Task-1
    // scale — the per-run setup cost in grid sweeps.
    let cfg1 = presets::task1();
    b.bench("fedenv_setup_task1", || {
        let env = FedEnv::new(&cfg1).expect("env");
        env.m()
    });

    // Native matmul kernel (the CNN hot loop): 480x200 @ 200x64.
    let (mm, kk, nn) = (480usize, 200usize, 64usize);
    let mut rng = Pcg64::new(1);
    let a: Vec<f32> = (0..mm * kk).map(|_| rng.next_f32() - 0.5).collect();
    let w: Vec<f32> = (0..kk * nn).map(|_| rng.next_f32() - 0.5).collect();
    let mut c = vec![0.0f32; mm * nn];
    b.bench("native_matmul_480x200x64", || {
        matmul(&mut c, &a, &w, mm, kk, nn, false);
        c[0]
    });

    b.write_json("results/microbench_hotpath.json")
        .expect("write results");
    // Machine-readable perf trajectory (format in EXPERIMENTS.md);
    // override the path with `-- --json <path>`.
    b.write_json(&json_path_from_args("BENCH_hotpath.json"))
        .expect("write BENCH json");
}
