//! Table XIV: best accuracy of the global model on Task 3 (the paper's 4
//! protocols plus the FedAsync baseline as an extra row).
//!
//! Real training on the scaled configuration (see DESIGN.md §6 /
//! EXPERIMENTS.md for the scaling argument); `SAFA_PRESET=paper` runs
//! Table II shapes.
use safa::config::ProtocolKind;
use safa::experiments::{accuracy_cfg, grid_table, Metric};

fn main() {
    safa::util::logging::init();
    let base = accuracy_cfg(3);
    let table = grid_table(
        "Table XIV — Task 3 best accuracy",
        &base,
        &ProtocolKind::ALL,
        Metric::BestAccuracy,
    );
    table.emit("table14_task3_accuracy");
}
