//! Table XI: synchronization ratio and futility percentage on Task 1.
//!
//! Paper-exact profile, Null trainer (SR and futility are timing-side
//! metrics). Emits two tables: SR and futility percentage.
use safa::config::ProtocolKind;
use safa::experiments::{grid_table, timing_cfg, Metric};

fn main() {
    safa::util::logging::init();
    let base = timing_cfg(1);
    let protos = [ProtocolKind::FedAvg, ProtocolKind::FedCs, ProtocolKind::Safa];
    grid_table("Table XI — Task 1 — synchronization ratio", &base, &protos, Metric::SyncRatio)
        .emit("table11_task1_sr");
    grid_table("Table XI — Task 1 — futility percentage", &base, &protos, Metric::Futility)
        .emit("table11_task1_futility");
}
