//! Table VIII: average federated round length (s) on Task 3, T_lim = 1620 s.
//!
//! Paper-exact environment profile (Table II), Null trainer — timing
//! metrics are invariant to gradient numerics. `SAFA_BENCH_FAST=1` trims
//! rounds; `SAFA_PRESET=paper` is implied (timing grids always run the
//! paper profile).
use safa::config::ProtocolKind;
use safa::experiments::{grid_table, timing_cfg, Metric};

fn main() {
    safa::util::logging::init();
    let base = timing_cfg(3);
    let table = grid_table(
        "Table VIII — Task 3 avg round length (s)",
        &base,
        &[ProtocolKind::FedAvg, ProtocolKind::FedCs, ProtocolKind::Safa],
        Metric::RoundLen,
    );
    table.emit("table8_task3_round_length");
}
