//! Fig. 8: loss traces of the global model on Task 3.
//!
//! Loss of the global model vs round at C = 0.3 for cr in
//! {0.1, 0.3, 0.5, 0.7}, all four protocols. Real training on the
//! scaled configuration.
use safa::experiments::loss_trace_figure;

fn main() {
    safa::util::logging::init();
    for (i, series) in loss_trace_figure(3, "Fig. 8 Task 3 loss").into_iter().enumerate() {
        series.emit(&format!("fig8_task3_loss_{}", ["a", "b", "c", "d"][i]));
    }
}
