//! Fig. 7: loss traces of the global model on Task 2.
//!
//! Loss of the global model vs round at C = 0.3 for cr in
//! {0.1, 0.3, 0.5, 0.7}, all four protocols. Real training on the
//! scaled configuration.
use safa::experiments::loss_trace_figure;

fn main() {
    safa::util::logging::init();
    for (i, series) in loss_trace_figure(2, "Fig. 7 Task 2 loss").into_iter().enumerate() {
        series.emit(&format!("fig7_task2_loss_{}", ["a", "b", "c", "d"][i]));
    }
}
