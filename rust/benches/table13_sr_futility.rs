//! Table XIII: synchronization ratio and futility percentage on Task 2.
//!
//! Paper-exact profile, Null trainer (SR and futility are timing-side
//! metrics). Emits two tables: SR and futility percentage.
use safa::config::ProtocolKind;
use safa::experiments::{grid_table, timing_cfg, Metric};

fn main() {
    safa::util::logging::init();
    let base = timing_cfg(2);
    let protos = [ProtocolKind::FedAvg, ProtocolKind::FedCs, ProtocolKind::Safa];
    grid_table("Table XIII — Task 2 — synchronization ratio", &base, &protos, Metric::SyncRatio)
        .emit("table13_task2_sr");
    grid_table("Table XIII — Task 2 — futility percentage", &base, &protos, Metric::Futility)
        .emit("table13_task2_futility");
}
