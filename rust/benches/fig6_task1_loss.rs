//! Fig. 6: loss traces of the global model on Task 1.
//!
//! Loss of the global model vs round at C = 0.3 for cr in
//! {0.1, 0.3, 0.5, 0.7}, all four protocols. Real training on the
//! paper Task-1 configuration.
use safa::experiments::loss_trace_figure;

fn main() {
    safa::util::logging::init();
    for (i, series) in loss_trace_figure(1, "Fig. 6 Task 1 loss").into_iter().enumerate() {
        series.emit(&format!("fig6_task1_loss_{}", ["a", "b", "c", "d"][i]));
    }
}
