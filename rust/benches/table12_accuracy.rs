//! Table XII: best accuracy of the global model on Task 2 (the paper's 4
//! protocols plus the FedAsync baseline as an extra row).
//!
//! Real training on the scaled configuration (see DESIGN.md §6 /
//! EXPERIMENTS.md for the scaling argument); `SAFA_PRESET=paper` runs
//! Table II shapes.
use safa::config::ProtocolKind;
use safa::experiments::{accuracy_cfg, grid_table, Metric};

fn main() {
    safa::util::logging::init();
    let base = accuracy_cfg(2);
    let table = grid_table(
        "Table XII — Task 2 best accuracy",
        &base,
        &ProtocolKind::ALL,
        Metric::BestAccuracy,
    );
    table.emit("table12_task2_accuracy");
}
