//! Table VI: average federated round length (s) on Task 2, T_lim = 5600 s.
//!
//! Paper-exact environment profile (Table II), Null trainer — timing
//! metrics are invariant to gradient numerics. `SAFA_BENCH_FAST=1` trims
//! rounds; `SAFA_PRESET=paper` is implied (timing grids always run the
//! paper profile).
use safa::config::ProtocolKind;
use safa::experiments::{grid_table, timing_cfg, Metric};

fn main() {
    safa::util::logging::init();
    let base = timing_cfg(2);
    let table = grid_table(
        "Table VI — Task 2 avg round length (s)",
        &base,
        &[ProtocolKind::FedAvg, ProtocolKind::FedCs, ProtocolKind::Safa],
        Metric::RoundLen,
    );
    table.emit("table6_task2_round_length");
}
