//! Table X: best accuracy of the global model on Task 1 (the paper's 4
//! protocols plus the FedAsync baseline as an extra row).
//!
//! Real training on the paper Task-1 configuration (see DESIGN.md §6 /
//! EXPERIMENTS.md for the scaling argument); `SAFA_PRESET=paper` runs
//! Table II shapes.
use safa::config::ProtocolKind;
use safa::experiments::{accuracy_cfg, grid_table, Metric};

fn main() {
    safa::util::logging::init();
    let base = accuracy_cfg(1);
    let table = grid_table(
        "Table X — Task 1 best accuracy",
        &base,
        &ProtocolKind::ALL,
        Metric::BestAccuracy,
    );
    table.emit("table10_task1_accuracy");
}
