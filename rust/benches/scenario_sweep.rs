//! Scenario sweep bench: what the continuous wall-clock scenario engine
//! costs and what diurnal churn / flash crowds do to round outcomes,
//! A/B'd against the same environment with the scenario off.
//!
//! Regimes per protocol (all five):
//!
//! * `baseline`   — scenario disabled: the legacy per-round availability
//!   paths on the diurnal preset's environment;
//! * `diurnal`    — the `diurnal` preset: exponential on/off dwells on
//!   the continuous clock under a strong day/night sine modulation;
//! * `flashcrowd` — the `flashcrowd` preset: contended fabric plus a
//!   scripted mass join, departures and a regional outage (dynamic
//!   fleet membership end to end).
//!
//! Each cell prints the survival outcome (crashed vs committed client
//! counts over the measured rounds) next to the timing line, so the
//! timeline walker's scheduling tax and its behavioral footprint land
//! in the same artifact. Emits `BENCH_scenario.json` (override with
//! `-- --json <path>`; BENCH schema documented in EXPERIMENTS.md).
//! `SAFA_BENCH_FAST=1` trims the grid for CI smoke runs.

use safa::bench_harness::{json_path_from_args, Bencher};
use safa::config::{presets, ProtocolKind};
use safa::coordinator::Coordinator;
use safa::scenario::ScenarioSpec;

fn main() {
    safa::util::logging::init();
    let fast = std::env::var("SAFA_BENCH_FAST").as_deref() == Ok("1");
    let mut b = Bencher::new();
    let protocols: &[ProtocolKind] = if fast {
        &[ProtocolKind::Safa, ProtocolKind::FedAvg]
    } else {
        &ProtocolKind::ALL
    };

    for &proto in protocols {
        for regime in ["baseline", "diurnal", "flashcrowd"] {
            // `baseline` is the diurnal environment with the scenario
            // switched off, so the A/B isolates the timeline walker.
            let mut cfg = match regime {
                "flashcrowd" => presets::preset("flashcrowd").expect("flashcrowd preset"),
                _ => presets::preset("diurnal").expect("diurnal preset"),
            };
            if regime == "baseline" {
                cfg.env.scenario = ScenarioSpec::default();
            }
            cfg.protocol.kind = proto;
            // Fresh coordinator per cell: rounds must be driven in order,
            // and the scratch pools warm up during calibration so the
            // measured rounds are steady-state.
            let mut coord = Coordinator::new(&cfg).expect("coordinator");
            let mut t = 1usize;
            let mut crashed = 0usize;
            let mut committed = 0usize;
            let name = format!("{}_round_{regime}", proto.name().to_ascii_lowercase());
            b.bench(&name, || {
                let rec = coord.protocol.run_round(t, &mut coord.env);
                t += 1;
                crashed += rec.n_crashed;
                committed += rec.n_committed;
                rec.round_len
            });
            println!(
                "    outcome: {crashed} crashed / {committed} committed \
                 client-rounds over {} rounds",
                t - 1
            );
        }
    }

    b.write_json("results/scenario_sweep.json").expect("write results");
    b.write_json(&json_path_from_args("BENCH_scenario.json"))
        .expect("write BENCH json");
}
