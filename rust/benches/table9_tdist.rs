//! Table IX: average model distribution overhead T_dist (s) on Task 3.
//!
//! Paper-exact environment profile (Table II), Null trainer — timing
//! metrics are invariant to gradient numerics. `SAFA_BENCH_FAST=1` trims
//! rounds; `SAFA_PRESET=paper` is implied (timing grids always run the
//! paper profile).
use safa::config::ProtocolKind;
use safa::experiments::{grid_table, timing_cfg, Metric};

fn main() {
    safa::util::logging::init();
    let base = timing_cfg(3);
    let table = grid_table(
        "Table IX — Task 3 avg T_dist (s)",
        &base,
        &[ProtocolKind::FedAvg, ProtocolKind::FedCs, ProtocolKind::Safa],
        Metric::TDist,
    );
    table.emit("table9_task3_tdist");
}
