//! Churn sweep: SAFA vs. FedAvg vs. FedAsync under two-state Markov
//! on/off churn on the Task-1 profile.
//!
//! Two grids over (mean downtime × mean uptime) dwell times:
//! * average federated round length (Null trainer — timing only), and
//! * best accuracy (native trainer, real gradients).
//!
//! `SAFA_BENCH_FAST=1` trims rounds for smoke runs. Emits the usual
//! stdout tables plus CSV/JSON under `results/`.

use safa::bench_harness::Table;
use safa::config::{presets, Backend, ChurnModel, ExperimentConfig, ProtocolKind};
use safa::coordinator::run_experiment;

const UPTIMES_S: [f64; 3] = [800.0, 400.0, 200.0];
const DOWNTIMES_S: [f64; 2] = [100.0, 400.0];
const PROTOCOLS: [ProtocolKind; 3] = [
    ProtocolKind::FedAvg,
    ProtocolKind::Safa,
    ProtocolKind::FedAsync,
];

fn fast_mode() -> bool {
    std::env::var("SAFA_BENCH_FAST").as_deref() == Ok("1")
}

fn churn_table(title: &str) -> Table {
    Table {
        title: title.to_string(),
        col_header: UPTIMES_S.iter().map(|u| format!("up {u}s")).collect(),
        row_header: DOWNTIMES_S.iter().map(|d| format!("dn {d}s")).collect(),
        blocks: Vec::new(),
        precision: 2,
    }
}

fn run_grid(title: &str, mut base: ExperimentConfig, value: impl Fn(&safa::metrics::RunResult) -> f64) -> Table {
    let mut table = churn_table(title);
    if fast_mode() {
        base.train.rounds = base.train.rounds.min(8);
    }
    for proto in PROTOCOLS {
        let mut rows = Vec::new();
        for &down in &DOWNTIMES_S {
            let mut row = Vec::new();
            for &up in &UPTIMES_S {
                let mut cfg = base.clone();
                cfg.protocol.kind = proto;
                cfg.env.churn = ChurnModel::Markov {
                    mean_uptime_s: up,
                    mean_downtime_s: down,
                };
                let r = run_experiment(&cfg)
                    .unwrap_or_else(|e| panic!("{title} {proto:?} up={up} down={down}: {e}"));
                row.push(value(&r));
            }
            rows.push(row);
        }
        table.add_block(proto.name(), rows);
    }
    table
}

fn main() {
    safa::util::logging::init();

    // Timing grid: paper Task-1 profile, Null trainer.
    let mut timing = presets::task1();
    timing.backend = Backend::Null;
    timing.eval_every = 1_000_000;
    timing.train.rounds = 30;
    let t = run_grid(
        "Churn sweep — Task 1 avg round length (s) under Markov churn",
        timing,
        |r| r.avg_round_len(),
    );
    t.emit("churn_sweep_round_length");

    // Accuracy grid: real training at Task-1 scale (already tiny).
    let mut acc = presets::task1();
    acc.backend = Backend::Native;
    acc.train.rounds = 30;
    let t = run_grid(
        "Churn sweep — Task 1 best accuracy under Markov churn",
        acc,
        |r| r.best_accuracy().unwrap_or(f64::NAN),
    );
    t.emit("churn_sweep_accuracy");
}
