//! Table IV: average federated round length (s) on Task 1, T_lim = 830 s.
//!
//! Paper-exact environment profile (Table II), Null trainer — timing
//! metrics are invariant to gradient numerics. `SAFA_BENCH_FAST=1` trims
//! rounds; `SAFA_PRESET=paper` is implied (timing grids always run the
//! paper profile).
use safa::config::ProtocolKind;
use safa::experiments::{grid_table, timing_cfg, Metric};

fn main() {
    safa::util::logging::init();
    let base = timing_cfg(1);
    let table = grid_table(
        "Table IV — Task 1 avg round length (s)",
        &base,
        &[ProtocolKind::FedAvg, ProtocolKind::FedCs, ProtocolKind::Safa],
        Metric::RoundLen,
    );
    table.emit("table4_task1_round_length");
}
