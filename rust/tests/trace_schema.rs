//! SAFA_TRACE v2 golden-schema pin + trace determinism across thread
//! widths.
//!
//! This binary intentionally holds exactly ONE #[test]: the trace
//! destination (`telemetry::set_trace`) and the lifecycle sample stride
//! are process-global, first-call-wins OnceLocks, so a second test in
//! the same binary could not choose its own trace file.
//!
//! What is pinned:
//! * every line of the trace parses as JSON and carries `v: 2`;
//! * per record type (`meta` / `round` / `client`), the key set matches
//!   `tests/golden/trace_v2_schema.txt` exactly — a new key, a dropped
//!   key, or a new client event name fails here until the golden file
//!   (and the schema version) is updated deliberately;
//! * `SAFA_TRACE_SAMPLE` stride: only clients with `id % stride == 0`
//!   appear;
//! * the trace is deterministic at any thread width: modulo the
//!   wall-clock `telemetry` span object on round lines, the byte
//!   stream at widths {1, 3, 8} is identical;
//! * `safa report`'s parser and renderers consume the trace end to end.

use std::collections::{BTreeMap, BTreeSet};

use safa::config::{presets, ExperimentConfig, ProtocolKind};
use safa::coordinator::run_experiment;
use safa::report::{self, parse_trace};
use safa::telemetry;
use safa::util::json::Json;
use safa::util::parallel::with_thread_count;

const WIDTHS: [usize; 3] = [1, 3, 8];
const KINDS: [ProtocolKind; 3] = [
    ProtocolKind::Safa,
    ProtocolKind::FedAvg,
    ProtocolKind::FedAsync,
];
const STRIDE: u64 = 7;
const M: usize = 60;
const ROUNDS: usize = 4;

fn cfg_for(kind: ProtocolKind) -> ExperimentConfig {
    let mut cfg = presets::preset("tiny").expect("tiny preset");
    cfg.protocol.kind = kind;
    cfg.env.m = M;
    cfg.task.n = 600;
    cfg.task.n_test = 60;
    cfg.env.crash_prob = 0.3;
    cfg.protocol.c_fraction = 0.5;
    cfg.train.rounds = ROUNDS;
    cfg
}

/// Key sets from tests/golden/trace_v2_schema.txt.
struct GoldenSchema {
    required: BTreeMap<String, BTreeSet<String>>,
    optional: BTreeMap<String, BTreeSet<String>>,
    events: BTreeSet<String>,
}

fn load_golden() -> GoldenSchema {
    let text = include_str!("golden/trace_v2_schema.txt");
    let mut schema = GoldenSchema {
        required: BTreeMap::new(),
        optional: BTreeMap::new(),
        events: BTreeSet::new(),
    };
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (head, rest) = line.split_once(':').expect("golden line missing ':'");
        let words: BTreeSet<String> = rest.split_whitespace().map(str::to_string).collect();
        match head.trim() {
            "events" => schema.events = words,
            head => {
                let (ty, class) = head.split_once(' ').expect("golden head: `<type> <class>`");
                match class {
                    "required" => {
                        schema.required.insert(ty.to_string(), words);
                    }
                    "optional" => {
                        schema.optional.insert(ty.to_string(), words);
                    }
                    other => panic!("golden class must be required/optional, got {other}"),
                }
            }
        }
    }
    assert!(
        !schema.required.is_empty() && !schema.events.is_empty(),
        "golden schema file parsed empty"
    );
    schema
}

#[test]
fn trace_v2_schema_and_width_determinism() {
    // Process-global telemetry setup must precede every engine call:
    // the TRACE OnceLock is first-call-wins and any `trace_active()`
    // probe would otherwise pin it to None for the whole process.
    telemetry::set_enabled(true);
    telemetry::lifecycle::set_sample_stride(STRIDE);
    let path = std::env::temp_dir().join(format!("safa_trace_schema_{}.jsonl", std::process::id()));
    let path_str = path.to_string_lossy().into_owned();
    assert!(
        telemetry::set_trace(&path_str),
        "cannot open trace destination {path_str}"
    );

    // 3 protocols × 3 widths, all appending to one trace file; each run
    // opens its own segment with a meta line.
    let mut results = Vec::new();
    for &width in &WIDTHS {
        for kind in KINDS {
            let cfg = cfg_for(kind);
            results.push(with_thread_count(width, || {
                run_experiment(&cfg).expect("run_experiment")
            }));
        }
    }
    assert_eq!(telemetry::trace_dropped(), 0, "trace writes were dropped");

    // (1) Simulation results are bit-identical across widths with the
    // trace recording live the whole time.
    for w in 1..WIDTHS.len() {
        for i in 0..KINDS.len() {
            let a = &results[i];
            let b = &results[w * KINDS.len() + i];
            let ctx = format!("{} at width {}", KINDS[i].name(), WIDTHS[w]);
            assert_eq!(a.rounds.len(), b.rounds.len(), "{ctx}: round count");
            for (x, y) in a.rounds.iter().zip(&b.rounds) {
                assert_eq!(
                    x.round_len.to_bits(),
                    y.round_len.to_bits(),
                    "{ctx}: round_len diverged at round {}",
                    x.round
                );
                assert_eq!(x.n_picked, y.n_picked, "{ctx}: n_picked");
                assert_eq!(x.n_committed, y.n_committed, "{ctx}: n_committed");
                assert_eq!(x.staleness, y.staleness, "{ctx}: staleness");
            }
        }
    }

    // (2) Line-by-line schema pin + canonicalized segment comparison.
    // Round lines carry a wall-clock `telemetry` span object; it is
    // stripped before the cross-width byte comparison (sim-time fields
    // must match exactly, wall-clock never can).
    let text = std::fs::read_to_string(&path).expect("read trace file");
    let golden = load_golden();
    let mut segments: Vec<Vec<String>> = Vec::new();
    let mut events_seen: BTreeSet<String> = BTreeSet::new();
    for (idx, line) in text.lines().enumerate() {
        let n = idx + 1;
        let mut j = Json::parse(line).unwrap_or_else(|e| panic!("trace line {n}: bad JSON: {e}"));
        let ty = j
            .get("type")
            .and_then(Json::as_str)
            .unwrap_or_else(|| panic!("trace line {n}: missing type"))
            .to_string();
        assert_eq!(
            j.get("v").and_then(Json::as_f64),
            Some(2.0),
            "trace line {n}: schema version"
        );
        let keys: BTreeSet<String> = match &j {
            Json::Obj(m) => m.keys().cloned().collect(),
            _ => panic!("trace line {n}: not an object"),
        };
        let required = golden
            .required
            .get(&ty)
            .unwrap_or_else(|| panic!("trace line {n}: unpinned record type {ty}"));
        let optional = golden.optional.get(&ty).cloned().unwrap_or_default();
        for k in required {
            assert!(keys.contains(k), "trace line {n}: {ty} line missing key {k}");
        }
        for k in &keys {
            assert!(
                required.contains(k) || optional.contains(k),
                "trace line {n}: {ty} line has key {k} not pinned in \
                 tests/golden/trace_v2_schema.txt"
            );
        }
        if ty == "client" {
            let event = j
                .get("event")
                .and_then(Json::as_str)
                .unwrap_or_else(|| panic!("trace line {n}: client event not a string"))
                .to_string();
            assert!(
                golden.events.contains(&event),
                "trace line {n}: event {event} not pinned in golden events list"
            );
            let client = j
                .get("client")
                .and_then(Json::as_usize)
                .unwrap_or_else(|| panic!("trace line {n}: client id"));
            assert_eq!(
                client as u64 % STRIDE,
                0,
                "trace line {n}: client {client} violates sample stride {STRIDE}"
            );
            events_seen.insert(event);
        }
        if ty == "meta" {
            segments.push(Vec::new());
        }
        let segment = segments
            .last_mut()
            .unwrap_or_else(|| panic!("trace line {n}: trace does not open with a meta line"));
        if ty == "round" {
            if let Json::Obj(m) = &mut j {
                m.remove("telemetry");
            }
            segment.push(j.to_string_compact());
        } else {
            segment.push(line.to_string());
        }
    }
    assert_eq!(
        segments.len(),
        WIDTHS.len() * KINDS.len(),
        "one meta-opened segment per run"
    );
    for w in 1..WIDTHS.len() {
        for i in 0..KINDS.len() {
            assert_eq!(
                segments[i],
                segments[w * KINDS.len() + i],
                "{} trace at width {} diverged from width {}",
                KINDS[i].name(),
                WIDTHS[w],
                WIDTHS[0]
            );
        }
    }
    // The fixed-seed runs exercise the core of the lifecycle alphabet.
    for event in ["picked", "distributed", "upload", "merged"] {
        assert!(events_seen.contains(event), "no {event} events in trace");
    }

    // (3) `safa report` machinery consumes the trace end to end.
    let trace = parse_trace(&text).expect("parse_trace");
    assert_eq!(trace.m, Some(M));
    assert_eq!(trace.sample, Some(STRIDE));
    assert_eq!(trace.rounds.len(), WIDTHS.len() * KINDS.len() * ROUNDS);
    assert_eq!(trace.skipped, 0, "parse_trace skipped lines");
    assert!(!trace.clients.is_empty(), "no client lines parsed");
    let summaries = report::summarize(&trace);
    assert_eq!(summaries.len(), KINDS.len(), "one summary per protocol");
    let rendered = report::render_report(&trace);
    for needle in ["SAFA", "FedAvg", "FedAsync", "round duration", "staleness"] {
        assert!(rendered.contains(needle), "report missing {needle}:\n{rendered}");
    }
    let as_json = report::report_json(&trace).to_string_compact();
    assert!(Json::parse(&as_json).is_ok(), "report_json round-trips");

    let _ = std::fs::remove_file(&path);
}
